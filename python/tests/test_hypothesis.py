"""Property-based sweeps of the Pallas kernels (hypothesis).

Shapes, scales and degenerate inputs (ties, duplicates, zero steps) are
drawn at random; every draw must agree with the pure-jnp oracle.
"""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import vq_chunk_pallas, distortion_partials_pallas
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


@st.composite
def vq_instance(draw):
    kappa = draw(st.integers(1, 24))
    d = draw(st.integers(1, 24))
    tau = draw(st.integers(1, 16))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    scale = draw(st.sampled_from([0.1, 1.0, 10.0]))
    w = rng.normal(size=(kappa, d), scale=scale).astype(np.float32)
    z = rng.normal(size=(tau, d), scale=scale).astype(np.float32)
    # occasionally force exact duplicates of prototypes into the data (ties)
    if draw(st.booleans()) and tau >= 2 and kappa >= 2:
        z[0] = w[0]
        z[1] = w[min(1, kappa - 1)]
    eps = rng.uniform(0.0, 1.0, size=(tau,)).astype(np.float32)
    if draw(st.booleans()):
        eps[: tau // 2] = 0.0  # zero-step prefix
    return w, z, eps


@given(vq_instance())
@settings(**SETTINGS)
def test_vq_chunk_property(inst):
    w, z, eps = (jnp.asarray(a) for a in inst)
    w_k, delta_k = vq_chunk_pallas(w, z, eps)
    w_r, delta_r = ref.vq_chunk_ref(w, z, eps)
    np.testing.assert_allclose(w_k, w_r, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(delta_k, delta_r, rtol=1e-5, atol=1e-5)
    # invariant: w_out == w - delta
    np.testing.assert_allclose(
        np.asarray(w_k), np.asarray(w - delta_k), rtol=1e-5, atol=1e-5)


@st.composite
def distortion_instance(draw):
    kappa = draw(st.integers(1, 32))
    d = draw(st.integers(1, 24))
    tiles = draw(st.integers(1, 6))
    bt = draw(st.sampled_from([8, 16, 64]))
    seed = draw(st.integers(0, 2**31 - 1))
    rng = np.random.default_rng(seed)
    scale = draw(st.sampled_from([0.5, 5.0]))
    w = rng.normal(size=(kappa, d), scale=scale).astype(np.float32)
    z = rng.normal(size=(tiles * bt, d), scale=scale).astype(np.float32)
    return w, z, bt


@given(distortion_instance())
@settings(**SETTINGS)
def test_distortion_property(inst):
    w, z, bt = inst
    w, z = jnp.asarray(w), jnp.asarray(z)
    got = float(jnp.sum(distortion_partials_pallas(w, z, block_points=bt)))
    want = float(ref.distortion_ref(w, z))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=1e-4)
    assert got >= 0.0
