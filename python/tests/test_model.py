"""L2 model-level tests: entry-point composition and shape contracts."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

RNG = np.random.default_rng(1)


def rand(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape, scale=scale), dtype=jnp.float32)


def test_multi_chunk_equals_repeated_vq_chunk():
    kappa, d, tau, s = 8, 4, 10, 5
    w = rand(kappa, d)
    zs = rand(s, tau, d)
    eps = jnp.abs(rand(s, tau, scale=0.1))
    w_scan, delta_scan = model.multi_chunk(w, zs, eps)
    w_loop = w
    delta_loop = jnp.zeros_like(w)
    for i in range(s):
        w_loop, dl = model.vq_chunk(w_loop, zs[i], eps[i])
        delta_loop = delta_loop + dl
    np.testing.assert_allclose(w_scan, w_loop, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(delta_scan, delta_loop, rtol=1e-5, atol=1e-6)


def test_multi_chunk_w_minus_delta():
    w = rand(16, 16)
    zs = rand(4, 10, 16)
    eps = jnp.abs(rand(4, 10, scale=0.1))
    w_out, delta = model.multi_chunk(w, zs, eps)
    np.testing.assert_allclose(np.asarray(w_out), np.asarray(w - delta),
                               rtol=1e-5, atol=1e-6)


def test_distortion_sum_scalar():
    w = rand(16, 16)
    z = rand(1024, 16)
    got = model.distortion_sum(w, z)
    assert got.shape == ()
    want = float(ref.distortion_ref(w, z))
    np.testing.assert_allclose(float(got), want, rtol=1e-4)


def test_batch_kmeans_step_matches_ref():
    w = rand(16, 8)
    z = rand(1024, 8)
    new_w, counts = model.batch_kmeans_step(w, z)
    want_w, want_counts = ref.kmeans_step_ref(w, z)
    np.testing.assert_allclose(new_w, want_w, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(counts, want_counts, atol=0)


def test_batch_kmeans_step_empty_cluster_keeps_prototype():
    # prototype 0 is far away from all data: it must stay put
    w = jnp.concatenate(
        [jnp.full((1, 4), 1e6, dtype=jnp.float32), rand(7, 4)], axis=0)
    z = rand(256, 4)
    new_w, counts = model.batch_kmeans_step(w, z)
    assert float(counts[0]) == 0.0
    np.testing.assert_allclose(np.asarray(new_w)[0], np.asarray(w)[0], atol=0)


def test_batch_kmeans_decreases_distortion():
    """Lloyd monotonicity (DESIGN.md invariant 6) on the same batch."""
    w = rand(8, 4, scale=3.0)
    z = rand(1024, 4)
    before = float(model.distortion_sum(w, z))
    new_w, _ = model.batch_kmeans_step(w, z)
    after = float(model.distortion_sum(new_w, z))
    assert after <= before + 1e-3
