"""Kernel-vs-oracle correctness: the CORE signal for the L1 layer.

Every Pallas kernel is pinned against the literal pure-jnp implementation
in kernels/ref.py.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile.kernels import (
    vq_chunk_pallas,
    distortion_partials_pallas,
    kmeans_partials_pallas,
)
from compile.kernels import ref

RNG = np.random.default_rng(0)


def rand(*shape, scale=1.0):
    return jnp.asarray(RNG.normal(size=shape, scale=scale), dtype=jnp.float32)


def eps_seq(tau, t0=0, a=0.5, b=50.0):
    """The classical Robbins-Monro schedule eps_t = a / (1 + (t0+t)/b)."""
    t = np.arange(t0, t0 + tau, dtype=np.float32)
    return jnp.asarray(a / (1.0 + t / b), dtype=jnp.float32)


# ---------------------------------------------------------------- vq_chunk


@pytest.mark.parametrize("kappa,d,tau", [(16, 16, 10), (32, 8, 10),
                                         (8, 2, 10), (16, 16, 1),
                                         (4, 4, 32), (1, 3, 7)])
def test_vq_chunk_matches_ref(kappa, d, tau):
    w = rand(kappa, d)
    z = rand(tau, d)
    eps = eps_seq(tau)
    w_k, delta_k = vq_chunk_pallas(w, z, eps)
    w_r, delta_r = ref.vq_chunk_ref(w, z, eps)
    np.testing.assert_allclose(w_k, w_r, rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(delta_k, delta_r, rtol=1e-6, atol=1e-6)


def test_vq_chunk_w_minus_delta_identity():
    """DESIGN.md invariant 1: w_out == w - delta, exactly."""
    w = rand(16, 16)
    z = rand(10, 16)
    eps = eps_seq(10)
    w_out, delta = vq_chunk_pallas(w, z, eps)
    np.testing.assert_allclose(np.asarray(w_out), np.asarray(w - delta),
                               rtol=0, atol=1e-6)


def test_vq_chunk_delta_additivity():
    """DESIGN.md invariant 2: Delta_{0->2tau} = Delta_{0->tau} + Delta_{tau->2tau}."""
    w = rand(8, 4)
    z = rand(20, 4)
    eps = eps_seq(20)
    w_full, delta_full = vq_chunk_pallas(w, z, eps)
    w_half, delta_a = vq_chunk_pallas(w, z[:10], eps[:10])
    w_out, delta_b = vq_chunk_pallas(w_half, z[10:], eps[10:])
    np.testing.assert_allclose(w_full, w_out, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(delta_full, delta_a + delta_b,
                               rtol=1e-5, atol=1e-6)


def test_vq_chunk_single_step_explicit():
    """Hand-computed single eq.-1 step."""
    w = jnp.asarray([[0.0, 0.0], [10.0, 10.0]], dtype=jnp.float32)
    z = jnp.asarray([[1.0, 1.0]], dtype=jnp.float32)
    eps = jnp.asarray([0.5], dtype=jnp.float32)
    w_out, delta = vq_chunk_pallas(w, z, eps)
    # winner is prototype 0; w0 <- w0 - 0.5*(w0 - z) = [0.5, 0.5]
    np.testing.assert_allclose(
        np.asarray(w_out), [[0.5, 0.5], [10.0, 10.0]], atol=1e-7)
    np.testing.assert_allclose(
        np.asarray(delta), [[-0.5, -0.5], [0.0, 0.0]], atol=1e-7)


def test_vq_chunk_tie_breaks_to_first():
    """Equidistant prototypes: the first minimum must win (matches Rust)."""
    w = jnp.asarray([[1.0, 0.0], [-1.0, 0.0]], dtype=jnp.float32)
    z = jnp.asarray([[0.0, 0.0]], dtype=jnp.float32)
    eps = jnp.asarray([1.0], dtype=jnp.float32)
    w_out, _ = vq_chunk_pallas(w, z, eps)
    # prototype 0 moves onto z; prototype 1 untouched
    np.testing.assert_allclose(
        np.asarray(w_out), [[0.0, 0.0], [-1.0, 0.0]], atol=1e-7)


def test_vq_chunk_zero_eps_is_identity():
    w = rand(8, 8)
    z = rand(10, 8)
    eps = jnp.zeros((10,), dtype=jnp.float32)
    w_out, delta = vq_chunk_pallas(w, z, eps)
    np.testing.assert_allclose(w_out, w, atol=0)
    np.testing.assert_allclose(delta, jnp.zeros_like(w), atol=0)


def test_vq_chunk_eps_one_snaps_to_point():
    """eps=1 moves the winner exactly onto the data point."""
    w = rand(4, 3)
    z = rand(1, 3)
    eps = jnp.ones((1,), dtype=jnp.float32)
    w_out, _ = vq_chunk_pallas(w, z, eps)
    d2 = np.sum((np.asarray(w) - np.asarray(z[0])) ** 2, axis=1)
    winner = int(np.argmin(d2))
    np.testing.assert_allclose(np.asarray(w_out)[winner], np.asarray(z[0]),
                               atol=1e-6)


# -------------------------------------------------------------- distortion


@pytest.mark.parametrize("kappa,d,n,bt", [(16, 16, 1024, 256),
                                          (32, 8, 512, 128),
                                          (8, 2, 256, 256),
                                          (4, 4, 64, 16)])
def test_distortion_matches_ref(kappa, d, n, bt):
    w = rand(kappa, d)
    z = rand(n, d, scale=2.0)
    partials = distortion_partials_pallas(w, z, block_points=bt)
    assert partials.shape == (n // bt,)
    got = float(jnp.sum(partials))
    want = float(ref.distortion_ref(w, z))
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_distortion_nonnegative():
    w = rand(16, 16, scale=10.0)
    z = rand(512, 16, scale=10.0)
    partials = distortion_partials_pallas(w, z)
    assert float(jnp.min(partials)) >= 0.0


def test_distortion_zero_when_prototypes_cover_points():
    z = rand(256, 4)
    # codebook contains every point's exact location? use 4 protos == 4 pts
    w = z[:4]
    zz = jnp.tile(w, (64, 1))  # batch made only of prototype locations
    partials = distortion_partials_pallas(w, zz, block_points=64)
    np.testing.assert_allclose(np.asarray(jnp.sum(partials)), 0.0, atol=1e-3)


def test_distortion_permutation_invariant():
    """DESIGN.md invariant 6."""
    w = rand(8, 8)
    z = rand(256, 8)
    perm = jnp.asarray(RNG.permutation(8))
    a = float(jnp.sum(distortion_partials_pallas(w, z)))
    b = float(jnp.sum(distortion_partials_pallas(w[perm], z)))
    np.testing.assert_allclose(a, b, rtol=1e-5)


# ------------------------------------------------------------------ kmeans


@pytest.mark.parametrize("kappa,d,n,bt", [(16, 16, 1024, 256),
                                          (8, 2, 256, 64)])
def test_kmeans_partials_match_ref(kappa, d, n, bt):
    w = rand(kappa, d)
    z = rand(n, d)
    sums, counts = kmeans_partials_pallas(w, z, block_points=bt)
    assign = np.asarray(ref.assignments_ref(w, z))
    want_counts = np.bincount(assign, minlength=kappa).astype(np.float32)
    np.testing.assert_allclose(
        np.asarray(jnp.sum(counts, axis=0)), want_counts, atol=0)
    want_sums = np.zeros((kappa, d), dtype=np.float32)
    np.scatter_add = None  # noqa - explicit loop below for clarity
    for i, a in enumerate(assign):
        want_sums[a] += np.asarray(z)[i]
    np.testing.assert_allclose(
        np.asarray(jnp.sum(sums, axis=0)), want_sums, rtol=1e-4, atol=1e-4)


def test_kmeans_counts_total():
    w = rand(16, 16)
    z = rand(512, 16)
    _, counts = kmeans_partials_pallas(w, z, block_points=128)
    assert float(jnp.sum(counts)) == 512.0
