"""AOT lowering smoke tests: HLO text emission and manifest integrity."""

import json
import os

import jax
import jax.numpy as jnp

from compile import aot, model
from compile.variants import VARIANTS, by_name


def test_variants_well_formed():
    names = [v.name for v in VARIANTS]
    assert len(names) == len(set(names))
    for v in VARIANTS:
        assert v.kappa >= 1 and v.dim >= 1 and v.tau >= 1
        assert v.eval_batch % v.eval_tile == 0
    assert by_name("k16d16").tau == 10


def test_lower_vq_chunk_to_hlo_text():
    spec = lambda *s: jax.ShapeDtypeStruct(s, jnp.float32)  # noqa: E731
    lowered = jax.jit(model.vq_chunk).lower(spec(8, 2), spec(10, 2), spec(10))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert len(text) > 200


def test_lower_all_one_variant(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = aot.lower_all(out, variant_names=["k8d2"])
    assert "k8d2" in manifest["variants"]
    entries = manifest["variants"]["k8d2"]["entries"]
    assert set(entries) == {
        "vq_chunk", "multi_chunk", "distortion_sum", "batch_kmeans_step"}
    for e in entries.values():
        path = os.path.join(out, e["file"])
        assert os.path.exists(path)
        with open(path) as f:
            assert "HloModule" in f.read(200)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk["variants"]["k8d2"]["params"]["kappa"] == 8
