"""AOT artifact variants.

Every entry point in ``model.py`` is lowered once per variant; the Rust
runtime selects a variant by name through ``artifacts/manifest.json``.
Shapes are static in HLO, so anything the coordinator wants to run on the
PJRT hot path must appear here.

Fields:
  kappa        — number of prototypes (paper: kappa)
  dim          — sample dimension d
  tau          — chunk length = points per vq_chunk call = the paper's
                 synchronization period tau (tau=10 in all figures)
  eval_batch   — batch size for the distortion / k-means entry points
  eval_tile    — Pallas tile (block_points) inside the eval kernels
  scan_chunks  — S for the multi_chunk entry point (S*tau points per call)
"""

from dataclasses import dataclass, asdict


@dataclass(frozen=True)
class Variant:
    name: str
    kappa: int
    dim: int
    tau: int
    eval_batch: int
    eval_tile: int
    scan_chunks: int

    def to_dict(self):
        return asdict(self)


VARIANTS = [
    # The paper's figure configuration: tau = 10. kappa/d chosen to be
    # MXU-friendly powers of two; see DESIGN.md §Substitutions for the data.
    Variant("k16d16", kappa=16, dim=16, tau=10, eval_batch=1024,
            eval_tile=256, scan_chunks=16),
    # Higher-kappa / lower-d variant (stresses the argmin side).
    Variant("k32d8", kappa=32, dim=8, tau=10, eval_batch=1024,
            eval_tile=256, scan_chunks=16),
    # 2-D variant for the quickstart example (human-inspectable output).
    Variant("k8d2", kappa=8, dim=2, tau=10, eval_batch=1024,
            eval_tile=256, scan_chunks=16),
    # tau = 1 variant for the ABL-tau ablation (merge every point).
    Variant("k16d16t1", kappa=16, dim=16, tau=1, eval_batch=1024,
            eval_tile=256, scan_chunks=16),
]


def by_name(name: str) -> Variant:
    for v in VARIANTS:
        if v.name == name:
            return v
    raise KeyError(f"unknown variant {name!r}")
