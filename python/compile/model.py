"""Layer-2 JAX model: the exported compute-graph entry points.

Each function here composes the L1 Pallas kernels into the computation the
Rust coordinator dispatches on its hot path. ``aot.py`` lowers every entry
point once per variant to HLO text; Python never runs at serve time.

Entry points (all f32):
  vq_chunk(w, z, eps)          -> (w_out, delta)        [paper eq. 1 + 7]
  multi_chunk(w, zs, eps)      -> (w_out, delta_total)  [S chunks via scan]
  distortion_sum(w, z)         -> scalar sum            [paper eq. 2, un-normalized]
  batch_kmeans_step(w, z)      -> (new_w, counts)       [Lloyd baseline]
  nearest_batch(w, z)          -> (codes, dists)        [serving read path]

Normalization of eq. 2 by 1/(nM) happens in Rust, where n and M live.
"""

import jax
import jax.numpy as jnp

from .kernels import (
    vq_chunk_pallas,
    distortion_partials_pallas,
    kmeans_partials_pallas,
    nearest_batch_pallas,
)


def vq_chunk(w, z, eps):
    """One tau-point sequential VQ walk (the L1 kernel, re-exported)."""
    return vq_chunk_pallas(w, z, eps)


def multi_chunk(w, zs, eps):
    """S consecutive tau-point walks, scanned to amortize dispatch.

    Args:
      w:   (kappa, d)
      zs:  (S, tau, d)
      eps: (S, tau)

    Returns:
      (w_out, delta_total) with ``w_out == w - delta_total`` (delta
      additivity, DESIGN.md invariant 2).
    """

    def body(carry, inp):
        w, acc = carry
        z_c, e_c = inp
        w_next, delta = vq_chunk_pallas(w, z_c, e_c)
        return (w_next, acc + delta), None

    (w_out, delta_total), _ = jax.lax.scan(
        body, (w, jnp.zeros_like(w)), (zs, eps)
    )
    return w_out, delta_total


def distortion_sum(w, z, *, eval_tile: int = 256):
    """Un-normalized empirical distortion over a batch (eq. 2 numerator)."""
    partials = distortion_partials_pallas(w, z, block_points=eval_tile)
    return jnp.sum(partials)


def nearest_batch(w, z, *, eval_tile: int = 256):
    """Nearest prototype per point: (codes, dists), both (n,) f32.

    Codes are f32-encoded indices (exact up to 2^24) so the output tuple
    stays homogeneous for the Rust literal helpers.
    """
    return nearest_batch_pallas(w, z, block_points=eval_tile)


def batch_kmeans_step(w, z, *, eval_tile: int = 256):
    """One Lloyd iteration over the batch; empty clusters keep their old
    prototype. Returns (new_w, counts)."""
    sums, counts = kmeans_partials_pallas(w, z, block_points=eval_tile)
    sums = jnp.sum(sums, axis=0)  # (kappa, d)
    counts = jnp.sum(counts, axis=0)  # (kappa,)
    new_w = jnp.where(
        counts[:, None] > 0.0,
        sums / jnp.maximum(counts, 1.0)[:, None],
        w,
    )
    return new_w, counts
