"""AOT compile path: lower every L2 entry point to HLO **text** artifacts.

HLO text (not ``HloModuleProto.serialize()``) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Usage (from python/):  python -m compile.aot --out-dir ../artifacts

Emits one ``<entry>__<variant>.hlo.txt`` per (entry point, variant) plus a
``manifest.json`` describing shapes, so the Rust runtime is fully
manifest-driven.
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .variants import VARIANTS


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entry_points(v):
    """(name, fn, example_args) for every export of variant ``v``."""
    k, d, tau = v.kappa, v.dim, v.tau
    s, b, bt = v.scan_chunks, v.eval_batch, v.eval_tile
    return [
        (
            "vq_chunk",
            model.vq_chunk,
            (_spec(k, d), _spec(tau, d), _spec(tau)),
        ),
        (
            "multi_chunk",
            model.multi_chunk,
            (_spec(k, d), _spec(s, tau, d), _spec(s, tau)),
        ),
        (
            "distortion_sum",
            functools.partial(model.distortion_sum, eval_tile=bt),
            (_spec(k, d), _spec(b, d)),
        ),
        (
            "batch_kmeans_step",
            functools.partial(model.batch_kmeans_step, eval_tile=bt),
            (_spec(k, d), _spec(b, d)),
        ),
        (
            "nearest_batch",
            functools.partial(model.nearest_batch, eval_tile=bt),
            (_spec(k, d), _spec(b, d)),
        ),
    ]


def lower_all(out_dir: str, variant_names=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text/return-tuple", "variants": {}}
    for v in VARIANTS:
        if variant_names and v.name not in variant_names:
            continue
        entry_manifest = {}
        for name, fn, args in entry_points(v):
            lowered = jax.jit(fn).lower(*args)
            text = to_hlo_text(lowered)
            fname = f"{name}__{v.name}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entry_manifest[name] = {
                "file": fname,
                "inputs": [
                    {"shape": list(a.shape), "dtype": str(a.dtype)}
                    for a in args
                ],
            }
            print(f"  lowered {fname} ({len(text)} chars)")
        manifest["variants"][v.name] = {
            "params": v.to_dict(),
            "entries": entry_manifest,
        }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest['variants'])} variants")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--variants",
        nargs="*",
        default=None,
        help="subset of variant names to lower (default: all)",
    )
    args = p.parse_args()
    lower_all(args.out_dir, args.variants)


if __name__ == "__main__":
    main()
