"""Pallas kernel: fused batch nearest-prototype scan (the serving read path).

Per batch tile of ``bt`` points the kernel materializes the full
(bt, kappa) distance matrix in matmul form

    ||z - w||^2 = ||z||^2 - 2 z . w^T + ||w||^2

and reduces it twice: ``argmin`` for the code, ``min`` for the winning
squared distance — the batched twin of the Rust serving scan
(``vq::nearest_batch``). The codebook block (kappa, d) is resident across
the grid; each grid step streams one (bt, d) tile of queries through VMEM
and writes a (bt,) code slice plus a (bt,) distance slice.

Codes are emitted as **f32** (one homogeneous output tuple on the wire —
the Rust literal helpers only unpack f32); indices are exact integers up
to 2^24, far beyond any kappa here. ``jnp.argmin`` keeps the first minimum
on ties, matching the native strict-`<` scan; the matmul-form distances
themselves agree with the native four-lane sum only to float tolerance,
so near-ties may resolve differently across engines.

VMEM per tile: bt*d + kappa*d + bt*kappa f32 — the same budget as the
distortion kernel.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _nearest_kernel(w_ref, z_ref, idx_ref, dist_ref):
    z = z_ref[...]  # (bt, d)
    w = w_ref[...]  # (kappa, d)
    zn = jnp.sum(z * z, axis=1, keepdims=True)  # (bt, 1)
    wn = jnp.sum(w * w, axis=1)[None, :]  # (1, kappa)
    cross = jnp.dot(z, w.T, preferred_element_type=jnp.float32)  # MXU
    d2 = zn - 2.0 * cross + wn  # (bt, kappa)
    # Matmul form can dip epsilon-negative; the true metric is >= 0.
    d2 = jnp.maximum(d2, 0.0)
    idx_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.float32)
    dist_ref[...] = jnp.min(d2, axis=1)


def nearest_batch_pallas(w, z, *, block_points: int = 256):
    """Nearest prototype per query point of a batch.

    Args:
      w: (kappa, d) codebook.
      z: (n, d) batch; ``n`` must be a multiple of ``block_points``
         (the AOT entry is shape-static; the Rust caller handles the
         remainder natively).

    Returns:
      (codes, dists): two (n,) f32 arrays — winning prototype index
      (first minimum on ties) and its squared distance.
    """
    n, d = z.shape
    kappa = w.shape[0]
    bt = min(block_points, n)
    assert n % bt == 0, f"batch {n} not a multiple of tile {bt}"
    grid = n // bt
    return pl.pallas_call(
        _nearest_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),  # codebook resident
            pl.BlockSpec((bt, d), lambda i: (i, 0)),  # stream batch tiles
        ],
        out_specs=[
            pl.BlockSpec((bt,), lambda i: (i,)),
            pl.BlockSpec((bt,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ],
        interpret=True,
    )(w, z)
