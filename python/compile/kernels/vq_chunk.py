"""Pallas kernel: a tau-point sequential stochastic-VQ walk.

This is the hot spot of the whole system. The paper's recursion (eq. 1):

    l(t)      = argmin_i || z_{t+1} - w_i(t) ||^2
    w_i(t+1)  = w_i(t) - eps_{t+1} (w_i(t) - z_{t+1})   if i == l(t)
              = w_i(t)                                   otherwise

has a loop-carried dependence from step to step — that sequentiality is the
*point* of the paper (online VQ is not embarrassingly parallel). The kernel
therefore parallelizes across the *codebook* dimension instead: each step is
a fully vectorized (kappa, d) masked update (one-hot selection of the
winning prototype), and the tau steps run in a ``fori_loop`` with the
codebook and the running displacement held in registers/VMEM.

Outputs:
  w_out  — the codebook after tau steps,
  delta  — the accumulated displacement
           Delta = sum_t eps_t * H(z_t, w(t))            (paper eq. 7)
           so that  w_out == w_in - delta  exactly. ``delta`` is what
           schemes B (eq. 8) and C (eq. 9) ship to the reducer.

TPU shaping (DESIGN.md §Hardware-Adaptation): w, delta and the tau-point
block of z all live in VMEM for the duration of the walk; footprint is
(2*kappa*d + tau*d + tau) f32, e.g. ~10 KiB for kappa=d=16, tau=10.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vq_chunk_kernel(w_ref, z_ref, eps_ref, w_out_ref, delta_ref, *, tau: int):
    w0 = w_ref[...]  # (kappa, d)
    z = z_ref[...]  # (tau, d)
    eps = eps_ref[...]  # (tau,)
    kappa = w0.shape[0]

    def body(t, carry):
        w, delta = carry
        zt = jax.lax.dynamic_index_in_dim(z, t, axis=0, keepdims=False)  # (d,)
        et = jax.lax.dynamic_index_in_dim(eps, t, axis=0, keepdims=False)
        diff = w - zt[None, :]  # (kappa, d)
        dists = jnp.sum(diff * diff, axis=1)  # (kappa,)
        # First-minimum tie break, mirrored bit-for-bit by the Rust engine.
        winner = jnp.argmin(dists)
        mask = (jax.lax.iota(jnp.int32, kappa) == winner).astype(w.dtype)
        upd = et * mask[:, None] * diff  # eps_t * (w_l - z_t) on row l
        return w - upd, delta + upd

    w_final, delta = jax.lax.fori_loop(
        0, tau, body, (w0, jnp.zeros_like(w0))
    )
    w_out_ref[...] = w_final
    delta_ref[...] = delta


def vq_chunk_pallas(w, z, eps):
    """Run ``tau = z.shape[0]`` sequential VQ steps as one fused kernel.

    Args:
      w:   (kappa, d) float32 codebook.
      z:   (tau, d)   float32 data chunk.
      eps: (tau,)     float32 per-step learning rates.

    Returns:
      (w_out, delta): both (kappa, d); ``w_out == w - delta``.
    """
    tau = z.shape[0]
    kernel = functools.partial(_vq_chunk_kernel, tau=tau)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct(w.shape, w.dtype),
            jax.ShapeDtypeStruct(w.shape, w.dtype),
        ),
        interpret=True,
    )(w, z, eps)
