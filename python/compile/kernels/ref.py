"""Pure-jnp correctness oracles for every L1 kernel.

These are deliberately written in the most literal, paper-faithful way
(no matmul tricks, no masking cleverness) so the pytest/hypothesis suites
can pin the Pallas kernels against an independent implementation of the
same math.
"""

import jax
import jax.numpy as jnp


def vq_step_ref(w, z, eps):
    """One step of the paper's recursion (eq. 1).

    Returns (w_next, upd) where ``upd`` is this step's contribution to
    Delta (eq. 7): eps * (w_l - z) on the winning row, zero elsewhere.
    """
    diff = w - z[None, :]
    dists = jnp.sum(diff * diff, axis=1)
    winner = jnp.argmin(dists)  # first-minimum tie break
    upd = jnp.zeros_like(w).at[winner].set(eps * diff[winner])
    return w - upd, upd


def vq_chunk_ref(w, z, eps):
    """tau sequential steps of eq. 1; returns (w_out, delta)."""

    def body(carry, inp):
        w, delta = carry
        zt, et = inp
        w, upd = vq_step_ref(w, zt, et)
        return (w, delta + upd), None

    (w_out, delta), _ = jax.lax.scan(
        body, (w, jnp.zeros_like(w)), (z, eps)
    )
    return w_out, delta


def distortion_ref(w, z):
    """Exact un-normalized empirical distortion (eq. 2): sum over the batch
    of the squared distance to the nearest prototype."""
    d2 = jnp.sum((z[:, None, :] - w[None, :, :]) ** 2, axis=2)  # (n, kappa)
    return jnp.sum(jnp.min(d2, axis=1))


def assignments_ref(w, z):
    """Nearest-prototype index for each point (first-minimum tie break)."""
    d2 = jnp.sum((z[:, None, :] - w[None, :, :]) ** 2, axis=2)
    return jnp.argmin(d2, axis=1)


def kmeans_step_ref(w, z):
    """One Lloyd iteration; empty clusters keep their old prototype."""
    assign = assignments_ref(w, z)
    kappa = w.shape[0]
    onehot = (assign[:, None] == jnp.arange(kappa)[None, :]).astype(z.dtype)
    sums = onehot.T @ z  # (kappa, d)
    counts = jnp.sum(onehot, axis=0)  # (kappa,)
    new_w = jnp.where(
        counts[:, None] > 0, sums / jnp.maximum(counts, 1.0)[:, None], w
    )
    return new_w, counts
