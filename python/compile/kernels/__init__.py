"""Layer-1 Pallas kernels for the parallel stochastic VQ stack.

Every kernel is authored with `jax.experimental.pallas` and lowered with
``interpret=True`` so the resulting HLO executes on the CPU PJRT client used
by the Rust runtime (real-TPU Mosaic lowering is compile-only in this image;
see DESIGN.md §Hardware-Adaptation).

Kernels:
  - ``vq_chunk``     : a tau-point sequential online-VQ walk (paper eq. 1),
                       returning the new codebook and the accumulated
                       displacement Delta (paper eq. 7).
  - ``distortion``   : tiled empirical distortion partial sums (paper eq. 2).
  - ``kmeans_assign``: tiled per-cluster sums/counts for the batch k-means
                       baseline (Lloyd iteration substrate).
  - ``nearest``      : fused batch nearest-prototype scan (codes +
                       distances) for the serving read path.
"""

from .vq_chunk import vq_chunk_pallas
from .distortion import distortion_partials_pallas
from .kmeans import kmeans_partials_pallas
from .nearest import nearest_batch_pallas

__all__ = [
    "vq_chunk_pallas",
    "distortion_partials_pallas",
    "kmeans_partials_pallas",
    "nearest_batch_pallas",
]
