"""Pallas kernel: tiled empirical distortion (paper eq. 2, un-normalized).

    C(w) ~ sum_t min_l || z_t - w_l ||^2

The kernel computes, per batch tile of ``bt`` points, the partial sum of
squared distances to the nearest prototype. The distance matrix is expressed
in matmul form

    ||z - w||^2 = ||z||^2 - 2 z . w^T + ||w||^2

so the (bt, kappa) cross term lands on the MXU on a real TPU (DESIGN.md
§Hardware-Adaptation). The codebook block (kappa, d) is resident across the
grid; each grid step streams one (bt, d) tile of the batch through VMEM and
writes one partial scalar. The final reduction over partials happens in the
L2 jax wrapper (model.distortion_sum).

VMEM per tile: bt*d + kappa*d + bt*kappa f32 — e.g. ~84 KiB for
bt=256, kappa=16, d=16, far below the ~16 MiB VMEM budget.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _distortion_kernel(w_ref, z_ref, out_ref):
    z = z_ref[...]  # (bt, d)
    w = w_ref[...]  # (kappa, d)
    zn = jnp.sum(z * z, axis=1, keepdims=True)  # (bt, 1)
    wn = jnp.sum(w * w, axis=1)[None, :]  # (1, kappa)
    cross = jnp.dot(z, w.T, preferred_element_type=jnp.float32)  # MXU
    d2 = zn - 2.0 * cross + wn  # (bt, kappa)
    # Matmul form can dip epsilon-negative; the true metric is >= 0.
    d2 = jnp.maximum(d2, 0.0)
    out_ref[...] = jnp.sum(jnp.min(d2, axis=1))[None]


def distortion_partials_pallas(w, z, *, block_points: int = 256):
    """Partial distortion sums per batch tile.

    Args:
      w: (kappa, d) codebook.
      z: (n, d) batch; ``n`` must be a multiple of ``block_points``
         (the L2 wrapper pads).

    Returns:
      (n // block_points,) partial sums; their total is the batch distortion.
    """
    n, d = z.shape
    kappa = w.shape[0]
    bt = min(block_points, n)
    assert n % bt == 0, f"batch {n} not a multiple of tile {bt}"
    grid = n // bt
    return pl.pallas_call(
        _distortion_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),  # codebook resident
            pl.BlockSpec((bt, d), lambda i: (i, 0)),  # stream batch tiles
        ],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((grid,), jnp.float32),
        interpret=True,
    )(w, z)
