"""Pallas kernel: per-tile assignment sums/counts for batch k-means.

Substrate for the baseline the paper's introduction contrasts against: the
(batch) k-means / Lloyd iteration *is* embarrassingly parallel, and this
kernel is exactly its parallel inner step. Each grid step assigns a
(bt, d) tile of points to their nearest prototype (same matmul-form distance
as the distortion kernel) and emits per-cluster partial sums and counts;
the L2 wrapper reduces partials and forms the new centroids.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_kernel(w_ref, z_ref, sums_ref, counts_ref):
    z = z_ref[...]  # (bt, d)
    w = w_ref[...]  # (kappa, d)
    kappa = w.shape[0]
    zn = jnp.sum(z * z, axis=1, keepdims=True)
    wn = jnp.sum(w * w, axis=1)[None, :]
    cross = jnp.dot(z, w.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(zn - 2.0 * cross + wn, 0.0)  # (bt, kappa)
    assign = jnp.argmin(d2, axis=1)  # (bt,)
    onehot = (assign[:, None] == jax.lax.iota(jnp.int32, kappa)[None, :]).astype(
        jnp.float32
    )  # (bt, kappa)
    sums_ref[...] = jnp.dot(onehot.T, z, preferred_element_type=jnp.float32)[
        None
    ]  # (1, kappa, d)
    counts_ref[...] = jnp.sum(onehot, axis=0)[None]  # (1, kappa)


def kmeans_partials_pallas(w, z, *, block_points: int = 256):
    """Per-tile cluster sums and counts.

    Returns:
      sums:   (grid, kappa, d)
      counts: (grid, kappa)
    """
    n, d = z.shape
    kappa = w.shape[0]
    bt = min(block_points, n)
    assert n % bt == 0, f"batch {n} not a multiple of tile {bt}"
    grid = n // bt
    return pl.pallas_call(
        _kmeans_kernel,
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((kappa, d), lambda i: (0, 0)),
            pl.BlockSpec((bt, d), lambda i: (i, 0)),
        ],
        out_specs=(
            pl.BlockSpec((1, kappa, d), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, kappa), lambda i: (i, 0)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((grid, kappa, d), jnp.float32),
            jax.ShapeDtypeStruct((grid, kappa), jnp.float32),
        ),
        interpret=True,
    )(w, z)
