//! The paper in one screen: run all three parallelization schemes on the
//! same dataset, same initial codebook, same learning-rate schedule, and
//! print the side-by-side wall-clock comparison that Sections 2–4 argue.
//!
//! ```bash
//! cargo run --release --example scheme_comparison
//! ```
//!
//! Expected shape (the paper's core result):
//!   * averaging  (eq. 3): M = 10 no better than M = 1,
//!   * delta sync (eq. 8): M = 10 clearly faster in wall time,
//!   * async      (eq. 9): ≈ delta sync despite stochastic delays.

use dalvq::config::{presets, SchemeConfig};
use dalvq::harness::{self, format_speedups};
use dalvq::metrics::speedup_table;
use dalvq::sim::DelayModel;
use dalvq::Result;

fn main() -> Result<()> {
    let schemes: [(&str, SchemeConfig); 3] = [
        ("averaging (eq. 3) — Figure 1", SchemeConfig::Averaging { tau: 10 }),
        ("delta sync (eq. 8) — Figure 2", SchemeConfig::DeltaSync { tau: 10 }),
        (
            "async delta (eq. 9) — Figure 3",
            SchemeConfig::AsyncDelta {
                tau: 10,
                up_delay: DelayModel::Geometric { p: 0.5, unit: 1e-4 },
                down_delay: DelayModel::Geometric { p: 0.5, unit: 1e-4 },
            },
        ),
    ];

    for (label, scheme) in schemes {
        let mut fig = presets::fig2(); // same data/shape for all three
        fig.base.scheme = scheme;
        fig.base.run.points_per_worker = 100_000;
        println!("\n=== {label} ===");
        let report = harness::run_figure(&fig)?;
        for s in &report.series {
            println!(
                "  {:>5}: C {:.5} -> {:.5}  ({} merges, {:.3}s wall)",
                s.name,
                s.first_value(),
                s.last_value(),
                s.merges,
                s.last_wall()
            );
        }
        // Speed-up at 90% of the M=1 improvement.
        let base = &report.series[0];
        let threshold =
            base.first_value() + (base.min_value() - base.first_value()) * 0.9;
        let rows = speedup_table(&report.series, threshold);
        print!("{}", format_speedups(threshold, &rows));
    }
    println!(
        "\nReading: averaging shows speed-up ~1x at every M (the paper's \
         negative result);\ndelta merge restores the expected gains; the \
         asynchronous variant keeps them."
    );
    Ok(())
}
