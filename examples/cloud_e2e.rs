//! End-to-end driver (DESIGN.md §End-to-end validation): the full system —
//! synthetic workload → AOT'd Pallas kernels through PJRT → thread-per-VM
//! cloud runtime with latency-injected blob/queue services → the paper's
//! headline metric (normalized distortion vs real wall-clock, and the
//! scale-up across M).
//!
//! This is the FIG4 pipeline on a real small workload, with every layer
//! composed: L1/L2 artifacts on the worker hot path, L3 coordination over
//! real threads and real (injected) latency.
//!
//! Testbed note: each simulated VM is paced to `point_compute` seconds per
//! point (here 100 µs — a 2012-Azure-worker rate), so a single host core
//! can carry the whole fleet the way the paper's 32 VMs carried theirs;
//! PJRT dispatch (~5 µs/pt) fits well inside the pacing budget up to
//! M = 16 on one core.
//!
//! ```bash
//! make artifacts && cargo run --release --example cloud_e2e
//! ```

use std::path::Path;

use dalvq::cloud::run_cloud;
use dalvq::config::{CloudConfig, ExperimentConfig, SchemeConfig};
use dalvq::metrics::time_to_threshold;
use dalvq::runtime::EngineSpec;
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;
use dalvq::Result;

fn main() -> Result<()> {
    let artifacts = Path::new("artifacts");
    let have_artifacts = artifacts.join("manifest.json").exists();
    if !have_artifacts {
        eprintln!(
            "warning: artifacts/ missing — run `make artifacts`; \
             using the native engine"
        );
    }

    let mut cfg = ExperimentConfig::default();
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant, // latency comes from the services
        down_delay: DelayModel::Instant,
    };
    cfg.vq.init = dalvq::vq::InitMethod::Gaussian;
    cfg.run.points_per_worker = 20_000;
    cfg.run.eval_interval = 0.01;
    cfg.vq.schedule = Schedule::InverseTime { eps0: 0.005, half_life: 50_000.0 };
    cfg.engine = if have_artifacts {
        EngineSpec::Pjrt { artifacts_dir: artifacts.into(), variant: "k16d16".into() }
    } else {
        EngineSpec::Native
    };
    let mut cloud = CloudConfig::default();
    cloud.point_compute = 1e-4; // 10k pts/s per "VM" (2012-class worker)
    cloud.service_latency = 0.005; // 5 ms one-way — cloud-storage scale
    cloud.points_per_exchange = 100;

    println!("== cloud end-to-end: async delta merge (paper eq. 9) ==");
    println!(
        "engine = {}, kappa = {}, d = {}, tau = {}, {} pts/worker @ {:.0} µs/pt, \
         service latency {:.1} ms ± {:.0}%",
        if have_artifacts { "pjrt(k16d16)" } else { "native" },
        cfg.vq.kappa,
        cfg.dim(),
        cfg.scheme.tau(),
        cfg.run.points_per_worker,
        cloud.point_compute * 1e6,
        cloud.service_latency * 1e3,
        cloud.latency_jitter * 100.0,
    );

    // Threshold fixed from the M = 1 curve (80% of its improvement) and
    // reused for every M — the paper's time-to-performance notion.
    let mut threshold: Option<f64> = None;
    let mut baseline_time: Option<f64> = None;
    println!(
        "\n{:>4} | {:>10} | {:>10} | {:>8} | {:>9} | {:>9} | {}",
        "M", "C(start)", "C(end)", "merges", "wall (s)", "t@thresh", "scale-up"
    );
    for m in [1usize, 2, 4, 8, 16] {
        let mut cfg_m = cfg.clone();
        cfg_m.m = m;
        let out = run_cloud(&cfg_m, &cloud)?;
        let th = *threshold.get_or_insert_with(|| {
            let s0 = out.series.first_value();
            s0 + (out.series.min_value() - s0) * 0.9
        });
        let t = time_to_threshold(&out.series, th);
        if m == 1 {
            baseline_time = t;
        }
        let scaleup = match (baseline_time, t) {
            (Some(b), Some(t)) if t > 0.0 => format!("{:.2}x", b / t),
            _ => "-".into(),
        };
        println!(
            "{:>4} | {:>10.5} | {:>10.5} | {:>8} | {:>9.3} | {:>9} | {}",
            m,
            out.series.first_value(),
            out.series.last_value(),
            out.merges,
            out.series.last_wall(),
            t.map(|t| format!("{t:.3}s")).unwrap_or_else(|| "never".into()),
            scaleup,
        );
    }
    println!(
        "\nExpected shape (paper Figure 4): distortion descends faster as M \
         grows,\nwith diminishing returns toward large M."
    );
    Ok(())
}
