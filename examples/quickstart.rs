//! Quickstart: cluster a 2-D synthetic mixture with the parallel VQ stack,
//! running the compute hot path on the **PJRT engine** (the AOT-compiled
//! Pallas kernels in `artifacts/`).
//!
//! ```bash
//! make artifacts                   # once: lower the JAX/Pallas kernels
//! cargo run --release --example quickstart
//! ```
//!
//! Falls back to the native engine with a warning if artifacts are absent,
//! so the example always runs.

use dalvq::config::presets;
use dalvq::coordinator::Orchestrator;
use dalvq::runtime::EngineSpec;
use dalvq::vq::{compression_report, nearest};
use dalvq::Result;

fn main() -> Result<()> {
    let mut cfg = presets::quickstart();
    // The preset points at artifacts/k8d2; verify they exist.
    if let EngineSpec::Pjrt { artifacts_dir, .. } = &cfg.engine {
        if !artifacts_dir.join("manifest.json").exists() {
            eprintln!(
                "warning: {} not found — run `make artifacts`; \
                 falling back to the native engine",
                artifacts_dir.join("manifest.json").display()
            );
            cfg.engine = EngineSpec::Native;
        }
    }

    println!("== dalvq quickstart ==");
    println!(
        "data: {} points, {} clusters in R^{}; kappa = {}, M = {}, scheme = {}",
        cfg.data.n_total,
        cfg.data.mixture.components,
        cfg.dim(),
        cfg.vq.kappa,
        cfg.m,
        cfg.scheme.label(),
    );

    let orch = Orchestrator::new();
    let outcome = orch.run_experiment(&cfg)?;

    println!("\nfinal prototypes (2-D):");
    for i in 0..outcome.final_shared.kappa() {
        let row = outcome.final_shared.row(i);
        println!("  w[{i}] = ({:+.3}, {:+.3})", row[0], row[1]);
    }

    // Sanity: every true mixture center should have a prototype nearby.
    let centers = cfg.data.mixture.centers(cfg.seed);
    let mut worst = 0.0f32;
    for c in centers.chunks_exact(cfg.dim()) {
        let i = nearest(&outcome.final_shared, c);
        let w = outcome.final_shared.row(i);
        let d = ((w[0] - c[0]).powi(2) + (w[1] - c[1]).powi(2)).sqrt();
        worst = worst.max(d);
    }
    println!("\nworst center-to-prototype distance: {worst:.3}");
    println!(
        "distortion: {:.4} -> {:.4} over {:.3}s of virtual wall time",
        outcome.series.first_value(),
        outcome.series.last_value(),
        outcome.series.last_wall(),
    );

    // The paper's motivation: the codebook is a dataset summary. Use it
    // as a codec and report the compression it buys.
    let data = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);
    let report = compression_report(&outcome.final_shared, data.flat());
    println!(
        "as a codec: {} -> {} bits/point ({}x compression) at MSE {:.4}",
        report.raw_bits_per_point,
        report.coded_bits_per_point,
        report.ratio.round(),
        report.mse,
    );
    Ok(())
}
