//! Streaming clustering — the workload the paper's introduction motivates:
//! summarize a very large dataset online, with the codebook available at
//! any moment.
//!
//! A producer streams mixture points whose distribution **drifts** halfway
//! through (the centers move); the online VQ tracks the drift while the
//! batch k-means baseline, fit on the first half, goes stale. This is the
//! classic argument for the *online* algorithm the paper parallelizes.
//!
//! ```bash
//! cargo run --release --example streaming_clustering
//! ```

use dalvq::data::MixtureSpec;
use dalvq::runtime::{Engine, NativeEngine};
use dalvq::vq::{distortion_mean, init_codebook, Delta, InitMethod, Schedule};
use dalvq::Result;

fn main() -> Result<()> {
    let dim = 8;
    let phase_a = MixtureSpec {
        components: 8,
        dim,
        separation: 5.0,
        std: 0.4,
        imbalance: 0.0,
        noise_frac: 0.01,
    };
    // Drifted regime: different seed -> different centers.
    let phase_b = phase_a.clone();
    let (seed_a, seed_b) = (100, 200);

    let kappa = 8;
    let tau = 10;
    let schedule = Schedule::Power { eps0: 0.05, half_life: 2000.0, alpha: 0.6 };
    let mut engine = NativeEngine::new();

    // Warm start both methods on an initial batch from phase A.
    let warm = phase_a.generate(4_096, seed_a, 0);
    let mut w_online = init_codebook(InitMethod::FromData, kappa, dim, &warm, 1);
    let mut w_batch = init_codebook(InitMethod::KmeansPlusPlus, kappa, dim, &warm, 1);
    for _ in 0..20 {
        engine.kmeans_step(&mut w_batch, &warm)?; // batch baseline, fit once
    }

    let eval_a = phase_a.eval_sample(2_048, seed_a);
    let eval_b = phase_b.eval_sample(2_048, seed_b);

    println!("== streaming clustering under distribution drift ==");
    println!(
        "{:>8} | {:>9} | {:>14} | {:>14} | {}",
        "points", "phase", "C(online)", "C(batch-fit)", "eval set"
    );

    let mut delta = Delta::zeros(kappa, dim);
    let mut eps = vec![0.0f32; tau];
    let mut t: u64 = 0;
    let total_chunks = 4_000u64;
    for chunk_idx in 0..total_chunks {
        let drifted = chunk_idx >= total_chunks / 2;
        let (spec, seed) = if drifted { (&phase_b, seed_b) } else { (&phase_a, seed_a) };
        // each chunk is a fresh draw from the live stream
        let chunk = spec.generate(tau, seed, 1000 + chunk_idx);
        schedule.fill(t, &mut eps);
        delta.clear();
        engine.vq_chunk(&mut w_online, &chunk, &eps, &mut delta)?;
        t += tau as u64;

        if chunk_idx % 500 == 499 {
            let eval = if drifted { &eval_b } else { &eval_a };
            println!(
                "{:>8} | {:>9} | {:>14.5} | {:>14.5} | phase {}",
                t,
                if drifted { "drifted" } else { "initial" },
                distortion_mean(&w_online, eval),
                distortion_mean(&w_batch, eval),
                if drifted { "B" } else { "A" },
            );
        }
    }

    let online_b = distortion_mean(&w_online, &eval_b);
    let batch_b = distortion_mean(&w_batch, &eval_b);
    println!(
        "\nafter drift: online C = {online_b:.5} vs stale batch C = {batch_b:.5} \
         ({}x better)",
        (batch_b / online_b).round()
    );
    assert!(
        online_b < batch_b,
        "online VQ should track the drift that the one-shot batch fit misses"
    );
    Ok(())
}
