//! Property-based tests over the DESIGN.md invariants.
//!
//! The offline build carries no proptest, so properties are checked with
//! seeded random sweeps from the in-tree RNG: many independently drawn
//! cases per property, deterministic under `DALVQ_PROP_SEED` (default 7),
//! failures print the case seed for replay.

use dalvq::config::{ExperimentConfig, SchemeConfig};
use dalvq::data::MixtureSpec;
use dalvq::schemes;
use dalvq::sim::DelayModel;
use dalvq::util::Rng;
use dalvq::vq::{self, Codebook, Delta, Schedule};

fn prop_seed() -> u64 {
    std::env::var("DALVQ_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(7)
}

/// Draw a random VQ instance: codebook, points, eps sequence.
fn draw_instance(rng: &mut Rng) -> (Codebook, Vec<f32>, Vec<f32>) {
    let kappa = 1 + rng.usize(24);
    let dim = 1 + rng.usize(24);
    let steps = 1 + rng.usize(40);
    let scale = [0.1f32, 1.0, 10.0][rng.usize(3)];
    let w = Codebook::from_flat(
        kappa,
        dim,
        (0..kappa * dim).map(|_| rng.normal_f32() * scale).collect(),
    );
    let mut z: Vec<f32> =
        (0..steps * dim).map(|_| rng.normal_f32() * scale).collect();
    // sometimes plant exact prototype duplicates (ties)
    if rng.bool(0.3) && steps >= 2 {
        z[..dim].copy_from_slice(w.row(rng.usize(kappa)));
    }
    let eps: Vec<f32> = (0..steps).map(|_| rng.f32()).collect();
    (w, z, eps)
}

#[test]
fn prop_chunk_identity_w_equals_w0_minus_delta() {
    let mut rng = Rng::from_seed_stream(prop_seed(), 1);
    for case in 0..200 {
        let (w0, z, eps) = draw_instance(&mut rng);
        let mut w = w0.clone();
        let mut delta = Delta::zeros(w.kappa(), w.dim());
        vq::vq_chunk(&mut w, &z, &eps, &mut delta);
        let mut w_check = w0.clone();
        w_check.apply_delta(&delta);
        let diff = w.max_abs_diff(&w_check);
        assert!(diff < 1e-4, "case {case}: identity violated by {diff}");
        assert!(w.is_finite(), "case {case}: non-finite codebook");
    }
}

#[test]
fn prop_delta_additivity_across_windows() {
    let mut rng = Rng::from_seed_stream(prop_seed(), 2);
    for case in 0..200 {
        let (w0, z, eps) = draw_instance(&mut rng);
        let dim = w0.dim();
        let steps = eps.len();
        let cut = rng.usize(steps + 1);

        let mut w_full = w0.clone();
        let mut d_full = Delta::zeros(w0.kappa(), dim);
        vq::vq_chunk(&mut w_full, &z, &eps, &mut d_full);

        let mut w_split = w0.clone();
        let mut d_split = Delta::zeros(w0.kappa(), dim);
        vq::vq_chunk(&mut w_split, &z[..cut * dim], &eps[..cut], &mut d_split);
        vq::vq_chunk(&mut w_split, &z[cut * dim..], &eps[cut..], &mut d_split);

        assert!(
            w_full.max_abs_diff(&w_split) < 1e-5,
            "case {case}: split walk diverged"
        );
        assert!(
            d_full.max_abs_diff(&d_split) < 1e-5,
            "case {case}: deltas not additive at cut {cut}"
        );
    }
}

#[test]
fn prop_reducer_fold_is_order_insensitive() {
    // DESIGN.md invariant 7: the merge is commutative up to fp tolerance.
    let mut rng = Rng::from_seed_stream(prop_seed(), 3);
    for case in 0..200 {
        let kappa = 1 + rng.usize(8);
        let dim = 1 + rng.usize(8);
        let n_deltas = 2 + rng.usize(10);
        let w0 = Codebook::from_flat(
            kappa,
            dim,
            (0..kappa * dim).map(|_| rng.normal_f32()).collect(),
        );
        let deltas: Vec<Delta> = (0..n_deltas)
            .map(|_| {
                Delta::from_flat(
                    kappa,
                    dim,
                    (0..kappa * dim).map(|_| rng.normal_f32() * 0.1).collect(),
                )
            })
            .collect();
        let mut w_fwd = w0.clone();
        for d in &deltas {
            w_fwd.apply_delta(d);
        }
        let mut w_perm = w0.clone();
        for &i in &rng.permutation(n_deltas) {
            w_perm.apply_delta(&deltas[i]);
        }
        let diff = w_fwd.max_abs_diff(&w_perm);
        assert!(diff < 1e-4, "case {case}: fold order changed result by {diff}");
    }
}

#[test]
fn prop_averaging_stays_in_convex_hull() {
    // DESIGN.md invariant 8: eq. 3's average lies in the per-coordinate
    // hull of the versions — this is exactly why it shrinks steps.
    let mut rng = Rng::from_seed_stream(prop_seed(), 4);
    for case in 0..200 {
        let kappa = 1 + rng.usize(6);
        let dim = 1 + rng.usize(6);
        let m = 1 + rng.usize(8);
        let versions: Vec<Codebook> = (0..m)
            .map(|_| {
                Codebook::from_flat(
                    kappa,
                    dim,
                    (0..kappa * dim).map(|_| rng.normal_f32()).collect(),
                )
            })
            .collect();
        let avg = Codebook::average(&versions);
        for idx in 0..kappa * dim {
            let lo = versions
                .iter()
                .map(|v| v.flat()[idx])
                .fold(f32::INFINITY, f32::min);
            let hi = versions
                .iter()
                .map(|v| v.flat()[idx])
                .fold(f32::NEG_INFINITY, f32::max);
            let x = avg.flat()[idx];
            assert!(
                x >= lo - 1e-5 && x <= hi + 1e-5,
                "case {case}: coord {idx} = {x} outside hull [{lo}, {hi}]"
            );
        }
    }
}

#[test]
fn prop_distortion_nonneg_and_permutation_invariant() {
    let mut rng = Rng::from_seed_stream(prop_seed(), 5);
    for case in 0..100 {
        let (w, z, _) = draw_instance(&mut rng);
        let c = vq::distortion_sum(&w, &z);
        assert!(c >= 0.0 && c.is_finite(), "case {case}: bad distortion {c}");
        // permute prototypes
        let perm = rng.permutation(w.kappa());
        let mut data = Vec::with_capacity(w.flat().len());
        for &i in &perm {
            data.extend_from_slice(w.row(i));
        }
        let w_perm = Codebook::from_flat(w.kappa(), w.dim(), data);
        let c_perm = vq::distortion_sum(&w_perm, &z);
        let rel = (c - c_perm).abs() / c.max(1e-9);
        assert!(rel < 1e-6, "case {case}: permutation changed distortion");
    }
}

#[test]
fn prop_schedules_are_positive_and_decay() {
    let mut rng = Rng::from_seed_stream(prop_seed(), 6);
    for _ in 0..100 {
        let eps0 = 0.01 + rng.f32() * 0.98;
        let half_life = 1.0 + rng.f32() * 10_000.0;
        let schedules = [
            Schedule::Constant { eps0 },
            Schedule::InverseTime { eps0, half_life },
            Schedule::Power { eps0, half_life, alpha: 0.5 + rng.f32() * 0.5 },
        ];
        for s in schedules {
            s.validate().unwrap();
            let mut prev = f32::INFINITY;
            for t in [0u64, 1, 10, 100, 10_000, 1_000_000] {
                let e = s.eps(t);
                assert!(e > 0.0 && e <= eps0 + 1e-6, "{s:?} at {t}: {e}");
                assert!(e <= prev + 1e-6, "{s:?} not non-increasing at {t}");
                prev = e;
            }
        }
    }
}

#[test]
fn prop_simulator_runs_are_deterministic() {
    // DESIGN.md invariant 10, across random configurations of scheme C.
    let mut rng = Rng::from_seed_stream(prop_seed(), 8);
    for case in 0..10 {
        let mut cfg = ExperimentConfig::default();
        cfg.seed = rng.next_u64();
        cfg.m = 1 + rng.usize(6);
        cfg.data.mixture.components = 4;
        cfg.data.mixture.dim = 1 + rng.usize(4);
        cfg.data.n_total = 2_000;
        cfg.data.eval_points = 128;
        cfg.vq.kappa = 4;
        cfg.vq.schedule = Schedule::InverseTime { eps0: 0.01, half_life: 5000.0 };
        cfg.run.points_per_worker = 2_000;
        cfg.run.eval_interval = 1e-3;
        cfg.run.trace_capacity = 10_000;
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Geometric { p: 0.4, unit: 5e-5 },
            down_delay: DelayModel::Geometric { p: 0.4, unit: 5e-5 },
        };
        let a = schemes::run_with_config(&cfg).unwrap();
        let b = schemes::run_with_config(&cfg).unwrap();
        assert_eq!(
            a.final_shared, b.final_shared,
            "case {case} (seed {}): non-deterministic shared version",
            cfg.seed
        );
        assert_eq!(a.series.merges, b.series.merges, "case {case}");
        assert_eq!(
            a.series.samples.len(),
            b.series.samples.len(),
            "case {case}"
        );
    }
}

#[test]
fn prop_mixture_shards_partition_the_dataset() {
    let mut rng = Rng::from_seed_stream(prop_seed(), 9);
    for case in 0..50 {
        let spec = MixtureSpec {
            components: 1 + rng.usize(8),
            dim: 1 + rng.usize(8),
            separation: 1.0 + rng.f32() * 9.0,
            std: 0.05 + rng.f32(),
            imbalance: rng.f32(),
            noise_frac: rng.f32() * 0.2,
        };
        let n = 100 + rng.usize(2_000);
        let m = 1 + rng.usize(16);
        if n < m {
            continue;
        }
        let ds = spec.dataset(n, rng.next_u64());
        let shards = ds.split(m);
        assert_eq!(
            shards.iter().map(|s| s.len()).sum::<usize>(),
            n,
            "case {case}: shards lost points"
        );
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        let (lo, hi) =
            (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(hi - lo <= 1, "case {case}: unbalanced shards {sizes:?}");
    }
}

#[test]
fn delta_merge_diverges_when_step_violates_envelope() {
    // Documented negative result (see Schedule::paper_default): the delta
    // merge is only stable when M·τ·ε/κ stays below ~1. This pins the
    // divergence so the constraint stays visible.
    let mut cfg = ExperimentConfig::default();
    cfg.m = 10;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 256;
    cfg.vq.kappa = 4;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.5 }; // envelope = 12.5
    cfg.scheme = SchemeConfig::DeltaSync { tau: 10 };
    cfg.run.points_per_worker = 10_000;
    cfg.run.eval_interval = 1e-3;
    let out = schemes::run_with_config(&cfg).unwrap();
    assert!(
        !out.final_shared.is_finite() || out.series.last_value() > 1e3,
        "expected divergence outside the stability envelope, got C = {}",
        out.series.last_value()
    );
}
