//! Cloud-runtime integration: protocol accounting, fault injection,
//! straggler latency, and the Figure-4 scale shape at test size.

use std::sync::Mutex;

use dalvq::cloud::{run_cloud, CloudOutcome};
use dalvq::config::{CloudConfig, ExperimentConfig, SchemeConfig};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// The cloud runtime measures real time; run these tests one at a time so
/// pacing sleeps aren't distorted by sibling tests' thread fleets.
static SERIAL: Mutex<()> = Mutex::new(());

fn cloud_cfg(m: usize, points: u64) -> (ExperimentConfig, CloudConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = m;
    cfg.data.mixture.components = 8;
    cfg.data.mixture.dim = 4;
    cfg.data.n_total = 8_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 8;
    cfg.vq.schedule = Schedule::InverseTime { eps0: 0.002, half_life: 10_000.0 };
    cfg.run.points_per_worker = points;
    cfg.run.eval_interval = 0.004;
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let cloud = CloudConfig {
        service_latency: 0.0003,
        latency_jitter: 0.5,
        drop_prob: 0.0,
        points_per_exchange: 100,
        // keep real CPU well inside the pacing budget in both profiles
        // (the debug engine is ~10x slower than release)
        point_compute: if cfg!(debug_assertions) { 1e-4 } else { 1e-5 },
    };
    (cfg, cloud)
}

/// Lock that survives a sibling test's failure (no poison cascade).
fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// No drops → every started exchange is delivered and folded exactly once.
#[test]
fn every_delta_folded_exactly_once() {
    let _serial = serial();
    let (cfg, cloud) = cloud_cfg(4, 5_000);
    let out = run_cloud(&cfg, &cloud).unwrap();
    let started: u64 = out.workers.iter().map(|w| w.exchanges_started).sum();
    assert_eq!(
        out.merges, started,
        "reducer folds ({}) must equal exchanges started ({started})",
        out.merges
    );
    for w in &out.workers {
        assert_eq!(w.pushes_dropped, 0);
        assert_eq!(w.exchanges_completed, w.exchanges_started);
        assert_eq!(w.points_done, 5_000);
        assert!(w.final_w.is_finite());
    }
}

/// Workers always flush their tail window, so the shared version contains
/// every displacement: series must descend and end finite.
#[test]
fn final_flush_preserves_convergence() {
    let _serial = serial();
    let (cfg, cloud) = cloud_cfg(2, 4_000);
    let out = run_cloud(&cfg, &cloud).unwrap();
    assert!(out.final_shared.is_finite());
    assert!(
        out.series.last_value() < out.series.first_value() * 0.9,
        "{} -> {}",
        out.series.first_value(),
        out.series.last_value()
    );
    assert!(out.series.is_time_monotone());
}

/// Fault injection: the protocol degrades gracefully under message loss.
#[test]
fn message_loss_degrades_gracefully() {
    let _serial = serial();
    let (cfg, mut cloud) = cloud_cfg(4, 5_000);
    cloud.drop_prob = 0.5;
    let out = run_cloud(&cfg, &cloud).unwrap();
    let started: u64 = out.workers.iter().map(|w| w.exchanges_started).sum();
    let dropped: u64 = out.workers.iter().map(|w| w.pushes_dropped).sum();
    assert!(dropped > 0, "expected drops at p=0.5");
    assert_eq!(out.merges + dropped, started, "drop accounting must balance");
    assert!(out.final_shared.is_finite());
    assert!(out.series.last_value() < out.series.first_value());
}

/// A slow network path for one worker (straggler) must not stall the
/// others — total runtime stays bounded by compute pacing, not by the
/// straggler's latency, and all points still get processed.
#[test]
fn straggler_latency_does_not_stall_the_fleet() {
    let _serial = serial();
    let (cfg, mut cloud) = cloud_cfg(4, 4_000);
    // make the service latency itself large relative to pacing: exchanges
    // become rare, but compute must proceed regardless (no barrier)
    cloud.service_latency = 0.02;
    let t0 = std::time::Instant::now();
    let out = run_cloud(&cfg, &cloud).unwrap();
    let elapsed = t0.elapsed().as_secs_f64();
    for w in &out.workers {
        assert_eq!(w.points_done, 4_000, "worker starved by slow exchanges");
        // far fewer exchanges than windows: the line was busy, compute went on
        assert!(w.exchanges_started < 4_000 / 100);
    }
    // pacing: 4000 pts x point_compute of compute; drain adds a few RTTs.
    assert!(
        elapsed < 4.0,
        "run took {elapsed}s — workers appear to have serialized on latency"
    );
}

/// The Figure-4 shape at test scale: more workers reach a distortion
/// threshold in less real time (scale-up), monotone in M on a coarse grid.
#[test]
fn scale_up_shape_holds_at_test_size() {
    let _serial = serial();
    let run_m = |m: usize| -> CloudOutcome {
        let (cfg, cloud) = cloud_cfg(m, 20_000);
        run_cloud(&cfg, &cloud).unwrap()
    };
    let m1 = run_m(1);
    let m8 = run_m(8);
    // same per-worker pacing => similar wall span; M=8 must be further
    // down. Integrate over the back half of the window rather than
    // sampling one instant — robust to monitor jitter.
    let horizon = m1.series.last_wall().min(m8.series.last_wall());
    let avg = |s: &dalvq::metrics::Series| {
        let n = 20;
        (0..n)
            .map(|i| s.value_at(horizon * (0.5 + 0.5 * i as f64 / n as f64)))
            .sum::<f64>()
            / n as f64
    };
    let c1 = avg(&m1.series);
    let c8 = avg(&m8.series);
    eprintln!("scale_up: M=1 avg C {c1:.6}, M=8 avg C {c8:.6}");
    assert!(
        c8 < c1,
        "M=8 ({c8:.6}) should be below M=1 ({c1:.6}) over the same window"
    );
}

/// 32 workers: the M of the paper's Figure 4, compressed run.
#[test]
fn thirty_two_workers_complete_and_converge() {
    let _serial = serial();
    let (mut cfg, cloud) = cloud_cfg(32, 2_000);
    cfg.data.n_total = 16_000;
    let out = run_cloud(&cfg, &cloud).unwrap();
    assert_eq!(out.series.points_processed, 32 * 2_000);
    assert_eq!(out.workers.len(), 32);
    assert!(out.final_shared.is_finite());
    assert!(out.merges > 32, "every worker should exchange at least once");
}
