//! Protocol robustness: the wire layer must be total — every byte string
//! either decodes to exactly the value that produced it, or returns `Err`.
//! No input may panic, and no length field may drive an allocation past
//! `MAX_FRAME`.
//!
//! Two families:
//!
//! * **Roundtrip property tests** — randomized `Request`/`Response` values
//!   (seed-deterministic via the crate RNG) encode→decode to equality.
//! * **Adversarial decode tests** — truncations at every byte boundary,
//!   length prefixes that lie about element counts, unknown opcodes,
//!   trailing garbage, and `read_frame` against mid-frame EOF.

use dalvq::serve::protocol::{
    read_frame, write_frame, Decoder, MetricEvent, MetricHist, MetricsReply,
    Request, Response, StateFile, StateShipment, StatsReply, WireSpan,
    WireTrace, FETCH_ANY_GENERATION, MAX_FRAME,
};
use dalvq::util::Rng;

// ------------------------------------------------------------ generators

fn rand_f32s(rng: &mut Rng, max_len: usize) -> Vec<f32> {
    let n = rng.usize(max_len + 1);
    (0..n)
        .map(|_| match rng.usize(8) {
            0 => 0.0,
            1 => -0.0,
            2 => f32::MIN,
            3 => f32::MAX,
            4 => f32::EPSILON,
            _ => rng.range_f32(-1e6, 1e6),
        })
        .collect()
}

fn rand_u32s(rng: &mut Rng, max_len: usize) -> Vec<u32> {
    let n = rng.usize(max_len + 1);
    (0..n).map(|_| rng.next_u64() as u32).collect()
}

fn rand_u64s(rng: &mut Rng, max_len: usize) -> Vec<u64> {
    let n = rng.usize(max_len + 1);
    (0..n).map(|_| rng.next_u64()).collect()
}

fn rand_string(rng: &mut Rng, max_len: usize) -> String {
    let len = rng.usize(max_len + 1);
    (0..len).map(|_| (b'a' + rng.usize(26) as u8) as char).collect()
}

fn rand_bytes(rng: &mut Rng, max_len: usize) -> Vec<u8> {
    let n = rng.usize(max_len + 1);
    (0..n).map(|_| rng.next_u64() as u8).collect()
}

/// Any request that is not a trace envelope (the envelope wraps exactly
/// these — nesting is a decode error).
fn rand_bare_request(rng: &mut Rng) -> Request {
    match rng.usize(12) {
        0 => Request::Encode { points: rand_f32s(rng, 64) },
        1 => Request::Nearest { points: rand_f32s(rng, 64) },
        2 => Request::Distortion { points: rand_f32s(rng, 64) },
        3 => Request::Ingest { points: rand_f32s(rng, 64) },
        4 => Request::Checkpoint,
        5 => Request::Rebalance { want_remap: rng.bool(0.5) },
        6 => Request::FetchState { have_generation: rng.next_u64() },
        7 => Request::Metrics { max_events: rng.next_u64() as u32 },
        8 => Request::Trace { max_traces: rng.next_u64() as u32 },
        9 => Request::FetchChunk {
            generation: rng.next_u64(),
            chunk: rng.next_u64() as u32,
        },
        10 => Request::Demote {
            generation: rng.next_u64(),
            leader: rand_string(rng, 32),
        },
        _ => Request::Stats,
    }
}

fn rand_request(rng: &mut Rng) -> Request {
    if rng.bool(0.2) {
        // One in five rides a trace envelope around any bare op.
        Request::Traced {
            hi: rng.next_u64(),
            lo: rng.next_u64(),
            parent: rng.next_u64(),
            inner: Box::new(rand_bare_request(rng)),
        }
    } else {
        rand_bare_request(rng)
    }
}

fn rand_spans(rng: &mut Rng, max_len: usize) -> Vec<WireSpan> {
    let n = rng.usize(max_len + 1);
    (0..n)
        .map(|_| WireSpan {
            id: rng.next_u64(),
            parent: rng.next_u64(),
            start_us: rng.next_u64(),
            dur_us: rng.next_u64(),
            name: rand_string(rng, 24),
        })
        .collect()
}

fn rand_traces(rng: &mut Rng, max_len: usize) -> Vec<WireTrace> {
    let n = rng.usize(max_len + 1);
    (0..n)
        .map(|_| WireTrace {
            hi: rng.next_u64(),
            lo: rng.next_u64(),
            ts_ms: rng.next_u64(),
            spans: rand_spans(rng, 6),
        })
        .collect()
}

fn rand_metric_pairs(rng: &mut Rng, max_len: usize) -> Vec<(String, u64)> {
    let n = rng.usize(max_len + 1);
    (0..n).map(|_| (rand_string(rng, 24), rng.next_u64())).collect()
}

/// Any response that is not a trace envelope.
fn rand_bare_response(rng: &mut Rng) -> Response {
    match rng.usize(14) {
        13 => Response::DemoteAck,
        12 => Response::Throttled {
            retry_after_ms: rng.next_u64(),
            message: rand_string(rng, 40),
        },
        11 => Response::Traces(rand_traces(rng, 4)),
        10 => Response::Metrics(MetricsReply {
            uptime_ms: rng.next_u64(),
            counters: rand_metric_pairs(rng, 8),
            gauges: rand_metric_pairs(rng, 8),
            hists: {
                let n = rng.usize(5);
                (0..n)
                    .map(|_| MetricHist {
                        name: rand_string(rng, 24),
                        count: rng.next_u64(),
                        mean_us: rng.range_f64(0.0, 1e9),
                        p50_us: rng.range_f64(0.0, 1e9),
                        p95_us: rng.range_f64(0.0, 1e9),
                        p99_us: rng.range_f64(0.0, 1e9),
                        max_us: rng.range_f64(0.0, 1e9),
                    })
                    .collect()
            },
            events: {
                let n = rng.usize(5);
                (0..n)
                    .map(|_| MetricEvent {
                        seq: rng.next_u64(),
                        ts_ms: rng.next_u64(),
                        // reserved levels must survive the wire verbatim
                        level: rng.next_u64() as u8,
                        kind: rand_string(rng, 24),
                        message: rand_string(rng, 64),
                    })
                    .collect()
            },
        }),
        9 => Response::State(StateShipment {
            generation: rng.next_u64(),
            leader_version: rng.next_u64(),
            chunk: rng.next_u64() as u32,
            chunks: rng.next_u64() as u32,
            delta: rng.bool(0.5),
            files: {
                let n = rng.usize(5);
                (0..n)
                    .map(|_| StateFile {
                        name: rand_string(rng, 24),
                        offset: rng.next_u64(),
                        file_len: rng.next_u64(),
                        bytes: rand_bytes(rng, 96),
                    })
                    .collect()
            },
        }),
        8 => Response::NotLeader { leader: rand_string(rng, 32) },
        7 => Response::RebalanceAck {
            router_version: rng.next_u64(),
            moved_rows: rng.next_u64(),
            shard_versions: rand_u64s(rng, 16),
            remap: rand_u32s(rng, 32),
        },
        6 => Response::CheckpointAck { versions: rand_u64s(rng, 16) },
        0 => Response::Codes {
            version: rng.next_u64(),
            codes: rand_u32s(rng, 64),
        },
        1 => {
            let indices = rand_u32s(rng, 64);
            let dists = rand_f32s(rng, indices.len());
            Response::Neighbors { version: rng.next_u64(), indices, dists }
        }
        2 => Response::Distortion {
            version: rng.next_u64(),
            value: rng.range_f64(0.0, 1e12),
        },
        3 => Response::IngestAck {
            accepted: rng.next_u64(),
            shed: rng.next_u64(),
        },
        4 => Response::Stats(StatsReply {
            version: rng.next_u64(),
            kappa: rng.next_u64(),
            dim: rng.next_u64(),
            workers: rng.next_u64(),
            shards: rng.next_u64(),
            probe_n: rng.next_u64(),
            router_version: rng.next_u64(),
            rebalances: rng.next_u64(),
            merges: rng.next_u64(),
            ingested: rng.next_u64(),
            ingest_shed: rng.next_u64(),
            queries: rng.next_u64(),
            shard_versions: rand_u64s(rng, 16),
            shard_merges: rand_u64s(rng, 16),
            shard_ingest: rand_u64s(rng, 16),
            shard_shed: rand_u64s(rng, 16),
            last_checkpoint: rand_u64s(rng, 16),
            state_dir: rand_string(rng, 32),
            role: rand_string(rng, 12),
            leader_addr: rand_string(rng, 24),
            sync_lag_folds: rng.next_u64(),
            last_sync: rng.next_u64(),
            uptime_ms: rng.next_u64(),
            op_encode: rng.next_u64(),
            op_nearest: rng.next_u64(),
            op_distortion: rng.next_u64(),
            op_ingest: rng.next_u64(),
            sync_source: rand_string(rng, 8),
        }),
        _ => Response::Error { message: rand_string(rng, 40) },
    }
}

fn rand_response(rng: &mut Rng) -> Response {
    if rng.bool(0.2) {
        Response::Traced {
            hi: rng.next_u64(),
            lo: rng.next_u64(),
            spans: rand_spans(rng, 6),
            inner: Box::new(rand_bare_response(rng)),
        }
    } else {
        rand_bare_response(rng)
    }
}

// --------------------------------------------------- roundtrip properties

#[test]
fn random_requests_roundtrip_exactly() {
    let mut rng = Rng::from_seed(0xF00D);
    for _ in 0..500 {
        let req = rand_request(&mut rng);
        let wire = req.encode();
        assert_eq!(Request::decode(&wire).unwrap(), req, "{req:?}");
    }
}

#[test]
fn random_responses_roundtrip_exactly() {
    let mut rng = Rng::from_seed(0xBEEF);
    for _ in 0..500 {
        let resp = rand_response(&mut rng);
        let wire = resp.encode();
        assert_eq!(Response::decode(&wire).unwrap(), resp, "{resp:?}");
    }
}

#[test]
fn random_frames_roundtrip_through_a_stream() {
    let mut rng = Rng::from_seed(0xCAFE);
    let payloads: Vec<Vec<u8>> =
        (0..50).map(|_| rand_request(&mut rng).encode()).collect();
    let mut wire = Vec::new();
    for p in &payloads {
        write_frame(&mut wire, p).unwrap();
    }
    let mut r = &wire[..];
    for p in &payloads {
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), *p);
    }
    assert_eq!(read_frame(&mut r).unwrap(), None); // clean EOF at boundary
}

// ------------------------------------------------------ adversarial decode

/// Every strict prefix of a valid encoding must return `Err` — never
/// panic, never succeed with a different value.
#[test]
fn every_truncation_of_every_variant_errs() {
    let mut rng = Rng::from_seed(0xD15C);
    for _ in 0..40 {
        let wire = rand_request(&mut rng).encode();
        for cut in 0..wire.len() {
            assert!(
                Request::decode(&wire[..cut]).is_err(),
                "request prefix {cut}/{} decoded",
                wire.len()
            );
        }
        let wire = rand_response(&mut rng).encode();
        for cut in 0..wire.len() {
            assert!(
                Response::decode(&wire[..cut]).is_err(),
                "response prefix {cut}/{} decoded",
                wire.len()
            );
        }
    }
}

#[test]
fn empty_payload_is_an_error() {
    assert!(Request::decode(&[]).is_err());
    assert!(Response::decode(&[]).is_err());
}

#[test]
fn unknown_opcodes_err_for_both_directions() {
    let known_req = [
        0x01, 0x02, 0x03, 0x04, 0x05, 0x06, 0x07, 0x08, 0x09, 0x0A, 0x0B,
        0x0C, 0x0D,
    ];
    let known_resp = [
        0x81, 0x82, 0x83, 0x84, 0x85, 0x86, 0x87, 0x88, 0x89, 0x8A, 0x8B,
        0x8C, 0xFD, 0xFE, 0xFF,
    ];
    for op in 0..=255u8 {
        if !known_req.contains(&op) {
            assert!(Request::decode(&[op]).is_err(), "req op 0x{op:02x}");
        }
        if !known_resp.contains(&op) {
            assert!(Response::decode(&[op]).is_err(), "resp op 0x{op:02x}");
        }
    }
}

/// A length prefix claiming more elements than the payload carries must
/// fail the bounds check before any allocation sized by the lie.
#[test]
fn lying_element_counts_err_without_overallocating() {
    // Encode { count = u32::MAX, no elements }
    let mut wire = vec![0x01u8];
    wire.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Request::decode(&wire).is_err());

    // count = 3 but only 2 f32s present
    let mut wire = vec![0x01u8];
    wire.extend_from_slice(&3u32.to_le_bytes());
    wire.extend_from_slice(&1.0f32.to_le_bytes());
    wire.extend_from_slice(&2.0f32.to_le_bytes());
    assert!(Request::decode(&wire).is_err());

    // Neighbors with a lying second vector (indices fine, dists lie)
    let mut wire = vec![0x82u8];
    wire.extend_from_slice(&7u64.to_le_bytes());
    wire.extend_from_slice(&1u32.to_le_bytes());
    wire.extend_from_slice(&5u32.to_le_bytes());
    wire.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Response::decode(&wire).is_err());

    // Stats reply with lying shard-vector counts: strip the whole
    // default tail — six empty vectors/strings at one u32 count each
    // (shard_versions, shard_merges, shard_ingest, shard_shed,
    // last_checkpoint, state_dir), the two empty replication strings
    // (role, leader_addr), the seven trailing u64s (sync_lag_folds,
    // last_sync, uptime_ms and the four per-op counters) and the empty
    // sync_source string = 9 * 4 + 7 * 8 = 92 bytes — and replace with
    // a lying pair
    let good = Response::Stats(StatsReply::default()).encode();
    let mut wire = good[..good.len() - 92].to_vec();
    wire.extend_from_slice(&9u32.to_le_bytes()); // shard_versions: claims 9
    wire.extend_from_slice(&0u32.to_le_bytes()); // shard_merges: 0
    assert!(Response::decode(&wire).is_err());

    // CheckpointAck whose version count lies
    let mut wire = vec![0x86u8];
    wire.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Response::decode(&wire).is_err());

    // RebalanceAck whose shard-version count lies
    let mut wire = vec![0x87u8];
    wire.extend_from_slice(&1u64.to_le_bytes());
    wire.extend_from_slice(&2u64.to_le_bytes());
    wire.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Response::decode(&wire).is_err());

    // RebalanceAck whose remap count lies (shard_versions fine)
    let mut wire = vec![0x87u8];
    wire.extend_from_slice(&1u64.to_le_bytes());
    wire.extend_from_slice(&2u64.to_le_bytes());
    wire.extend_from_slice(&0u32.to_le_bytes()); // no shard versions
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // remap lies
    assert!(Response::decode(&wire).is_err());

    // Stats whose state_dir length outruns the payload: strip the
    // post-state_dir tail (role + leader_addr + sync_source counts,
    // seven u64s = 68 bytes) plus the state_dir count itself, then lie
    // about its length
    let good = Response::Stats(StatsReply::default()).encode();
    let mut wire = good[..good.len() - 72].to_vec();
    wire.extend_from_slice(&1_000u32.to_le_bytes());
    wire.extend_from_slice(b"short");
    assert!(Response::decode(&wire).is_err());

    // Stats whose sync_source length lies: drop the trailing empty
    // sync_source string (one u32 count) and replace it with a count
    // that outruns the payload
    let good = Response::Stats(StatsReply::default()).encode();
    let mut wire = good[..good.len() - 4].to_vec();
    wire.extend_from_slice(&64u32.to_le_bytes());
    wire.extend_from_slice(b"delta");
    assert!(Response::decode(&wire).is_err());

    // State whose file count lies (claims a file, carries none)
    let mut wire = vec![0x88u8];
    wire.extend_from_slice(&1u64.to_le_bytes()); // generation
    wire.extend_from_slice(&2u64.to_le_bytes()); // leader_version
    wire.extend_from_slice(&1u32.to_le_bytes()); // chunk
    wire.extend_from_slice(&1u32.to_le_bytes()); // chunks
    wire.push(0); // delta: full
    wire.extend_from_slice(&1u32.to_le_bytes()); // claims 1 file
    assert!(Response::decode(&wire).is_err());

    // State whose file-bytes length outruns the payload (the per-file
    // offset and file_len fields present and sane — only the bytes lie)
    let mut wire = vec![0x88u8];
    wire.extend_from_slice(&1u64.to_le_bytes());
    wire.extend_from_slice(&2u64.to_le_bytes());
    wire.extend_from_slice(&2u32.to_le_bytes()); // chunk 2
    wire.extend_from_slice(&3u32.to_le_bytes()); // of 3
    wire.push(1); // delta
    wire.extend_from_slice(&1u32.to_le_bytes()); // one file
    wire.extend_from_slice(&1u32.to_le_bytes()); // name len 1
    wire.push(b'x');
    wire.extend_from_slice(&4096u64.to_le_bytes()); // offset
    wire.extend_from_slice(&8192u64.to_le_bytes()); // file_len
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // bytes len lies
    assert!(Response::decode(&wire).is_err());

    // State cut off inside the chunk header (pre-v2 encoders stopped
    // after leader_version — their frames must now be rejected, not
    // misread as a zero-file shipment)
    let mut wire = vec![0x88u8];
    wire.extend_from_slice(&1u64.to_le_bytes());
    wire.extend_from_slice(&2u64.to_le_bytes());
    assert!(Response::decode(&wire).is_err());

    // FetchChunk cut off after the generation (chunk index missing)
    let mut wire = vec![0x0Cu8];
    wire.extend_from_slice(&7u64.to_le_bytes());
    assert!(Request::decode(&wire).is_err());

    // Demote whose leader-address length outruns the payload
    let mut wire = vec![0x0Du8];
    wire.extend_from_slice(&7u64.to_le_bytes()); // generation
    wire.extend_from_slice(&500u32.to_le_bytes()); // addr len lies
    wire.extend_from_slice(b"1.2.3.4:5");
    assert!(Request::decode(&wire).is_err());

    // Metrics whose counter count lies (claims u32::MAX, carries none) —
    // each counter consumes at least 12 bytes (name count + value), so
    // the bounds check must fire before any allocation sized by the lie
    let mut wire = vec![0x89u8];
    wire.extend_from_slice(&7u64.to_le_bytes()); // uptime_ms
    wire.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Response::decode(&wire).is_err());

    // Metrics whose histogram count lies (counters and gauges fine)
    let mut wire = vec![0x89u8];
    wire.extend_from_slice(&7u64.to_le_bytes()); // uptime_ms
    wire.extend_from_slice(&0u32.to_le_bytes()); // no counters
    wire.extend_from_slice(&0u32.to_le_bytes()); // no gauges
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // hists lie
    assert!(Response::decode(&wire).is_err());

    // Metrics whose event count lies (everything before it fine)
    let mut wire = vec![0x89u8];
    wire.extend_from_slice(&7u64.to_le_bytes()); // uptime_ms
    wire.extend_from_slice(&0u32.to_le_bytes()); // no counters
    wire.extend_from_slice(&0u32.to_le_bytes()); // no gauges
    wire.extend_from_slice(&0u32.to_le_bytes()); // no hists
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // events lie
    assert!(Response::decode(&wire).is_err());

    // Metrics whose event message length outruns the payload
    let mut wire = vec![0x89u8];
    wire.extend_from_slice(&7u64.to_le_bytes()); // uptime_ms
    wire.extend_from_slice(&0u32.to_le_bytes()); // no counters
    wire.extend_from_slice(&0u32.to_le_bytes()); // no gauges
    wire.extend_from_slice(&0u32.to_le_bytes()); // no hists
    wire.extend_from_slice(&1u32.to_le_bytes()); // one event
    wire.extend_from_slice(&1u64.to_le_bytes()); // seq
    wire.extend_from_slice(&2u64.to_le_bytes()); // ts_ms
    wire.push(0); // level
    wire.extend_from_slice(&1u32.to_le_bytes()); // kind len 1
    wire.push(b'k');
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // message lies
    assert!(Response::decode(&wire).is_err());

    // Traces whose trace count lies (claims u32::MAX, carries none) —
    // each trace consumes at least 28 bytes, so the bounds check fires
    // before any allocation sized by the lie
    let mut wire = vec![0x8Au8];
    wire.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Response::decode(&wire).is_err());

    // Traces whose span count lies (trace header fine, spans absent)
    let mut wire = vec![0x8Au8];
    wire.extend_from_slice(&1u32.to_le_bytes()); // one trace
    wire.extend_from_slice(&1u64.to_le_bytes()); // hi
    wire.extend_from_slice(&2u64.to_le_bytes()); // lo
    wire.extend_from_slice(&3u64.to_le_bytes()); // ts_ms
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // spans lie
    assert!(Response::decode(&wire).is_err());

    // Traces whose span-name length outruns the payload
    let mut wire = vec![0x8Au8];
    wire.extend_from_slice(&1u32.to_le_bytes()); // one trace
    wire.extend_from_slice(&1u64.to_le_bytes()); // hi
    wire.extend_from_slice(&2u64.to_le_bytes()); // lo
    wire.extend_from_slice(&3u64.to_le_bytes()); // ts_ms
    wire.extend_from_slice(&1u32.to_le_bytes()); // one span
    wire.extend_from_slice(&4u64.to_le_bytes()); // id
    wire.extend_from_slice(&0u64.to_le_bytes()); // parent
    wire.extend_from_slice(&5u64.to_le_bytes()); // start_us
    wire.extend_from_slice(&6u64.to_le_bytes()); // dur_us
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // name lies
    assert!(Response::decode(&wire).is_err());

    // Traced request envelope whose inner length lies
    let mut wire = vec![0x0Bu8];
    wire.extend_from_slice(&1u64.to_le_bytes()); // hi
    wire.extend_from_slice(&2u64.to_le_bytes()); // lo
    wire.extend_from_slice(&3u64.to_le_bytes()); // parent
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // inner lies
    assert!(Request::decode(&wire).is_err());

    // Traced response envelope whose span count lies
    let mut wire = vec![0x8Bu8];
    wire.extend_from_slice(&1u64.to_le_bytes()); // hi
    wire.extend_from_slice(&2u64.to_le_bytes()); // lo
    wire.extend_from_slice(&u32::MAX.to_le_bytes()); // spans lie
    assert!(Response::decode(&wire).is_err());

    // NotLeader whose address length lies
    let mut wire = vec![0xFEu8];
    wire.extend_from_slice(&500u32.to_le_bytes());
    wire.extend_from_slice(b"1.2.3.4:5");
    assert!(Response::decode(&wire).is_err());

    // Error response whose message length lies
    let mut wire = vec![0xFFu8];
    wire.extend_from_slice(&1000u32.to_le_bytes());
    wire.extend_from_slice(b"short");
    assert!(Response::decode(&wire).is_err());
}

/// Point payloads carrying NaN or ±Inf must be rejected at decode, for
/// every point-carrying op, with the offending index named — a NaN that
/// reaches the scan answers code 0 at distance NaN, and one that reaches
/// `Ingest` poisons a codebook row for every later query. Hand-crafted
/// frames, since `rand_f32s` is deliberately finite-only (the roundtrip
/// property above depends on that).
#[test]
fn non_finite_point_payloads_err_at_decode() {
    let point_ops = [0x01u8, 0x02, 0x03, 0x04]; // encode/nearest/distortion/ingest
    let bads = [
        f32::NAN.to_le_bytes(),
        f32::INFINITY.to_le_bytes(),
        f32::NEG_INFINITY.to_le_bytes(),
        // a signalling-ish NaN payload pattern, not just the canonical one
        [0x01, 0x00, 0x80, 0x7F],
    ];
    for op in point_ops {
        for bad in bads {
            let mut wire = vec![op];
            wire.extend_from_slice(&3u32.to_le_bytes());
            wire.extend_from_slice(&1.5f32.to_le_bytes());
            wire.extend_from_slice(&bad);
            wire.extend_from_slice(&(-2.5f32).to_le_bytes());
            let err = Request::decode(&wire).unwrap_err().to_string();
            assert!(
                err.contains("non-finite") && err.contains("index 1"),
                "op 0x{op:02x}: unexpected error {err:?}"
            );
        }
        // finite extremes still pass through the same arm
        let mut wire = vec![op];
        wire.extend_from_slice(&2u32.to_le_bytes());
        wire.extend_from_slice(&f32::MIN.to_le_bytes());
        wire.extend_from_slice(&f32::MAX.to_le_bytes());
        assert!(Request::decode(&wire).is_ok(), "op 0x{op:02x}");
    }
}

/// The replication fields of `StatsReply` survive the wire exactly —
/// a leader's defaults (empty role strings are what pre-replication
/// encoders would have sent for a default reply) and a fully populated
/// follower reply both roundtrip.
#[test]
fn stats_follower_fields_roundtrip_exactly() {
    let follower = StatsReply {
        version: 41,
        kappa: 16,
        dim: 2,
        workers: 0, // a follower runs no training fleet
        shards: 4,
        probe_n: 2,
        router_version: 2,
        rebalances: 0,
        merges: 41,
        ingested: 0,
        ingest_shed: 0,
        queries: 1_000,
        shard_versions: vec![10, 11, 10, 10],
        shard_merges: vec![10, 11, 10, 10],
        shard_ingest: vec![0; 4],
        shard_shed: vec![0; 4],
        last_checkpoint: vec![10, 11, 10, 10],
        state_dir: "/var/lib/dalvq/follower".into(),
        role: "follower".into(),
        leader_addr: "10.1.2.3:7171".into(),
        sync_lag_folds: 7,
        last_sync: 312,
        uptime_ms: 90_000,
        op_encode: 250,
        op_nearest: 500,
        op_distortion: 125,
        op_ingest: 0, // a follower answers NotLeader to every ingest
        sync_source: "delta".into(),
    };
    let wire = Response::Stats(follower.clone()).encode();
    match Response::decode(&wire).unwrap() {
        Response::Stats(s) => {
            assert_eq!(s, follower);
            assert_eq!(s.role, "follower");
            assert_eq!(s.leader_addr, "10.1.2.3:7171");
            assert_eq!(s.sync_lag_folds, 7);
            assert_eq!(s.last_sync, 312);
            assert_eq!(s.sync_source, "delta");
        }
        other => panic!("expected Stats, got {other:?}"),
    }
    // a leader reply carries the defaults
    let leader = StatsReply { role: "leader".into(), ..StatsReply::default() };
    let wire = Response::Stats(leader.clone()).encode();
    assert_eq!(Response::decode(&wire).unwrap(), Response::Stats(leader));
}

/// The replication-v2 wire shapes survive exactly: a whole-cut shipment
/// carries the default chunk header (chunk 1 of 1, not a delta), a
/// mid-cut delta piece keeps its byte offsets verbatim, and the three
/// new ops (`FetchChunk`, `Demote`, `DemoteAck`) roundtrip at their
/// extremes.
#[test]
fn replication_v2_shapes_roundtrip_exactly() {
    // A whole cut: the default header is what single-frame replies carry.
    let whole = StateShipment {
        generation: 3,
        leader_version: 41,
        files: vec![StateFile {
            name: "manifest.json".into(),
            offset: 0,
            file_len: 2,
            bytes: vec![b'{', b'}'],
        }],
        ..StateShipment::default()
    };
    assert_eq!((whole.chunk, whole.chunks, whole.delta), (1, 1, false));
    let wire = Response::State(whole.clone()).encode();
    assert_eq!(Response::decode(&wire).unwrap(), Response::State(whole));

    // A mid-cut piece: offsets and the delta flag must not be coerced.
    let piece = StateShipment {
        generation: u64::MAX - 1,
        leader_version: u64::MAX,
        chunk: 2,
        chunks: 7,
        delta: true,
        files: vec![StateFile {
            name: "shard_0003.bin".into(),
            offset: 63 << 20,
            file_len: 1 << 40,
            bytes: vec![0xAB; 17],
        }],
    };
    let wire = Response::State(piece.clone()).encode();
    assert_eq!(Response::decode(&wire).unwrap(), Response::State(piece));

    for req in [
        Request::FetchState { have_generation: FETCH_ANY_GENERATION },
        Request::FetchChunk { generation: 0, chunk: 1 },
        Request::FetchChunk { generation: u64::MAX, chunk: u32::MAX },
        Request::Demote { generation: 1 << 20, leader: "10.0.0.1:7171".into() },
        Request::Demote { generation: u64::MAX, leader: String::new() },
    ] {
        let wire = req.encode();
        assert_eq!(Request::decode(&wire).unwrap(), req, "{req:?}");
    }
    let wire = Response::DemoteAck.encode();
    assert_eq!(Response::decode(&wire).unwrap(), Response::DemoteAck);
}

/// The trace envelope is a backward-compatible *extension*: a bare op's
/// bytes are identical to what pre-tracing encoders emitted (no flag, no
/// reserved field), the envelope's payload is the bare encoding verbatim,
/// and envelopes never nest — in either direction.
#[test]
fn trace_envelopes_extend_the_protocol_without_changing_bare_frames() {
    let mut rng = Rng::from_seed(0x7_2ACE);
    for _ in 0..40 {
        // Old-client-to-new-server direction: a bare request re-wrapped
        // in an envelope carries the bare bytes verbatim after the
        // 29-byte envelope prefix (opcode + hi + lo + parent + len).
        let bare = rand_bare_request(&mut rng);
        let bare_wire = bare.encode();
        let enveloped = Request::Traced {
            hi: 7,
            lo: 9,
            parent: 11,
            inner: Box::new(bare.clone()),
        }
        .encode();
        assert_eq!(&enveloped[29..], &bare_wire[..], "{bare:?}");
        // …and the envelope decodes back to exactly the bare inner.
        match Request::decode(&enveloped).unwrap() {
            Request::Traced { hi: 7, lo: 9, parent: 11, inner } => {
                assert_eq!(*inner, bare);
            }
            other => panic!("expected envelope, got {other:?}"),
        }
        // New-server-to-old-client direction: an untraced call is
        // answered bare, so the old decoder never sees 0x8B. Here:
        // bare responses still decode as themselves even with the
        // envelope ops known.
        let resp = rand_bare_response(&mut rng);
        let wire = resp.encode();
        assert_eq!(Response::decode(&wire).unwrap(), resp);
    }

    // Nested envelopes are rejected at decode, both directions: splice a
    // valid envelope into another envelope's inner-blob slot by hand
    // (the typed encoder debug-asserts against building one).
    let inner_env = Request::Traced {
        hi: 1,
        lo: 2,
        parent: 3,
        inner: Box::new(Request::Stats),
    }
    .encode();
    let mut wire = vec![0x0Bu8];
    wire.extend_from_slice(&4u64.to_le_bytes());
    wire.extend_from_slice(&5u64.to_le_bytes());
    wire.extend_from_slice(&6u64.to_le_bytes());
    wire.extend_from_slice(&(inner_env.len() as u32).to_le_bytes());
    wire.extend_from_slice(&inner_env);
    let err = Request::decode(&wire).unwrap_err().to_string();
    assert!(err.contains("nested"), "{err}");

    let inner_env = Response::Traced {
        hi: 1,
        lo: 2,
        spans: vec![],
        inner: Box::new(Response::Error { message: "x".into() }),
    }
    .encode();
    let mut wire = vec![0x8Bu8];
    wire.extend_from_slice(&4u64.to_le_bytes());
    wire.extend_from_slice(&5u64.to_le_bytes());
    wire.extend_from_slice(&0u32.to_le_bytes()); // no spans
    wire.extend_from_slice(&(inner_env.len() as u32).to_le_bytes());
    wire.extend_from_slice(&inner_env);
    let err = Response::decode(&wire).unwrap_err().to_string();
    assert!(err.contains("nested"), "{err}");
}

#[test]
fn trailing_garbage_is_rejected() {
    let mut rng = Rng::from_seed(0x7A11);
    for _ in 0..40 {
        let mut wire = rand_request(&mut rng).encode();
        wire.push(0x00);
        assert!(Request::decode(&wire).is_err());
        let mut wire = rand_response(&mut rng).encode();
        wire.push(0xAB);
        assert!(Response::decode(&wire).is_err());
    }
}

/// Fuzz: random byte soup must never panic, whatever it decodes to.
#[test]
fn random_bytes_never_panic_the_decoders() {
    let mut rng = Rng::from_seed(0x5EED);
    for _ in 0..2_000 {
        let len = rng.usize(96);
        let buf: Vec<u8> =
            (0..len).map(|_| rng.next_u64() as u8).collect();
        let _ = Request::decode(&buf);
        let _ = Response::decode(&buf);
    }
}

/// Bit-flip fuzz: corrupt one byte of a valid encoding at a time.
#[test]
fn single_byte_corruptions_never_panic() {
    let mut rng = Rng::from_seed(0xB17F);
    for _ in 0..60 {
        let wire = rand_response(&mut rng).encode();
        for i in 0..wire.len() {
            let mut bad = wire.clone();
            bad[i] ^= 1 << rng.usize(8);
            let _ = Response::decode(&bad); // Ok or Err, never a panic
        }
    }
}

// --------------------------------------------------------------- framing

#[test]
fn eof_at_a_frame_boundary_is_none_but_mid_header_is_an_error() {
    // EOF exactly at a frame boundary: None (peer hung up between frames)
    let empty: &[u8] = &[];
    assert_eq!(read_frame(&mut &empty[..]).unwrap(), None);
    // EOF inside the 4-byte length header: a dying peer, not a clean
    // hang-up — must be an error for every partial header length
    for cut in 1..4 {
        let partial = [0x02u8, 0x00, 0x00];
        assert!(
            read_frame(&mut &partial[..cut]).is_err(),
            "mid-header EOF at {cut} bytes treated as clean"
        );
    }
}

#[test]
fn read_frame_mid_payload_eof_is_an_error() {
    // Header promises 100 bytes; only 10 follow.
    let mut wire = Vec::new();
    wire.extend_from_slice(&100u32.to_le_bytes());
    wire.extend_from_slice(&[0u8; 10]);
    assert!(read_frame(&mut &wire[..]).is_err());
}

#[test]
fn oversized_length_prefixes_are_rejected_before_allocation() {
    for len in [MAX_FRAME + 1, u32::MAX, u32::MAX - 3] {
        let wire = len.to_le_bytes();
        assert!(read_frame(&mut &wire[..]).is_err(), "len {len}");
    }
    // the cap itself is allowed through to the payload read (which then
    // hits EOF — an error, but not the cap error)
    let wire = MAX_FRAME.to_le_bytes();
    assert!(read_frame(&mut &wire[..]).is_err());
}

#[test]
fn write_frame_refuses_oversized_payloads() {
    // One allocation just over the cap: the writer must reject it before
    // emitting a single byte (a half-written frame would desync the peer).
    let payload = vec![0u8; (MAX_FRAME as usize) + 1];
    let mut sink = Vec::new();
    assert!(write_frame(&mut sink, &payload).is_err());
    assert!(sink.is_empty(), "nothing may be written for a rejected frame");
}

/// The event loop's incremental decoder must be byte-split-invariant:
/// however the kernel slices a frame stream across reads, the frames it
/// yields are identical. Replays a 3-frame stream (a points request, a
/// trace envelope, a `Throttled` reply payload among them) split at
/// *every* byte boundary, plus in jittered random chunks, against a
/// one-shot parse of the whole stream.
#[test]
fn frames_split_at_every_byte_boundary_decode_identically() {
    let mut rng = Rng::from_seed(0xD1CE);
    let frames: Vec<Vec<u8>> = vec![
        Request::Encode { points: rand_f32s(&mut rng, 32) }.encode(),
        Request::Traced {
            hi: rng.next_u64(),
            lo: rng.next_u64(),
            parent: rng.next_u64(),
            inner: Box::new(Request::Ingest {
                points: rand_f32s(&mut rng, 32),
            }),
        }
        .encode(),
        Response::Throttled {
            retry_after_ms: 42,
            message: "rate quota exceeded: 5 requests/s".into(),
        }
        .encode(),
    ];
    let mut stream = Vec::new();
    for f in &frames {
        stream.extend_from_slice(&(f.len() as u32).to_le_bytes());
        stream.extend_from_slice(f);
    }

    // Feed the stream to a Decoder in two chunks cut at `split`, for
    // every split point, and collect the frames it yields.
    let parse_split = |cuts: &[usize]| -> Vec<Vec<u8>> {
        let mut dec = Decoder::new();
        let mut got = Vec::new();
        let mut at = 0usize;
        for &cut in cuts.iter().chain(std::iter::once(&stream.len())) {
            let chunk = &stream[at..cut];
            at = cut;
            let spare = dec.spare(chunk.len().max(1));
            spare[..chunk.len()].copy_from_slice(chunk);
            dec.advance(chunk.len());
            while let Some(frame) = dec.next_frame().unwrap() {
                got.push(frame.to_vec());
            }
        }
        got
    };

    let whole = parse_split(&[]);
    assert_eq!(whole, frames, "one-shot parse must yield the input frames");
    for split in 0..=stream.len() {
        assert_eq!(
            parse_split(&[split]),
            frames,
            "stream split at byte {split} diverged"
        );
    }
    // Random multi-way jitter: many small cuts at once.
    for _ in 0..200 {
        let mut cuts: Vec<usize> =
            (0..rng.usize(12)).map(|_| rng.usize(stream.len() + 1)).collect();
        cuts.sort_unstable();
        assert_eq!(parse_split(&cuts), frames, "cuts {cuts:?} diverged");
    }
    // Leftover partial bytes stay pending, never yield a frame.
    let mut dec = Decoder::new();
    let cut = stream.len() - 3;
    let spare = dec.spare(cut);
    spare[..cut].copy_from_slice(&stream[..cut]);
    dec.advance(cut);
    let mut n = 0;
    while dec.next_frame().unwrap().is_some() {
        n += 1;
    }
    assert_eq!(n, frames.len() - 1, "a partial tail frame must not yield");
    assert!(dec.pending() > 0);
}
