//! Admission control and the event-loop front-end, end-to-end: the
//! acceptance suite for PR "async event-loop server core".
//!
//! Pinned here: a tripped quota answers in-band (`Throttled` with a
//! retry hint) on a connection that stays usable; a brownout sheds
//! ingest while reads keep flowing, and both transitions land in the
//! journal; hundreds of concurrently pipelined connections — far more
//! than the worker pool — all complete with replies byte-identical to a
//! sequential client against the same quiesced service; and shutdown is
//! prompt with idle connections open (the event loop's wake token, not
//! the old throwaway-connection hack).

use std::io::Write;
use std::net::{Shutdown, TcpStream};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::serve::protocol::{
    read_frame, write_frame, MetricsReply, Request, Response,
};
use dalvq::serve::{Client, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A small, fast serving deployment on the native engine.
fn tiny_preset() -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 2;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 4;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.points_per_exchange = 50;
    serve.point_compute = 0.0;
    (cfg, serve)
}

fn start_stack(
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> (Arc<VqService>, Server) {
    let service = VqService::start(cfg, serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    (service, server)
}

/// Block until `f` returns true or `secs` elapse (then panic with `what`).
fn wait_for(secs: u64, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn counter(m: &MetricsReply, name: &str) -> u64 {
    m.counters.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v)
}

fn gauge(m: &MetricsReply, name: &str) -> u64 {
    m.gauges.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v)
}

/// Pipeline `reqs` down one connection and collect every reply in order.
fn burst(client: &mut Client, reqs: &[Request]) -> Vec<Response> {
    for r in reqs {
        client.send(r).unwrap();
    }
    client.flush().unwrap();
    reqs.iter().map(|_| client.recv().unwrap()).collect()
}

/// A rate quota refuses in-band — `Throttled`, retry hint, which quota
/// tripped — and the connection keeps answering afterwards: refusals
/// are admission control, not connection failures.
#[test]
fn rate_quota_answers_throttled_and_the_connection_survives() {
    let _serial = serial();
    let (cfg, mut serve) = tiny_preset();
    serve.rate_limit = 5; // 5 req/s per connection, one-second burst
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    let reqs = vec![Request::Stats; 20];
    let replies = burst(&mut client, &reqs);
    let ok = replies
        .iter()
        .filter(|r| matches!(r, Response::Stats(_)))
        .count();
    let throttled: Vec<_> = replies
        .iter()
        .filter_map(|r| match r {
            Response::Throttled { retry_after_ms, message } => {
                Some((*retry_after_ms, message.clone()))
            }
            _ => None,
        })
        .collect();
    // The bucket opens with one second of budget (5 tokens); refill
    // during a sub-second burst admits at most a couple more.
    assert!(ok >= 5, "only {ok} of 20 admitted at rate 5/s");
    assert!(throttled.len() >= 10, "only {} throttled", throttled.len());
    assert_eq!(ok + throttled.len(), 20);
    let (retry_ms, message) = &throttled[0];
    assert!(*retry_ms >= 1, "retry hint must be at least 1 ms");
    assert!(
        message.contains("rate quota"),
        "throttle reason should name the quota: {message:?}"
    );

    // The bucket refills; the same connection serves again.
    std::thread::sleep(Duration::from_millis(1_100));
    client.stats().expect("connection must survive throttling");

    let m = client.metrics(16).unwrap();
    assert!(
        counter(&m, "conn.rejected") >= throttled.len() as u64,
        "conn.rejected must count every refusal"
    );

    server.shutdown().unwrap();
    service.shutdown().unwrap();
}

/// An in-flight quota caps how deep one connection may pipeline: a
/// burst parsed in one read admits the cap and throttles the rest,
/// and the stream stays in order throughout.
#[test]
fn inflight_quota_throttles_a_pipelined_burst() {
    let _serial = serial();
    let (cfg, mut serve) = tiny_preset();
    serve.max_inflight = 2;
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    let reqs = vec![Request::Stats; 16];
    let replies = burst(&mut client, &reqs);
    let ok = replies
        .iter()
        .filter(|r| matches!(r, Response::Stats(_)))
        .count();
    let throttled: Vec<_> = replies
        .iter()
        .filter_map(|r| match r {
            Response::Throttled { message, .. } => Some(message.clone()),
            _ => None,
        })
        .collect();
    // A 16-frame burst normally lands in one read: 2 admitted (the cap),
    // 14 refused. A racy read split can only admit more, never fewer.
    assert!(ok >= 2, "the in-flight cap itself must be admitted");
    assert!(!throttled.is_empty(), "a 16-deep burst must trip a cap of 2");
    assert_eq!(ok + throttled.len(), 16);
    assert!(
        throttled[0].contains("in-flight quota"),
        "throttle reason should name the quota: {:?}",
        throttled[0]
    );

    // One-at-a-time traffic never trips an in-flight cap of 2.
    for _ in 0..4 {
        client.stats().unwrap();
    }

    server.shutdown().unwrap();
    service.shutdown().unwrap();
}

/// A burst pipelined deeper than the reactor's parse-ahead bound (64
/// frames) must still answer completely. The whole burst is consumed
/// off the socket into the decoder in one or two reads; parsing pauses
/// at the watermark and the socket goes silent, so only the
/// level-triggered re-parse on worker completions can reach the
/// leftover frames — an edge-triggered loop deadlocks here with the
/// client waiting forever for the tail of its replies. The second leg
/// half-closes right after writing: frames the peer pipelined before
/// EOF are still owed answers, then the server hangs up cleanly.
#[test]
fn bursts_deeper_than_parse_ahead_answer_completely() {
    let _serial = serial();
    const BURST: usize = 200; // > PARSE_AHEAD = 64, by a wide margin
    let (cfg, serve) = tiny_preset();
    let (service, server) = start_stack(&cfg, &serve);
    let addr = server.local_addr();

    // One contiguous byte blob of BURST Stats frames (5 bytes each —
    // the whole burst fits one TCP segment and lands in one read).
    let payload = Request::Stats.encode();
    let mut blob = Vec::new();
    for _ in 0..BURST {
        write_frame(&mut blob, &payload).unwrap();
    }

    // Leg 1: write the burst, only then start reading replies. The
    // read timeout turns a reactor deadlock into a loud failure
    // instead of a hung test.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(&blob).unwrap();
    for i in 0..BURST {
        let frame = read_frame(&mut stream)
            .unwrap_or_else(|e| panic!("reply {i} of {BURST}: {e:#}"))
            .unwrap_or_else(|| panic!("server hung up before reply {i}"));
        match Response::decode(&frame).unwrap() {
            Response::Stats(_) => {}
            other => panic!("reply {i}: unexpected {other:?}"),
        }
    }

    // Leg 2: same burst, then an immediate write-side half-close. The
    // peer going quiet must not discard frames it already sent.
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(30))).unwrap();
    stream.write_all(&blob).unwrap();
    stream.shutdown(Shutdown::Write).unwrap();
    for i in 0..BURST {
        let frame = read_frame(&mut stream)
            .unwrap_or_else(|e| panic!("half-close reply {i}: {e:#}"))
            .unwrap_or_else(|| panic!("half-close: hangup before reply {i}"));
        match Response::decode(&frame).unwrap() {
            Response::Stats(_) => {}
            other => panic!("half-close reply {i}: unexpected {other:?}"),
        }
    }
    // Every owed reply arrived; now the server closes its side.
    assert!(
        read_frame(&mut stream).unwrap().is_none(),
        "clean EOF after the last owed reply"
    );

    server.shutdown().unwrap();
    service.shutdown().unwrap();
}

/// Brownout sheds ingest before reads: with the training fleet paused
/// and the queue-depth gauge at the watermark, ingest answers
/// `Throttled` while encode keeps serving; draining the queue restores
/// ingest, and both transitions are journaled.
#[test]
fn brownout_sheds_ingest_before_reads_and_journals_transitions() {
    let _serial = serial();
    let (cfg, mut serve) = tiny_preset();
    serve.start_paused = true; // nothing drains the ingest queues
    serve.ingest_queue = 1_024;
    serve.brownout_depth = 4;
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();
    let batch = [0.5f32, -0.5];

    // Four accepted batches park four entries on the paused queue.
    for i in 0..4 {
        match burst(&mut client, &[Request::Ingest { points: batch.to_vec() }])
            .remove(0)
        {
            Response::IngestAck { .. } => {}
            other => panic!("ingest {i} below the watermark: {other:?}"),
        }
    }
    // The watermark is reached: the next ingest is shed, in-band.
    match burst(&mut client, &[Request::Ingest { points: batch.to_vec() }])
        .remove(0)
    {
        Response::Throttled { retry_after_ms, message } => {
            assert!(retry_after_ms >= 1);
            assert!(
                message.contains("brownout"),
                "shed reason should say brownout: {message:?}"
            );
        }
        other => panic!("ingest at the watermark must shed: {other:?}"),
    }
    // …while the read path keeps answering on the same connection.
    client.encode(&batch).expect("brownout must not shed reads");
    client.stats().expect("brownout must not shed stats");

    // Release the fleet: the queue drains, ingest is restored.
    service.resume();
    wait_for(10, "brownout exit after the queue drains", || {
        matches!(
            burst(&mut client, &[Request::Ingest { points: batch.to_vec() }])
                .remove(0),
            Response::IngestAck { .. }
        )
    });
    let m = client.metrics(64).unwrap();
    let kinds: Vec<&str> = m.events.iter().map(|e| e.kind.as_str()).collect();
    assert!(kinds.contains(&"brownout.enter"), "journal: {kinds:?}");
    assert!(kinds.contains(&"brownout.exit"), "journal: {kinds:?}");

    server.shutdown().unwrap();
    service.shutdown().unwrap();
}

/// Raise the soft fd limit toward the hard one (the 512-connection test
/// needs ~3 fds per connection); returns the resulting soft limit.
#[cfg(target_os = "linux")]
fn raise_fd_limit() -> u64 {
    #[repr(C)]
    struct RLimit {
        cur: u64,
        max: u64,
    }
    extern "C" {
        fn getrlimit(resource: i32, rlim: *mut RLimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const RLimit) -> i32;
    }
    const RLIMIT_NOFILE: i32 = 7;
    unsafe {
        let mut r = RLimit { cur: 0, max: 0 };
        if getrlimit(RLIMIT_NOFILE, &mut r) != 0 {
            return 1_024;
        }
        let want = r.max.min(1 << 16);
        if want > r.cur {
            let bumped = RLimit { cur: want, max: r.max };
            if setrlimit(RLIMIT_NOFILE, &bumped) == 0 {
                return want;
            }
        }
        r.cur
    }
}

#[cfg(not(target_os = "linux"))]
fn raise_fd_limit() -> u64 {
    1_024
}

/// The scale test: hundreds of concurrently *pipelined* connections —
/// far more than the worker pool — against a quiesced service, every
/// reply identical to a sequential client issuing the same requests.
/// Quiescing first freezes the snapshots, so "identical" is exact, not
/// statistical: any reply misordering, cross-connection mixup, or
/// buffer corruption in the event loop shows up as a diff.
#[test]
fn hundreds_of_pipelined_connections_match_a_sequential_client() {
    let _serial = serial();
    let fd_limit = raise_fd_limit();
    // ~3 fds per connection (client stream + its try_clone + the server
    // side) plus generous slack for the harness.
    let connections: usize = if fd_limit >= 2_600 { 512 } else { 64 };
    const WINDOW: usize = 32;

    let (cfg, serve) = tiny_preset();
    let (service, server) = start_stack(&cfg, &serve);
    // Freeze the codebooks: reads now answer from immutable snapshots.
    service.shutdown().unwrap();
    let addr = server.local_addr().to_string();

    // One deterministic request script, shared by every connection.
    let points = cfg.data.mixture.eval_sample(64, cfg.seed);
    let reqs: Arc<Vec<Request>> = Arc::new(
        (0..24)
            .map(|i| {
                let batch =
                    points[(i % 8) * 16..(i % 8) * 16 + 16].to_vec();
                match i % 3 {
                    0 => Request::Encode { points: batch },
                    1 => Request::Nearest { points: batch },
                    _ => Request::Distortion { points: batch },
                }
            })
            .collect(),
    );

    // The oracle: one connection, classic request/reply.
    let mut oracle = Client::connect(addr.as_str()).unwrap();
    let expected: Arc<Vec<String>> = Arc::new(
        reqs.iter()
            .map(|r| {
                oracle.send(r).unwrap();
                oracle.flush().unwrap();
                format!("{:?}", oracle.recv().unwrap())
            })
            .collect(),
    );

    let joins: Vec<_> = (0..connections)
        .map(|c| {
            let addr = addr.clone();
            let reqs = Arc::clone(&reqs);
            let expected = Arc::clone(&expected);
            std::thread::Builder::new()
                .name(format!("dalvq-adm-{c}"))
                .spawn(move || {
                    let mut client = Client::connect(addr.as_str()).unwrap();
                    let (mut sent, mut recvd) = (0usize, 0usize);
                    while recvd < reqs.len() {
                        while sent < reqs.len() && sent - recvd < WINDOW {
                            client.send(&reqs[sent]).unwrap();
                            sent += 1;
                        }
                        client.flush().unwrap();
                        let got = format!("{:?}", client.recv().unwrap());
                        assert_eq!(
                            got, expected[recvd],
                            "conn {c}: reply {recvd} diverged"
                        );
                        recvd += 1;
                    }
                })
                .unwrap()
        })
        .collect();
    for j in joins {
        j.join().expect("pipelined connection panicked");
    }

    let m = oracle.metrics(16).unwrap();
    assert!(
        counter(&m, "conn.accepted") >= connections as u64 + 1,
        "every connection must be accepted"
    );

    server.shutdown().unwrap();
}

/// Shutdown is deterministic with idle connections open: the wake token
/// interrupts the poll — no throwaway self-connection, no waiting out a
/// timeout — and the connection gauges track accepts and hangups.
#[test]
fn shutdown_is_prompt_with_idle_connections_open() {
    let _serial = serial();
    let (cfg, serve) = tiny_preset();
    let (service, server) = start_stack(&cfg, &serve);
    let addr = server.local_addr().to_string();

    let mut clients: Vec<Client> = (0..4)
        .map(|_| Client::connect(addr.as_str()).unwrap())
        .collect();
    for c in clients.iter_mut() {
        c.stats().unwrap();
    }
    let m = clients[0].metrics(16).unwrap();
    assert!(counter(&m, "conn.accepted") >= 4);
    assert!(gauge(&m, "conn.active") >= 4);

    // A hangup is noticed by readiness, not by a read timeout.
    let before = gauge(&m, "conn.active");
    drop(clients.pop());
    wait_for(5, "conn.active to drop after a hangup", || {
        let m = clients[0].metrics(16).unwrap();
        gauge(&m, "conn.active") < before
    });

    let t = Instant::now();
    server.shutdown().unwrap();
    let took = t.elapsed();
    assert!(
        took < Duration::from_secs(3),
        "shutdown with idle connections took {took:?}"
    );
    service.shutdown().unwrap();

    // The remaining clients see a closed connection, not a hang.
    assert!(clients[0].stats().is_err());
}
