//! Checkpoint-shipped read replicas end-to-end: the acceptance suite for
//! leader/follower query scale-out.
//!
//! The paper's final scheme wins on cloud hardware precisely because it
//! drops inter-machine synchronization in favor of asynchronous, delayed
//! state exchange; Patra's companion analysis shows delayed-view
//! consumers of the shared version still converge. Followers are that
//! argument applied to the read tier: a follower restores from a shipped
//! copy of the leader's state dir, serves the full read surface from
//! epoch-swapped snapshots, and re-syncs by polling checkpoint
//! generations — no write-path coordination at all.
//!
//! Pinned here: a follower synced from a quiesced leader answers
//! `nearest` identically (>= 99% agreement, in practice byte-equal); its
//! `sync_lag_folds` stays bounded while the leader trains and ingests
//! continuously, and drains to zero once the leader quiesces; a leader
//! rebalance's bumped `router_version` is adopted without read downtime;
//! and every write aimed at a follower answers `NotLeader` on the wire,
//! which the client follows transparently to the leader while the
//! connection keeps serving reads locally. (Delta shipping, sync trees
//! and failover are pinned separately in `replication_v2_e2e.rs`.)

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::serve::{run_load, Client, LoadSpec, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs / rebalance_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh state directory unique to `tag` (removed first, so reruns of
/// a failed test never see stale state).
fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dalvq-replication-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The standard durable sharded leader of this suite: 4 shards x 4
/// prototypes over a 4-component mixture, one worker per shard, paced
/// gently enough that fold rates are bounded by wall clock (the lag
/// assertions depend on that), checkpointing frequently.
fn leader_cfg(dir: &Path) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 16;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = 4;
    serve.probe_n = 2;
    serve.points_per_exchange = 50;
    // 50 pts * 20 us = 1 ms per fold per shard: fast enough to train in
    // test time, slow enough that a sync cadence of 25 ms keeps lag in
    // the hundreds of folds, never unbounded.
    serve.point_compute = 2e-5;
    serve.ingest_queue = 1_024;
    serve.state_dir = Some(dir.to_path_buf());
    serve.checkpoint_every = 8;
    (cfg, serve)
}

/// A follower of `leader_addr`, polling fast so tests converge quickly.
fn follower_serve(leader_addr: &str, dir: Option<&Path>) -> ServeConfig {
    let mut serve = ServeConfig::default();
    serve.follow = Some(leader_addr.to_string());
    serve.sync_every_ms = 25;
    serve.probe_n = 2;
    serve.state_dir = dir.map(|d| d.to_path_buf());
    serve
}

/// Block until `f` returns true or `secs` elapse (then panic with `what`).
fn wait_for(secs: u64, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// A quiesced leader's follower answers `nearest` identically, reports
/// follower-shaped stats, and (when given its own state dir) mirrors the
/// leader's checkpoint files byte-identically.
#[test]
fn follower_serves_the_leaders_quiesced_state_identically() {
    let _serial = serial();
    let ldir = state_dir("basic-leader");
    let fdir = state_dir("basic-follower");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    // Train: route some load and let folds land, then quiesce. The
    // shutdown's final checkpoint drain makes the state dir carry
    // exactly what the read path serves.
    let eval = cfg.data.mixture.eval_sample(512, cfg.seed);
    lclient.ingest(&eval).unwrap();
    let v0 = leader.version();
    wait_for(30, "leader folds", || leader.version() >= v0 + 20);
    leader.shutdown().unwrap();
    let leader_version = leader.version();

    // The follower bootstraps from the quiesced leader's shipped bundle.
    let fserve = follower_serve(&laddr, Some(&fdir));
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let mut fclient = Client::connect(fsrv.local_addr()).unwrap();

    // Topology adopted from the leader's manifest, not the local config.
    assert_eq!(follower.shards(), 4);
    assert_eq!(follower.kappa(), 16);
    assert_eq!(follower.dim(), 2);
    assert_eq!(follower.version(), leader_version);

    let stats = fclient.stats().unwrap();
    assert_eq!(stats.role, "follower");
    assert_eq!(stats.leader_addr, laddr);
    assert_eq!(stats.workers, 0, "a follower runs no training fleet");
    assert_eq!(stats.shards, 4);
    assert_eq!(stats.sync_lag_folds, 0, "quiesced leader: nothing to lag");

    // The acceptance bar: >= 99% probe-vs-oracle agreement against the
    // leader's quiesced epoch. Identical state + identical router means
    // it is in practice 100%.
    let (lcodes, ldists, lv) = lclient.nearest(&eval).unwrap();
    let (fcodes, fdists, fv) = fclient.nearest(&eval).unwrap();
    assert_eq!(lv, fv, "follower must serve the leader's version");
    let agree = lcodes.iter().zip(&fcodes).filter(|(a, b)| a == b).count();
    assert!(
        agree as f64 >= 0.99 * lcodes.len() as f64,
        "follower agreed on only {agree}/{} lookups",
        lcodes.len()
    );
    for (ld, fd) in ldists.iter().zip(&fdists) {
        assert_eq!(ld, fd, "distances must match on identical state");
    }
    // encode and distortion agree too
    let (lc, _) = lclient.encode(&eval).unwrap();
    let (fc, _) = fclient.encode(&eval).unwrap();
    assert_eq!(lc, fc);
    let (ldist, _) = lclient.distortion(&eval).unwrap();
    let (fdist, _) = fclient.distortion(&eval).unwrap();
    assert_eq!(ldist, fdist);

    // The mirror is byte-identical, file by file: a follower restart (or
    // promotion) warm-starts from exactly the leader's image.
    for entry in std::fs::read_dir(&ldir).unwrap() {
        let name = entry.unwrap().file_name();
        let l = std::fs::read(ldir.join(&name)).unwrap();
        let f = std::fs::read(fdir.join(&name)).unwrap();
        assert_eq!(l, f, "{name:?} differs between leader and mirror");
    }

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
    std::fs::remove_dir_all(&fdir).unwrap();
}

/// Under continuous leader training + ingest, the follower keeps
/// adopting new generations: its served version advances, its lag stays
/// bounded, and once the leader quiesces the lag drains to exactly zero.
#[test]
fn sync_lag_stays_bounded_under_continuous_ingest() {
    let _serial = serial();
    let ldir = state_dir("lag-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let fserve = follower_serve(&laddr, None);
    let follower = VqService::start(&cfg, &fserve).unwrap();

    // Drive ingest while sampling the follower: the served version must
    // keep advancing (multiple generations adopted), and the lag must
    // stay within the envelope the pacing implies. At 1 ms/fold/shard
    // the leader folds <= ~4 folds/ms; a checkpoint lands every 8
    // folds/shard and the follower polls every 25 ms, so thousands of
    // folds of lag would mean the sync loop is broken, not slow.
    let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
    let mut versions_seen = Vec::new();
    let mut max_lag = 0u64;
    let run_until = Instant::now() + Duration::from_secs(3);
    let mut stream_t = 0u64;
    while Instant::now() < run_until {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        let stats = follower.stats();
        assert_eq!(stats.role, "follower");
        max_lag = max_lag.max(stats.sync_lag_folds);
        if versions_seen.last() != Some(&stats.version) {
            versions_seen.push(stats.version);
        }
        // the follower answers reads at every sample point
        let (_, codes, dists) = follower.query_nearest(&eval);
        assert_eq!(codes.len(), 256);
        assert!(dists.iter().all(|d| d.is_finite()));
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(
        versions_seen.len() >= 3,
        "follower never advanced: versions {versions_seen:?}"
    );
    assert!(
        versions_seen.windows(2).all(|w| w[0] < w[1]),
        "follower version went backwards: {versions_seen:?}"
    );
    // Pacing caps folding at ~4 folds/ms, so a wholly broken sync loop
    // would accumulate ~12k folds of lag over the 3 s run; a working one
    // stays in the hundreds (checkpoint cadence + poll cadence), with
    // headroom here for CI scheduling jitter.
    assert!(
        max_lag < 6_000,
        "sync lag {max_lag} folds is out of the pacing envelope"
    );

    // Quiesce the leader: the final checkpoint drain ships everything,
    // so the follower converges to the leader's exact final version and
    // the lag drains to zero.
    leader.shutdown().unwrap();
    let final_version = leader.version();
    wait_for(20, "follower to drain its lag", || {
        let s = follower.stats();
        s.version == final_version && s.sync_lag_folds == 0
    });

    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// A leader rebalance bumps the router epoch; the follower adopts the
/// new partition on its next sync without ever refusing a read, and the
/// requested remap table is a valid permutation.
#[test]
fn follower_adopts_a_leader_rebalance_epoch_bump() {
    let _serial = serial();
    let ldir = state_dir("rebalance-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let fserve = follower_serve(&laddr, None);
    let follower = VqService::start(&cfg, &fserve).unwrap();
    assert_eq!(follower.router_version(), 0);

    // Load gives the retrainer weights; then rebalance with the remap.
    let eval = cfg.data.mixture.eval_sample(512, cfg.seed);
    lclient.ingest(&eval).unwrap();
    let (rv, _moved, shard_versions, remap) =
        lclient.rebalance_full(true).unwrap();
    assert_eq!(rv, 1);
    assert_eq!(shard_versions.len(), 4);
    // the remap is a permutation of the 16 global codes
    assert_eq!(remap.len(), 16);
    let mut sorted = remap.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..16).collect::<Vec<u32>>());

    // The follower adopts the bumped epoch on a sync tick; reads answer
    // at every poll in between (no downtime while the swap replicates).
    wait_for(30, "follower to adopt router epoch 1", || {
        let (_, codes, _) = follower.query_nearest(&eval);
        assert_eq!(codes.len(), 512);
        assert!(codes.iter().all(|&c| (c as usize) < 16));
        follower.router_version() == 1
    });
    let stats = follower.stats();
    assert_eq!(stats.router_version, 1);
    assert_eq!(stats.shards, 4);

    follower.shutdown().unwrap();
    leader.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// Writes aimed at a follower answer `NotLeader` on the wire; the v2
/// client follows the redirect transparently (reconnecting to the named
/// leader and resending), so the caller sees success — and
/// [`Client::redirected_to`] reports where the call actually landed.
/// The in-process service surface still refuses writes outright, and a
/// read-only load run against the follower completes with zero ingest.
#[test]
fn writes_to_a_follower_redirect_to_the_leader() {
    let _serial = serial();
    let ldir = state_dir("notleader-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();

    let fserve = follower_serve(&laddr, None);
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let mut fclient = Client::connect(fsrv.local_addr()).unwrap();

    let eval = cfg.data.mixture.eval_sample(64, cfg.seed);
    // reads answer locally: no redirect happens
    let (codes, _) = fclient.encode(&eval).unwrap();
    assert_eq!(codes.len(), 64);
    assert_eq!(fclient.redirected_to(), None);

    // a write follows the NotLeader redirect to the leader and succeeds
    let (accepted, shed) = fclient.ingest(&eval).unwrap();
    assert_eq!(accepted + shed, 64, "the leader absorbed the batch");
    assert_eq!(
        fclient.redirected_to().as_deref(),
        Some(laddr.as_str()),
        "the redirect landed on the leader"
    );
    // the connection now speaks to the leader; admin writes and state
    // fetches work end-to-end (this follower keeps no mirror, so its
    // FetchState redirects too)
    assert_eq!(fclient.stats().unwrap().role, "leader");
    fclient.checkpoint().unwrap();
    let ship = fclient.fetch_state(0).unwrap();
    assert!(ship.generation > 0, "the leader shipped a cut");
    assert!(!ship.files.is_empty());

    // the in-process surface still refuses outright (redirecting is the
    // wire client's job, not the service's)
    let err = format!("{:#}", follower.ingest(&eval).unwrap_err());
    assert!(err.contains(&laddr), "{err}");
    assert!(follower.checkpoint_now().is_err());
    assert!(follower.rebalance().is_err());

    // a read-only load run completes cleanly against the follower
    let mut spec = LoadSpec::default();
    spec.connections = 4;
    spec.requests_per_conn = 50;
    spec.batch_points = 32;
    spec.ingest_frac = 0.5; // read_only must override this
    spec.read_only = true;
    spec.seed = cfg.seed;
    let report = run_load(
        &fsrv.local_addr().to_string(),
        &spec,
        &cfg.data.mixture,
    )
    .unwrap();
    assert_eq!(report.requests, 4 * 50);
    assert_eq!(report.ops.ingest, 0);
    assert_eq!(
        report.ops.encode + report.ops.nearest + report.ops.distortion,
        4 * 50
    );

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    leader.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}
