//! Telemetry plane end-to-end: the acceptance suite for server-side
//! observability.
//!
//! The paper's whole argument is about where wall-clock time goes —
//! compute vs. routing vs. synchronization — and until now the repo only
//! measured that from the *outside* (the load generator's client-side
//! percentiles). Pinned here: the server measures itself consistently
//! with what clients observe (same nearest-rank percentile definition,
//! so server-side latency digests must sit within the client-side
//! envelope), the fleet journals its lifecycle (sync adoptions on a
//! follower, slow queries over a configured threshold), and the plane is
//! reachable all three ways — the `Metrics` wire op, `StatsReply`'s
//! per-op counters, and `--metrics-file` JSON snapshots.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::serve::protocol::MetricsReply;
use dalvq::serve::{run_load, Client, LoadSpec, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::util::Json;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs / replication_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh scratch directory unique to `tag` (removed first, so reruns
/// of a failed test never see stale state).
fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dalvq-telemetry-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The durable sharded leader of this suite (the replication_e2e shape):
/// 4 shards x 4 prototypes over a 4-component mixture, paced gently,
/// checkpointing frequently.
fn leader_cfg(dir: &Path) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 16;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = 4;
    serve.probe_n = 2;
    serve.points_per_exchange = 50;
    serve.point_compute = 2e-5;
    serve.ingest_queue = 1_024;
    serve.state_dir = Some(dir.to_path_buf());
    serve.checkpoint_every = 8;
    (cfg, serve)
}

/// Block until `f` returns true or `secs` elapse (then panic with `what`).
fn wait_for(secs: u64, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn counter(m: &MetricsReply, name: &str) -> u64 {
    m.counters.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v)
}

fn gauge_names(m: &MetricsReply) -> Vec<&str> {
    m.gauges.iter().map(|(n, _)| n.as_str()).collect()
}

/// The acceptance scenario: a follower's telemetry plane — reached
/// through the `Metrics` wire op on the follower itself — reports its
/// `sync.lag_folds` gauge and journals every checkpoint-generation
/// adoption, while the leader's plane journals the `state.ship` cuts and
/// `checkpoint.flush`es that fed it.
#[test]
fn follower_reports_sync_adoptions_and_lag_through_the_metrics_op() {
    let _serial = serial();
    let ldir = state_dir("sync-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let mut fserve = ServeConfig::default();
    fserve.follow = Some(laddr.clone());
    fserve.sync_every_ms = 25;
    fserve.probe_n = 2;
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let mut fclient = Client::connect(fsrv.local_addr()).unwrap();

    // The bootstrap restore is itself a journaled adoption, so the
    // follower's plane is never empty.
    let m = fclient.metrics(64).unwrap();
    assert!(
        m.events.iter().any(|e| e.kind == "sync.adopt"),
        "bootstrap adoption missing from {:?}",
        m.events
    );

    // Drive leader training until the follower adopts a *new* generation
    // (a second sync.adopt event beyond the bootstrap one) — and that
    // steady-state adoption must arrive as a delta, not a full refetch.
    let v0 = follower.version();
    let mut stream_t = 0u64;
    wait_for(30, "a post-bootstrap delta adoption", || {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        follower.version() > v0
            && fclient.metrics(64).unwrap().events.iter().any(|e| {
                e.kind == "sync.adopt" && e.message.contains("via delta")
            })
    });

    let m = fclient.metrics(64).unwrap();
    let adoptions =
        m.events.iter().filter(|e| e.kind == "sync.adopt").count();
    assert!(adoptions >= 2, "only {adoptions} adoption(s) in {:?}", m.events);
    // every adoption is info-leveled and says what it closed
    for e in m.events.iter().filter(|e| e.kind == "sync.adopt") {
        assert_eq!(e.level, 0, "{e:?}");
        assert!(e.message.contains("generation"), "{e:?}");
    }
    // the lag gauge is part of the same snapshot the Stats surface reports
    assert!(
        gauge_names(&m).contains(&"sync.lag_folds"),
        "no sync.lag_folds gauge in {:?}",
        m.gauges
    );
    assert!(m.uptime_ms > 0);

    // The sync tier accounts its wire bytes by source. The bootstrap
    // restore was a full bundle; the steady-state adoptions above were
    // deltas — and a delta sync must move strictly fewer bytes per
    // adoption than a full one (the whole point of shipping deltas).
    let delta_bytes = counter(&m, "sync.delta_bytes");
    let full_bytes = counter(&m, "sync.full_bytes");
    assert!(delta_bytes > 0, "no delta bytes accounted in {:?}", m.counters);
    assert!(full_bytes > 0, "the bootstrap full fetch went unaccounted");
    let deltas = m
        .events
        .iter()
        .filter(|e| e.kind == "sync.adopt" && e.message.contains("via delta"))
        .count() as u64;
    let fulls = 1 + m
        .events
        .iter()
        .filter(|e| e.kind == "sync.adopt" && e.message.contains("via full"))
        .count() as u64; // the bootstrap restore + any forced refetches
    assert!(
        delta_bytes / deltas < full_bytes / fulls,
        "a delta sync ({delta_bytes} B / {deltas}) must move fewer bytes \
         than a full one ({full_bytes} B / {fulls})"
    );
    // a healthy follower never promotes itself
    assert_eq!(counter(&m, "failover.promotions"), 0, "{:?}", m.counters);

    // The Stats surface tells the same story: the last adoption arrived
    // as a delta on the follower, while the leader (which never syncs)
    // reports no source at all.
    assert_eq!(fclient.stats().unwrap().sync_source, "delta");
    assert_eq!(lclient.stats().unwrap().sync_source, "");

    // The leader's plane journals the producer side of the same story:
    // checkpoint flushes and the state bundles it shipped to the follower.
    let lm = lclient.metrics(64).unwrap();
    assert!(
        lm.events.iter().any(|e| e.kind == "checkpoint.flush"),
        "no checkpoint.flush in {:?}",
        lm.events
    );
    assert!(
        lm.events.iter().any(|e| e.kind == "state.ship"),
        "no state.ship in {:?}",
        lm.events
    );

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    leader.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// Server-side per-op accounting agrees with the load generator's
/// client-side view: request counters match the driven op mix exactly
/// (on the Metrics surface *and* the StatsReply tail), and the
/// server-side latency digest sits inside the client-side envelope —
/// a handler cannot take longer than the slowest round trip.
#[test]
fn server_side_latency_digest_sits_inside_the_loadgen_envelope() {
    let _serial = serial();
    let ldir = state_dir("loadgen");
    let (cfg, serve) = leader_cfg(&ldir);
    let service = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    let addr = server.local_addr().to_string();

    let mut spec = LoadSpec::default();
    spec.connections = 4;
    spec.requests_per_conn = 50;
    spec.batch_points = 32;
    spec.ingest_frac = 0.25;
    spec.seed = cfg.seed;
    let report = run_load(&addr, &spec, &cfg.data.mixture).unwrap();
    assert_eq!(report.requests, 4 * 50);

    let mut client = Client::connect(addr.as_str()).unwrap();
    let m = client.metrics(0).unwrap();

    // Per-op counters match the workload exactly — nothing else drove
    // the query ops.
    assert_eq!(counter(&m, "op.encode.requests"), report.ops.encode);
    assert_eq!(counter(&m, "op.nearest.requests"), report.ops.nearest);
    assert_eq!(counter(&m, "op.distortion.requests"), report.ops.distortion);
    assert_eq!(counter(&m, "op.ingest.requests"), report.ops.ingest);

    // ...and the StatsReply tail carries the same counts.
    let stats = client.stats().unwrap();
    assert_eq!(stats.op_encode, report.ops.encode);
    assert_eq!(stats.op_nearest, report.ops.nearest);
    assert_eq!(stats.op_distortion, report.ops.distortion);
    assert_eq!(stats.op_ingest, report.ops.ingest);
    assert_eq!(
        stats.op_encode + stats.op_nearest + stats.op_distortion,
        stats.queries,
        "read ops and the query counter must agree"
    );
    assert!(stats.uptime_ms > 0);

    // The server-side digest is per-op and excludes framing + network,
    // so no op's p99 may exceed the slowest client-observed round trip
    // (plus the histogram's <= 6.25% bucket quantization and a little
    // scheduling slack).
    let bound = report.max_us * 1.25 + 500.0;
    for op in ["encode", "nearest", "distortion", "ingest"] {
        let name = format!("op.{op}.total_us");
        let h = m
            .hists
            .iter()
            .find(|h| h.name == name)
            .unwrap_or_else(|| panic!("no {name} digest"));
        assert_eq!(h.count, counter(&m, &format!("op.{op}.requests")));
        assert!(
            h.p99_us <= bound,
            "{name} p99 {} us outruns the client envelope {} us",
            h.p99_us,
            bound
        );
        assert!(h.p50_us <= h.p95_us && h.p95_us <= h.p99_us, "{name}");
        assert!(h.p99_us <= h.max_us, "{name}: digest clamps to the max");
    }
    // the stage digests cover the same requests: every routed read
    // recorded a route and a scan sample
    let reads = report.ops.encode + report.ops.nearest + report.ops.distortion;
    for stage in ["query.route_us", "query.scan_us"] {
        let h = m.hists.iter().find(|h| h.name == stage).unwrap();
        assert_eq!(h.count, reads, "{stage}");
    }

    server.shutdown().unwrap();
    service.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// With `slow_query_us` armed at 1 µs, every query is "slow": the
/// counter climbs and the journal carries warn-leveled events naming the
/// op with its route/scan stage breakdown.
#[test]
fn slow_query_log_journals_over_threshold_requests() {
    let _serial = serial();
    let ldir = state_dir("slow-query");
    let (cfg, mut serve) = leader_cfg(&ldir);
    serve.slow_query_us = 1;
    let service = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    let eval = cfg.data.mixture.eval_sample(512, cfg.seed);
    let (codes, _, _) = client.nearest(&eval).unwrap();
    assert_eq!(codes.len(), 512);

    let m = client.metrics(64).unwrap();
    assert!(counter(&m, "slow_queries") >= 1, "{:?}", m.counters);
    let slow: Vec<_> =
        m.events.iter().filter(|e| e.kind == "slow_query").collect();
    assert!(!slow.is_empty(), "no slow_query events in {:?}", m.events);
    let e = slow
        .iter()
        .find(|e| e.message.starts_with("nearest"))
        .unwrap_or_else(|| panic!("no nearest slow_query in {slow:?}"));
    assert_eq!(e.level, 1, "slow queries are warn-leveled: {e:?}");
    assert!(e.message.contains("threshold 1 us"), "{e:?}");
    // reads carry the stage breakdown
    assert!(e.message.contains("route"), "{e:?}");
    assert!(e.message.contains("scan"), "{e:?}");

    server.shutdown().unwrap();
    service.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// `--metrics-file` snapshots parse as JSON with live per-op counters,
/// both mid-run (periodic writes) and after shutdown (the final write).
#[test]
fn metrics_file_snapshots_parse_with_live_counters() {
    let _serial = serial();
    let dir = state_dir("metrics-file");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("metrics.json");

    let (cfg, mut serve) = leader_cfg(&dir.join("state"));
    serve.metrics_file = Some(path.clone());
    serve.metrics_every_ms = 50;
    let service = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    let eval = cfg.data.mixture.eval_sample(128, cfg.seed);
    client.nearest(&eval).unwrap();
    client.ingest(&eval).unwrap();

    // A periodic snapshot lands with the driven counters. Snapshots are
    // written atomically (temp + fsync + rename), so any file a reader
    // sees is a COMPLETE document: a parse failure here is a writer bug,
    // never a benign race — panic, don't retry.
    let nearest_count = |path: &Path| -> Option<u64> {
        if !path.exists() {
            return None; // first snapshot not due yet
        }
        let text = std::fs::read_to_string(path).unwrap();
        let doc = Json::parse(&text).unwrap_or_else(|e| {
            panic!("snapshot must parse (atomic writes): {e:#}\n{text}")
        });
        Some(
            doc.req("counters")
                .unwrap()
                .req("op.nearest.requests")
                .unwrap()
                .as_u64()
                .unwrap(),
        )
    };
    wait_for(15, "a periodic snapshot with the driven counters", || {
        nearest_count(&path).is_some_and(|n| n >= 1)
    });

    server.shutdown().unwrap();
    service.shutdown().unwrap();

    // The shutdown path wrote one final snapshot; it parses and carries
    // the full document shape.
    let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
    assert!(doc.req("uptime_ms").unwrap().as_u64().unwrap() > 0);
    let counters = doc.req("counters").unwrap();
    assert!(counters.req("op.nearest.requests").unwrap().as_u64().unwrap() >= 1);
    assert!(counters.req("op.ingest.requests").unwrap().as_u64().unwrap() >= 1);
    let h = doc.req("histograms").unwrap().req("op.nearest.total_us").unwrap();
    assert!(h.req("count").unwrap().as_u64().unwrap() >= 1);
    assert!(h.req("p99_us").unwrap().as_f64().unwrap() > 0.0);
    doc.req("gauges").unwrap();
    doc.req("events").unwrap().as_arr().unwrap();

    std::fs::remove_dir_all(&dir).unwrap();
}
