//! End-to-end serving: the full stack (fleet + reducer + snapshot store +
//! TCP server + client) in one process, on the native engine.
//!
//! The headline test ingests a *drifted* mixture stream and asserts the
//! served codebook tracks it: distortion of drifted-sample queries must
//! fall well below its pre-drift value. Assertions are poll-based with
//! generous deadlines (the fleet runs real threads), never timing-exact.

use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{presets, ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::data::MixtureSpec;
use dalvq::serve::{Client, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// cloud_integration.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A small, fast serving deployment on the native engine.
fn tiny_preset() -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 2;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 4;
    // Constant step: the serving fleet must keep tracking drift. Stay
    // inside the delta-merge stability envelope (Schedule docs):
    // M*window*eps/kappa = 2*50*0.02/4 = 0.5.
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.points_per_exchange = 50;
    // free-running training: drift absorption in well under a second
    serve.point_compute = 0.0;
    (cfg, serve)
}

fn start_stack(
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> (Arc<VqService>, Server) {
    let service = VqService::start(cfg, serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    (service, server)
}

fn stop_stack(service: Arc<VqService>, server: Server) {
    server.shutdown().unwrap();
    service.shutdown().unwrap();
}

/// Shift a flat point buffer by a constant offset per coordinate — a
/// deterministic, unambiguous distribution drift.
fn shifted(points: &[f32], offset: f32) -> Vec<f32> {
    points.iter().map(|x| x + offset).collect()
}

/// The acceptance-criteria test: ingest a drifting mixture stream and
/// watch queries reflect the drift.
#[test]
fn ingested_drift_reaches_the_query_path() {
    let _serial = serial();
    let (cfg, serve) = tiny_preset();
    // The drifted world: the same mixture translated far outside the
    // original support (centers live in [-5, 5]^2; +20 per coordinate is
    // unambiguously elsewhere). Deterministic geometry, no seed luck.
    const DRIFT: f32 = 20.0;
    let drifted: MixtureSpec = cfg.data.mixture.clone();
    let drift_eval = shifted(&drifted.eval_sample(512, cfg.seed), DRIFT);

    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    // Pre-drift: the codebook fits the original mixture, so the drifted
    // sample sits ~DRIFT away from every prototype.
    let (c_before, _v) = client.distortion(&drift_eval).unwrap();
    assert!(
        c_before > 100.0,
        "drifted sample must start far from the codebook, got C = {c_before}"
    );

    // Stream drifted points in; the workers' sliding windows fill with
    // them (2k points per worker window), so within a few window
    // turnovers the fleet is training on the drifted world only.
    let deadline = Instant::now() + Duration::from_secs(30);
    let mut stream_t = 0u64;
    let mut c_now = c_before;
    while c_now > c_before * 0.1 {
        assert!(
            Instant::now() < deadline,
            "drift never reached the query path: C {c_before:.4} -> {c_now:.4}"
        );
        for _ in 0..20 {
            let batch =
                shifted(&drifted.generate(128, cfg.seed, 2 + stream_t), DRIFT);
            stream_t += 1;
            client.ingest(&batch).unwrap();
        }
        std::thread::sleep(Duration::from_millis(20));
        let (c, _v) = client.distortion(&drift_eval).unwrap();
        c_now = c;
    }
    // Queries answer from a published epoch, and codes are in range.
    let (codes, version) = client.encode(&drift_eval).unwrap();
    assert_eq!(codes.len(), 512);
    assert!(codes.iter().all(|&c| (c as usize) < cfg.vq.kappa));
    assert!(version > 0, "queries should see a trained epoch");

    let stats = client.stats().unwrap();
    assert!(stats.ingested > 0);
    assert_eq!(stats.dim as usize, cfg.dim());
    assert_eq!(stats.workers as usize, cfg.m);
    assert!(stats.queries >= 2, "distortion queries must be counted");

    stop_stack(service, server);
}

/// Nearest / encode / distortion agree with each other and with local math.
#[test]
fn query_surface_is_self_consistent() {
    let _serial = serial();
    let (cfg, serve) = tiny_preset();
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    let pts = cfg.data.mixture.eval_sample(64, cfg.seed);
    let (codes, _) = client.encode(&pts).unwrap();
    let (indices, dists, _) = client.nearest(&pts).unwrap();
    let (c_mean, _) = client.distortion(&pts).unwrap();
    assert_eq!(codes.len(), 64);
    // encode and nearest may answer from different epochs under live
    // training, but each must be internally consistent
    assert_eq!(indices.len(), 64);
    assert_eq!(dists.len(), 64);
    assert!(dists.iter().all(|d| d.is_finite() && *d >= 0.0));
    assert!(c_mean.is_finite() && c_mean >= 0.0);
    // the service's own snapshot agrees with the remote answer shape
    let snap = service.snapshot();
    assert_eq!(snap.codebook.kappa(), cfg.vq.kappa);
    assert_eq!(snap.codebook.dim(), cfg.dim());

    stop_stack(service, server);
}

/// Protocol-level errors: wrong dimensionality must come back as a clean
/// error response, not a dropped connection.
#[test]
fn dimension_mismatch_is_a_clean_error() {
    let _serial = serial();
    let (cfg, serve) = tiny_preset();
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    // dim = 2; send 3 floats
    let err = client.encode(&[1.0, 2.0, 3.0]).unwrap_err();
    assert!(format!("{err:#}").contains("dim"), "{err:#}");
    // the connection survives the error
    let (codes, _) = client.encode(&[1.0, 2.0]).unwrap();
    assert_eq!(codes.len(), 1);

    stop_stack(service, server);
}

/// A sharded deployment of the tiny stack: 4 codebook shards of 2
/// prototypes each behind the coarse-quantizer router, probe width 2.
fn sharded_preset() -> (ExperimentConfig, ServeConfig) {
    let (mut cfg, mut serve) = tiny_preset();
    cfg.m = 1; // one worker per shard (4 worker threads total)
    cfg.vq.kappa = 8;
    serve.shards = 4;
    serve.probe_n = 2;
    (cfg, serve)
}

/// Acceptance criterion, half 1 — **probe oracle**: with `S = 4`, routed
/// nearest-centroid lookups at `probe_n = 2` must agree with the
/// `S = 1`-equivalent oracle (`probe_n = S`: an exhaustive scan of the
/// same global codebook, exactly what a single-shard service computes) on
/// at least 99% of points.
///
/// The fleet is quiesced first (shutdown publishes each shard's final
/// epoch; the read path stays up by design), so routed and oracle answers
/// come from the identical frozen codebooks.
#[test]
fn sharded_probe_agrees_with_single_shard_oracle() {
    let _serial = serial();
    let (cfg, serve) = sharded_preset();
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    let stats = client.stats().unwrap();
    assert_eq!(stats.shards, 4);
    assert_eq!(stats.probe_n, 2);
    assert_eq!(stats.kappa, 8);
    assert_eq!(stats.shard_versions.len(), 4);

    // let every shard fleet publish at least one trained epoch
    let deadline = Instant::now() + Duration::from_secs(15);
    while service.shard_versions().iter().any(|&v| v == 0) {
        assert!(Instant::now() < deadline, "some shard never published");
        std::thread::sleep(Duration::from_millis(10));
    }
    // Quiesce: joins the fleets and publishes final epochs; queries keep
    // answering from those.
    service.shutdown().unwrap();

    let probe_pts = cfg.data.mixture.eval_sample(2_000, cfg.seed);
    let (_, routed, routed_d) = service.query_nearest_probed(&probe_pts, 2);
    let (_, oracle, oracle_d) = service.query_nearest_probed(&probe_pts, 4);
    assert_eq!(routed.len(), 2_000);
    let agree = routed.iter().zip(&oracle).filter(|(a, b)| a == b).count();
    assert!(
        agree as f64 >= 0.99 * routed.len() as f64,
        "probe_n=2 agreed with the full-scan oracle on only {agree}/{} lookups",
        routed.len()
    );
    // where they disagree the oracle can only be strictly better
    for (dr, do_) in routed_d.iter().zip(&oracle_d) {
        assert!(do_ <= dr, "oracle distance {do_} worse than routed {dr}");
    }
    // the wire path answers with global codes over the full kappa range,
    // from the same frozen epochs
    let (codes, _) = client.encode(&probe_pts).unwrap();
    assert_eq!(codes.len(), 2_000);
    assert!(codes.iter().all(|&c| (c as usize) < cfg.vq.kappa));

    server.shutdown().unwrap();
}

/// Acceptance criterion, half 2 — **sharded drift**: a drifted ingest
/// stream routed through the coarse quantizer still reaches the owning
/// shard's fleet, and routed distortion queries watch it converge.
#[test]
fn sharded_ingest_drift_reaches_the_query_path() {
    let _serial = serial();
    let (cfg, serve) = sharded_preset();
    let (service, server) = start_stack(&cfg, &serve);
    let mut client = Client::connect(server.local_addr()).unwrap();

    // The drifted world lives far outside every coarse cell, so the
    // router sends the whole stream to one shard — that fleet's 2
    // prototypes must absorb it while the other 3 shards stay put.
    const DRIFT: f32 = 20.0;
    let drift_eval = shifted(&cfg.data.mixture.eval_sample(512, cfg.seed), DRIFT);
    let (c_before, _) = client.distortion(&drift_eval).unwrap();
    assert!(c_before > 100.0, "drifted sample must start far away: {c_before}");

    let deadline = Instant::now() + Duration::from_secs(30);
    let mut stream_t = 0u64;
    let mut c_now = c_before;
    while c_now > c_before * 0.2 {
        assert!(
            Instant::now() < deadline,
            "sharded drift never converged: C {c_before:.2} -> {c_now:.2}"
        );
        for _ in 0..20 {
            let batch =
                shifted(&cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t), DRIFT);
            stream_t += 1;
            client.ingest(&batch).unwrap();
        }
        std::thread::sleep(Duration::from_millis(20));
        let (c, _) = client.distortion(&drift_eval).unwrap();
        c_now = c;
    }

    let stats = client.stats().unwrap();
    assert!(stats.ingested > 0);
    assert!(stats.shard_merges.iter().sum::<u64>() > 0);
    assert_eq!(stats.shard_merges.len(), 4);

    stop_stack(service, server);
}

/// The shipped `serve` preset stands up, answers, and shuts down — the
/// exact stack `dalvq loadtest --preset serve` drives.
#[test]
fn serve_preset_end_to_end_with_loadgen() {
    let _serial = serial();
    let p = presets::serve();
    let service = VqService::start(&p.base, &p.serve).unwrap();
    let server = Server::start(Arc::clone(&service), &p.serve.addr).unwrap();
    let addr = server.local_addr().to_string();

    let spec = dalvq::serve::LoadSpec {
        connections: 4,
        requests_per_conn: 50,
        batch_points: 32,
        pipeline: 1,
        ingest_frac: 0.25,
        skew: 0.0,
        read_only: false,
        trace: false,
        seed: p.base.seed,
    };
    let report = dalvq::serve::run_load(&addr, &spec, &p.base.data.mixture).unwrap();
    assert_eq!(report.requests, 4 * 50);
    assert!(report.throughput_rps > 0.0);
    assert!(report.p50_us > 0.0 && report.p50_us <= report.p99_us);
    assert!(report.ops.ingest > 0, "mixed workload must include ingest");
    assert!(
        report.ops.encode + report.ops.nearest + report.ops.distortion > 0,
        "mixed workload must include reads"
    );
    assert!(!report.format().is_empty());

    server.shutdown().unwrap();
    let out = service.shutdown().unwrap();
    assert!(out.merges > 0, "the fleet must have trained during the load run");
}
