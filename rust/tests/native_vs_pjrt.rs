//! Engine duality: the PJRT execution of the AOT Pallas artifacts must
//! agree with the bit-mirrored native engine to float tolerance — this is
//! what licenses running the big sweeps natively while claiming the
//! artifact path is the system under test.
//!
//! Requires `artifacts/` (run `make artifacts`); each test skips with a
//! note when artifacts are absent so `cargo test` works pre-AOT. The whole
//! file additionally compiles only with the `pjrt` feature — the default
//! std-only build carries no XLA runtime to compare against.

#![cfg(feature = "pjrt")]

use std::path::{Path, PathBuf};

use dalvq::data::MixtureSpec;
use dalvq::runtime::{Engine, NativeEngine, PjrtEngine};
use dalvq::vq::{Codebook, Delta, Schedule};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let manifest = dir.join("manifest.json");
    if manifest.exists() {
        Some(dir)
    } else {
        // Loud, greppable, and names the exact missing path — CI surfaces
        // this line so a silently-skipped engine comparison can't read as
        // a passing one.
        eprintln!(
            "SKIPPED native_vs_pjrt: {} not found — run `make artifacts` \
             to lower the Pallas kernels before comparing engines",
            manifest.display()
        );
        None
    }
}

fn fixture(kappa: usize, dim: usize, n: usize) -> (Codebook, Vec<f32>) {
    let spec = MixtureSpec {
        components: kappa,
        dim,
        separation: 4.0,
        std: 0.5,
        imbalance: 0.3,
        noise_frac: 0.05,
    };
    let points = spec.generate(n, 42, 0);
    let w0 = Codebook::from_flat(kappa, dim, points[..kappa * dim].to_vec());
    (w0, points)
}

#[test]
fn vq_chunk_trajectories_agree_over_long_walks() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir, "k16d16").unwrap();
    let mut native = NativeEngine::new();
    let (w0, points) = fixture(16, 16, 5_000);
    let tau = pjrt.params().tau;
    let schedule = Schedule::paper_default();

    let mut w_p = w0.clone();
    let mut w_n = w0.clone();
    let mut d_p = Delta::zeros(16, 16);
    let mut d_n = Delta::zeros(16, 16);
    let mut eps = vec![0.0f32; tau];
    // 500 chunks = 5000 sequential steps through both engines
    for c in 0..500u64 {
        let start = (c as usize * tau * 16) % (points.len() - tau * 16);
        let chunk = &points[start..start + tau * 16];
        schedule.fill(c * tau as u64, &mut eps);
        pjrt.vq_chunk(&mut w_p, chunk, &eps, &mut d_p).unwrap();
        native.vq_chunk(&mut w_n, chunk, &eps, &mut d_n).unwrap();
    }
    let diff = w_p.max_abs_diff(&w_n);
    assert!(diff < 1e-4, "codebooks diverged: max abs diff {diff}");
    let ddiff = d_p.max_abs_diff(&d_n);
    assert!(ddiff < 1e-3, "deltas diverged: max abs diff {ddiff}");
}

#[test]
fn distortion_sums_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir, "k16d16").unwrap();
    let mut native = NativeEngine::new();
    // 2.5 batches: exercises both the artifact path and the remainder path
    let (w0, points) = fixture(16, 16, 2_560);
    let a = pjrt.distortion_sum(&w0, &points).unwrap();
    let b = native.distortion_sum(&w0, &points).unwrap();
    let rel = (a - b).abs() / b.abs().max(1e-9);
    assert!(rel < 1e-4, "distortion mismatch: pjrt {a} vs native {b}");
}

#[test]
fn nearest_chunks_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir, "k16d16").unwrap();
    let mut native = NativeEngine::new();
    // 2.5 batches: artifact path plus the native remainder path
    let (w0, points) = fixture(16, 16, 2_560);
    let (cp, dp) = match pjrt.nearest_chunk(&w0, &points) {
        Ok(out) => out,
        Err(e) => {
            eprintln!(
                "SKIPPED nearest_chunks_agree: {e:#} (artifact predates the \
                 batched read path — re-run `make artifacts`)"
            );
            return;
        }
    };
    let (cn, dn) = native.nearest_chunk(&w0, &points).unwrap();
    assert_eq!(cp, cn, "nearest codes disagree across engines");
    for (i, (a, b)) in dp.iter().zip(&dn).enumerate() {
        let rel = (a - b).abs() / b.abs().max(1e-9);
        assert!(rel < 1e-4, "dist {i}: pjrt {a} vs native {b}");
    }
}

#[test]
fn kmeans_steps_agree() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir, "k16d16").unwrap();
    let mut native = NativeEngine::new();
    let (w0, points) = fixture(16, 16, 1_024);
    let mut w_p = w0.clone();
    let mut w_n = w0.clone();
    let c_p = pjrt.kmeans_step(&mut w_p, &points).unwrap();
    let c_n = native.kmeans_step(&mut w_n, &points).unwrap();
    assert_eq!(c_p, c_n, "assignment counts differ");
    let diff = w_p.max_abs_diff(&w_n);
    assert!(diff < 1e-4, "centroids differ: {diff}");
}

#[test]
fn multi_chunk_matches_repeated_vq_chunk() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir, "k16d16").unwrap();
    let (w0, points) = fixture(16, 16, 2_000);
    let (s, tau) = (pjrt.params().scan_chunks, pjrt.params().tau);
    let steps = s * tau;
    let schedule = Schedule::paper_default();
    let mut eps_all = vec![0.0f32; steps];
    schedule.fill(0, &mut eps_all);
    let chunks = &points[..steps * 16];

    let mut w_scan = w0.clone();
    let mut d_scan = Delta::zeros(16, 16);
    pjrt.multi_chunk(&mut w_scan, chunks, &eps_all, &mut d_scan).unwrap();

    let mut w_loop = w0.clone();
    let mut d_loop = Delta::zeros(16, 16);
    for c in 0..s {
        let z = &chunks[c * tau * 16..(c + 1) * tau * 16];
        let e = &eps_all[c * tau..(c + 1) * tau];
        pjrt.vq_chunk(&mut w_loop, z, e, &mut d_loop).unwrap();
    }
    assert!(w_scan.max_abs_diff(&w_loop) < 1e-5);
    assert!(d_scan.max_abs_diff(&d_loop) < 1e-5);
    // delta identity holds through the scanned path too
    let mut w_check = w0.clone();
    w_check.apply_delta(&d_scan);
    assert!(w_check.max_abs_diff(&w_scan) < 1e-5);
}

#[test]
fn all_variants_load_and_execute() {
    let Some(dir) = artifacts_dir() else { return };
    let manifest = dalvq::runtime::Manifest::load(&dir).unwrap();
    for (name, vm) in &manifest.variants {
        let mut engine = PjrtEngine::load(&dir, name).unwrap();
        let p = vm.params.clone();
        let (w0, points) = fixture(p.kappa, p.dim, p.eval_batch.max(p.tau * 2));
        let mut w = w0.clone();
        let mut delta = Delta::zeros(p.kappa, p.dim);
        let eps = vec![0.01f32; p.tau];
        engine
            .vq_chunk(&mut w, &points[..p.tau * p.dim], &eps, &mut delta)
            .unwrap_or_else(|e| panic!("variant {name}: vq_chunk failed: {e}"));
        assert!(w.is_finite(), "variant {name} produced non-finite codebook");
        let c = engine
            .distortion_sum(&w0, &points[..p.eval_batch * p.dim])
            .unwrap_or_else(|e| panic!("variant {name}: distortion failed: {e}"));
        assert!(c >= 0.0 && c.is_finite(), "variant {name}: bad distortion {c}");
    }
}

#[test]
fn pjrt_rejects_shape_mismatches() {
    let Some(dir) = artifacts_dir() else { return };
    let mut pjrt = PjrtEngine::load(&dir, "k16d16").unwrap();
    let (w0, points) = fixture(16, 16, 100);
    // wrong tau
    let mut w = w0.clone();
    let mut delta = Delta::zeros(16, 16);
    let eps = vec![0.01f32; 7];
    assert!(pjrt.vq_chunk(&mut w, &points[..7 * 16], &eps, &mut delta).is_err());
    // wrong codebook shape
    let mut w_bad = Codebook::zeros(8, 16);
    let eps = vec![0.01f32; 10];
    assert!(pjrt
        .vq_chunk(&mut w_bad, &points[..10 * 16], &eps, &mut delta)
        .is_err());
    // unknown variant
    assert!(PjrtEngine::load(&dir, "nope").is_err());
}
