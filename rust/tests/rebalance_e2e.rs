//! Live shard rebalancing end-to-end: the acceptance suite for versioned
//! router epochs.
//!
//! The headline scenario is the regime PR 2's drift e2e demonstrated
//! breaks the frozen router: a drifted ingest stream lands entirely in
//! one coarse cell, so one shard's fleet absorbs the whole write load
//! while the other `S - 1` idle. Here the skew monitor notices
//! (max/mean per-shard ingest), auto-triggers an online rebalance —
//! checkpoint, offline ingest-weighted router retrain, prototype-row
//! migration, fleet restart at a bumped router version — and ingest
//! balance is restored below 1.5x max/mean while queries keep answering
//! throughout (old epoch serves until the new one publishes).
//!
//! Also pinned: probe-vs-oracle agreement >= 99% on the quiesced
//! post-rebalance epoch, the `Rebalance` wire op, the frozen-router
//! control (no monitor: skew stays ~S), and kill + warm restart resuming
//! the post-rebalance partition at the bumped router version.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::persist;
use dalvq::serve::{max_over_mean, Client, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh state directory unique to `tag` (removed first, so reruns of a
/// failed test never see stale state).
fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dalvq-rebalance-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// A sharded durable deployment built to expose the frozen-router
/// pathology: 4 shards x 4 prototypes over a 4-component mixture, free
/// running so drift absorption and folds happen in milliseconds.
fn rebalance_cfg(dir: &Path, skew: f64) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1; // one worker per shard
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 16; // 4 prototypes per shard
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = 4;
    serve.probe_n = 2;
    serve.points_per_exchange = 50;
    serve.point_compute = 0.0; // free running
    serve.ingest_queue = 1_024;
    serve.state_dir = Some(dir.to_path_buf());
    serve.checkpoint_every = 16;
    serve.rebalance_skew = skew;
    // The retrain weights rows by observed load, so the shard codebooks
    // must have actually trained on it first: ~100 folds/shard between
    // epoch start and the earliest trigger.
    serve.rebalance_min_folds = 400;
    (cfg, serve)
}

/// Shift a flat point buffer by a constant per coordinate — the
/// deterministic drift of the serve_e2e suite. +20 puts the stream far
/// outside every bootstrap coarse cell (centers live in [-5, 5]^2), so a
/// frozen router sends ALL of it to one shard.
fn shifted(points: &[f32], offset: f32) -> Vec<f32> {
    points.iter().map(|x| x + offset).collect()
}

const DRIFT: f32 = 20.0;

/// Control: with the monitor off, the frozen router piles the whole
/// drifted stream onto one shard — max/mean ingest goes to ~S and stays
/// there. This is the "unbounded skew" half of the acceptance criterion.
#[test]
fn frozen_router_skew_is_unbounded_under_drift() {
    let _serial = serial();
    let dir = state_dir("frozen");
    let (cfg, serve) = rebalance_cfg(&dir, 0.0); // monitor off
    let svc = VqService::start(&cfg, &serve).unwrap();

    let mut stream_t = 0u64;
    let mut accepted = 0u64;
    let deadline = Instant::now() + Duration::from_secs(30);
    while accepted < 5_000 {
        assert!(Instant::now() < deadline, "ingest never reached 5k points");
        let batch = shifted(&cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t), DRIFT);
        stream_t += 1;
        let (acc, _shed) = svc.ingest(&batch).unwrap();
        accepted += acc;
    }
    let stats = svc.stats();
    assert_eq!(stats.router_version, 0, "nothing may rebalance here");
    assert_eq!(stats.rebalances, 0);
    let skew = max_over_mean(&stats.shard_ingest);
    assert!(
        skew >= 3.0,
        "frozen router should concentrate the drifted stream: \
         skew {skew:.2}, ingest {:?}",
        stats.shard_ingest
    );
    svc.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The headline acceptance test: under the same drifted stream, the
/// armed skew monitor auto-rebalances (possibly more than once — each
/// epoch's training refines the next retrain) until per-shard ingest
/// imbalance drops below 1.5x; queries answer correctly throughout the
/// swaps; the quiesced post-rebalance epoch keeps probe-vs-oracle >= 99%;
/// and a kill + warm restart resumes the bumped partition.
#[test]
fn auto_rebalance_restores_ingest_balance_under_skewed_drift() {
    let _serial = serial();
    let dir = state_dir("auto");
    // Trigger below the acceptance bound: the monitor keeps refining
    // until the served partition is better than what we assert.
    let (cfg, serve) = rebalance_cfg(&dir, 1.4);
    let svc = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&svc), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    let drift_eval = shifted(&cfg.data.mixture.eval_sample(512, cfg.seed), DRIFT);

    // Stream drifted points while polling: every iteration also exercises
    // the read path, so queries run *across* the epoch swaps the monitor
    // performs concurrently.
    let deadline = Instant::now() + Duration::from_secs(90);
    let mut stream_t = 0u64;
    let balanced = loop {
        assert!(
            Instant::now() < deadline,
            "rebalance never restored balance: {:?}",
            client.stats().unwrap()
        );
        for _ in 0..20 {
            let batch =
                shifted(&cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t), DRIFT);
            stream_t += 1;
            client.ingest(&batch).unwrap();
        }
        // reads must stay correct mid-migration: in-range codes, finite
        // distortion, whatever epoch answers
        let (codes, _v) = client.encode(&drift_eval).unwrap();
        assert_eq!(codes.len(), 512);
        assert!(codes.iter().all(|&c| (c as usize) < cfg.vq.kappa));
        let (c_now, _v) = client.distortion(&drift_eval).unwrap();
        assert!(c_now.is_finite() && c_now >= 0.0);

        let stats = client.stats().unwrap();
        // Judge balance only on a settled epoch: at least one rebalance
        // behind us and enough post-swap ingest to be statistical.
        if stats.rebalances >= 1 {
            let epoch_ingest: u64 = stats.shard_ingest.iter().sum();
            if epoch_ingest >= 5_000 {
                let skew = max_over_mean(&stats.shard_ingest);
                if skew < 1.5 {
                    break stats;
                }
            }
        }
        std::thread::sleep(Duration::from_millis(20));
    };
    assert!(balanced.rebalances >= 1);
    assert!(balanced.router_version >= 1);
    // the read path tracked the drift through the migrations
    let (c_after, _v) = client.distortion(&drift_eval).unwrap();
    assert!(
        c_after < 50.0,
        "post-rebalance codebook should live in the drifted region: C = {c_after}"
    );

    // Quiesce, then the probe-correctness half: routed probe-2 answers
    // vs the exhaustive oracle on the frozen final epoch.
    server.shutdown().unwrap();
    svc.shutdown().unwrap();
    let (_, routed, routed_d) = svc.query_nearest_probed(&drift_eval, 2);
    let (_, oracle, oracle_d) = svc.query_nearest_probed(&drift_eval, 4);
    let agree = routed.iter().zip(&oracle).filter(|(a, b)| a == b).count();
    assert!(
        agree as f64 >= 0.99 * routed.len() as f64,
        "probe 2 agreed with the oracle on only {agree}/{} post-rebalance lookups",
        routed.len()
    );
    for (dr, df) in routed_d.iter().zip(&oracle_d) {
        assert!(df <= dr, "oracle distance {df} worse than routed {dr}");
    }

    // Kill + warm restart: the bumped partition is what comes back. The
    // state dir (written by the final checkpoint drain) is authoritative.
    let saved = persist::load_state(&dir).unwrap().unwrap();
    assert!(saved.manifest.router_version >= 1);
    let svc2 = VqService::start(&cfg, &serve).unwrap();
    assert_eq!(svc2.router_version(), saved.manifest.router_version);
    let router_bits: Vec<u32> = svc2
        .router()
        .centroids()
        .flat()
        .iter()
        .map(|x| x.to_bits())
        .collect();
    let saved_bits: Vec<u32> = saved
        .router
        .centroids
        .flat()
        .iter()
        .map(|x| x.to_bits())
        .collect();
    assert_eq!(router_bits, saved_bits, "router must restore, not retrain");
    for (v, st) in svc2.shard_versions().iter().zip(&saved.shards) {
        assert!(*v >= st.version, "restart lost folds: {v} < {}", st.version);
    }
    // and the restarted partition still answers drifted queries sensibly
    let (_, codes, dists) = svc2.query_nearest(&drift_eval);
    assert_eq!(codes.len(), 512);
    assert!(dists.iter().all(|d| d.is_finite()));
    svc2.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}

/// The wire surface: `Rebalance` over TCP swaps the epoch and acks with
/// the bumped version; `Stats` carries the new observability fields; a
/// service without durable state answers with a clean error and the
/// connection survives.
#[test]
fn rebalance_over_tcp_and_stats_fields() {
    let _serial = serial();
    let dir = state_dir("tcp");
    let (cfg, serve) = rebalance_cfg(&dir, 0.0); // manual trigger only
    let svc = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&svc), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    // Route some load so the retrain has weights to read.
    let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
    client.ingest(&eval).unwrap();

    let stats = client.stats().unwrap();
    assert_eq!(stats.router_version, 0);
    assert_eq!(stats.rebalances, 0);
    assert_eq!(stats.shard_ingest.len(), 4);
    assert_eq!(stats.shard_shed.len(), 4);
    assert_eq!(
        stats.shard_ingest.iter().sum::<u64>() + stats.shard_shed.iter().sum::<u64>(),
        256
    );

    let (rv, _moved, versions) = client.rebalance().unwrap();
    assert_eq!(rv, 1);
    assert_eq!(versions.len(), 4);
    let stats = client.stats().unwrap();
    assert_eq!(stats.router_version, 1);
    assert_eq!(stats.rebalances, 1);
    // per-epoch counters reset with the new partition
    assert_eq!(stats.shard_ingest, vec![0; 4]);
    // the connection that asked for the rebalance keeps working
    let (codes, _) = client.encode(&eval).unwrap();
    assert_eq!(codes.len(), 256);

    server.shutdown().unwrap();
    svc.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();

    // No durable state: a clean error, not a dropped connection.
    let (cfg, mut serve) = rebalance_cfg(&state_dir("tcp-none"), 0.0);
    serve.state_dir = None;
    let svc = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&svc), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let err = format!("{:#}", client.rebalance().unwrap_err());
    assert!(err.contains("state-dir"), "{err}");
    let stats = client.stats().unwrap();
    assert_eq!(stats.rebalances, 0);
    server.shutdown().unwrap();
    svc.shutdown().unwrap();
}

/// The offline path: `dalvq state rebalance` semantics — a quiesced
/// directory is migrated in place, and a service started on it serves
/// the bumped partition (epoch continuity without a live process).
#[test]
fn offline_rebalance_then_serve_resumes_bumped_partition() {
    let _serial = serial();
    let dir = state_dir("offline");
    let (cfg, serve) = rebalance_cfg(&dir, 0.0);
    let svc = VqService::start(&cfg, &serve).unwrap();
    // some load + folds, then a durable flush and a clean stop
    let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
    svc.ingest(&eval).unwrap();
    let deadline = Instant::now() + Duration::from_secs(20);
    while svc.version() < 8 {
        assert!(Instant::now() < deadline, "fleet never folded");
        std::thread::sleep(Duration::from_millis(5));
    }
    svc.shutdown().unwrap();

    // offline rebalance of the quiesced directory (what the CLI runs)
    let report = persist::rebalance_state_dir(&dir, 8, 42).unwrap();
    assert_eq!(report.router_version, 1);
    assert_eq!(report.remap.len(), 16);

    // a restarted service resumes the migrated partition
    let svc2 = VqService::start(&cfg, &serve).unwrap();
    assert_eq!(svc2.router_version(), 1);
    let (_, codes, _) = svc2.query_nearest(&eval);
    assert!(codes.iter().all(|&c| (c as usize) < 16));
    svc2.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();
}
