//! Durable-state end-to-end: checkpoint a serving fleet, tear it down,
//! restart from the same `--state-dir`, and verify the restarted service
//! serves **byte-identical** codebooks at versions `>= V` without
//! retraining — for the single-shard and the 4-shard deployment, under
//! the determinism knobs (`start_paused` + `sync_exchange` +
//! `max_points_per_worker`), so "identical" means bitwise, not
//! approximately.
//!
//! Also pinned here: a checkpoint interrupted mid-write (a stale `.tmp`
//! left in the directory) is ignored on restore rather than corrupting
//! state; a state dir written at one shape is rejected loudly by a
//! mismatched config; the `Checkpoint` wire op and the `StatsReply`
//! persistence fields work over TCP.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::persist;
use dalvq::serve::{Client, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

const PPE: usize = 50; // points per exchange
const MAX_POINTS: u64 = 300; // per worker, per run => 6 folds/shard at m=1

/// A fresh state directory unique to `tag` (removed first, so reruns of a
/// failed test never see stale state).
fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dalvq-persist-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The deterministic deployment of the serve_determinism suite, plus a
/// state dir: one worker per shard, synchronous exchanges, bounded
/// training, paused start.
fn durable_cfg(shards: usize, dir: &Path) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.n_total = 2_000;
    cfg.data.eval_points = 128;
    cfg.vq.kappa = 8;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = shards;
    serve.probe_n = 2.min(shards);
    serve.points_per_exchange = PPE;
    serve.ingest_queue = 1_024;
    serve.start_paused = true;
    serve.sync_exchange = true;
    serve.max_points_per_worker = MAX_POINTS;
    serve.state_dir = Some(dir.to_path_buf());
    serve.checkpoint_every = 1_000_000; // checkpoints are explicit here
    (cfg, serve)
}

fn wait_versions_at_least(svc: &VqService, target: u64) {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let versions = svc.shard_versions();
        if versions.iter().all(|&v| v >= target) {
            return;
        }
        assert!(
            Instant::now() < deadline,
            "shards never reached version {target}: {versions:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
}

fn codebook_bytes(svc: &VqService) -> Vec<Vec<u32>> {
    (0..svc.shards())
        .map(|s| {
            svc.shard_snapshot(s)
                .codebook
                .flat()
                .iter()
                .map(|x| x.to_bits())
                .collect()
        })
        .collect()
}

/// Train a fleet to exactly `V` folds per shard, checkpoint, and shut it
/// down. Returns the checkpointed versions and per-shard codebook bits.
fn train_and_checkpoint(
    cfg: &ExperimentConfig,
    serve: &ServeConfig,
) -> (Vec<u64>, Vec<Vec<u32>>, Vec<u32>) {
    let svc = VqService::start(cfg, serve).unwrap();
    // Preload a deterministic ingest stream while the fleet is paused
    // (the same discipline as the determinism suite).
    for batch_id in 0..10u64 {
        let batch = cfg.data.mixture.generate(32, cfg.seed, 1_000 + batch_id);
        let (accepted, shed) = svc.ingest(&batch).unwrap();
        assert_eq!(accepted, 32);
        assert_eq!(shed, 0);
    }
    svc.resume();
    let expected_folds = MAX_POINTS / PPE as u64;
    wait_versions_at_least(&svc, expected_folds);

    let ckpt = svc.checkpoint_now().unwrap();
    assert_eq!(ckpt.len(), serve.shards);
    assert!(ckpt.iter().all(|&v| v >= expected_folds), "{ckpt:?}");
    assert_eq!(svc.last_checkpoint(), ckpt);

    let books = codebook_bytes(&svc);
    let router_bits: Vec<u32> = svc
        .router()
        .centroids()
        .flat()
        .iter()
        .map(|x| x.to_bits())
        .collect();
    svc.shutdown().unwrap();
    (ckpt, books, router_bits)
}

/// The acceptance criterion: checkpoint at versions `V`, kill, restart
/// with the same state dir — the restarted service serves byte-identical
/// codebooks at versions `>= V` without retraining.
fn warm_restart_is_byte_identical(shards: usize) {
    let dir = state_dir(&format!("warm-s{shards}"));
    let (cfg, serve) = durable_cfg(shards, &dir);
    let (ckpt, books, router_bits) = train_and_checkpoint(&cfg, &serve);

    // Restart against the same directory, paused: nothing may train, so
    // what the service serves IS what restore produced.
    let svc2 = VqService::start(&cfg, &serve).unwrap();
    assert_eq!(
        svc2.shard_versions(),
        ckpt,
        "restored service must resume at the checkpointed versions"
    );
    assert_eq!(
        codebook_bytes(&svc2),
        books,
        "restored codebooks must be byte-identical to the checkpoint"
    );
    let router2: Vec<u32> = svc2
        .router()
        .centroids()
        .flat()
        .iter()
        .map(|x| x.to_bits())
        .collect();
    assert_eq!(router2, router_bits, "router must be restored, not retrained");

    // The read path answers from the restored epochs immediately.
    let eval = cfg.data.mixture.eval_sample(64, cfg.seed);
    let (version, codes, dists) = svc2.query_nearest(&eval);
    assert_eq!(version, ckpt.iter().sum::<u64>());
    assert_eq!(codes.len(), 64);
    assert!(codes.iter().all(|&c| (c as usize) < cfg.vq.kappa));
    assert!(dists.iter().all(|d| d.is_finite()));
    svc2.shutdown().unwrap();

    // Third incarnation: resume training — versions continue past V
    // (monotone across restarts; the fleet picks up where it left off
    // rather than retraining from scratch).
    let svc3 = VqService::start(&cfg, &serve).unwrap();
    svc3.resume();
    let expected = ckpt[0] + MAX_POINTS / PPE as u64;
    wait_versions_at_least(&svc3, expected);
    assert!(svc3.shard_versions().iter().all(|&v| v >= ckpt[0]));
    let out = svc3.shutdown().unwrap();
    assert!(out.merges >= expected * shards as u64);

    std::fs::remove_dir_all(&dir).unwrap();
}

#[test]
fn warm_restart_single_shard() {
    let _serial = serial();
    warm_restart_is_byte_identical(1);
}

#[test]
fn warm_restart_four_shards() {
    let _serial = serial();
    warm_restart_is_byte_identical(4);
}

/// A checkpoint interrupted mid-write leaves a `.tmp` behind; restore
/// must ignore it and come up from the last complete state.
#[test]
fn interrupted_checkpoint_tmp_is_ignored_on_restore() {
    let _serial = serial();
    let dir = state_dir("interrupted");
    let (cfg, serve) = durable_cfg(4, &dir);
    let (ckpt, books, _) = train_and_checkpoint(&cfg, &serve);

    // Simulate a crash mid-checkpoint: half-written temp files next to
    // the good state.
    std::fs::write(dir.join("shard-0.state.tmp"), b"half a shard write").unwrap();
    std::fs::write(dir.join("manifest.json.tmp"), b"{\"trunc").unwrap();

    let svc = VqService::start(&cfg, &serve).unwrap();
    assert_eq!(svc.shard_versions(), ckpt);
    assert_eq!(codebook_bytes(&svc), books);
    svc.shutdown().unwrap();
    assert!(
        !dir.join("shard-0.state.tmp").exists(),
        "stale tmp files must be swept, not read"
    );
    std::fs::remove_dir_all(&dir).unwrap();
}

/// A state dir written at one shape must be rejected by a mismatched
/// config — wrong dim, wrong shard count — never silently retrained over
/// or loaded into the wrong fleet.
#[test]
fn mismatched_config_is_rejected_on_restore() {
    let _serial = serial();
    let dir = state_dir("mismatch");
    let (cfg, serve) = durable_cfg(4, &dir);
    train_and_checkpoint(&cfg, &serve);

    // Wrong dimensionality (saved dim 2, config dim 3). (`err()` rather
    // than `unwrap_err()`: VqService deliberately has no Debug impl.)
    let (mut cfg3, serve3) = durable_cfg(4, &dir);
    cfg3.data.mixture.dim = 3;
    let err = VqService::start(&cfg3, &serve3)
        .err()
        .expect("dim mismatch must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("dim"), "{msg}");

    // Wrong shard count (saved 4, config 2).
    let (cfg2, serve2) = durable_cfg(2, &dir);
    let err = VqService::start(&cfg2, &serve2)
        .err()
        .expect("shard-count mismatch must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("shards"), "{msg}");

    // Changed exchange window (saved 50, config 100): the saved schedule
    // cursors would be misinterpreted, so restore refuses.
    let (cfg5, mut serve5) = durable_cfg(4, &dir);
    serve5.points_per_exchange = 100;
    let err = VqService::start(&cfg5, &serve5)
        .err()
        .expect("points_per_exchange mismatch must be rejected");
    let msg = format!("{err:#}");
    assert!(msg.contains("points_per_exchange"), "{msg}");

    // A corrupted shard file is a hard error, not a silent cold start.
    let shard_path = dir.join(persist::shard_file(1));
    let mut bytes = std::fs::read(&shard_path).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xFF;
    std::fs::write(&shard_path, bytes).unwrap();
    let (cfg4, serve4) = durable_cfg(4, &dir);
    assert!(VqService::start(&cfg4, &serve4).is_err());

    std::fs::remove_dir_all(&dir).unwrap();
}

/// The wire surface: `Checkpoint` forces a durable flush and acks with
/// per-shard versions; `Stats` reports the state dir and last-checkpoint
/// vector; a service without persistence answers `Checkpoint` with a
/// clean error, not a dropped connection.
#[test]
fn checkpoint_and_stats_over_tcp() {
    let _serial = serial();
    let dir = state_dir("tcp");
    let (cfg, serve) = durable_cfg(1, &dir);
    let service = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    service.resume();
    let folds = MAX_POINTS / PPE as u64;
    wait_versions_at_least(&service, folds);

    let versions = client.checkpoint().unwrap();
    assert_eq!(versions.len(), 1);
    assert!(versions[0] >= folds, "{versions:?}");

    let stats = client.stats().unwrap();
    assert_eq!(stats.state_dir, dir.display().to_string());
    assert_eq!(stats.last_checkpoint, versions);
    assert_eq!(stats.shard_versions.len(), 1);

    server.shutdown().unwrap();
    service.shutdown().unwrap();
    std::fs::remove_dir_all(&dir).unwrap();

    // No persistence: Checkpoint answers with a clean error and the
    // connection survives; Stats reports an empty state dir.
    let (cfg, mut serve) = durable_cfg(1, &state_dir("tcp-none"));
    serve.state_dir = None;
    let service = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&service), &serve.addr).unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();
    let err = format!("{:#}", client.checkpoint().unwrap_err());
    assert!(err.contains("state"), "{err}");
    let stats = client.stats().unwrap();
    assert!(stats.state_dir.is_empty());
    assert!(stats.last_checkpoint.is_empty());
    server.shutdown().unwrap();
    service.shutdown().unwrap();
}

/// The loadtest path must fail fast with a clear error when no server is
/// listening — bounded connect attempts, not a hang.
#[test]
fn client_connect_fails_fast_when_server_is_down() {
    // A port with nothing behind it: bind-then-drop guarantees refusal.
    let addr = {
        let l = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        l.local_addr().unwrap()
    };
    let start = Instant::now();
    let err = Client::connect_with(addr, Duration::from_millis(500), 1)
        .map(|_| ())
        .unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("attempt"), "{msg}");
    // 2 bounded attempts + one 100 ms backoff: well under the 30 s a
    // default no-timeout connect could burn on an unroutable address.
    assert!(
        start.elapsed() < Duration::from_secs(10),
        "connect did not fail fast: {:?}",
        start.elapsed()
    );
}
