//! Scheme-equivalence invariants (DESIGN.md invariants 3–5):
//!
//! * scheme A (averaging) with `M = 1` is *exactly* the sequential walk
//!   (averaging one version is the identity);
//! * scheme B (delta merge) with `M = 1` tracks the sequential walk to the
//!   float re-association tolerance of eq. 8's `w_srd − Σ` form;
//! * scheme C with zero delays matches scheme B's final distortion closely.

use dalvq::config::{ExperimentConfig, SchemeConfig};
use dalvq::schemes;
use dalvq::sim::DelayModel;

fn base_cfg(points: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.data.mixture.components = 8;
    cfg.data.mixture.dim = 4;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 8;
    cfg.m = 1;
    cfg.run.points_per_worker = points;
    cfg.run.eval_interval = 1e-3;
    cfg
}

/// The figure-preset regime: random init, overlapping mixture, slow
/// schedule — convergence stays transport-limited over the run, which is
/// where the paper's wall-clock comparisons live (see presets::fig1).
fn paper_regime(mut cfg: ExperimentConfig) -> ExperimentConfig {
    cfg.vq.init = dalvq::vq::InitMethod::Gaussian;
    cfg.vq.schedule = dalvq::vq::Schedule::InverseTime {
        eps0: 0.005,
        half_life: 50_000.0,
    };
    cfg.data.mixture.std = 1.2;
    cfg.data.mixture.noise_frac = 0.05;
    cfg.data.mixture.imbalance = 0.5;
    cfg
}

#[test]
fn averaging_m1_is_exactly_sequential() {
    let mut cfg_avg = base_cfg(10_000);
    cfg_avg.scheme = SchemeConfig::Averaging { tau: 10 };
    let mut cfg_seq = base_cfg(10_000);
    cfg_seq.scheme = SchemeConfig::Sequential;

    let avg = schemes::run_with_config(&cfg_avg).unwrap();
    let seq = schemes::run_with_config(&cfg_seq).unwrap();
    // identical trajectory: averaging a single version is the identity,
    // and the sequential runner uses the same tau-chunked kernel
    assert_eq!(
        avg.final_shared, seq.final_shared,
        "averaging M=1 must be bit-identical to sequential"
    );
}

#[test]
fn delta_sync_m1_tracks_sequential() {
    let mut cfg_b = base_cfg(10_000);
    cfg_b.scheme = SchemeConfig::DeltaSync { tau: 10 };
    let mut cfg_seq = base_cfg(10_000);
    cfg_seq.scheme = SchemeConfig::Sequential;

    let b = schemes::run_with_config(&cfg_b).unwrap();
    let seq = schemes::run_with_config(&cfg_seq).unwrap();
    let diff = b.final_shared.max_abs_diff(&seq.final_shared);
    assert!(diff < 1e-3, "delta sync M=1 drifted {diff} from sequential");
    // and the distortion curves land in the same place
    let rel = (b.series.last_value() - seq.series.last_value()).abs()
        / seq.series.last_value().max(1e-12);
    assert!(rel < 1e-3, "final distortion off by {rel}");
}

#[test]
fn async_with_zero_delay_matches_delta_sync_distortion() {
    let mut cfg_b = base_cfg(20_000);
    cfg_b.m = 4;
    cfg_b.scheme = SchemeConfig::DeltaSync { tau: 10 };
    let mut cfg_c = cfg_b.clone();
    cfg_c.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let b = schemes::run_with_config(&cfg_b).unwrap();
    let c = schemes::run_with_config(&cfg_c).unwrap();
    // Not bit-identical (event interleaving differs from lockstep rounds),
    // but the schemes are algorithmically equivalent at zero delay: same
    // points, same learning rates, same merge rule.
    let rel = (b.series.last_value() - c.series.last_value()).abs()
        / b.series.last_value().max(1e-12);
    assert!(
        rel < 0.15,
        "async@0-delay final C {} vs delta-sync {}",
        c.series.last_value(),
        b.series.last_value()
    );
    assert_eq!(b.series.points_processed, c.series.points_processed);
}

#[test]
fn sequential_chunking_is_trajectory_invariant() {
    // tau chunking is dispatch batching only: tau=1 vs tau=10 delta-sync
    // at M=1 gives the same walk (same schedule indexing)
    let mut cfg_1 = base_cfg(5_000);
    cfg_1.scheme = SchemeConfig::DeltaSync { tau: 1 };
    let mut cfg_10 = base_cfg(5_000);
    cfg_10.scheme = SchemeConfig::DeltaSync { tau: 10 };
    let a = schemes::run_with_config(&cfg_1).unwrap();
    let b = schemes::run_with_config(&cfg_10).unwrap();
    let diff = a.final_shared.max_abs_diff(&b.final_shared);
    assert!(diff < 1e-3, "tau chunking changed the trajectory by {diff}");
}

#[test]
fn paper_shape_fig1_vs_fig2_at_m10() {
    // The paper's central comparison, at test scale: with the SAME budget,
    // averaging (eq. 3) gives ~no wall-clock gain while delta merge
    // (eq. 8) converges strictly faster than its own M=1.
    let points = 30_000u64;

    let run = |scheme: SchemeConfig, m: usize| {
        let mut cfg = paper_regime(base_cfg(points));
        cfg.m = m;
        cfg.scheme = scheme;
        schemes::run_with_config(&cfg).unwrap()
    };

    let avg1 = run(SchemeConfig::Averaging { tau: 10 }, 1);
    let avg10 = run(SchemeConfig::Averaging { tau: 10 }, 10);
    let b1 = run(SchemeConfig::DeltaSync { tau: 10 }, 1);
    let b10 = run(SchemeConfig::DeltaSync { tau: 10 }, 10);

    // Time to reach 80% of the respective M=1 improvement — the paper's
    // speed-up notion (time to a performance threshold, Section 1).
    use dalvq::metrics::time_to_threshold;
    let threshold = |s: &dalvq::metrics::Series| {
        s.first_value() + (s.min_value() - s.first_value()) * 0.8
    };

    // Averaging: M=10 gives no meaningful wall-clock gain.
    let th_a = threshold(&avg1.series);
    let ta1 = time_to_threshold(&avg1.series, th_a).unwrap();
    let ta10 = time_to_threshold(&avg10.series, th_a);
    if let Some(ta10) = ta10 {
        assert!(
            ta10 > ta1 * 0.7,
            "averaging M=10 ({ta10:.4}s) should NOT strongly beat M=1 ({ta1:.4}s)"
        );
    } // never reaching the threshold is also "no speed-up"

    // Delta merge: M=10 reaches the same threshold much sooner.
    let th_b = threshold(&b1.series);
    let tb1 = time_to_threshold(&b1.series, th_b).unwrap();
    let tb10 = time_to_threshold(&b10.series, th_b)
        .expect("delta merge M=10 must reach the M=1 threshold");
    assert!(
        tb10 < tb1 * 0.7,
        "delta merge M=10 ({tb10:.4}s) should clearly beat M=1 ({tb1:.4}s)"
    );
}
