//! Distributed tracing end-to-end: the acceptance suite for
//! wire-propagated spans.
//!
//! The paper's decomposition of wall time — per-point compute vs.
//! communication vs. synchronization delay — only means something for a
//! *particular* causal unit; aggregates can't say which stage a given
//! sync cycle spent its 40 ms in. These tests pin the tracing plane's
//! two contracts at full-stack scope:
//!
//! * a client that stamps a trace context on a request gets the server's
//!   span breakdown shipped back in the reply envelope, and the server
//!   keeps its half in the ring even with local sampling off (the caller
//!   already committed to the trace);
//! * one follower sync cycle is ONE trace spanning both processes: the
//!   follower's `sync.cycle` tree contains the leader's `state.cut` /
//!   `state.ship` spans grafted under `sync.fetch` (same 128-bit trace
//!   id on both rings), and span durations nest within the cycle's wall
//!   time.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::obs::NO_PARENT;
use dalvq::serve::{Client, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs / replication_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh state directory unique to `tag` (removed first, so reruns of
/// a failed test never see stale state).
fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("dalvq-trace-e2e-{tag}-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The small sharded service of this suite (the replication_e2e preset:
/// 4 shards x 4 prototypes, gentle pacing, frequent checkpoints).
fn leader_cfg(dir: Option<&Path>) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 16;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = 4;
    serve.probe_n = 2;
    serve.points_per_exchange = 50;
    serve.point_compute = 2e-5;
    serve.ingest_queue = 1_024;
    serve.state_dir = dir.map(|d| d.to_path_buf());
    serve.checkpoint_every = 8;
    (cfg, serve)
}

/// Block until `f` returns true or `secs` elapse (then panic with `what`).
fn wait_for(secs: u64, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// A stamped request ships the server's stage breakdown back in the
/// reply envelope — even with the server's local sampling OFF — and the
/// server's half of the trace lands in its ring under the stamped id,
/// fetchable through the `Trace` wire op.
#[test]
fn a_traced_request_ships_the_servers_span_breakdown_back() {
    let _serial = serial();
    let (cfg, serve) = leader_cfg(None); // trace_sample stays 0
    let svc = VqService::start(&cfg, &serve).unwrap();
    let srv = Server::start(Arc::clone(&svc), &serve.addr).unwrap();
    let mut client = Client::connect(srv.local_addr()).unwrap();
    let eval = cfg.data.mixture.eval_sample(64, cfg.seed);

    // An untraced call ships nothing: the frame is byte-identical to the
    // pre-tracing protocol, and there are no stale spans to take.
    let _ = client.nearest(&eval).unwrap();
    assert!(client.take_server_spans().is_empty());

    // A stamped call comes back with the handler's stage tree.
    client.trace_next(0xABCD, 0x1234, 0);
    let _ = client.nearest(&eval).unwrap();
    let spans = client.take_server_spans();
    let find = |name: &str| {
        spans
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("no {name} span in {spans:?}"))
    };
    let root = find("req.nearest");
    assert_eq!(root.parent, 0, "the shipped root is detached");
    for stage in ["decode", "route", "scan", "encode"] {
        let s = find(stage);
        assert_eq!(s.parent, root.id, "{stage} must hang off the root");
        assert!(
            s.start_us + s.dur_us <= root.start_us + root.dur_us + 1_000,
            "{stage} must nest within the root: {spans:?}"
        );
    }
    // Draining: a second take is empty.
    assert!(client.take_server_spans().is_empty());

    // The wire context forced the server to keep its half despite
    // sampling being off; the Trace op serves it under the stamped id.
    let traces = client.trace(8).unwrap();
    let kept = traces
        .iter()
        .find(|t| t.hi == 0xABCD && t.lo == 0x1234)
        .unwrap_or_else(|| panic!("stamped trace not in ring: {traces:?}"));
    assert!(kept.spans.iter().any(|s| s.name == "scan"), "{kept:?}");
    assert_eq!(traces.len(), 1, "sampling off: only the forced trace");

    srv.shutdown().unwrap();
    svc.shutdown().unwrap();
}

/// The tentpole acceptance pin: one follower sync cycle that adopts a
/// generation yields ONE trace spanning both processes — shared 128-bit
/// trace id in both rings, the leader's `state.cut` / `state.ship`
/// grafted under the follower's `sync.fetch`, and every stage nesting
/// within the cycle's wall time.
#[test]
fn one_sync_cycle_is_one_trace_across_both_processes() {
    let _serial = serial();
    let ldir = state_dir("one-trace-leader");
    let fdir = state_dir("one-trace-follower");
    let (cfg, serve) = leader_cfg(Some(&ldir));
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    // Train past the first checkpoints so the follower can bootstrap.
    let eval = cfg.data.mixture.eval_sample(512, cfg.seed);
    lclient.ingest(&eval).unwrap();
    let v0 = leader.version();
    wait_for(30, "leader folds", || leader.version() >= v0 + 20);

    // Follower with every sync cycle sampled; the leader's own sampling
    // stays OFF, so anything in the leader's ring got there through a
    // wire-forced trace.
    let mut fserve = ServeConfig::default();
    fserve.follow = Some(laddr.clone());
    fserve.sync_every_ms = 25;
    fserve.probe_n = 2;
    fserve.state_dir = Some(fdir.clone());
    fserve.trace_sample = 1;
    let follower = VqService::start(&cfg, &fserve).unwrap();

    // Keep the leader checkpointing until the follower commits a sync
    // trace that actually adopted files (empty polls drop uncommitted).
    let mut stream_t = 0u64;
    let mut found = None;
    wait_for(30, "a traced sync cycle that adopted a generation", || {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        found = follower
            .telemetry()
            .tracer()
            .recent(64)
            .into_iter()
            .find(|t| t.spans.iter().any(|s| s.name == "state.ship"));
        found.is_some()
    });
    let trace = found.unwrap();
    // Grab the leader's half right away (its ring holds one forced
    // trace per poll, and the cap evicts oldest-first).
    let leader_traces = lclient.trace(64).unwrap();

    let span = |name: &str| {
        trace
            .spans
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("no {name} span in {:?}", trace.spans))
    };
    // The follower's half: the cycle root and its local stages.
    let root = span("sync.cycle");
    assert_eq!(root.parent, NO_PARENT);
    let fetch = span("sync.fetch");
    let decode = span("sync.decode");
    let mirror = span("sync.mirror");
    let adopt = span("sync.adopt");
    for s in [fetch, decode, mirror, adopt] {
        assert_eq!(s.parent, root.id, "{} must hang off the cycle", s.name);
    }
    // The leader's half, grafted over the wire: its handler root sits
    // under sync.fetch, with the cut/ship stages below it. (This is also
    // the regression pin for span-id collisions across processes — a
    // raw foreign parent id would nest the leader's root under one of
    // its own children.)
    let lroot = span("req.fetch_state");
    assert_eq!(lroot.parent, fetch.id, "leader root grafts under fetch");
    let cut = span("state.cut");
    let ship = span("state.ship");
    assert_eq!(cut.parent, lroot.id);
    assert_eq!(ship.parent, lroot.id);

    // Durations nest: the leader's spans fit inside the RPC window, and
    // the local stages fit inside (and roughly account for) the cycle.
    const SLOP_US: u64 = 1_000;
    for s in [lroot, cut, ship] {
        assert!(
            s.start_us + s.dur_us <= fetch.start_us + fetch.dur_us + SLOP_US,
            "{} must fit inside sync.fetch: {:?}",
            s.name,
            trace.spans
        );
    }
    let stages_us: u64 =
        [fetch, decode, mirror, adopt].iter().map(|s| s.dur_us).sum();
    let root_end = root.start_us + root.dur_us;
    for s in [fetch, decode, mirror, adopt] {
        assert!(
            s.start_us + s.dur_us <= root_end + SLOP_US,
            "{} must fit inside sync.cycle: {:?}",
            s.name,
            trace.spans
        );
    }
    assert!(
        stages_us <= root.dur_us + SLOP_US,
        "stages ({stages_us} us) exceed the cycle ({} us)",
        root.dur_us
    );

    // ONE trace: the leader's ring holds the same 128-bit id (kept by
    // the wire force — its sampling is off), and its copy of the root is
    // parented under the follower's actual sync.fetch span id.
    let ltrace = leader_traces
        .iter()
        .find(|t| t.hi == trace.hi && t.lo == trace.lo)
        .unwrap_or_else(|| {
            panic!(
                "trace {:016x}{:016x} not in the leader ring",
                trace.hi, trace.lo
            )
        });
    let lspan = |name: &str| {
        ltrace
            .spans
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("no {name} span in {:?}", ltrace.spans))
    };
    assert_eq!(
        lspan("req.fetch_state").parent,
        fetch.id,
        "the leader's root must name the follower's fetch span as parent"
    );
    assert!(ltrace.spans.iter().any(|s| s.name == "state.cut"));
    assert!(ltrace.spans.iter().any(|s| s.name == "state.ship"));

    follower.shutdown().unwrap();
    leader.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
    std::fs::remove_dir_all(&fdir).unwrap();
}
