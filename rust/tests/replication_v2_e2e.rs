//! Replication v2 end-to-end: delta shipping, fan-out sync trees, and
//! automatic failover, proven under deterministic fault injection.
//!
//! `replication_e2e.rs` pins the v1 star topology (full-bundle shipping
//! to read-only followers). This suite pins what makes that a
//! production sync *tier*:
//!
//! * **Delta shipping** — a steady-state poll moves only the shard
//!   files whose version advanced (strictly fewer bytes per sync than a
//!   full bundle, asserted via the `sync.delta_bytes` /
//!   `sync.full_bytes` counters).
//! * **Fan-out trees** — a mirror-keeping follower answers `FetchState`
//!   itself, so a leaf syncs through a relay instead of the leader; a
//!   partition of the tree's links stalls adoption without ever
//!   dropping a read, and heals to convergence.
//! * **Automatic failover** — a leader killed mid-ship is replaced by
//!   its mirrored follower (`--miss-threshold`): the follower promotes
//!   from its byte-identical mirror at a fenced generation, serves
//!   reads throughout, and a stale leader that returns is demoted by
//!   the promotee's patrol (writes and state fetches then redirect).
//! * **Damage tolerance** — an injected mid-shipment truncation is
//!   caught by bundle validation and healed by an automatic full
//!   re-fetch on the next poll.
//!
//! Every fault scenario is scripted through [`dalvq::serve::faults`]
//! (seeded, visit-counted rules — no real signals, no raw-socket
//! races). `DALVQ_FAULT_SEED` reseeds the plans; CI runs the suite
//! twice under different seeds to shake out order dependence.

use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::serve::faults::{self, FaultAction, FaultPlan, FaultRule};
use dalvq::serve::protocol::{MetricsReply, FETCH_ANY_GENERATION};
use dalvq::serve::{Client, Server, VqService};
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

/// Real-time fleets AND a process-global fault registry; run tests one
/// at a time (same discipline as replication_e2e.rs, doubly required
/// here).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A fresh state directory unique to `tag` (removed first, so reruns of
/// a failed test never see stale state).
fn state_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "dalvq-replication-v2-e2e-{tag}-{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

/// The standard durable sharded leader of this suite (the
/// replication_e2e shape): 4 shards x 4 prototypes over a 4-component
/// mixture, paced gently, checkpointing frequently.
fn leader_cfg(dir: &Path) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.mixture.noise_frac = 0.0;
    cfg.data.n_total = 4_000;
    cfg.data.eval_points = 512;
    cfg.vq.kappa = 16;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = 4;
    serve.probe_n = 2;
    serve.points_per_exchange = 50;
    serve.point_compute = 2e-5;
    serve.ingest_queue = 1_024;
    serve.state_dir = Some(dir.to_path_buf());
    serve.checkpoint_every = 8;
    (cfg, serve)
}

/// A follower of `leader_addr`, polling fast so tests converge quickly;
/// `dir` arms the local mirror (what relays relay and failover promotes
/// from), `miss_threshold` arms automatic failover.
fn follower_serve(
    leader_addr: &str,
    dir: Option<&Path>,
    miss_threshold: u64,
) -> ServeConfig {
    let mut serve = ServeConfig::default();
    serve.follow = Some(leader_addr.to_string());
    serve.sync_every_ms = 25;
    serve.probe_n = 2;
    serve.state_dir = dir.map(|d| d.to_path_buf());
    serve.miss_threshold = miss_threshold;
    serve
}

/// Block until `f` returns true or `secs` elapse (then panic with `what`).
fn wait_for(secs: u64, what: &str, mut f: impl FnMut() -> bool) {
    let deadline = Instant::now() + Duration::from_secs(secs);
    while !f() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

fn counter(m: &MetricsReply, name: &str) -> u64 {
    m.counters.iter().find(|(n, _)| n == name).map_or(0, |(_, v)| *v)
}

/// The scenario seed: fixed default, reseedable from the environment so
/// the CI flake guard can run the whole binary under two different
/// fault-coin streams.
fn fault_seed() -> u64 {
    std::env::var("DALVQ_FAULT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1)
}

/// Disarms the process-global fault plan when the test exits — panic or
/// not — so one failing scenario never bleeds rules into the next.
struct FaultGuard;

impl Drop for FaultGuard {
    fn drop(&mut self) {
        faults::disarm();
    }
}

fn arm(rules: Vec<FaultRule>) -> FaultGuard {
    faults::arm(FaultPlan { seed: fault_seed(), rules });
    FaultGuard
}

/// Steady-state sync rides the delta path: after the full-bundle
/// bootstrap, every adoption ships only the advanced files, the
/// follower's `StatsReply` says so (`sync_source = "delta"`), and the
/// byte counters prove a delta sync moves strictly fewer bytes than a
/// full one.
#[test]
fn steady_state_sync_ships_deltas_with_fewer_bytes_than_full() {
    let _serial = serial();
    faults::disarm();
    let ldir = state_dir("delta-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let fserve = follower_serve(&laddr, None, 0);
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let mut fclient = Client::connect(fsrv.local_addr()).unwrap();

    // Drive leader training until the follower has adopted at least two
    // generations via the delta path.
    let delta_adoptions = |m: &MetricsReply| {
        m.events
            .iter()
            .filter(|e| e.kind == "sync.adopt" && e.message.contains("via delta"))
            .count()
    };
    let mut stream_t = 0u64;
    wait_for(30, "two delta adoptions", || {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        delta_adoptions(&fclient.metrics(64).unwrap()) >= 2
    });

    let stats = fclient.stats().unwrap();
    assert_eq!(stats.role, "follower");
    assert_eq!(
        stats.sync_source, "delta",
        "the last adoption must have ridden the delta path"
    );

    let m = fclient.metrics(128).unwrap();
    let delta_bytes = counter(&m, "sync.delta_bytes");
    let full_bytes = counter(&m, "sync.full_bytes");
    assert!(delta_bytes > 0, "no delta bytes counted: {:?}", m.counters);
    assert!(full_bytes > 0, "the bootstrap full fetch must be counted");
    // Per-sync, a delta moves strictly fewer bytes than a full bundle:
    // it never re-ships the router (and skips unadvanced shards). The
    // full side counts the bootstrap plus any journaled "via full"
    // re-fetches; the delta side counts the journaled "via delta" ones.
    let deltas = delta_adoptions(&m) as u64;
    let fulls = 1 + m
        .events
        .iter()
        .filter(|e| e.kind == "sync.adopt" && e.message.contains("via full"))
        .count() as u64;
    assert!(
        delta_bytes / deltas < full_bytes / fulls,
        "a delta sync ({delta_bytes} B / {deltas}) must move fewer bytes \
         than a full one ({full_bytes} B / {fulls})"
    );

    // Quiesce: the follower converges on the leader's exact final state.
    leader.shutdown().unwrap();
    let final_version = leader.version();
    wait_for(20, "follower to drain", || {
        let s = follower.stats();
        s.version == final_version && s.sync_lag_folds == 0
    });

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// The fan-out tree: a mirror-keeping follower (the relay) answers
/// `FetchState` from its own mirror, a leaf follower syncs through it,
/// and a scripted partition of the sync links stalls adoption without
/// dropping a single read — then heals to full convergence, the leaf
/// riding the relay's deltas.
#[test]
fn a_leaf_syncs_through_a_relay_and_survives_a_partition() {
    let _serial = serial();
    faults::disarm();
    let ldir = state_dir("tree-leader");
    let rdir = state_dir("tree-relay");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    // The relay mirrors the leader's bundles; the leaf follows the
    // relay, never touching the leader.
    let rserve = follower_serve(&laddr, Some(&rdir), 0);
    let relay = VqService::start(&cfg, &rserve).unwrap();
    let rsrv = Server::start(Arc::clone(&relay), &rserve.addr).unwrap();
    let raddr = rsrv.local_addr().to_string();

    let leaf_serve = follower_serve(&raddr, None, 0);
    let leaf = VqService::start(&cfg, &leaf_serve).unwrap();
    assert_eq!(leaf.follower_of().as_deref(), Some(raddr.as_str()));
    assert_eq!(leaf.shards(), 4, "topology adopted through the relay");

    // Partition the tree's sync links for a while: after 4 more polls
    // (relay and leaf interleaved on the shared point), the next 12 are
    // dropped. Reads must keep answering from the last adopted epoch on
    // both nodes throughout.
    let _guard = arm(vec![FaultRule {
        point: "sync.fetch".into(),
        after: 4,
        count: 12,
        prob: 1.0,
        action: FaultAction::Drop,
    }]);

    let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
    let mut stream_t = 0u64;
    let v0 = leaf.version();
    wait_for(40, "the leaf to advance and the partition to be exercised", || {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        // no read ever drops, partitioned or not
        let (_, codes, _) = leaf.query_nearest(&eval);
        assert_eq!(codes.len(), 256);
        let (_, codes, _) = relay.query_nearest(&eval);
        assert_eq!(codes.len(), 256);
        std::thread::sleep(Duration::from_millis(20));
        // both: the leaf adopted something through the relay, AND the
        // drop window (visits 5..=16) is fully behind us
        leaf.version() > v0 && faults::hits("sync.fetch") > 16
    });

    // Quiesce the leader; every survivor converges to its exact final
    // version through the tree (proof the post-heal links work), and
    // the leaf's steady-state syncs were served by the relay as deltas.
    leader.shutdown().unwrap();
    let final_version = leader.version();
    wait_for(30, "the tree to converge", || {
        relay.version() == final_version && leaf.version() == final_version
    });
    assert_eq!(leaf.stats().sync_source, "delta");
    let (_, lcodes, ldists) = leader.query_nearest(&eval);
    let (_, fcodes, fdists) = leaf.query_nearest(&eval);
    assert_eq!(lcodes, fcodes, "leaf must answer like the leader");
    assert_eq!(ldists, fdists);

    leaf.shutdown().unwrap();
    rsrv.shutdown().unwrap();
    relay.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
    std::fs::remove_dir_all(&rdir).unwrap();
}

/// Kill the leader mid-ship: a `DelayMs` fault holds the leader inside
/// `state.ship` while the test shuts it down, so the shipment dies in
/// flight. The mirrored follower (miss_threshold = 2) promotes itself
/// from its byte-identical mirror at a fenced generation — strictly
/// above anything the dead leader's disk carries — and serves reads at
/// every poll of the whole ordeal.
#[test]
fn a_leader_killed_mid_ship_fails_over_to_its_mirrored_follower() {
    let _serial = serial();
    faults::disarm();
    let ldir = state_dir("failover-leader");
    let fdir = state_dir("failover-mirror");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let fserve = follower_serve(&laddr, Some(&fdir), 2);
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let mut fclient = Client::connect(fsrv.local_addr()).unwrap();

    // Bootstrap done (disarmed visits are uncounted); from here every
    // real shipment stalls 400 ms inside state.ship — long enough for
    // the test to land the kill while the leader is mid-ship.
    let _guard = arm(vec![FaultRule {
        point: "state.ship".into(),
        after: 0,
        count: u64::MAX,
        prob: 1.0,
        action: FaultAction::DelayMs(400),
    }]);

    // Drive new folds so a fresh checkpoint generation lands and the
    // follower's poll walks into the stalled ship.
    let mut stream_t = 0u64;
    wait_for(30, "the leader to enter a stalled ship", || {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        std::thread::sleep(Duration::from_millis(10));
        faults::hits("state.ship") >= 1
    });
    // The leader is inside the ship right now. Kill it.
    drop(lclient);
    lsrv.shutdown().unwrap();
    leader.shutdown().unwrap();

    // The follower rides out the misses and promotes — answering reads
    // at every single poll in between (the promise of failover: the
    // read tier never blinks).
    let eval = cfg.data.mixture.eval_sample(256, cfg.seed);
    wait_for(30, "the mirrored follower to promote itself", || {
        let (_, codes, dists) = follower.query_nearest(&eval);
        assert_eq!(codes.len(), 256);
        assert!(dists.iter().all(|d| d.is_finite()));
        follower.stats().role == "leader"
    });
    assert!(follower.follower_of().is_none(), "a promotee redirects no one");

    // The fencing rule, on disk: the promoted mirror's generation is
    // strictly above whatever the dead leader's state dir carries, so
    // any generation comparison sees the promotee as newer.
    let lgen = dalvq::persist::read_bundle(&ldir).unwrap().unwrap().generation;
    let fgen = dalvq::persist::read_bundle(&fdir).unwrap().unwrap().generation;
    assert!(
        fgen > lgen,
        "promoted generation {fgen} must fence the dead leader's {lgen}"
    );

    // Telemetry: exactly one promotion, journaled.
    let m = fclient.metrics(128).unwrap();
    assert_eq!(counter(&m, "failover.promotions"), 1, "{:?}", m.counters);
    assert!(
        m.events.iter().any(|e| e.kind == "failover.promote"),
        "no failover.promote event in {:?}",
        m.events
    );

    // The promotee serves the read surface as a leader; writes tell the
    // operator to restart it as a real one (it has no training fleets).
    assert_eq!(fclient.stats().unwrap().role, "leader");
    let (codes, _) = fclient.encode(&eval).unwrap();
    assert_eq!(codes.len(), 256);
    let err = format!("{:#}", follower.ingest(&eval).unwrap_err());
    assert!(err.contains("promoted"), "{err}");

    // ...and it ships state: a new follower could bootstrap from it.
    let ship = fclient.fetch_state(FETCH_ANY_GENERATION).unwrap();
    assert_eq!(ship.generation, fgen);
    assert!(!ship.files.is_empty());

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
    std::fs::remove_dir_all(&fdir).unwrap();
}

/// A partitioned follower promotes while the old leader is still alive;
/// when the partition heals, the promotee's demote patrol reaches the
/// old leader, which steps down: its write and state-fetch surface
/// flips to `NotLeader` redirects pointing at the promotee, and a
/// client following them lands on the new leader's fenced generation —
/// the whole tier converges on one authority.
#[test]
fn a_returning_stale_leader_is_demoted_by_the_promotees_patrol() {
    let _serial = serial();
    faults::disarm();
    let ldir = state_dir("demote-leader");
    let fdir = state_dir("demote-mirror");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();

    let fserve = follower_serve(&laddr, Some(&fdir), 2);
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let faddr = fsrv.local_addr().to_string();

    // Partition the follower's view of the leader (every poll drops
    // before it connects — the leader itself never goes down). The
    // demote patrol's point stays clear, so the "healed link" is the
    // patrol finding the old leader alive.
    let _guard = arm(vec![FaultRule::every(
        "sync.fetch",
        FaultAction::Drop,
    )]);

    wait_for(30, "the partitioned follower to promote", || {
        follower.stats().role == "leader"
    });
    wait_for(30, "the patrol to demote the old leader", || {
        leader.follower_of().as_deref() == Some(faddr.as_str())
    });
    assert!(
        !leader.can_ship_state(),
        "a demoted leader's cut is fenced stale and must not ship"
    );

    // A client talking to the old address is transparently redirected:
    // the state it fetches is the promotee's fenced generation.
    let fgen = dalvq::persist::read_bundle(&fdir).unwrap().unwrap().generation;
    let mut stale = Client::connect(laddr.as_str()).unwrap();
    let ship = stale.fetch_state(FETCH_ANY_GENERATION).unwrap();
    assert_eq!(stale.redirected_to().as_deref(), Some(faddr.as_str()));
    assert_eq!(
        ship.generation, fgen,
        "the tier converged on the promotee's generation"
    );

    // The demotion is journaled on the old leader's plane.
    let mut lclient = Client::connect(laddr.as_str()).unwrap();
    let lm = lclient.metrics(128).unwrap();
    assert!(
        lm.events.iter().any(|e| e.kind == "failover.demote"),
        "no failover.demote event in {:?}",
        lm.events
    );
    // Reads on the demoted leader still answer locally (it serves its
    // last epoch; only writes and state fetches redirect).
    let eval = cfg.data.mixture.eval_sample(64, cfg.seed);
    let (codes, _) = lclient.encode(&eval).unwrap();
    assert_eq!(codes.len(), 64);
    assert_eq!(lclient.redirected_to(), None);

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    leader.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
    std::fs::remove_dir_all(&fdir).unwrap();
}

/// An injected truncation chews the tail file of a shipped delta; the
/// follower's bundle validation catches the damage instead of adopting
/// it, and the next poll automatically re-fetches the full bundle and
/// converges — the delta path can never wedge a follower on one bad
/// shipment.
#[test]
fn a_truncated_shipment_is_rejected_and_healed_by_a_full_refetch() {
    let _serial = serial();
    faults::disarm();
    let ldir = state_dir("truncate-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let fserve = follower_serve(&laddr, None, 0);
    let follower = VqService::start(&cfg, &fserve).unwrap();
    let v0 = follower.version();
    let fsrv = Server::start(Arc::clone(&follower), &fserve.addr).unwrap();
    let mut fclient = Client::connect(fsrv.local_addr()).unwrap();

    // The first post-bootstrap shipment arrives with its tail file
    // chopped (the rule is spent after one firing).
    let _guard = arm(vec![FaultRule::once_after(
        "sync.files",
        0,
        FaultAction::Truncate,
    )]);

    let mut stream_t = 0u64;
    wait_for(30, "the follower to adopt past the damaged shipment", || {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        std::thread::sleep(Duration::from_millis(20));
        follower.version() > v0
    });
    assert!(faults::hits("sync.files") >= 1, "the truncation never fired");

    // The recovery is visible in the journal: an adoption that rode the
    // full path after the bootstrap (the forced re-fetch).
    let m = fclient.metrics(128).unwrap();
    assert!(
        m.events
            .iter()
            .any(|e| e.kind == "sync.adopt" && e.message.contains("via full")),
        "no full-path recovery adoption in {:?}",
        m.events
    );

    // And the follower still converges exactly.
    leader.shutdown().unwrap();
    let final_version = leader.version();
    wait_for(20, "the follower to converge past the damage", || {
        let s = follower.stats();
        s.version == final_version && s.sync_lag_folds == 0
    });

    fsrv.shutdown().unwrap();
    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}

/// Chaos under a seeded coin: a bounded burst of probabilistic poll
/// drops (the exact pattern fixed by `DALVQ_FAULT_SEED`) cannot keep a
/// follower from converging once the leader quiesces — under any seed.
#[test]
fn seeded_probabilistic_drops_still_converge() {
    let _serial = serial();
    faults::disarm();
    let ldir = state_dir("chaos-leader");
    let (cfg, serve) = leader_cfg(&ldir);
    let leader = VqService::start(&cfg, &serve).unwrap();
    let lsrv = Server::start(Arc::clone(&leader), &serve.addr).unwrap();
    let laddr = lsrv.local_addr().to_string();
    let mut lclient = Client::connect(laddr.as_str()).unwrap();

    let fserve = follower_serve(&laddr, None, 0);
    let follower = VqService::start(&cfg, &fserve).unwrap();

    // Each of the next polls flips the plan's seeded coin; at most 8
    // drop. The rule spends itself, so convergence is guaranteed even
    // under a maximally unlucky seed.
    let _guard = arm(vec![FaultRule {
        point: "sync.fetch".into(),
        after: 0,
        count: 8,
        prob: 0.5,
        action: FaultAction::Drop,
    }]);

    let run_until = Instant::now() + Duration::from_secs(2);
    let mut stream_t = 0u64;
    while Instant::now() < run_until {
        let batch = cfg.data.mixture.generate(128, cfg.seed, 2 + stream_t);
        stream_t += 1;
        lclient.ingest(&batch).unwrap();
        std::thread::sleep(Duration::from_millis(20));
    }
    assert!(faults::hits("sync.fetch") > 0, "no polls were coin-flipped");

    leader.shutdown().unwrap();
    let final_version = leader.version();
    wait_for(30, "convergence despite seeded drops", || {
        let s = follower.stats();
        s.version == final_version && s.sync_lag_folds == 0
    });

    follower.shutdown().unwrap();
    lsrv.shutdown().unwrap();
    std::fs::remove_dir_all(&ldir).unwrap();
}
