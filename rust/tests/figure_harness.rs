//! Harness-level integration: each paper figure regenerates at test scale
//! with the expected *shape*, and reports persist/round-trip.

use dalvq::config::presets;
use dalvq::coordinator::Orchestrator;
use dalvq::harness;
use dalvq::metrics::FigureReport;
use dalvq::util::Json;

fn shrink(fig: &mut dalvq::config::FigureConfig, points: u64) {
    fig.base.run.points_per_worker = points;
    fig.base.data.n_total = 8_000;
    fig.base.data.eval_points = 512;
}

#[test]
fn fig1_shape_averaging_brings_no_speedup() {
    let mut fig = presets::fig1();
    shrink(&mut fig, 30_000);
    let report = harness::run_figure(&fig).unwrap();
    assert_eq!(report.series.len(), 3);
    let (_, rows) = harness::speedups_at(&report, 0.8);
    // the paper's negative result: no meaningful speed-up at any M
    for row in &rows[1..] {
        if let Some(s) = row.speedup {
            assert!(
                s < 1.6,
                "{}: averaging speed-up {s:.2} should be ~1",
                row.name
            );
        }
    }
}

#[test]
fn fig2_shape_delta_merge_speeds_up() {
    let mut fig = presets::fig2();
    shrink(&mut fig, 30_000);
    let report = harness::run_figure(&fig).unwrap();
    let (_, rows) = harness::speedups_at(&report, 0.8);
    let m10 = rows
        .iter()
        .find(|r| r.name == "M=10")
        .and_then(|r| r.speedup)
        .expect("M=10 should reach the threshold");
    assert!(m10 > 2.0, "delta merge M=10 speed-up {m10:.2} too small");
    let m2 = rows
        .iter()
        .find(|r| r.name == "M=2")
        .and_then(|r| r.speedup)
        .expect("M=2 should reach the threshold");
    assert!(m2 > 1.2, "delta merge M=2 speed-up {m2:.2} too small");
    assert!(m10 > m2, "speed-up should grow with M");
}

#[test]
fn fig3_shape_async_keeps_the_speedups() {
    let mut fig2 = presets::fig2();
    shrink(&mut fig2, 30_000);
    let mut fig3 = presets::fig3();
    shrink(&mut fig3, 30_000);
    let r2 = harness::run_figure(&fig2).unwrap();
    let r3 = harness::run_figure(&fig3).unwrap();
    // paper: "small delays and asynchronism only slightly impacts
    // performances, compared to the scheme given by equations (8)"
    let horizon = r2.series[2].last_wall().min(r3.series[2].last_wall()) * 0.9;
    let c2 = r2.series[2].value_at(horizon); // M=10 sync
    let c3 = r3.series[2].value_at(horizon); // M=10 async+delays
    let rel = (c3 - c2).abs() / c2.max(1e-12);
    assert!(
        rel < 0.35,
        "async M=10 ({c3:.6}) strayed {rel:.2} from sync M=10 ({c2:.6})"
    );
}

#[test]
fn ablation_tau_frequent_merges_win() {
    // paper §3: "the acceleration is greater when the reducing phase is
    // frequent" — smaller tau converges at least as fast at M=10
    let mut figs = presets::ablation_tau();
    for f in figs.iter_mut() {
        shrink(f, 30_000);
        // keep points a multiple of every tau (200 divides 30k)
    }
    let mut finals = Vec::new();
    for f in &figs {
        let r = harness::run_figure(f).unwrap();
        finals.push((f.id.clone(), r.series[0].last_value()));
    }
    let c_tau10 = finals.iter().find(|(id, _)| id == "abl_tau_10").unwrap().1;
    let c_tau200 = finals.iter().find(|(id, _)| id == "abl_tau_200").unwrap().1;
    assert!(
        c_tau10 <= c_tau200 * 1.1,
        "tau=10 ({c_tau10:.6}) should not lose to tau=200 ({c_tau200:.6})"
    );
}

#[test]
fn reports_persist_and_round_trip() {
    let dir = std::env::temp_dir().join("dalvq_fig_harness_test");
    let _ = std::fs::remove_dir_all(&dir);
    let orch = Orchestrator { out_dir: Some(dir.clone()), quiet: true };
    let mut fig = presets::fig2();
    shrink(&mut fig, 5_000);
    fig.ms = vec![1, 2];
    let report = orch.run_figure(&fig).unwrap();

    // CSV exists and has the long format header
    let csv = std::fs::read_to_string(dir.join("fig2.csv")).unwrap();
    assert!(csv.starts_with("series,wall,value"));
    assert!(csv.contains("M=2,"));

    // JSON round-trips to an equal report
    let text = std::fs::read_to_string(dir.join("fig2.json")).unwrap();
    let back = FigureReport::from_json(&Json::parse(&text).unwrap()).unwrap();
    assert_eq!(back.id, report.id);
    assert_eq!(back.series.len(), report.series.len());
    for (a, b) in back.series.iter().zip(&report.series) {
        assert_eq!(a.samples.len(), b.samples.len());
        assert_eq!(a.points_processed, b.points_processed);
    }
}

#[test]
fn figure_runs_are_reproducible() {
    let mut fig = presets::fig3();
    shrink(&mut fig, 5_000);
    fig.ms = vec![2];
    let a = harness::run_figure(&fig).unwrap();
    let b = harness::run_figure(&fig).unwrap();
    assert_eq!(a.series[0].samples.len(), b.series[0].samples.len());
    for (x, y) in a.series[0].samples.iter().zip(&b.series[0].samples) {
        assert_eq!(x.wall, y.wall);
        assert_eq!(x.value, y.value);
    }
}
