//! The paper's data-insensitivity claim: “our conclusions are more
//! sensitive to the loss function smoothness and convexity than to the
//! data choice.” Rerun the central scheme comparison on *functional*
//! (B-spline) data — the family the authors' own generator produced — and
//! check the same shapes: averaging ~1x, delta merge > 2x at M = 10.

use dalvq::data::SplineSpec;
use dalvq::metrics::{time_to_threshold, Series};
use dalvq::runtime::NativeEngine;
use dalvq::schemes::{self, SchemeInputs};
use dalvq::sim::{CostModel, Evaluator, Trace};
use dalvq::vq::{init_codebook, Codebook, InitMethod, Schedule};

struct Fixture {
    dataset: dalvq::data::Dataset,
    w0: Codebook,
    eval_pts: Vec<f32>,
}

fn fixture() -> Fixture {
    let spec = SplineSpec {
        components: 16,
        dim: 16,
        control_points: 8,
        amplitude: 5.0,
        coeff_std: 1.0,
    };
    let dataset = spec.dataset(8_000, 17);
    let w0 = init_codebook(InitMethod::Gaussian, 16, 16, dataset.flat(), 17);
    let eval_pts = spec.eval_sample(1_024, 17);
    Fixture { dataset, w0, eval_pts }
}

fn run_scheme(
    f: &Fixture,
    m: usize,
    averaging: bool,
    points: u64,
) -> Series {
    let shards = f.dataset.split(m);
    let mut engine = NativeEngine::new();
    let mut eval = Evaluator::new(f.eval_pts.clone(), 16, 1e-3);
    let mut trace = Trace::disabled();
    let mut inputs = SchemeInputs {
        engine: &mut engine,
        shards: &shards,
        w0: f.w0.clone(),
        schedule: Schedule::InverseTime { eps0: 0.005, half_life: 50_000.0 },
        cost: CostModel::default(),
        points_per_worker: points,
        eval: &mut eval,
        trace: &mut trace,
        seed: 17,
    };
    let out = if averaging {
        schemes::averaging::run(&mut inputs, 10).unwrap()
    } else {
        schemes::delta_sync::run(&mut inputs, 10).unwrap()
    };
    out.series
}

#[test]
fn paper_shapes_hold_on_functional_data() {
    let f = fixture();
    let points = 30_000u64;
    let avg1 = run_scheme(&f, 1, true, points);
    let avg10 = run_scheme(&f, 10, true, points);
    let b1 = run_scheme(&f, 1, false, points);
    let b10 = run_scheme(&f, 10, false, points);

    let threshold = |s: &Series| {
        s.first_value() + (s.min_value() - s.first_value()) * 0.8
    };

    // averaging: no meaningful speed-up on splines either
    let th = threshold(&avg1);
    let ta1 = time_to_threshold(&avg1, th).unwrap();
    if let Some(ta10) = time_to_threshold(&avg10, th) {
        assert!(
            ta10 > ta1 * 0.7,
            "averaging M=10 sped up on functional data ({ta1:.4} -> {ta10:.4})"
        );
    }

    // delta merge: clear speed-up on splines too
    let th = threshold(&b1);
    let tb1 = time_to_threshold(&b1, th).unwrap();
    let tb10 = time_to_threshold(&b10, th)
        .expect("delta merge M=10 must reach the threshold");
    assert!(
        tb10 < tb1 * 0.5,
        "delta merge speed-up too small on functional data \
         ({tb1:.4}s -> {tb10:.4}s)"
    );
}

#[test]
fn functional_quantization_recovers_curve_structure() {
    // after training, prototypes should themselves be smooth curves
    let f = fixture();
    let series = run_scheme(&f, 4, false, 20_000);
    assert!(series.last_value() < series.first_value() * 0.6);
    // (smoothness of the prototypes follows from them being convex
    // combinations of smooth data curves; the distortion drop above is
    // the quantitative check that the codebook matched the curve family)
}
