//! The batched query plane, end to end: the fused shard-grouped scan and
//! the cross-request coalescer must answer **bit-identically** to the
//! scalar per-point path, over random shapes and under concurrency, and
//! the admission layer must reject a query whose reply could never be
//! framed before any scan work is spent on it.
//!
//! Three families:
//!
//! * **Shape property test** — random (dim, kappa, shards, probe_n,
//!   batch size) deployments; every fused answer is checked against a
//!   scalar oracle built from the same public parts (router probes +
//!   `Snapshot::nearest_one` + probe-order strict-`<` merge).
//! * **Coalescer over TCP** — a server armed with `batch_window_us`
//!   answers concurrent clients; every reply must equal the direct
//!   in-process path bit for bit, and the drain histograms must have
//!   recorded themselves.
//! * **Reply-size admission** — at dim 1 a `Nearest` request can be
//!   admissible while its reply (17 + 8n bytes) overruns `MAX_FRAME`;
//!   such a query must come back as a clear in-band error, leaving the
//!   connection usable, while a constant-size `Distortion` reply for the
//!   same batch passes.

use std::sync::{Arc, Mutex};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::serve::protocol::MAX_FRAME;
use dalvq::serve::{Client, Server, Snapshot, VqService};
use dalvq::sim::DelayModel;
use dalvq::util::Rng;
use dalvq::vq::Schedule;

/// Real-time fleets; run tests one at a time (same discipline as
/// serve_e2e.rs).
static SERIAL: Mutex<()> = Mutex::new(());

fn serial() -> std::sync::MutexGuard<'static, ()> {
    SERIAL.lock().unwrap_or_else(|e| e.into_inner())
}

/// A small serving deployment with the given read-path shape.
fn shaped_cfg(
    dim: usize,
    kappa: usize,
    shards: usize,
    probe_n: usize,
) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1;
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = dim;
    cfg.data.n_total = 2_000;
    cfg.data.eval_points = 256;
    cfg.vq.kappa = kappa;
    cfg.vq.schedule = Schedule::Constant { eps0: 0.01 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.points_per_exchange = 50;
    serve.point_compute = 2e-6;
    serve.shards = shards;
    serve.probe_n = probe_n;
    (cfg, serve)
}

/// The scalar per-point oracle the fused plane must reproduce bit for
/// bit: probe the router, scan each probed shard one point at a time,
/// merge in probe order with strict `<` (ties keep the earlier probe).
fn scalar_oracle(
    svc: &VqService,
    snaps: &[Arc<Snapshot>],
    points: &[f32],
    probe_n: usize,
) -> (Vec<u32>, Vec<f32>) {
    let dim = svc.dim();
    let kappa_shard = svc.kappa() / svc.shards();
    let router = svc.router();
    let mut probes = Vec::new();
    let mut codes = Vec::new();
    let mut dists = Vec::new();
    for z in points.chunks_exact(dim) {
        router.probe_into(z, probe_n, &mut probes);
        let mut best_code = 0u32;
        let mut best_d = f32::INFINITY;
        for &s in &probes {
            let (local, d) = snaps[s].nearest_one(z);
            if d < best_d {
                best_d = d;
                best_code = (s * kappa_shard) as u32 + local;
            }
        }
        codes.push(best_code);
        dists.push(best_d);
    }
    (codes, dists)
}

/// Random shapes: dims that exercise the four-lane kernel's remainder
/// tail, shard counts from unsharded to kappa-wide, probe widths from 1
/// to all shards, batch sizes from a single point up. Every fused answer
/// must equal the scalar oracle bit for bit.
#[test]
fn fused_plane_matches_the_scalar_oracle_across_shapes() {
    let _serial = serial();
    let mut rng = Rng::from_seed(0x9A7E);
    for &(dim, kappa, shards) in
        &[(1, 4, 1), (2, 8, 4), (3, 6, 2), (5, 8, 2), (9, 12, 4)]
    {
        let (cfg, serve) = shaped_cfg(dim, kappa, shards, 2.min(shards));
        let svc = VqService::start(&cfg, &serve).unwrap();
        // Quiesce so oracle and fused path read the same frozen epoch.
        svc.shutdown().unwrap();
        let snaps = svc.snapshots();
        for probe_n in 1..=shards {
            for &n in &[1usize, 3, 17, 64] {
                let points: Vec<f32> = (0..n * dim)
                    .map(|_| rng.range_f32(-6.0, 6.0))
                    .collect();
                let (version, codes, dists) =
                    svc.query_nearest_probed(&points, probe_n);
                let (want_codes, want_dists) =
                    scalar_oracle(&svc, &snaps, &points, probe_n);
                assert!(version > 0);
                assert_eq!(
                    codes, want_codes,
                    "codes diverged at dim={dim} kappa={kappa} \
                     shards={shards} probe_n={probe_n} n={n}"
                );
                let got: Vec<u32> =
                    dists.iter().map(|d| d.to_bits()).collect();
                let want: Vec<u32> =
                    want_dists.iter().map(|d| d.to_bits()).collect();
                assert_eq!(
                    got, want,
                    "dists diverged at dim={dim} kappa={kappa} \
                     shards={shards} probe_n={probe_n} n={n}"
                );
            }
        }
    }
}

/// The coalescer over real TCP: concurrent clients against a server
/// armed with `--batch-window-us` get answers bit-identical to the
/// direct in-process path, and the drain telemetry records itself.
#[test]
fn coalesced_server_answers_bit_identically_over_tcp() {
    let _serial = serial();
    let (cfg, mut serve) = shaped_cfg(2, 8, 4, 2);
    serve.batch_window_us = 400;
    serve.batch_max_points = 256;
    let svc = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&svc), &serve.addr).unwrap();
    // Quiesce the fleets so every drain and the oracle read the same
    // frozen snapshots (the read path survives shutdown by design).
    svc.shutdown().unwrap();
    let addr = server.local_addr();

    let eval = cfg.data.mixture.eval_sample(128, cfg.seed);
    let mut joins = Vec::new();
    for t in 0..4usize {
        let svc = Arc::clone(&svc);
        let mine: Vec<f32> = eval[t * 32 * 2..(t + 1) * 32 * 2].to_vec();
        joins.push(std::thread::spawn(move || {
            let mut client = Client::connect(addr).unwrap();
            for _ in 0..8 {
                let (codes, dists, version) = client.nearest(&mine).unwrap();
                let (want_v, want_codes, want_dists) =
                    svc.query_nearest_probed(&mine, svc.probe_n());
                assert_eq!(version, want_v);
                assert_eq!(codes, want_codes);
                assert_eq!(
                    dists.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                    want_dists.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
                );
                let (enc_codes, enc_v) = client.encode(&mine).unwrap();
                assert_eq!(enc_v, want_v);
                assert_eq!(enc_codes, want_codes);
            }
        }));
    }
    for j in joins {
        j.join().unwrap();
    }

    // Every armed read drained through the coalescer and said so.
    let mut client = Client::connect(addr).unwrap();
    let metrics = client.metrics(0).unwrap();
    let hist = |name: &str| {
        metrics
            .hists
            .iter()
            .find(|h| h.name == name)
            .unwrap_or_else(|| panic!("no histogram {name}"))
            .count
    };
    assert!(hist("batch.size") > 0, "no drains recorded");
    assert!(hist("batch.wait_us") > 0, "no batch waits recorded");
    drop(client);
    server.shutdown().unwrap();
}

/// Admission must reject a read whose reply could never be framed —
/// before any routing or scan work — and leave the connection usable.
/// At dim 1, a `Nearest` request of n points is 5 + 4n bytes (admissible
/// up to ~16.7M points) but its reply is 17 + 8n (over the cap past
/// ~8.4M), so the top half of the admissible range is answerable only by
/// rejection. A `Distortion` query over the same batch has a
/// constant-size reply and must pass.
#[test]
fn oversized_reply_is_rejected_at_admission_not_mid_scan() {
    let _serial = serial();
    let (cfg, serve) = shaped_cfg(1, 4, 1, 1);
    let svc = VqService::start(&cfg, &serve).unwrap();
    let server = Server::start(Arc::clone(&svc), &serve.addr).unwrap();
    svc.shutdown().unwrap();
    let mut client = Client::connect(server.local_addr()).unwrap();

    // Smallest point count whose Neighbors reply overruns the cap.
    let n = (MAX_FRAME as usize - 17) / 8 + 1;
    assert!(5 + 4 * n <= MAX_FRAME as usize, "request must be admissible");
    let points = vec![0.5f32; n];
    let err = client.nearest(&points).unwrap_err().to_string();
    assert!(
        err.contains("frame cap") && err.contains("split the batch"),
        "unexpected error: {err}"
    );

    // Same batch, constant-size reply: the distortion arm has no
    // admission cap to hit, so the scan actually runs.
    let (value, _version) = client.distortion(&points).unwrap();
    assert!(value.is_finite());

    // The rejection was in-band; the connection still answers.
    let (codes, _v) = client.encode(&[0.25f32]).unwrap();
    assert_eq!(codes.len(), 1);
    server.shutdown().unwrap();
}
