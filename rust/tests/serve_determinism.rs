//! Seed reproducibility of the serving fleet, with and without codebook
//! sharding: two services built from the same config and fed the
//! identical ingest stream must publish **byte-identical** codebooks at
//! the same version.
//!
//! The deterministic regime is explicit in `ServeConfig`:
//!
//! * `start_paused` — the ingest stream is preloaded into the worker
//!   queues before any chunk is trained, so absorption interleaves with
//!   training on a schedule fixed by the config, not by thread timing;
//! * `sync_exchange` — each worker blocks until its delta is folded, so
//!   every exchange carries exactly `points_per_exchange` points and the
//!   downloaded shared version is a pure function of the fold sequence
//!   (one worker per shard makes that sequence total);
//! * `max_points_per_worker` — the run's endpoint is part of the config.
//!
//! Routing must not break any of this: the coarse quantizer is trained
//! deterministically from the seed, and each shard's fleet is as
//! reproducible as the single-fleet deployment.

use std::time::{Duration, Instant};

use dalvq::config::{ExperimentConfig, SchemeConfig, ServeConfig};
use dalvq::serve::VqService;
use dalvq::sim::DelayModel;
use dalvq::vq::Schedule;

const PPE: usize = 50; // points per exchange
const MAX_POINTS: u64 = 300; // per worker => 6 folds per shard at m = 1

fn deterministic_cfg(shards: usize) -> (ExperimentConfig, ServeConfig) {
    let mut cfg = ExperimentConfig::default();
    cfg.m = 1; // one worker per shard: a total fold order
    cfg.data.mixture.components = 4;
    cfg.data.mixture.dim = 2;
    cfg.data.n_total = 2_000;
    cfg.data.eval_points = 128;
    cfg.vq.kappa = 8; // divisible by every shard count used here
    cfg.vq.schedule = Schedule::Constant { eps0: 0.02 };
    cfg.scheme = SchemeConfig::AsyncDelta {
        tau: 10,
        up_delay: DelayModel::Instant,
        down_delay: DelayModel::Instant,
    };
    let mut serve = ServeConfig::default();
    serve.shards = shards;
    serve.probe_n = 2.min(shards);
    serve.points_per_exchange = PPE;
    serve.point_compute = 0.0;
    serve.ingest_queue = 1_024;
    serve.start_paused = true;
    serve.sync_exchange = true;
    serve.max_points_per_worker = MAX_POINTS;
    (cfg, serve)
}

/// One full deterministic run: preload the ingest stream, release the
/// fleet, wait for every shard to publish its final fold, return
/// `(per-shard versions, per-shard codebook bytes, final global codebook)`.
fn run_once(shards: usize) -> (Vec<u64>, Vec<Vec<f32>>, Vec<f32>) {
    let (cfg, serve) = deterministic_cfg(shards);
    let svc = VqService::start(&cfg, &serve).unwrap();

    // The identical ingest stream, preloaded while the fleet is paused so
    // its absorption schedule is part of the configuration.
    for batch_id in 0..10u64 {
        let batch = cfg.data.mixture.generate(32, cfg.seed, 1_000 + batch_id);
        let (accepted, shed) = svc.ingest(&batch).unwrap();
        assert_eq!(accepted, 32, "preloaded batch {batch_id} must be accepted");
        assert_eq!(shed, 0);
    }
    svc.resume();

    let expected_folds = MAX_POINTS / PPE as u64;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let versions = svc.shard_versions();
        if versions.iter().all(|&v| v >= expected_folds) {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "shards never reached fold {expected_folds}: {versions:?}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    let versions = svc.shard_versions();
    let codebooks: Vec<Vec<f32>> = (0..shards)
        .map(|s| svc.shard_snapshot(s).codebook.flat().to_vec())
        .collect();
    let out = svc.shutdown().unwrap();
    assert_eq!(out.merges, expected_folds * shards as u64);
    (versions, codebooks, out.final_shared.flat().to_vec())
}

fn assert_bitwise_reproducible(shards: usize) {
    let (v1, c1, f1) = run_once(shards);
    let (v2, c2, f2) = run_once(shards);
    assert_eq!(v1, v2, "S={shards}: published versions diverged");
    for (s, (a, b)) in c1.iter().zip(&c2).enumerate() {
        let same = a.len() == b.len()
            && a.iter().zip(b).all(|(x, y)| x.to_bits() == y.to_bits());
        assert!(same, "S={shards}: shard {s} codebooks not byte-identical");
    }
    let same = f1.len() == f2.len()
        && f1.iter().zip(&f2).all(|(x, y)| x.to_bits() == y.to_bits());
    assert!(same, "S={shards}: final global codebooks not byte-identical");
    // and the run did move the codebook (a frozen fleet would trivially
    // "reproduce")
    let (cfg, serve) = deterministic_cfg(shards);
    let svc = VqService::start(&cfg, &serve).unwrap();
    let w0: Vec<f32> = (0..shards)
        .flat_map(|s| svc.shard_snapshot(s).codebook.flat().to_vec())
        .collect();
    svc.shutdown().unwrap();
    assert_ne!(w0, f1, "S={shards}: training never changed the codebook");
}

#[test]
fn single_shard_fleet_is_bitwise_reproducible() {
    assert_bitwise_reproducible(1);
}

#[test]
fn sharded_fleet_is_bitwise_reproducible() {
    assert_bitwise_reproducible(4);
}

/// The two deployments share the seed but not the trajectory — sanity
/// check that sharding actually changes the partition (S = 4 trains four
/// independent 2-prototype fleets, not one 8-prototype fleet).
#[test]
fn sharded_and_unsharded_runs_differ() {
    let (_, _, f1) = run_once(1);
    let (_, _, f4) = run_once(4);
    assert_eq!(f1.len(), f4.len());
    assert_ne!(f1, f4);
}
