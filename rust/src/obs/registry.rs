//! Named metric registry: counters, gauges, histograms.
//!
//! Lookup is get-or-create behind a mutex over a sorted map; callers on
//! hot paths resolve their handles once at startup and afterwards touch
//! only atomics. Snapshots iterate the maps in name order so every
//! rendering (wire, JSON file, `dalvq top`) agrees on ordering.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering::Relaxed};
use std::sync::{Arc, Mutex};

use super::hist::{Histogram, HistogramSummary};

/// Monotone event counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn inc(&self) {
        self.0.fetch_add(1, Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Instantaneous level (queue depth, replication lag). Decrements
/// saturate at zero so a racing reader never sees a wrapped value.
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.store(v, Relaxed);
    }

    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Relaxed);
    }

    pub fn sub(&self, n: u64) {
        let _ = self
            .0
            .fetch_update(Relaxed, Relaxed, |v| Some(v.saturating_sub(n)));
    }

    pub fn get(&self) -> u64 {
        self.0.load(Relaxed)
    }
}

/// Name-keyed metric store.
#[derive(Debug, Default)]
pub struct Registry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    hists: Mutex<BTreeMap<String, Arc<Histogram>>>,
}

impl Registry {
    /// Get or create the counter `name`.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        get_or_create(&self.counters, name)
    }

    /// Get or create the gauge `name`.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        get_or_create(&self.gauges, name)
    }

    /// Get or create the histogram `name`.
    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        get_or_create(&self.hists, name)
    }

    /// All counters, name-sorted.
    pub fn counters(&self) -> Vec<(String, u64)> {
        let map = self.counters.lock().unwrap();
        map.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// All gauges, name-sorted.
    pub fn gauges(&self) -> Vec<(String, u64)> {
        let map = self.gauges.lock().unwrap();
        map.iter().map(|(k, v)| (k.clone(), v.get())).collect()
    }

    /// All histogram digests, name-sorted.
    pub fn histograms(&self) -> Vec<(String, HistogramSummary)> {
        let map = self.hists.lock().unwrap();
        map.iter().map(|(k, v)| (k.clone(), v.summary())).collect()
    }
}

fn get_or_create<T: Default>(
    map: &Mutex<BTreeMap<String, Arc<T>>>,
    name: &str,
) -> Arc<T> {
    let mut map = map.lock().unwrap();
    match map.get(name) {
        Some(existing) => Arc::clone(existing),
        None => {
            let created = Arc::new(T::default());
            map.insert(name.to_string(), Arc::clone(&created));
            created
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn get_or_create_returns_the_same_handle() {
        let r = Registry::default();
        let a = r.counter("hits");
        let b = r.counter("hits");
        a.inc();
        b.add(2);
        assert_eq!(r.counter("hits").get(), 3);
        assert_eq!(r.counters(), vec![("hits".to_string(), 3)]);
    }

    #[test]
    fn snapshots_are_name_sorted() {
        let r = Registry::default();
        r.gauge("zeta").set(1);
        r.gauge("alpha").set(2);
        let names: Vec<String> = r.gauges().into_iter().map(|(n, _)| n).collect();
        assert_eq!(names, vec!["alpha".to_string(), "zeta".to_string()]);
    }

    #[test]
    fn gauge_sub_saturates_at_zero() {
        let g = Gauge::default();
        g.add(3);
        g.sub(5);
        assert_eq!(g.get(), 0);
        g.set(7);
        g.sub(2);
        assert_eq!(g.get(), 5);
    }

    #[test]
    fn concurrent_counter_increments_all_land() {
        let r = Arc::new(Registry::default());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let r = Arc::clone(&r);
                std::thread::spawn(move || {
                    let c = r.counter("shared");
                    for _ in 0..10_000 {
                        c.inc();
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(r.counter("shared").get(), 80_000);
    }
}
