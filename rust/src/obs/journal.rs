//! Bounded ring-buffer event journal.
//!
//! Fleet lifecycle moments — checkpoint flushes, follower sync adoptions,
//! rebalance phases, slow queries — land here as leveled structured
//! events. The buffer is a fixed-capacity ring: old events fall off the
//! front, the monotone sequence number keeps falling-off observable, and
//! emission is one short mutex hold (all emitters are cold paths).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};
use std::sync::Mutex;
use std::time::{SystemTime, UNIX_EPOCH};

/// Event severity.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    Info,
    Warn,
    Error,
}

impl Level {
    pub fn as_u8(self) -> u8 {
        match self {
            Level::Info => 0,
            Level::Warn => 1,
            Level::Error => 2,
        }
    }

    pub fn from_u8(b: u8) -> Option<Level> {
        match b {
            0 => Some(Level::Info),
            1 => Some(Level::Warn),
            2 => Some(Level::Error),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            Level::Info => "info",
            Level::Warn => "warn",
            Level::Error => "error",
        }
    }
}

/// One structured journal entry.
#[derive(Debug, Clone, PartialEq)]
pub struct Event {
    /// Monotone per-journal sequence number (gaps at the front of
    /// [`Journal::recent`] mean events were evicted).
    pub seq: u64,
    /// Wall-clock milliseconds since the Unix epoch.
    pub ts_ms: u64,
    pub level: Level,
    /// Dot-separated event family, e.g. `checkpoint.flush`.
    pub kind: String,
    /// Human-readable detail line.
    pub message: String,
}

/// Fixed-capacity event ring.
#[derive(Debug)]
pub struct Journal {
    cap: usize,
    seq: AtomicU64,
    /// Latched on the first eviction so the ring carries exactly one
    /// self-describing `journal.evict` note: later gaps in `seq` are
    /// then expected wraparound, not silent data loss.
    evicted_once: AtomicBool,
    buf: Mutex<VecDeque<Event>>,
}

impl Journal {
    pub fn new(cap: usize) -> Self {
        let cap = cap.max(1);
        Self {
            cap,
            seq: AtomicU64::new(0),
            evicted_once: AtomicBool::new(false),
            buf: Mutex::new(VecDeque::with_capacity(cap)),
        }
    }

    /// Append an event, evicting the oldest if the ring is full. The
    /// first eviction journals an info of its own (inline — `emit` is
    /// not reentrant under the buffer lock), so a reader seeing a `seq`
    /// gap can tell a wrapped ring from a broken one.
    pub fn emit(&self, level: Level, kind: &str, message: String) {
        let mut buf = self.buf.lock().unwrap();
        // The notice goes in ahead of the triggering event so it never
        // displaces it (a capacity-1 ring must still keep the newest
        // real event).
        if buf.len() == self.cap && !self.evicted_once.swap(true, Relaxed) {
            let notice = Event {
                seq: self.seq.fetch_add(1, Relaxed),
                ts_ms: unix_ms(),
                level: Level::Info,
                kind: "journal.evict".to_string(),
                message: format!(
                    "journal ring full at {} entries; oldest events are now \
                     evicted as new ones land (raise --journal-capacity to \
                     retain more)",
                    self.cap
                ),
            };
            buf.pop_front();
            buf.push_back(notice);
        }
        let event = Event {
            seq: self.seq.fetch_add(1, Relaxed),
            ts_ms: unix_ms(),
            level,
            kind: kind.to_string(),
            message,
        };
        if buf.len() == self.cap {
            buf.pop_front();
        }
        buf.push_back(event);
    }

    pub fn info(&self, kind: &str, message: String) {
        self.emit(Level::Info, kind, message);
    }

    pub fn warn(&self, kind: &str, message: String) {
        self.emit(Level::Warn, kind, message);
    }

    pub fn error(&self, kind: &str, message: String) {
        self.emit(Level::Error, kind, message);
    }

    /// The newest `max` events, oldest first.
    pub fn recent(&self, max: usize) -> Vec<Event> {
        let buf = self.buf.lock().unwrap();
        let skip = buf.len().saturating_sub(max);
        buf.iter().skip(skip).cloned().collect()
    }

    /// Total events ever emitted (not just retained).
    pub fn emitted(&self) -> u64 {
        self.seq.load(Relaxed)
    }
}

fn unix_ms() -> u64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_millis() as u64)
        .unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_round_trip_through_u8() {
        for level in [Level::Info, Level::Warn, Level::Error] {
            assert_eq!(Level::from_u8(level.as_u8()), Some(level));
        }
        assert_eq!(Level::from_u8(3), None);
    }

    #[test]
    fn ring_wraps_and_keeps_the_newest() {
        let j = Journal::new(4);
        for i in 0..10 {
            j.info("tick", format!("event {i}"));
        }
        let recent = j.recent(100);
        assert_eq!(recent.len(), 4);
        // The four newest survive, in order, with their original seqs —
        // shifted by one because the first eviction injected its
        // `journal.evict` notice (seq 4) ahead of event 4.
        let seqs: Vec<u64> = recent.iter().map(|e| e.seq).collect();
        assert_eq!(seqs, vec![7, 8, 9, 10]);
        assert_eq!(recent[3].message, "event 9");
        assert_eq!(j.emitted(), 11, "10 events + the eviction notice");
    }

    #[test]
    fn first_eviction_journals_a_notice_exactly_once() {
        let j = Journal::new(4);
        for i in 0..4 {
            j.info("tick", format!("{i}"));
        }
        // No eviction yet, no notice.
        assert!(j.recent(10).iter().all(|e| e.kind != "journal.evict"));
        j.info("tick", "4".into()); // first eviction
        let notices: Vec<Event> = j
            .recent(10)
            .into_iter()
            .filter(|e| e.kind == "journal.evict")
            .collect();
        assert_eq!(notices.len(), 1);
        assert_eq!(notices[0].level, Level::Info);
        assert!(notices[0].message.contains("4 entries"), "{notices:?}");
        // Later evictions stay silent — the latch fired.
        j.info("tick", "5".into());
        j.info("tick", "6".into());
        let again = j
            .recent(10)
            .into_iter()
            .filter(|e| e.kind == "journal.evict")
            .count();
        assert_eq!(again, 1);
    }

    #[test]
    fn recent_caps_the_tail() {
        let j = Journal::new(16);
        for i in 0..8 {
            j.warn("w", format!("{i}"));
        }
        let tail = j.recent(3);
        assert_eq!(tail.len(), 3);
        assert_eq!(tail[0].message, "5");
        assert_eq!(tail[2].message, "7");
        assert_eq!(tail[0].level, Level::Warn);
    }

    #[test]
    fn zero_capacity_is_clamped_to_one() {
        let j = Journal::new(0);
        j.error("boom", "first".into());
        j.error("boom", "second".into());
        let recent = j.recent(10);
        assert_eq!(recent.len(), 1);
        assert_eq!(recent[0].message, "second");
    }
}
