//! Log-linear latency histogram with lock-free recording.
//!
//! Values are microseconds. Below [`LINEAR_MAX`] every value has its own
//! bucket (small latencies are exact); above, each power-of-two octave is
//! split into [`SUBS`] sub-buckets, which bounds the relative quantization
//! error of any reported percentile by `1 / SUBS` = 6.25%. Recording is a
//! single relaxed `fetch_add` per bucket plus a running sum and an exact
//! tracked max, so the request hot path never takes a lock.

use std::sync::atomic::{AtomicU64, Ordering::Relaxed};

use super::percentile::nearest_rank_index;

/// Values below this get one bucket each (exact).
const LINEAR_MAX: u64 = 16;
/// Sub-buckets per power-of-two octave.
const SUBS: u64 = 16;
/// Octaves covered above the linear range (top bit 4 through 63).
const OCTAVES: u64 = 60;
/// Total bucket count.
pub const NUM_BUCKETS: usize = (LINEAR_MAX + OCTAVES * SUBS) as usize;

/// Bucket index for a recorded value — total over all of `u64`.
fn bucket_index(v: u64) -> usize {
    if v < LINEAR_MAX {
        return v as usize;
    }
    let top = 63 - u64::from(v.leading_zeros()); // >= 4
    let offset = (v >> (top - 4)) - SUBS; // 0..SUBS
    (LINEAR_MAX + (top - 4) * SUBS + offset) as usize
}

/// Inclusive upper bound of a bucket — the value percentiles report.
fn bucket_high(idx: usize) -> u64 {
    let idx = idx as u64;
    if idx < LINEAR_MAX {
        return idx;
    }
    let octave = (idx - LINEAR_MAX) / SUBS;
    let offset = (idx - LINEAR_MAX) % SUBS;
    let width = 1u64 << octave;
    (SUBS + offset) * width + (width - 1)
}

/// Point-in-time digest of a [`Histogram`]. Empty histograms report zeros
/// (not NaN — the digest is serialized into JSON snapshots and onto the
/// wire, where NaN has no representation worth keeping).
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct HistogramSummary {
    /// Samples recorded.
    pub count: u64,
    /// Exact mean of the recorded values (microseconds).
    pub mean_us: f64,
    /// Nearest-rank percentiles over the bucketed distribution
    /// (microseconds, quantized to at most 6.25% relative error and
    /// clamped to the exact max).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Exact maximum recorded value (microseconds).
    pub max_us: f64,
}

/// Lock-free log-linear histogram of microsecond durations.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Self {
        Self {
            buckets: (0..NUM_BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }
}

impl std::fmt::Debug for Histogram {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Histogram").field("summary", &self.summary()).finish()
    }
}

impl Histogram {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one duration in microseconds.
    pub fn record(&self, v_us: u64) {
        self.buckets[bucket_index(v_us)].fetch_add(1, Relaxed);
        self.sum.fetch_add(v_us, Relaxed);
        self.max.fetch_max(v_us, Relaxed);
    }

    /// Digest the current distribution.
    pub fn summary(&self) -> HistogramSummary {
        let counts: Vec<u64> =
            self.buckets.iter().map(|b| b.load(Relaxed)).collect();
        let count: u64 = counts.iter().sum();
        if count == 0 {
            return HistogramSummary::default();
        }
        let max = self.max.load(Relaxed);
        let pct = |q: f64| percentile_of(&counts, count, q).min(max as f64);
        HistogramSummary {
            count,
            mean_us: self.sum.load(Relaxed) as f64 / count as f64,
            p50_us: pct(0.50),
            p95_us: pct(0.95),
            p99_us: pct(0.99),
            max_us: max as f64,
        }
    }
}

/// Nearest-rank percentile over bucket counts: find the bucket holding
/// the rank-`q` sample and report its upper bound.
fn percentile_of(counts: &[u64], total: u64, q: f64) -> f64 {
    let Some(rank) = nearest_rank_index(total as usize, q) else {
        return 0.0;
    };
    let mut seen = 0u64;
    for (i, &c) in counts.iter().enumerate() {
        seen += c;
        if seen > rank as u64 {
            return bucket_high(i) as f64;
        }
    }
    0.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_range_buckets_are_exact() {
        for v in 0..LINEAR_MAX {
            assert_eq!(bucket_index(v), v as usize);
            assert_eq!(bucket_high(v as usize), v);
        }
    }

    #[test]
    fn octave_boundaries_land_where_designed() {
        // First log bucket starts exactly at LINEAR_MAX.
        assert_eq!(bucket_index(15), 15);
        assert_eq!(bucket_index(16), 16);
        assert_eq!(bucket_index(31), 31);
        // Next octave: width-2 buckets.
        assert_eq!(bucket_index(32), 32);
        assert_eq!(bucket_index(33), 32);
        assert_eq!(bucket_index(34), 33);
        assert_eq!(bucket_high(32), 33);
        // The top of u64 still maps inside the table.
        assert_eq!(bucket_index(u64::MAX), NUM_BUCKETS - 1);
        assert_eq!(bucket_high(NUM_BUCKETS - 1), u64::MAX);
    }

    #[test]
    fn bucket_index_is_monotone_and_high_bounds_hold() {
        let mut prev = 0usize;
        let mut v = 1u64;
        while v < u64::MAX / 3 {
            let idx = bucket_index(v);
            assert!(idx >= prev, "index regressed at {v}");
            assert!(bucket_high(idx) >= v, "upper bound below value at {v}");
            // Relative quantization error is bounded by 1/SUBS.
            if v >= LINEAR_MAX {
                let err = (bucket_high(idx) - v) as f64 / v as f64;
                assert!(err <= 1.0 / SUBS as f64 + 1e-12, "err {err} at {v}");
            }
            prev = idx;
            v = v * 3 + 1;
        }
    }

    #[test]
    fn empty_summary_is_all_zeros() {
        let s = Histogram::new().summary();
        assert_eq!(s, HistogramSummary::default());
    }

    #[test]
    fn small_values_give_exact_percentiles() {
        // Everything below LINEAR_MAX is bucketed exactly, so the
        // histogram's nearest-rank percentiles match the definition
        // applied to the raw sorted series.
        let h = Histogram::new();
        let series: Vec<u64> = (0..=15).chain(0..=15).collect();
        for &v in &series {
            h.record(v);
        }
        let mut sorted = series.clone();
        sorted.sort_unstable();
        let s = h.summary();
        let expect = |q: f64| sorted[nearest_rank_index(sorted.len(), q).unwrap()] as f64;
        assert_eq!(s.p50_us, expect(0.50));
        assert_eq!(s.p95_us, expect(0.95));
        assert_eq!(s.p99_us, expect(0.99));
        assert_eq!(s.max_us, 15.0);
        assert_eq!(s.count, 32);
    }

    #[test]
    fn large_values_stay_within_error_bound() {
        let h = Histogram::new();
        for i in 0..1000u64 {
            h.record(1_000 + i * 37);
        }
        let s = h.summary();
        // p50 of 1000..~38000 with uniform spacing: true median ~ 19500.
        let true_p50 = 1_000.0 + 499.0 * 37.0;
        assert!((s.p50_us - true_p50).abs() / true_p50 <= 1.0 / 16.0 + 1e-9);
        assert_eq!(s.max_us, (1_000 + 999 * 37) as f64);
        assert!(s.p99_us <= s.max_us);
    }

    #[test]
    fn concurrent_increments_lose_nothing() {
        let h = std::sync::Arc::new(Histogram::new());
        let threads: Vec<_> = (0..8)
            .map(|t| {
                let h = std::sync::Arc::clone(&h);
                std::thread::spawn(move || {
                    for i in 0..10_000u64 {
                        h.record(t * 1_000 + i % 100);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        let s = h.summary();
        assert_eq!(s.count, 80_000);
        assert_eq!(s.max_us, (7 * 1_000 + 99) as f64);
    }
}
