//! Nearest-rank percentile selection.
//!
//! One definition, shared by the load generator's client-side latency
//! report and the server-side latency histograms, so the two sides of a
//! benchmark quote the same statistic: the sample at index
//! `round((len - 1) * q)` of the sorted series.

/// Index of the nearest-rank `q`-quantile in a sorted series of `len`
/// samples (`q` in `[0, 1]`). Returns `None` on an empty series.
pub fn nearest_rank_index(len: usize, q: f64) -> Option<usize> {
    if len == 0 {
        return None;
    }
    let idx = ((len - 1) as f64 * q).round() as usize;
    Some(idx.min(len - 1))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_series_has_no_rank() {
        assert_eq!(nearest_rank_index(0, 0.5), None);
        assert_eq!(nearest_rank_index(0, 0.0), None);
    }

    #[test]
    fn single_sample_is_every_percentile() {
        for q in [0.0, 0.5, 0.95, 0.99, 1.0] {
            assert_eq!(nearest_rank_index(1, q), Some(0));
        }
    }

    #[test]
    fn extremes_pick_first_and_last() {
        assert_eq!(nearest_rank_index(100, 0.0), Some(0));
        assert_eq!(nearest_rank_index(100, 1.0), Some(99));
    }

    #[test]
    fn known_series_ranks() {
        // 101 samples: rank(q) = round(100 q), exactly.
        assert_eq!(nearest_rank_index(101, 0.50), Some(50));
        assert_eq!(nearest_rank_index(101, 0.95), Some(95));
        assert_eq!(nearest_rank_index(101, 0.99), Some(99));
        // Two samples: the median rounds up to the second.
        assert_eq!(nearest_rank_index(2, 0.5), Some(1));
        assert_eq!(nearest_rank_index(2, 0.49), Some(0));
    }

    #[test]
    fn out_of_range_q_is_clamped() {
        assert_eq!(nearest_rank_index(10, 2.0), Some(9));
    }
}
