//! Distributed request tracing: the causal companion to the metric plane.
//!
//! Histograms say *how much* time each stage takes in aggregate; a trace
//! says *where one particular request's* wall clock went, across process
//! boundaries. The paper's decomposition — per-point compute vs.
//! communication vs. synchronization delay — becomes a span tree: one
//! 128-bit trace id names a causal unit (a request, a follower sync
//! cycle, a training exchange), and every layer that touches it records
//! named child spans with microsecond offsets relative to the trace
//! root.
//!
//! Design constraints mirror the registry's: recording is allocation-
//! light and lock-free (a [`TraceBuilder`] is owned by exactly one
//! thread — the connection handler, the sync loop, a worker — so span
//! appends are plain `Vec` pushes); the only shared state is the bounded
//! ring of *completed* traces behind a mutex, touched once per sampled
//! unit at commit, never per span.
//!
//! Sampling is deterministic 1-in-N (`--trace-sample N`; 0 = off, 1 =
//! every unit) with two always-keep overrides: units over the
//! `--slow-query-us` threshold, and units that arrived with a wire
//! trace context (a remote caller already paid for the trace — dropping
//! our half would orphan theirs).

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard};
use std::time::{Instant, SystemTime, UNIX_EPOCH};

/// Parent id of a root span (span ids start at 1, so 0 is never taken).
pub const NO_PARENT: u64 = 0;

/// How many completed traces the ring retains (oldest evicted). Small on
/// purpose: a trace is for looking at, not for aggregating — the
/// histograms already do that.
pub const TRACE_RING_CAP: usize = 64;

/// One recorded span: a named interval inside a trace, in microseconds
/// relative to the trace root's start.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRec {
    /// Span id, unique within the trace (never 0).
    pub id: u64,
    /// Parent span id, or [`NO_PARENT`] for the root.
    pub parent: u64,
    /// Catalog name (`req.nearest`, `scan`, `state.ship`, …).
    pub name: String,
    /// Start offset from the trace origin, µs.
    pub start_us: u64,
    /// Duration, µs (0 while the span is still open).
    pub dur_us: u64,
}

/// A committed trace: id, commit wall-clock, and the finished span tree.
#[derive(Debug, Clone, PartialEq)]
pub struct FinishedTrace {
    /// High 64 bits of the 128-bit trace id.
    pub hi: u64,
    /// Low 64 bits of the 128-bit trace id.
    pub lo: u64,
    /// Unix-epoch milliseconds at commit.
    pub ts_ms: u64,
    /// Spans in recording order (the root is first).
    pub spans: Vec<SpanRec>,
}

impl FinishedTrace {
    /// The 32-hex-digit rendering of the 128-bit id (what `dalvq trace`
    /// prints and the loadgen report names).
    pub fn id_hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }

    /// End of the latest-ending span: the trace's total extent, µs.
    pub fn total_us(&self) -> u64 {
        self.spans.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(0)
    }
}

/// A single-owner span recorder for one causal unit. Not shared, not
/// `Sync` by construction (every method takes `&mut self`): the owning
/// thread appends spans with plain pushes and hands the whole builder to
/// [`Tracer::commit`] when the unit completes.
#[derive(Debug)]
pub struct TraceBuilder {
    hi: u64,
    lo: u64,
    /// The trace origin: span offsets are measured from here.
    t0: Instant,
    /// Deterministically sampled at start (the 1-in-N draw); commit also
    /// keeps forced and over-threshold traces.
    lucky: bool,
    /// Arrived with a wire trace context — always kept.
    forced: bool,
    next_id: u64,
    spans: Vec<SpanRec>,
    /// Open spans: (span id, start instant) — a handful at most, so a
    /// linear scan beats any map.
    open: Vec<(u64, Instant)>,
}

impl TraceBuilder {
    fn new(hi: u64, lo: u64, lucky: bool, forced: bool, t0: Instant) -> Self {
        Self { hi, lo, t0, lucky, forced, next_id: 1, spans: Vec::new(), open: Vec::new() }
    }

    /// The 128-bit trace id as (hi, lo) — what goes on the wire.
    pub fn trace_id(&self) -> (u64, u64) {
        (self.hi, self.lo)
    }

    /// True when a wire context forced this trace (it will be kept at
    /// commit regardless of the sampler).
    pub fn forced(&self) -> bool {
        self.forced
    }

    /// Microseconds elapsed since the trace origin.
    pub fn now_us(&self) -> u64 {
        self.t0.elapsed().as_micros() as u64
    }

    /// Open a span starting now; returns its id for `end` / child spans.
    pub fn begin(&mut self, name: &str, parent: u64) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        let now = Instant::now();
        self.spans.push(SpanRec {
            id,
            parent,
            name: name.to_string(),
            start_us: now.duration_since(self.t0).as_micros() as u64,
            dur_us: 0,
        });
        self.open.push((id, now));
        id
    }

    /// Close an open span (a double-end or unknown id is a no-op — a
    /// tracing slip must never take down the request it observes).
    pub fn end(&mut self, id: u64) {
        let Some(pos) = self.open.iter().position(|(i, _)| *i == id) else {
            return;
        };
        let (_, started) = self.open.swap_remove(pos);
        let dur = started.elapsed().as_micros() as u64;
        if let Some(s) = self.spans.iter_mut().find(|s| s.id == id) {
            s.dur_us = dur;
        }
    }

    /// Record a span with explicit offsets — for stages whose timing was
    /// measured elsewhere (the stage timers, a coalesced drain) and is
    /// being replayed into the tree.
    pub fn add(
        &mut self,
        name: &str,
        parent: u64,
        start_us: u64,
        dur_us: u64,
    ) -> u64 {
        let id = self.next_id;
        self.next_id += 1;
        self.spans.push(SpanRec {
            id,
            parent,
            name: name.to_string(),
            start_us,
            dur_us,
        });
        id
    }

    /// Graft a remote process's spans (same trace id, shipped back over
    /// the wire) under `parent`: every remote span gets a fresh local id
    /// (parent links preserved), and remote offsets — relative to the
    /// *remote* origin — are re-anchored at `anchor_us` on the local
    /// timeline. Remote spans whose parent is not in the shipment attach
    /// to `parent` — that is how the remote root lands: the server ships
    /// it detached (parent 0), because span ids are sequential in both
    /// processes and a raw foreign parent id could collide with one of
    /// the shipment's own ids.
    pub fn graft(
        &mut self,
        parent: u64,
        anchor_us: u64,
        remote: &[SpanRec],
    ) {
        let mut id_map: Vec<(u64, u64)> = Vec::with_capacity(remote.len());
        for r in remote {
            id_map.push((r.id, self.next_id));
            self.next_id += 1;
        }
        let local = |rid: u64| id_map.iter().find(|(r, _)| *r == rid);
        for r in remote {
            let id = local(r.id).expect("just mapped").1;
            let mapped_parent = match local(r.parent) {
                Some((_, l)) => *l,
                None => parent,
            };
            self.spans.push(SpanRec {
                id,
                parent: mapped_parent,
                name: r.name.clone(),
                start_us: anchor_us.saturating_add(r.start_us),
                dur_us: r.dur_us,
            });
        }
    }

    /// The spans recorded so far (open spans carry `dur_us = 0`).
    pub fn spans(&self) -> &[SpanRec] {
        &self.spans
    }

    /// End of the latest-ending recorded span, µs from the origin.
    pub fn total_us(&self) -> u64 {
        self.spans.iter().map(|s| s.start_us + s.dur_us).max().unwrap_or(0)
    }
}

/// The shared tracing plane: sampling policy + the ring of completed
/// traces. One per [`super::Telemetry`].
#[derive(Debug)]
pub struct Tracer {
    /// 0 = tracing off, 1 = every unit, N = deterministic 1-in-N.
    sample_n: AtomicU64,
    /// Always-keep threshold, µs (0 = no threshold). Mirrors
    /// `--slow-query-us`, so the slow-query journal line and the kept
    /// trace name the same request.
    slow_us: AtomicU64,
    /// The 1-in-N rotor.
    draw: AtomicU64,
    /// Trace-id sequence (mixed with wall clock so ids are unique across
    /// processes, not just within one).
    seq: AtomicU64,
    /// Traces kept at commit (the `trace.sampled` counter's source).
    committed: AtomicU64,
    ring: Mutex<VecDeque<FinishedTrace>>,
    cap: usize,
}

impl Tracer {
    /// A tracer retaining at most `cap` completed traces, initially off.
    pub fn new(cap: usize) -> Self {
        Self {
            sample_n: AtomicU64::new(0),
            slow_us: AtomicU64::new(0),
            draw: AtomicU64::new(0),
            seq: AtomicU64::new(0),
            committed: AtomicU64::new(0),
            ring: Mutex::new(VecDeque::new()),
            cap: cap.max(1),
        }
    }

    /// Arm (or disarm) sampling: `sample_n` as in `--trace-sample`,
    /// `slow_us` the always-keep threshold shared with the slow-query
    /// log.
    pub fn configure(&self, sample_n: u64, slow_us: u64) {
        self.sample_n.store(sample_n, Ordering::Relaxed);
        self.slow_us.store(slow_us, Ordering::Relaxed);
    }

    /// Whether any tracing is armed at all (the hot-path early-out).
    pub fn armed(&self) -> bool {
        self.sample_n.load(Ordering::Relaxed) > 0
    }

    /// Traces kept at commit since startup.
    pub fn committed(&self) -> u64 {
        self.committed.load(Ordering::Relaxed)
    }

    /// One deterministic 1-in-N draw.
    fn draw_lucky(&self) -> bool {
        match self.sample_n.load(Ordering::Relaxed) {
            0 => false,
            1 => true,
            n => self.draw.fetch_add(1, Ordering::Relaxed) % n == 0,
        }
    }

    /// A fresh 128-bit trace id: a sequence counter mixed with the wall
    /// clock through splitmix64, so two processes started in the same
    /// millisecond still diverge.
    fn fresh_id(&self) -> (u64, u64) {
        let nanos = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map_or(0, |d| d.as_nanos() as u64);
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        (splitmix64(nanos ^ seq.rotate_left(32)), splitmix64(seq ^ nanos.rotate_left(17)))
    }

    /// Start a locally-rooted trace with origin `t0` (pass the instant
    /// the unit actually began — e.g. when its frame arrived — so the
    /// decode span can be replayed at offset 0). `None` when tracing is
    /// off: the caller then records nothing at all.
    pub fn begin_at(&self, t0: Instant) -> Option<TraceBuilder> {
        if !self.armed() {
            return None;
        }
        let (hi, lo) = self.fresh_id();
        Some(TraceBuilder::new(hi, lo, self.draw_lucky(), false, t0))
    }

    /// Start a locally-rooted trace with origin now.
    pub fn begin(&self) -> Option<TraceBuilder> {
        self.begin_at(Instant::now())
    }

    /// Start a trace continuing a wire context: the remote caller's
    /// trace id is adopted and the commit is unconditional. Available
    /// even when local sampling is off — the remote side already decided
    /// this unit is worth a trace.
    pub fn begin_forced_at(
        &self,
        hi: u64,
        lo: u64,
        t0: Instant,
    ) -> TraceBuilder {
        TraceBuilder::new(hi, lo, false, true, t0)
    }

    /// Commit a finished unit: kept when it was forced, won its 1-in-N
    /// draw, or ran past the slow threshold; dropped (cheaply) otherwise.
    /// Returns whether it was kept.
    pub fn commit(&self, tb: TraceBuilder) -> bool {
        let slow = self.slow_us.load(Ordering::Relaxed);
        let keep =
            tb.forced || tb.lucky || (slow > 0 && tb.total_us() >= slow);
        if !keep {
            return false;
        }
        let ts_ms = SystemTime::now()
            .duration_since(UNIX_EPOCH)
            .map_or(0, |d| d.as_millis() as u64);
        let trace =
            FinishedTrace { hi: tb.hi, lo: tb.lo, ts_ms, spans: tb.spans };
        let mut ring = self.ring();
        if ring.len() == self.cap {
            ring.pop_front();
        }
        ring.push_back(trace);
        drop(ring);
        self.committed.fetch_add(1, Ordering::Relaxed);
        true
    }

    /// The newest `max` completed traces, newest first.
    pub fn recent(&self, max: usize) -> Vec<FinishedTrace> {
        self.ring().iter().rev().take(max).cloned().collect()
    }

    fn ring(&self) -> MutexGuard<'_, VecDeque<FinishedTrace>> {
        self.ring.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// `Option<&mut TraceBuilder>` — the shape every traced layer threads
/// through: `None` costs one branch, `Some` costs a `Vec` push per span.
pub type TraceSink<'a> = Option<&'a mut TraceBuilder>;

/// SplitMix64: the standard 64-bit finalizer (public-domain constants),
/// enough mixing that sequential seeds yield unrelated-looking ids.
fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    x = (x ^ (x >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_tracer_starts_nothing() {
        let t = Tracer::new(4);
        assert!(!t.armed());
        assert!(t.begin().is_none());
    }

    #[test]
    fn always_sampling_keeps_every_commit() {
        let t = Tracer::new(4);
        t.configure(1, 0);
        for _ in 0..3 {
            let mut tb = t.begin().unwrap();
            let root = tb.begin("req.nearest", NO_PARENT);
            tb.end(root);
            assert!(t.commit(tb));
        }
        assert_eq!(t.committed(), 3);
        assert_eq!(t.recent(10).len(), 3);
    }

    #[test]
    fn one_in_n_sampling_is_deterministic() {
        let t = Tracer::new(64);
        t.configure(4, 0);
        let kept: Vec<bool> = (0..12)
            .map(|_| {
                let tb = t.begin().unwrap();
                t.commit(tb)
            })
            .collect();
        let hits = kept.iter().filter(|k| **k).count();
        assert_eq!(hits, 3, "{kept:?}");
        // the rotor is a strict 1-in-4: every 4th draw wins
        assert!(kept[0] && kept[4] && kept[8], "{kept:?}");
    }

    #[test]
    fn slow_units_are_kept_even_when_the_draw_loses() {
        let t = Tracer::new(4);
        t.configure(1_000_000, 50); // draw practically never wins
        let mut tb = t.begin().unwrap();
        tb.add("req.nearest", NO_PARENT, 0, 75); // over the 50 µs bar
        assert!(t.commit(tb));
        let mut tb = t.begin().unwrap();
        tb.add("req.nearest", NO_PARENT, 0, 10); // under it
        assert!(!t.commit(tb));
    }

    #[test]
    fn forced_traces_adopt_the_wire_id_and_always_commit() {
        let t = Tracer::new(4);
        // local sampling entirely off — the wire context still traces
        let mut tb = t.begin_forced_at(7, 9, Instant::now());
        assert_eq!(tb.trace_id(), (7, 9));
        let root = tb.begin("req.fetch_state", NO_PARENT);
        tb.end(root);
        assert!(t.commit(tb));
        let got = &t.recent(1)[0];
        assert_eq!((got.hi, got.lo), (7, 9));
    }

    #[test]
    fn ring_is_bounded_and_newest_first() {
        let t = Tracer::new(2);
        t.configure(1, 0);
        for i in 0..5u64 {
            let mut tb = t.begin().unwrap();
            tb.add("tick", NO_PARENT, i, 1);
            t.commit(tb);
        }
        let recent = t.recent(10);
        assert_eq!(recent.len(), 2);
        // newest first: the last-committed trace leads
        assert_eq!(recent[0].spans[0].start_us, 4);
        assert_eq!(recent[1].spans[0].start_us, 3);
        assert_eq!(t.committed(), 5, "eviction does not uncount commits");
    }

    #[test]
    fn span_tree_records_offsets_parents_and_explicit_stages() {
        let t = Tracer::new(4);
        t.configure(1, 0);
        let mut tb = t.begin().unwrap();
        let root = tb.begin("req.nearest", NO_PARENT);
        tb.add("decode", root, 0, 12);
        let scan = tb.begin("scan", root);
        tb.end(scan);
        tb.end(root);
        assert!(t.commit(tb));
        let trace = &t.recent(1)[0];
        assert_eq!(trace.spans.len(), 3);
        let root_rec = &trace.spans[0];
        assert_eq!(root_rec.name, "req.nearest");
        assert_eq!(root_rec.parent, NO_PARENT);
        for child in &trace.spans[1..] {
            assert_eq!(child.parent, root_rec.id);
        }
        assert!(trace.total_us() >= 12);
        assert_eq!(trace.id_hex().len(), 32);
    }

    #[test]
    fn ending_an_unknown_span_is_a_no_op() {
        let t = Tracer::new(4);
        t.configure(1, 0);
        let mut tb = t.begin().unwrap();
        tb.end(99); // nothing open — must not panic
        let s = tb.begin("x", NO_PARENT);
        tb.end(s);
        tb.end(s); // double end — still fine
        assert!(t.commit(tb));
    }

    #[test]
    fn graft_remaps_ids_reanchors_offsets_and_preserves_structure() {
        let t = Tracer::new(4);
        t.configure(1, 0);
        let mut tb = t.begin().unwrap();
        let root = tb.begin("sync.cycle", NO_PARENT);
        let fetch = tb.begin("sync.fetch", root);
        // a remote tree: root (id 1) with two children, offsets relative
        // to the remote origin
        let remote = vec![
            SpanRec {
                id: 1,
                parent: 0,
                name: "req.fetch_state".into(),
                start_us: 0,
                dur_us: 40,
            },
            SpanRec {
                id: 2,
                parent: 1,
                name: "state.cut".into(),
                start_us: 5,
                dur_us: 20,
            },
            SpanRec {
                id: 3,
                parent: 1,
                name: "state.ship".into(),
                start_us: 25,
                dur_us: 10,
            },
        ];
        tb.graft(fetch, 100, &remote);
        tb.end(fetch);
        tb.end(root);
        t.commit(tb);
        let trace = &t.recent(1)[0];
        let by_name = |n: &str| {
            trace.spans.iter().find(|s| s.name == n).unwrap_or_else(|| {
                panic!("no span {n} in {:?}", trace.spans)
            })
        };
        let remote_root = by_name("req.fetch_state");
        assert_eq!(remote_root.parent, fetch, "remote root hangs off fetch");
        assert_eq!(remote_root.start_us, 100, "re-anchored at the rpc start");
        let cut = by_name("state.cut");
        assert_eq!(cut.parent, remote_root.id, "remote structure preserved");
        assert_eq!(cut.start_us, 105);
        let ship = by_name("state.ship");
        assert_eq!(ship.parent, remote_root.id);
        assert_eq!(ship.start_us, 125);
        // grafted ids never collide with local ones
        let mut ids: Vec<u64> = trace.spans.iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), trace.spans.len());
    }

    #[test]
    fn graft_attaches_unknown_parent_spans_under_the_graft_point() {
        // A remote span that kept a foreign parent id (one that is not
        // in the shipment) still lands under the graft point — never
        // dropped, never left dangling.
        let t = Tracer::new(4);
        t.configure(1, 0);
        let mut tb = t.begin().unwrap();
        let fetch = tb.begin("sync.fetch", NO_PARENT);
        let remote = vec![SpanRec {
            id: 1,
            parent: 777, // lives in some other process's ring
            name: "req.fetch_state".into(),
            start_us: 0,
            dur_us: 5,
        }];
        tb.graft(fetch, 10, &remote);
        tb.end(fetch);
        let grafted = tb
            .spans()
            .iter()
            .find(|s| s.name == "req.fetch_state")
            .unwrap()
            .clone();
        assert_eq!(grafted.parent, fetch);
        assert_eq!(grafted.start_us, 10);
        t.commit(tb);
    }
}
