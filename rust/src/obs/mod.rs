//! Server-side telemetry plane.
//!
//! The serving fleet's in-process observability: what the paper measures
//! from the outside (where wall-clock time goes — compute vs. routing vs.
//! synchronization) this module measures from the inside, live.
//!
//! - [`Registry`]: named atomic [`Counter`]s, [`Gauge`]s and log-linear
//!   latency [`Histogram`]s — lock-free on the record path, name-sorted
//!   in snapshots.
//! - [`Journal`]: a bounded ring of leveled structured [`Event`]s for
//!   fleet lifecycle moments (checkpoint flushes, sync adoptions,
//!   rebalance phases, slow queries).
//! - [`Tracer`] / [`TraceBuilder`]: distributed request tracing — 128-bit
//!   trace ids, per-unit span trees, deterministic 1-in-N sampling, and a
//!   bounded ring of completed traces. Trace context rides the wire
//!   (`docs/PROTOCOL.md`), so one trace spans client, leader and
//!   follower.
//! - [`Telemetry`]: one registry + journal + tracer + start instant,
//!   owned by a [`crate::serve::VqService`] and exposed three ways — the
//!   `Metrics`/`Trace` wire ops, `dalvq top` / `dalvq trace`, and
//!   `--metrics-file` JSON snapshots.
//! - [`nearest_rank_index`]: the percentile definition shared with the
//!   load generator, so server-side and client-side p99 are the same
//!   statistic.

mod hist;
mod journal;
mod percentile;
mod registry;
mod trace;

use std::sync::Arc;
use std::time::Instant;

use crate::util::Json;

pub use hist::{Histogram, HistogramSummary, NUM_BUCKETS};
pub use journal::{Event, Journal, Level};
pub use percentile::nearest_rank_index;
pub use registry::{Counter, Gauge, Registry};
pub use trace::{
    FinishedTrace, SpanRec, TraceBuilder, TraceSink, Tracer, NO_PARENT,
    TRACE_RING_CAP,
};

/// How many completed traces a snapshot carries (the ring may hold
/// more; `--metrics-file` and the `Metrics` path stay bounded).
pub const SNAPSHOT_TRACES: usize = 16;

/// One service's telemetry: metric registry, event journal, tracer,
/// start time.
#[derive(Debug)]
pub struct Telemetry {
    registry: Registry,
    journal: Arc<Journal>,
    tracer: Tracer,
    start: Instant,
}

impl Telemetry {
    /// A fresh plane retaining at most `journal_cap` events. The tracer
    /// comes up disarmed; [`Tracer::configure`] turns sampling on.
    pub fn new(journal_cap: usize) -> Arc<Self> {
        Arc::new(Self {
            registry: Registry::default(),
            journal: Arc::new(Journal::new(journal_cap)),
            tracer: Tracer::new(TRACE_RING_CAP),
            start: Instant::now(),
        })
    }

    /// The distributed-tracing plane (sampling policy + completed-trace
    /// ring).
    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.registry.counter(name)
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.registry.gauge(name)
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.registry.histogram(name)
    }

    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Milliseconds since this plane (and its service) came up.
    pub fn uptime_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Point-in-time digest of everything: all metrics plus the newest
    /// `max_events` journal entries and [`SNAPSHOT_TRACES`] completed
    /// traces. `trace.sampled` is synthesized into the counter list from
    /// the tracer's commit count (it has no registry entry of its own),
    /// preserving name order; it only appears once tracing has ever been
    /// armed or kept a trace, so untraced deployments see an unchanged
    /// catalog.
    pub fn snapshot(&self, max_events: usize) -> TelemetrySnapshot {
        let mut counters = self.registry.counters();
        let committed = self.tracer.committed();
        if committed > 0 || self.tracer.armed() {
            let at = counters
                .binary_search_by(|(n, _)| n.as_str().cmp("trace.sampled"));
            match at {
                Ok(i) => counters[i].1 = committed,
                Err(i) => {
                    counters.insert(i, ("trace.sampled".to_string(), committed))
                }
            }
        }
        TelemetrySnapshot {
            uptime_ms: self.uptime_ms(),
            counters,
            gauges: self.registry.gauges(),
            hists: self.registry.histograms(),
            events: self.journal.recent(max_events),
            traces: self.tracer.recent(SNAPSHOT_TRACES),
        }
    }
}

/// A consistent-enough digest of a [`Telemetry`] plane (each metric is
/// read atomically; the set is not a global atomic snapshot).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySnapshot {
    pub uptime_ms: u64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub hists: Vec<(String, HistogramSummary)>,
    pub events: Vec<Event>,
    pub traces: Vec<FinishedTrace>,
}

impl TelemetrySnapshot {
    /// The `--metrics-file` document: one JSON object a bench or CI step
    /// can parse and diff offline.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, v) in &self.counters {
            counters = counters.set(name.as_str(), *v);
        }
        let mut gauges = Json::obj();
        for (name, v) in &self.gauges {
            gauges = gauges.set(name.as_str(), *v);
        }
        let mut hists = Json::obj();
        for (name, s) in &self.hists {
            hists = hists.set(
                name.as_str(),
                Json::obj()
                    .set("count", s.count)
                    .set("mean_us", s.mean_us)
                    .set("p50_us", s.p50_us)
                    .set("p95_us", s.p95_us)
                    .set("p99_us", s.p99_us)
                    .set("max_us", s.max_us),
            );
        }
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj()
                    .set("seq", e.seq)
                    .set("ts_ms", e.ts_ms)
                    .set("level", e.level.label())
                    .set("kind", e.kind.as_str())
                    .set("message", e.message.as_str())
            })
            .collect();
        let traces: Vec<Json> = self
            .traces
            .iter()
            .map(|t| {
                let spans: Vec<Json> = t
                    .spans
                    .iter()
                    .map(|s| {
                        Json::obj()
                            .set("id", s.id)
                            .set("parent", s.parent)
                            .set("name", s.name.as_str())
                            .set("start_us", s.start_us)
                            .set("dur_us", s.dur_us)
                    })
                    .collect();
                Json::obj()
                    .set("trace_id", t.id_hex().as_str())
                    .set("ts_ms", t.ts_ms)
                    .set("total_us", t.total_us())
                    .set("spans", Json::Arr(spans))
            })
            .collect();
        Json::obj()
            .set("uptime_ms", self.uptime_ms)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
            .set("events", Json::Arr(events))
            .set("traces", Json::Arr(traces))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_metrics_and_events() {
        let t = Telemetry::new(8);
        t.counter("op.encode.requests").add(3);
        t.gauge("shard.0.queue_depth").set(2);
        t.histogram("op.encode.total_us").record(120);
        t.journal().info("sync.adopt", "generation 4".into());

        let snap = t.snapshot(16);
        assert_eq!(
            snap.counters,
            vec![("op.encode.requests".to_string(), 3)]
        );
        assert_eq!(snap.gauges, vec![("shard.0.queue_depth".to_string(), 2)]);
        assert_eq!(snap.hists.len(), 1);
        assert_eq!(snap.hists[0].1.count, 1);
        assert_eq!(snap.events.len(), 1);
        assert_eq!(snap.events[0].kind, "sync.adopt");
    }

    #[test]
    fn snapshot_json_round_trips_through_the_parser() {
        let t = Telemetry::new(8);
        t.counter("hits").inc();
        t.histogram("lat_us").record(42);
        t.journal().warn("slow_query", "nearest took 9ms".into());

        let text = t.snapshot(4).to_json().to_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.req("counters").unwrap().req("hits").unwrap().as_u64().unwrap(),
            1
        );
        let h = back.req("histograms").unwrap().req("lat_us").unwrap();
        assert_eq!(h.req("count").unwrap().as_u64().unwrap(), 1);
        let events = back.req("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(
            events[0].req("level").unwrap().as_str().unwrap(),
            "warn"
        );
    }

    #[test]
    fn snapshot_carries_traces_and_the_synthesized_sample_counter() {
        let t = Telemetry::new(8);
        // Disarmed: no trace.sampled counter, no traces.
        let snap = t.snapshot(4);
        assert!(snap.traces.is_empty());
        assert!(snap.counters.iter().all(|(n, _)| n != "trace.sampled"));

        t.tracer().configure(1, 0);
        t.counter("op.encode.requests").inc();
        t.counter("zz.last").inc();
        let mut tb = t.tracer().begin().unwrap();
        let root = tb.begin("req.nearest", NO_PARENT);
        tb.end(root);
        assert!(t.tracer().commit(tb));

        let snap = t.snapshot(4);
        assert_eq!(snap.traces.len(), 1);
        assert_eq!(snap.traces[0].spans[0].name, "req.nearest");
        // the synthesized counter lands in name-sorted position
        let names: Vec<&str> =
            snap.counters.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(
            names,
            vec!["op.encode.requests", "trace.sampled", "zz.last"]
        );
        let sampled = snap
            .counters
            .iter()
            .find(|(n, _)| n == "trace.sampled")
            .map(|(_, v)| *v);
        assert_eq!(sampled, Some(1));

        // ...and the JSON document renders the trace tree.
        let text = snap.to_json().to_pretty();
        let back = Json::parse(&text).unwrap();
        let traces = back.req("traces").unwrap().as_arr().unwrap();
        assert_eq!(traces.len(), 1);
        let id = traces[0].req("trace_id").unwrap().as_str().unwrap();
        assert_eq!(id.len(), 32);
        let spans = traces[0].req("spans").unwrap().as_arr().unwrap();
        assert_eq!(
            spans[0].req("name").unwrap().as_str().unwrap(),
            "req.nearest"
        );
    }

    #[test]
    fn events_are_capped_by_max_events() {
        let t = Telemetry::new(32);
        for i in 0..10 {
            t.journal().info("tick", format!("{i}"));
        }
        assert_eq!(t.snapshot(3).events.len(), 3);
        assert_eq!(t.snapshot(0).events.len(), 0);
    }
}
