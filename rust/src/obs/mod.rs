//! Server-side telemetry plane.
//!
//! The serving fleet's in-process observability: what the paper measures
//! from the outside (where wall-clock time goes — compute vs. routing vs.
//! synchronization) this module measures from the inside, live.
//!
//! - [`Registry`]: named atomic [`Counter`]s, [`Gauge`]s and log-linear
//!   latency [`Histogram`]s — lock-free on the record path, name-sorted
//!   in snapshots.
//! - [`Journal`]: a bounded ring of leveled structured [`Event`]s for
//!   fleet lifecycle moments (checkpoint flushes, sync adoptions,
//!   rebalance phases, slow queries).
//! - [`Telemetry`]: one registry + journal + start instant, owned by a
//!   [`crate::serve::VqService`] and exposed three ways — the `Metrics`
//!   wire op, `dalvq top`, and `--metrics-file` JSON snapshots.
//! - [`nearest_rank_index`]: the percentile definition shared with the
//!   load generator, so server-side and client-side p99 are the same
//!   statistic.

mod hist;
mod journal;
mod percentile;
mod registry;

use std::sync::Arc;
use std::time::Instant;

use crate::util::Json;

pub use hist::{Histogram, HistogramSummary, NUM_BUCKETS};
pub use journal::{Event, Journal, Level};
pub use percentile::nearest_rank_index;
pub use registry::{Counter, Gauge, Registry};

/// One service's telemetry: metric registry, event journal, start time.
#[derive(Debug)]
pub struct Telemetry {
    registry: Registry,
    journal: Arc<Journal>,
    start: Instant,
}

impl Telemetry {
    /// A fresh plane retaining at most `journal_cap` events.
    pub fn new(journal_cap: usize) -> Arc<Self> {
        Arc::new(Self {
            registry: Registry::default(),
            journal: Arc::new(Journal::new(journal_cap)),
            start: Instant::now(),
        })
    }

    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.registry.counter(name)
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.registry.gauge(name)
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.registry.histogram(name)
    }

    pub fn journal(&self) -> &Arc<Journal> {
        &self.journal
    }

    /// Milliseconds since this plane (and its service) came up.
    pub fn uptime_ms(&self) -> u64 {
        self.start.elapsed().as_millis() as u64
    }

    /// Point-in-time digest of everything: all metrics plus the newest
    /// `max_events` journal entries.
    pub fn snapshot(&self, max_events: usize) -> TelemetrySnapshot {
        TelemetrySnapshot {
            uptime_ms: self.uptime_ms(),
            counters: self.registry.counters(),
            gauges: self.registry.gauges(),
            hists: self.registry.histograms(),
            events: self.journal.recent(max_events),
        }
    }
}

/// A consistent-enough digest of a [`Telemetry`] plane (each metric is
/// read atomically; the set is not a global atomic snapshot).
#[derive(Debug, Clone, PartialEq)]
pub struct TelemetrySnapshot {
    pub uptime_ms: u64,
    pub counters: Vec<(String, u64)>,
    pub gauges: Vec<(String, u64)>,
    pub hists: Vec<(String, HistogramSummary)>,
    pub events: Vec<Event>,
}

impl TelemetrySnapshot {
    /// The `--metrics-file` document: one JSON object a bench or CI step
    /// can parse and diff offline.
    pub fn to_json(&self) -> Json {
        let mut counters = Json::obj();
        for (name, v) in &self.counters {
            counters = counters.set(name.as_str(), *v);
        }
        let mut gauges = Json::obj();
        for (name, v) in &self.gauges {
            gauges = gauges.set(name.as_str(), *v);
        }
        let mut hists = Json::obj();
        for (name, s) in &self.hists {
            hists = hists.set(
                name.as_str(),
                Json::obj()
                    .set("count", s.count)
                    .set("mean_us", s.mean_us)
                    .set("p50_us", s.p50_us)
                    .set("p95_us", s.p95_us)
                    .set("p99_us", s.p99_us)
                    .set("max_us", s.max_us),
            );
        }
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj()
                    .set("seq", e.seq)
                    .set("ts_ms", e.ts_ms)
                    .set("level", e.level.label())
                    .set("kind", e.kind.as_str())
                    .set("message", e.message.as_str())
            })
            .collect();
        Json::obj()
            .set("uptime_ms", self.uptime_ms)
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
            .set("events", Json::Arr(events))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn snapshot_carries_metrics_and_events() {
        let t = Telemetry::new(8);
        t.counter("op.encode.requests").add(3);
        t.gauge("shard.0.queue_depth").set(2);
        t.histogram("op.encode.total_us").record(120);
        t.journal().info("sync.adopt", "generation 4".into());

        let snap = t.snapshot(16);
        assert_eq!(
            snap.counters,
            vec![("op.encode.requests".to_string(), 3)]
        );
        assert_eq!(snap.gauges, vec![("shard.0.queue_depth".to_string(), 2)]);
        assert_eq!(snap.hists.len(), 1);
        assert_eq!(snap.hists[0].1.count, 1);
        assert_eq!(snap.events.len(), 1);
        assert_eq!(snap.events[0].kind, "sync.adopt");
    }

    #[test]
    fn snapshot_json_round_trips_through_the_parser() {
        let t = Telemetry::new(8);
        t.counter("hits").inc();
        t.histogram("lat_us").record(42);
        t.journal().warn("slow_query", "nearest took 9ms".into());

        let text = t.snapshot(4).to_json().to_pretty();
        let back = Json::parse(&text).unwrap();
        assert_eq!(
            back.req("counters").unwrap().req("hits").unwrap().as_u64().unwrap(),
            1
        );
        let h = back.req("histograms").unwrap().req("lat_us").unwrap();
        assert_eq!(h.req("count").unwrap().as_u64().unwrap(), 1);
        let events = back.req("events").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 1);
        assert_eq!(
            events[0].req("level").unwrap().as_str().unwrap(),
            "warn"
        );
    }

    #[test]
    fn events_are_capped_by_max_events() {
        let t = Telemetry::new(32);
        for i in 0..10 {
            t.journal().info("tick", format!("{i}"));
        }
        assert_eq!(t.snapshot(3).events.len(), 3);
        assert_eq!(t.snapshot(0).events.len(), 0);
    }
}
