//! Self-contained substrates: RNG and JSON.
//!
//! The build is fully offline (no crates.io), so the two pieces a project
//! would normally pull from `rand` and `serde_json` are implemented here,
//! small and well-tested: a splittable counter-based RNG ([`rng::Rng`])
//! and a minimal JSON parser/writer ([`json::Json`]) used for the artifact
//! manifest, config files and figure reports.

pub mod json;
pub mod rng;

pub use json::Json;
pub use rng::Rng;
