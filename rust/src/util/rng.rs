//! Deterministic, splittable pseudo-randomness (xoshiro256++ seeded via
//! SplitMix64 — the standard construction, dependency-free).
//!
//! Determinism discipline: every component that needs randomness derives
//! its own stream via [`Rng::from_seed_stream`] so that, e.g., worker 3's
//! delay sequence is identical whether or not workers 0–2 exist
//! (DESIGN.md invariant 10 rests on this).

/// xoshiro256++ generator.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seed a generator from a single u64.
    pub fn from_seed(seed: u64) -> Self {
        let mut sm = seed;
        Self {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// An independent stream `(seed, stream)` — used to split per worker /
    /// per shard / per purpose.
    pub fn from_seed_stream(seed: u64, stream: u64) -> Self {
        Self::from_seed(seed ^ stream.wrapping_mul(0xA076_1D64_78BD_642F))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, 1)` with 53 bits of precision.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[0, 1)` as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform integer in `[0, n)`.
    #[inline]
    pub fn usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // 128-bit multiply avoids modulo bias for practical n
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal (Box–Muller).
    pub fn normal_f32(&mut self) -> f32 {
        let u1 = self.f32().max(f32::EPSILON);
        let u2 = self.f32();
        (-2.0 * u1.ln()).sqrt() * (std::f32::consts::TAU * u2).cos()
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        p > 0.0 && self.f64() < p
    }

    /// A random permutation of `0..n` (Fisher–Yates).
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut v: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = self.usize(i + 1);
            v.swap(i, j);
        }
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::from_seed(7);
        let mut b = Rng::from_seed(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::from_seed(8);
        assert_ne!(Rng::from_seed(7).next_u64(), c.next_u64());
    }

    #[test]
    fn streams_are_independent_of_one_another() {
        let s1: Vec<u64> =
            (0..10).scan(Rng::from_seed_stream(1, 3), |r, _| Some(r.next_u64())).collect();
        let s2: Vec<u64> =
            (0..10).scan(Rng::from_seed_stream(1, 4), |r, _| Some(r.next_u64())).collect();
        assert_ne!(s1, s2);
        // re-derive stream 3: identical
        let s1b: Vec<u64> =
            (0..10).scan(Rng::from_seed_stream(1, 3), |r, _| Some(r.next_u64())).collect();
        assert_eq!(s1, s1b);
    }

    #[test]
    fn f64_in_unit_interval_and_uniformish() {
        let mut r = Rng::from_seed(42);
        let n = 100_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "{mean}");
    }

    #[test]
    fn usize_unbiased_over_small_n() {
        let mut r = Rng::from_seed(1);
        let mut counts = [0usize; 5];
        for _ in 0..50_000 {
            counts[r.usize(5)] += 1;
        }
        for c in counts {
            assert!((c as f64 / 10_000.0 - 1.0).abs() < 0.05, "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::from_seed(3);
        let n = 100_000;
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for _ in 0..n {
            let x = r.normal_f32() as f64;
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.03, "{var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut r = Rng::from_seed(9);
        let p = r.permutation(100);
        let mut seen = vec![false; 100];
        for &i in &p {
            assert!(!seen[i]);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }
}
