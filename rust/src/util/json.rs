//! Minimal JSON: a recursive-descent parser and a pretty writer.
//!
//! Replaces `serde_json` in this offline build. Scope: everything the
//! system actually needs — the Python-emitted `artifacts/manifest.json`,
//! config files, and figure reports. Full string escapes, numbers as f64
//! (the manifest only carries small integers, well within the 2^53 exact
//! range), object key order preserved.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Result};

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ----------------------------------------------------------- building

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Append a key to an object (panics on non-objects — builder misuse).
    pub fn set(mut self, key: &str, value: impl Into<Json>) -> Json {
        match &mut self {
            Json::Obj(fields) => fields.push((key.to_string(), value.into())),
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ----------------------------------------------------------- access

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => {
                fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
            }
            _ => None,
        }
    }

    /// `get` that errors with the key name (manifest/config parsing).
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as usize)
    }

    pub fn as_u64(&self) -> Result<u64> {
        let x = self.as_f64()?;
        if x < 0.0 || x.fract() != 0.0 {
            bail!("expected non-negative integer, got {x}");
        }
        Ok(x as u64)
    }

    pub fn as_f32(&self) -> Result<f32> {
        Ok(self.as_f64()? as f32)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    /// Object fields as an ordered map view.
    pub fn as_obj(&self) -> Result<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Ok(v),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// Object fields as a BTreeMap (sorted iteration).
    pub fn to_map(&self) -> Result<BTreeMap<String, Json>> {
        Ok(self.as_obj()?.iter().cloned().collect())
    }

    // ----------------------------------------------------------- writing

    /// Compact encoding.
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty encoding with 2-space indent.
    pub fn to_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        let (nl, pad, pad_in) = match indent {
            Some(w) => (
                "\n",
                " ".repeat(w * depth),
                " ".repeat(w * (depth + 1)),
            ),
            None => ("", String::new(), String::new()),
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    item.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push(']');
            }
            Json::Obj(fields) => {
                if fields.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push_str(nl);
                    out.push_str(&pad_in);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push_str(nl);
                out.push_str(&pad);
                out.push('}');
            }
        }
    }

    // ----------------------------------------------------------- parsing

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            bail!("trailing garbage at byte {}", p.pos);
        }
        Ok(v)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Json {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Json {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Json {
        Json::Bool(b)
    }
}
impl From<Vec<Json>> for Json {
    fn from(v: Vec<Json>) -> Json {
        Json::Arr(v)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let value = self.value()?;
            fields.push((key, value));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                _ => bail!("expected ',' or '}}' at byte {}", self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => bail!("expected ',' or ']' at byte {}", self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .bytes
                                .get(self.pos + 1..self.pos + 5)
                                .ok_or_else(|| anyhow!("truncated \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)?,
                                16,
                            )?;
                            s.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad \\u escape"))?,
                            );
                            self.pos += 4;
                        }
                        other => bail!("bad escape {other:?}"),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let text = std::str::from_utf8(&self.bytes[start..])?;
                    let c = text.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(text.parse::<f64>()?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
          "format": "hlo-text/return-tuple",
          "variants": {
            "k8d2": {
              "params": {"kappa": 8, "dim": 2},
              "entries": {"vq_chunk": {"file": "a.hlo.txt",
                          "inputs": [{"shape": [8, 2], "dtype": "float32"}]}}
            }
          }
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.req("format").unwrap().as_str().unwrap(), "hlo-text/return-tuple");
        let v = j.req("variants").unwrap().req("k8d2").unwrap();
        assert_eq!(v.req("params").unwrap().req("kappa").unwrap().as_usize().unwrap(), 8);
        let inputs = v
            .req("entries").unwrap()
            .req("vq_chunk").unwrap()
            .req("inputs").unwrap()
            .as_arr().unwrap();
        assert_eq!(inputs[0].req("shape").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn round_trips_all_value_kinds() {
        let j = Json::obj()
            .set("s", "hi \"there\"\n")
            .set("i", 42usize)
            .set("f", 1.5)
            .set("neg", Json::Num(-7.0))
            .set("b", true)
            .set("n", Json::Null)
            .set("a", vec![Json::Num(1.0), Json::Str("x".into())]);
        for text in [j.to_string(), j.to_pretty()] {
            let back = Json::parse(&text).unwrap();
            assert_eq!(back, j, "{text}");
        }
    }

    #[test]
    fn integers_print_without_decimal_point() {
        assert_eq!(Json::Num(1024.0).to_string(), "1024");
        assert_eq!(Json::Num(1.25).to_string(), "1.25");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn unicode_and_escapes() {
        let j = Json::parse(r#""café → ok""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "café → ok");
    }

    #[test]
    fn accessor_errors_are_typed() {
        let j = Json::parse(r#"{"x": 1.5}"#).unwrap();
        assert!(j.req("x").unwrap().as_usize().is_err());
        assert!(j.req("y").is_err());
        assert!(j.req("x").unwrap().as_str().is_err());
    }
}
