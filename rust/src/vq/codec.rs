//! The quantizer as a codec — the paper's motivating use-case.
//!
//! “The VQ technique computes a summary of a dataset … with κ prototypes”:
//! once trained, the codebook *is* a lossy compressor. [`encode`] maps each
//! point to its nearest prototype's index (`⌈log2 κ⌉` bits instead of
//! `32·d`), [`decode`] reconstructs, and [`CompressionReport`] quantifies
//! the trade: compression ratio vs mean reconstruction error — which is
//! exactly the distortion criterion the schemes minimize.

use super::{assignments, distortion_mean, Codebook};

/// Encoded form of a dataset: prototype indices against a codebook.
#[derive(Debug, Clone, PartialEq)]
pub struct Encoded {
    /// Nearest-prototype index per point.
    pub codes: Vec<u32>,
    kappa: usize,
    dim: usize,
}

impl Encoded {
    pub fn len(&self) -> usize {
        self.codes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.codes.is_empty()
    }

    /// Bits per point at entropy-free fixed-width coding.
    pub fn bits_per_point(&self) -> u32 {
        (usize::BITS - (self.kappa - 1).leading_zeros()).max(1)
    }
}

/// Quantize every point to its nearest prototype's index.
pub fn encode(w: &Codebook, points: &[f32]) -> Encoded {
    Encoded {
        codes: assignments(w, points).into_iter().map(|i| i as u32).collect(),
        kappa: w.kappa(),
        dim: w.dim(),
    }
}

/// Reconstruct the (lossy) dataset from codes.
pub fn decode(w: &Codebook, encoded: &Encoded) -> Vec<f32> {
    assert_eq!(encoded.kappa, w.kappa(), "codebook mismatch");
    assert_eq!(encoded.dim, w.dim(), "codebook mismatch");
    let mut out = Vec::with_capacity(encoded.codes.len() * w.dim());
    for &c in &encoded.codes {
        out.extend_from_slice(w.row(c as usize));
    }
    out
}

/// Compression accounting for a codebook on a dataset.
#[derive(Debug, Clone, PartialEq)]
pub struct CompressionReport {
    /// Raw size: 32 bits × d per point.
    pub raw_bits_per_point: u64,
    /// Fixed-width code size (excluding the κ·d·32-bit codebook itself).
    pub coded_bits_per_point: u64,
    /// `raw / coded` (codebook amortized over the dataset).
    pub ratio: f64,
    /// Mean squared reconstruction error = normalized distortion `C`.
    pub mse: f64,
}

/// Evaluate the codebook as a compressor over `points`.
pub fn compression_report(w: &Codebook, points: &[f32]) -> CompressionReport {
    let n = (points.len() / w.dim()) as u64;
    let encoded = encode(w, points);
    let raw = 32 * w.dim() as u64;
    let coded = encoded.bits_per_point() as u64;
    let codebook_bits = (w.kappa() * w.dim()) as u64 * 32;
    let total_coded = coded * n + codebook_bits;
    CompressionReport {
        raw_bits_per_point: raw,
        coded_bits_per_point: coded,
        ratio: (raw * n) as f64 / total_coded.max(1) as f64,
        mse: distortion_mean(w, points) / w.dim() as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::runtime::{Engine, NativeEngine};
    use crate::vq::{init_codebook, InitMethod};

    #[test]
    fn encode_decode_round_trip_on_prototype_points() {
        let w = Codebook::from_flat(4, 2, vec![0., 0., 1., 0., 0., 1., 1., 1.]);
        let pts = [1.0f32, 1.0, 0.0, 0.0, 1.0, 0.0];
        let enc = encode(&w, &pts);
        assert_eq!(enc.codes, vec![3, 0, 1]);
        let dec = decode(&w, &enc);
        assert_eq!(dec, pts, "points on prototypes reconstruct exactly");
        assert_eq!(enc.bits_per_point(), 2);
    }

    #[test]
    fn reconstruction_error_equals_distortion() {
        let spec = MixtureSpec { components: 4, dim: 4, ..Default::default() };
        let pts = spec.generate(512, 3, 0);
        let w = init_codebook(InitMethod::FromData, 8, 4, &pts, 3);
        let enc = encode(&w, &pts);
        let dec = decode(&w, &enc);
        // MSE of reconstruction == normalized distortion / d, by definition
        let mse: f64 = pts
            .iter()
            .zip(&dec)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / pts.len() as f64;
        let report = compression_report(&w, &pts);
        let rel = (mse - report.mse).abs() / mse.max(1e-12);
        assert!(rel < 1e-6, "{mse} vs {} (rel {rel})", report.mse);
    }

    #[test]
    fn training_improves_the_codec() {
        let spec = MixtureSpec {
            components: 8,
            dim: 8,
            separation: 5.0,
            std: 0.3,
            imbalance: 0.0,
            noise_frac: 0.0,
        };
        let pts = spec.generate(4_096, 9, 0);
        let w0 = init_codebook(InitMethod::Gaussian, 8, 8, &pts, 9);
        let before = compression_report(&w0, &pts);
        // train with a few k-means steps (any scheme would do)
        let mut eng = NativeEngine::new();
        let mut w = w0;
        for _ in 0..10 {
            eng.kmeans_step(&mut w, &pts).unwrap();
        }
        let after = compression_report(&w, &pts);
        assert!(after.mse < before.mse * 0.2, "{} -> {}", before.mse, after.mse);
        assert_eq!(after.coded_bits_per_point, 3); // kappa = 8
        assert!(after.ratio > 50.0, "ratio {}", after.ratio); // 256 -> ~3.5 bits
    }

    #[test]
    fn bits_per_point_handles_non_power_of_two() {
        let w = Codebook::zeros(5, 2);
        let enc = encode(&w, &[0.0, 0.0]);
        assert_eq!(enc.bits_per_point(), 3);
        let w = Codebook::zeros(1, 2);
        let enc = encode(&w, &[0.0, 0.0]);
        assert_eq!(enc.bits_per_point(), 1);
    }
}
