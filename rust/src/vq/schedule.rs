//! Learning-rate schedules `(ε_t)_{t>0}`.
//!
//! The paper assumes “a satisfactory VQ implementation” whose step sequence
//! is already adapted to the dataset, and its core argument (Section 3) is
//! about how parallel schemes change the *effective* learning rate per
//! processed sample. The classical Robbins–Monro family used by the
//! CloudDALVQ code is `ε_t = ε₀ / (1 + t/T)^α`.
//!
//! Each *worker* indexes the schedule by its **local** step count `t` —
//! exactly the `ε_{t'+1}` indexing of eqs. 5–9.


/// A step-size sequence `(ε_t)_{t ≥ 0}`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Schedule {
    /// `ε_t = eps0` — constant step (exploration never decays).
    Constant { eps0: f32 },
    /// `ε_t = eps0 / (1 + t / half_life)` — the classical 1/t decay.
    InverseTime { eps0: f32, half_life: f32 },
    /// `ε_t = eps0 / (1 + t / half_life)^alpha` with `α ∈ (0.5, 1]`.
    Power { eps0: f32, half_life: f32, alpha: f32 },
}

impl Schedule {
    /// The paper-typical default: `ε_t = 0.02 / (1 + t/5000)`.
    ///
    /// The paper assumes “a satisfactory VQ implementation [whose] series
    /// of steps is supposed to be adapted to the dataset”. For the *delta*
    /// merge (eq. 8) that adaptation must respect a stability envelope:
    /// each reduce applies ≈ `M·τ/κ` worker displacements per prototype,
    /// so `ε` must keep `M·τ·ε/κ` below ~1 or the shared version
    /// overshoots and diverges (demonstrated by the
    /// `delta_merge_diverges_when_step_violates_envelope` test and the
    /// ABL-τ ablation). `ε₀ = 0.02` keeps the paper's grid
    /// (M ≤ 32, τ = 10, κ = 16) safely inside the envelope.
    pub fn paper_default() -> Self {
        Schedule::InverseTime { eps0: 0.02, half_life: 5000.0 }
    }

    /// Step size at (0-based) local iteration `t`.
    #[inline]
    pub fn eps(&self, t: u64) -> f32 {
        match *self {
            Schedule::Constant { eps0 } => eps0,
            Schedule::InverseTime { eps0, half_life } => {
                eps0 / (1.0 + t as f32 / half_life)
            }
            Schedule::Power { eps0, half_life, alpha } => {
                eps0 / (1.0 + t as f32 / half_life).powf(alpha)
            }
        }
    }

    /// Fill `out` with `ε_{t0}, …, ε_{t0+out.len()-1}` (what the engines
    /// feed to the `vq_chunk` artifact per window).
    pub fn fill(&self, t0: u64, out: &mut [f32]) {
        for (i, e) in out.iter_mut().enumerate() {
            *e = self.eps(t0 + i as u64);
        }
    }

    /// Validate parameters (positive, finite, α in range).
    pub fn validate(&self) -> Result<(), String> {
        let ok = |x: f32| x.is_finite() && x > 0.0;
        match *self {
            Schedule::Constant { eps0 } => {
                if !ok(eps0) || eps0 > 1.0 {
                    return Err(format!("constant eps0 must be in (0, 1], got {eps0}"));
                }
            }
            Schedule::InverseTime { eps0, half_life } => {
                if !ok(eps0) || eps0 > 1.0 || !ok(half_life) {
                    return Err("inverse_time needs eps0 in (0,1], half_life > 0".into());
                }
            }
            Schedule::Power { eps0, half_life, alpha } => {
                if !ok(eps0) || eps0 > 1.0 || !ok(half_life) {
                    return Err("power needs eps0 in (0,1], half_life > 0".into());
                }
                if !(0.5..=1.0).contains(&alpha) {
                    return Err(format!("power alpha must be in [0.5, 1], got {alpha}"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constant_is_constant() {
        let s = Schedule::Constant { eps0: 0.3 };
        assert_eq!(s.eps(0), 0.3);
        assert_eq!(s.eps(1_000_000), 0.3);
    }

    #[test]
    fn inverse_time_halves_at_half_life() {
        let s = Schedule::InverseTime { eps0: 0.8, half_life: 100.0 };
        assert!((s.eps(100) - 0.4).abs() < 1e-6);
        assert!(s.eps(0) > s.eps(10) && s.eps(10) > s.eps(1000));
    }

    #[test]
    fn power_interpolates() {
        let inv = Schedule::InverseTime { eps0: 0.5, half_life: 50.0 };
        let pow1 = Schedule::Power { eps0: 0.5, half_life: 50.0, alpha: 1.0 };
        for t in [0u64, 7, 50, 500] {
            assert!((inv.eps(t) - pow1.eps(t)).abs() < 1e-6);
        }
        let pow_half = Schedule::Power { eps0: 0.5, half_life: 50.0, alpha: 0.5 };
        assert!(pow_half.eps(500) > pow1.eps(500), "slower decay for smaller alpha");
    }

    #[test]
    fn fill_matches_eps() {
        let s = Schedule::paper_default();
        let mut buf = [0.0f32; 5];
        s.fill(42, &mut buf);
        for (i, v) in buf.iter().enumerate() {
            assert_eq!(*v, s.eps(42 + i as u64));
        }
    }

    #[test]
    fn validate_rejects_bad_params() {
        assert!(Schedule::Constant { eps0: 0.0 }.validate().is_err());
        assert!(Schedule::Constant { eps0: 1.5 }.validate().is_err());
        assert!(Schedule::Power { eps0: 0.5, half_life: 10.0, alpha: 0.2 }
            .validate()
            .is_err());
        assert!(Schedule::paper_default().validate().is_ok());
    }
}
