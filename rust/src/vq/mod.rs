//! Core vector-quantization math (pure Rust, mirrors the L1 kernels).
//!
//! This module is the native twin of the Pallas kernels: the paper's
//! recursion (eq. 1), displacement accumulation (eq. 7), the empirical
//! distortion criterion (eq. 2), learning-rate schedules and codebook
//! initialization. The [`crate::runtime::NativeEngine`] is a thin wrapper
//! over these functions; integration tests pin them against the PJRT
//! execution of the AOT artifacts.

mod batch;
mod codebook;
mod codec;
mod delta;
mod distortion;
mod init;
mod schedule;
mod step;

pub use batch::nearest_batch;
pub(crate) use batch::nearest_batch_into;
pub use codebook::Codebook;
pub use codec::{compression_report, decode, encode, CompressionReport, Encoded};
pub use delta::Delta;
pub use distortion::{
    assignments, distortion_mean, distortion_sum, nearest, nearest_with_dist,
};
pub use init::{init_codebook, InitMethod};
pub use schedule::Schedule;
pub use step::{vq_chunk, vq_step};
pub(crate) use step::row_dist_sq;
