//! The empirical distortion criterion (paper eq. 2).
//!
//! `C_{n,M}(w) = (1/nM) Σ_i Σ_t min_ℓ ‖z_t^i − w_ℓ‖²` — the quantity every
//! figure in the paper plots against wall-clock time. The native
//! implementation accumulates in `f64` (the batches are large); the PJRT
//! path uses the tiled matmul-form kernel and agrees to relative 1e-4.

use super::Codebook;
use super::step::nearest_row;

/// Index of the nearest prototype to `z` (first-minimum tie break).
pub fn nearest(w: &Codebook, z: &[f32]) -> usize {
    nearest_row(w, z)
}

/// `(index, squared distance)` of the nearest prototype to `z` — one scan,
/// for callers that need both (the serving read path).
pub fn nearest_with_dist(w: &Codebook, z: &[f32]) -> (usize, f32) {
    super::step::nearest_row_with_dist(w, z)
}

/// Un-normalized distortion: `Σ_t min_ℓ ‖z_t − w_ℓ‖²` over flat row-major
/// `points` (length must be a multiple of `w.dim()`).
pub fn distortion_sum(w: &Codebook, points: &[f32]) -> f64 {
    let dim = w.dim();
    assert_eq!(points.len() % dim, 0, "points not a multiple of dim");
    let mut total = 0.0f64;
    // Perf (EXPERIMENTS.md §Perf): bounds-check-free row walk, zip-fold
    // distances (auto-vectorized). The evaluator calls this on every
    // distortion snapshot, so it dominates harness wall time.
    for z in points.chunks_exact(dim) {
        let mut best = f32::INFINITY;
        for row in w.flat().chunks_exact(dim) {
            let d = super::step::row_dist_sq(row, z);
            if d < best {
                best = d;
            }
        }
        total += best as f64;
    }
    total
}

/// Normalized distortion: the paper's `C` with the `1/(count)` factor
/// (the `1/(nM)` of eq. 2 — callers pass the total number of points).
pub fn distortion_mean(w: &Codebook, points: &[f32]) -> f64 {
    let n = points.len() / w.dim();
    if n == 0 {
        return 0.0;
    }
    distortion_sum(w, points) / n as f64
}

/// Nearest-prototype assignment for every point.
pub fn assignments(w: &Codebook, points: &[f32]) -> Vec<usize> {
    points.chunks_exact(w.dim()).map(|z| nearest_row(w, z)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zero_when_points_sit_on_prototypes() {
        let w = Codebook::from_flat(2, 2, vec![0.0, 0.0, 1.0, 1.0]);
        let pts = [0.0f32, 0.0, 1.0, 1.0, 0.0, 0.0];
        assert_eq!(distortion_sum(&w, &pts), 0.0);
    }

    #[test]
    fn hand_computed_value() {
        let w = Codebook::from_flat(2, 1, vec![0.0, 10.0]);
        // 3 -> proto 0 (d=9); 8 -> proto 1 (d=4)
        let pts = [3.0f32, 8.0];
        assert_eq!(distortion_sum(&w, &pts), 13.0);
        assert_eq!(distortion_mean(&w, &pts), 6.5);
    }

    #[test]
    fn permutation_invariant_in_prototypes() {
        let w1 = Codebook::from_flat(2, 2, vec![0.0, 0.0, 3.0, 3.0]);
        let w2 = Codebook::from_flat(2, 2, vec![3.0, 3.0, 0.0, 0.0]);
        let pts = [0.5f32, 0.5, 2.5, 2.5, -1.0, 4.0];
        assert_eq!(distortion_sum(&w1, &pts), distortion_sum(&w2, &pts));
    }

    #[test]
    fn assignments_pick_nearest() {
        let w = Codebook::from_flat(2, 1, vec![0.0, 10.0]);
        assert_eq!(assignments(&w, &[1.0, 9.0, 4.9, 5.1]), vec![0, 1, 0, 1]);
    }

    #[test]
    fn empty_points_mean_is_zero() {
        let w = Codebook::from_flat(1, 2, vec![0.0, 0.0]);
        assert_eq!(distortion_mean(&w, &[]), 0.0);
    }
}
