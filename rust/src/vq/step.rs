//! The paper's recursion (eq. 1) and the fused `τ`-point walk.
//!
//! This is the native mirror of the L1 Pallas `vq_chunk` kernel: identical
//! math, identical first-minimum tie break, so the two engines can be
//! cross-checked to float tolerance over long trajectories.

use super::{Codebook, Delta};

/// One step of eq. 1: find the prototype nearest to `z` (first minimum on
/// ties), move it by `ε (w_l − z)`, and accumulate the displacement into
/// `delta`. Returns the winning index.
///
/// `w(t+1)_l = w(t)_l − ε_{t+1} (w(t)_l − z)`.
#[inline]
pub fn vq_step(w: &mut Codebook, z: &[f32], eps: f32, delta: &mut Delta) -> usize {
    debug_assert_eq!(z.len(), w.dim());
    let winner = nearest_row(w, z);
    let dim = w.dim();
    let wrow = w.row_mut(winner);
    let drow = delta.row_mut(winner);
    for k in 0..dim {
        let upd = eps * (wrow[k] - z[k]);
        wrow[k] -= upd;
        drow[k] += upd;
    }
    winner
}

/// Index of the prototype nearest to `z` (squared Euclidean, first-minimum
/// tie break — must match `jnp.argmin` in the Pallas kernel).
///
/// Perf (EXPERIMENTS.md §Perf): bounds-check-free row walk via
/// `chunks_exact` + the 4-lane [`row_dist_sq`]. Measured-and-reverted
/// variants: partial-distance early exit (~1.7x slower at kappa=16/d=16 —
/// the horizontal-sum checks cost more than the skipped work) and 8-lane
/// accumulation (~12% slower than 4-lane on this core). See
/// EXPERIMENTS.md §Perf for the iteration log.
#[inline]
pub(crate) fn nearest_row(w: &Codebook, z: &[f32]) -> usize {
    nearest_row_with_dist(w, z).0
}

/// [`nearest_row`] returning the winning squared distance as well — the
/// serving read path needs both and must not rescan the winning row.
#[inline]
pub(crate) fn nearest_row_with_dist(w: &Codebook, z: &[f32]) -> (usize, f32) {
    let dim = z.len();
    let mut best = 0usize;
    let mut best_d = f32::INFINITY;
    for (i, row) in w.flat().chunks_exact(dim).enumerate() {
        let d = row_dist_sq(row, z);
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    (best, best_d)
}

/// Squared Euclidean distance between two equal-length slices.
///
/// Four independent accumulator lanes: a strict sequential `sum()` cannot
/// be vectorized by LLVM (FP addition is not associative), so the lanes
/// re-associate explicitly and let the 4-wide chunks compile to SIMD.
#[inline]
pub(crate) fn row_dist_sq(row: &[f32], z: &[f32]) -> f32 {
    debug_assert_eq!(row.len(), z.len());
    let mut lanes = [0.0f32; 4];
    let r4 = row.chunks_exact(4);
    let z4 = z.chunks_exact(4);
    let (r_tail, z_tail) = (r4.remainder(), z4.remainder());
    for (r, zz) in r4.zip(z4) {
        let d0 = r[0] - zz[0];
        let d1 = r[1] - zz[1];
        let d2 = r[2] - zz[2];
        let d3 = r[3] - zz[3];
        lanes[0] += d0 * d0;
        lanes[1] += d1 * d1;
        lanes[2] += d2 * d2;
        lanes[3] += d3 * d3;
    }
    let mut d = (lanes[0] + lanes[1]) + (lanes[2] + lanes[3]);
    for (a, b) in r_tail.iter().zip(z_tail) {
        let diff = a - b;
        d += diff * diff;
    }
    d
}

/// A `τ`-point sequential walk (the L1 `vq_chunk` kernel): applies eq. 1 to
/// each point of `chunk` in order, accumulating the window displacement
/// `Δ` (eq. 7) into `delta`.
///
/// * `chunk` — `τ · d` flat row-major points,
/// * `eps`   — `τ` per-step learning rates,
///
/// On return, `w` has advanced by `τ` steps and
/// `w_out == w_in − (delta_out − delta_in)` exactly.
pub fn vq_chunk(w: &mut Codebook, chunk: &[f32], eps: &[f32], delta: &mut Delta) {
    let dim = w.dim();
    assert_eq!(chunk.len(), eps.len() * dim, "chunk/eps length mismatch");
    for (t, z) in chunk.chunks_exact(dim).enumerate() {
        vq_step(w, z, eps[t], delta);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn w2() -> Codebook {
        Codebook::from_flat(2, 2, vec![0.0, 0.0, 10.0, 10.0])
    }

    #[test]
    fn single_step_moves_winner_towards_point() {
        let mut w = w2();
        let mut d = Delta::zeros(2, 2);
        let winner = vq_step(&mut w, &[1.0, 1.0], 0.5, &mut d);
        assert_eq!(winner, 0);
        assert_eq!(w.row(0), &[0.5, 0.5]);
        assert_eq!(w.row(1), &[10.0, 10.0]);
        assert_eq!(d.flat(), &[-0.5, -0.5, 0.0, 0.0]);
    }

    #[test]
    fn eps_one_snaps_onto_point() {
        let mut w = w2();
        let mut d = Delta::zeros(2, 2);
        vq_step(&mut w, &[3.0, -1.0], 1.0, &mut d);
        assert_eq!(w.row(0), &[3.0, -1.0]);
    }

    #[test]
    fn zero_eps_is_identity() {
        let mut w = w2();
        let before = w.clone();
        let mut d = Delta::zeros(2, 2);
        vq_step(&mut w, &[1.0, 1.0], 0.0, &mut d);
        assert_eq!(w, before);
        assert!(d.is_zero());
    }

    #[test]
    fn tie_breaks_to_first_row() {
        let mut w = Codebook::from_flat(2, 1, vec![1.0, -1.0]);
        let mut d = Delta::zeros(2, 1);
        let winner = vq_step(&mut w, &[0.0], 1.0, &mut d);
        assert_eq!(winner, 0, "equidistant prototypes must pick the first");
    }

    #[test]
    fn chunk_identity_w_equals_w0_minus_delta() {
        let mut w = Codebook::from_flat(2, 2, vec![0.1, 0.2, 5.0, 5.0]);
        let w0 = w.clone();
        let mut delta = Delta::zeros(2, 2);
        let chunk = [1.0f32, 0.0, 4.5, 5.5, 0.0, 1.0, -1.0, 0.5];
        let eps = [0.5f32, 0.3, 0.2, 0.1];
        vq_chunk(&mut w, &chunk, &eps, &mut delta);
        for (i, (wv, (w0v, dv))) in w
            .flat()
            .iter()
            .zip(w0.flat().iter().zip(delta.flat()))
            .enumerate()
        {
            assert!((wv - (w0v - dv)).abs() < 1e-6, "mismatch at {i}");
        }
    }

    #[test]
    fn chunk_matches_stepwise() {
        let mut w_a = w2();
        let mut w_b = w2();
        let mut d_a = Delta::zeros(2, 2);
        let mut d_b = Delta::zeros(2, 2);
        let chunk = [1.0f32, 1.0, 9.0, 9.0, 0.5, 0.5];
        let eps = [0.5f32, 0.4, 0.3];
        vq_chunk(&mut w_a, &chunk, &eps, &mut d_a);
        for t in 0..3 {
            vq_step(&mut w_b, &chunk[t * 2..(t + 1) * 2], eps[t], &mut d_b);
        }
        assert_eq!(w_a, w_b);
        assert_eq!(d_a, d_b);
    }
}
