//! Displacement accumulators — the `Δ` of the paper (eq. 7).
//!
//! `Δ^j_{t1→t2} = Σ_{t'=t1+1}^{t2} ε_{t'+1} H(z^j, w^j(t'))` is what
//! schemes B (eq. 8) and C (eq. 9) ship to the reducer instead of whole
//! versions. Deltas form a commutative monoid under addition, and along a
//! single worker's walk they are additive across windows
//! (`Δ_{t1→t3} = Δ_{t1→t2} + Δ_{t2→t3}`) — both properties are load-bearing
//! for the asynchronous scheme and are property-tested.


/// Accumulated displacement, same layout as a [`super::Codebook`].
#[derive(Debug, Clone, PartialEq)]
pub struct Delta {
    kappa: usize,
    dim: usize,
    data: Vec<f32>,
}

impl Delta {
    pub fn zeros(kappa: usize, dim: usize) -> Self {
        Self { kappa, dim, data: vec![0.0; kappa * dim] }
    }

    pub fn from_flat(kappa: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), kappa * dim, "flat buffer length mismatch");
        Self { kappa, dim, data }
    }

    pub fn kappa(&self) -> usize {
        self.kappa
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// `self ← self + other` (the reducer's fold; commutative).
    pub fn accumulate(&mut self, other: &Delta) {
        assert_eq!(self.data.len(), other.data.len(), "delta shape mismatch");
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    /// Reset to zero (a worker starting a fresh accumulation window).
    pub fn clear(&mut self) {
        self.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// True iff every entry is exactly zero.
    pub fn is_zero(&self) -> bool {
        self.data.iter().all(|x| *x == 0.0)
    }

    /// Squared Frobenius norm.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    /// Max absolute element-wise difference.
    pub fn max_abs_diff(&self, other: &Delta) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulate_is_elementwise_add() {
        let mut a = Delta::from_flat(1, 2, vec![1.0, -1.0]);
        let b = Delta::from_flat(1, 2, vec![0.5, 0.5]);
        a.accumulate(&b);
        assert_eq!(a.flat(), &[1.5, -0.5]);
    }

    #[test]
    fn clear_zeroes() {
        let mut a = Delta::from_flat(1, 2, vec![1.0, 2.0]);
        a.clear();
        assert!(a.is_zero());
    }
}
