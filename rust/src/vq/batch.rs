//! Fused batch nearest-prototype scan — the read-path distance kernel.
//!
//! [`nearest_batch`] is the batched twin of the per-point scan in
//! [`super::step::nearest_row_with_dist`]: one call takes a row-major block
//! of query points plus one codebook and produces codes and squared
//! distances for every point in a single tiled pass. The tiling keeps a
//! codebook row hot across a whole tile of points (the shared-memory LBG
//! batching argument), while the per-point arithmetic is **bit-identical**
//! to the scalar scan: same [`super::step::row_dist_sq`] four-lane sum,
//! same row order, same strict-`<` first-minimum tie break. Batching is a
//! scheduling change, never a numerics change.

use super::step::row_dist_sq;
use super::Codebook;

/// Points per tile. The tile of queries stays L1-resident while the outer
/// loop streams codebook rows over it, so each row is loaded once per
/// `TILE` points instead of once per point.
const TILE: usize = 64;

/// Nearest prototype for every point of a flat row-major block: returns
/// `(codes, squared distances)`, one entry per point.
///
/// Per point this is bit-identical to [`nearest_with_dist`]
/// (`jnp.argmin` semantics: first minimum wins on ties) — the property
/// tests in `rust/tests/query_plane.rs` pin the equivalence over random
/// shapes.
///
/// [`nearest_with_dist`]: super::nearest_with_dist
///
/// # Panics
/// If `points.len()` is not a multiple of the codebook dimension.
pub fn nearest_batch(w: &Codebook, points: &[f32]) -> (Vec<u32>, Vec<f32>) {
    let dim = w.dim();
    assert_eq!(points.len() % dim, 0, "points not a multiple of dim {dim}");
    let n = points.len() / dim;
    let mut codes = vec![0u32; n];
    let mut dists = vec![0.0f32; n];
    nearest_batch_into(w, points, &mut codes, &mut dists);
    (codes, dists)
}

/// [`nearest_batch`] writing into caller-owned slices — the serving scan
/// scatters per-(point, probe) results into one flat pair buffer and must
/// not allocate per shard.
///
/// # Panics
/// If `points.len()` is not a multiple of the codebook dimension, or the
/// output slices don't hold exactly one entry per point.
pub(crate) fn nearest_batch_into(
    w: &Codebook,
    points: &[f32],
    codes: &mut [u32],
    dists: &mut [f32],
) {
    let dim = w.dim();
    assert_eq!(points.len() % dim, 0, "points not a multiple of dim {dim}");
    let n = points.len() / dim;
    assert_eq!(codes.len(), n, "codes slice holds {} of {n} points", codes.len());
    assert_eq!(dists.len(), n, "dists slice holds {} of {n} points", dists.len());
    let mut start = 0usize;
    while start < n {
        let end = (start + TILE).min(n);
        let tile = &points[start * dim..end * dim];
        let tile_codes = &mut codes[start..end];
        let tile_dists = &mut dists[start..end];
        tile_dists.fill(f32::INFINITY);
        for (i, row) in w.flat().chunks_exact(dim).enumerate() {
            let zs = tile.chunks_exact(dim);
            for (z, (code, best)) in
                zs.zip(tile_codes.iter_mut().zip(tile_dists.iter_mut()))
            {
                let d = row_dist_sq(row, z);
                if d < *best {
                    *best = d;
                    *code = i as u32;
                }
            }
        }
        start = end;
    }
}

#[cfg(test)]
mod tests {
    use super::super::nearest_with_dist;
    use super::*;

    /// Tiny deterministic generator (xorshift), enough to stress shapes.
    struct Rng(u64);
    impl Rng {
        fn next_f32(&mut self) -> f32 {
            self.0 ^= self.0 << 13;
            self.0 ^= self.0 >> 7;
            self.0 ^= self.0 << 17;
            // Map to [-4, 4) with coarse granularity so exact ties occur.
            ((self.0 >> 32) as u32 % 64) as f32 / 8.0 - 4.0
        }
    }

    #[test]
    fn batch_matches_scalar_bitwise_over_shapes() {
        let mut rng = Rng(0x9e3779b97f4a7c15);
        for &(kappa, dim, n) in
            &[(1, 1, 7), (2, 3, 1), (8, 2, 65), (16, 4, 130), (5, 7, 200)]
        {
            let flat: Vec<f32> =
                (0..kappa * dim).map(|_| rng.next_f32()).collect();
            let w = Codebook::from_flat(kappa, dim, flat);
            let points: Vec<f32> =
                (0..n * dim).map(|_| rng.next_f32()).collect();
            let (codes, dists) = nearest_batch(&w, &points);
            for (i, z) in points.chunks_exact(dim).enumerate() {
                let (code, dist) = nearest_with_dist(&w, z);
                assert_eq!(codes[i] as usize, code, "code mismatch at point {i}");
                assert_eq!(
                    dists[i].to_bits(),
                    dist.to_bits(),
                    "distance not bit-identical at point {i}"
                );
            }
        }
    }

    #[test]
    fn ties_break_to_first_row_like_scalar() {
        // Two identical prototypes: every point is equidistant, and both
        // paths must pick row 0 (strict `<` keeps the first minimum).
        let w = Codebook::from_flat(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let (codes, dists) = nearest_batch(&w, &[0.0, 0.0, 3.0, 3.0]);
        assert_eq!(codes, vec![0, 0]);
        assert_eq!(dists, vec![2.0, 8.0]);
    }

    #[test]
    fn empty_batch_is_empty() {
        let w = Codebook::from_flat(2, 2, vec![0.0; 4]);
        let (codes, dists) = nearest_batch(&w, &[]);
        assert!(codes.is_empty());
        assert!(dists.is_empty());
    }
}
