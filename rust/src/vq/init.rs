//! Codebook initialization.
//!
//! All schemes in the paper start every worker from the *same* random
//! initial version `w^1(0) = … = w^M(0)`; the initialization itself is a
//! substrate choice. We provide draw-from-data (the CloudDALVQ default),
//! standard Gaussian, and k-means++ (used to give the batch baseline its
//! customary seeding).

use crate::util::Rng;

use super::Codebook;

/// Initialization strategies.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InitMethod {
    /// Draw `κ` distinct points from the dataset (CloudDALVQ default).
    FromData,
    /// i.i.d. standard Gaussian entries.
    Gaussian,
    /// k-means++ seeding (D² sampling) — for the batch baseline.
    KmeansPlusPlus,
}

/// Build an initial codebook from `points` (flat row-major, `dim` columns).
pub fn init_codebook(
    method: InitMethod,
    kappa: usize,
    dim: usize,
    points: &[f32],
    seed: u64,
) -> Codebook {
    let mut rng = Rng::from_seed_stream(seed, 0x1217);
    match method {
        InitMethod::Gaussian => {
            let data = (0..kappa * dim)
                .map(|_| rng.normal_f32())
                .collect();
            Codebook::from_flat(kappa, dim, data)
        }
        InitMethod::FromData => {
            let n = points.len() / dim;
            assert!(n >= kappa, "need at least kappa data points to init");
            let mut chosen = Vec::with_capacity(kappa);
            let mut data = Vec::with_capacity(kappa * dim);
            while chosen.len() < kappa {
                let i = rng.usize(n);
                if !chosen.contains(&i) {
                    chosen.push(i);
                    data.extend_from_slice(&points[i * dim..(i + 1) * dim]);
                }
            }
            Codebook::from_flat(kappa, dim, data)
        }
        InitMethod::KmeansPlusPlus => {
            let n = points.len() / dim;
            assert!(n >= kappa, "need at least kappa data points to init");
            let mut data = Vec::with_capacity(kappa * dim);
            let first = rng.usize(n);
            data.extend_from_slice(&points[first * dim..(first + 1) * dim]);
            // d2[i] = squared distance of point i to its nearest chosen center
            let mut d2 = vec![f32::INFINITY; n];
            for _ in 1..kappa {
                let last = &data[data.len() - dim..];
                let mut total = 0.0f64;
                for i in 0..n {
                    let p = &points[i * dim..(i + 1) * dim];
                    let mut d = 0.0f32;
                    for k in 0..dim {
                        let diff = p[k] - last[k];
                        d += diff * diff;
                    }
                    d2[i] = d2[i].min(d);
                    total += d2[i] as f64;
                }
                let next = if total <= 0.0 {
                    rng.usize(n) // all mass on chosen points: uniform
                } else {
                    let mut target = rng.range_f64(0.0, total);
                    let mut pick = n - 1;
                    for (i, &dd) in d2.iter().enumerate() {
                        target -= dd as f64;
                        if target <= 0.0 {
                            pick = i;
                            break;
                        }
                    }
                    pick
                };
                data.extend_from_slice(&points[next * dim..(next + 1) * dim]);
            }
            Codebook::from_flat(kappa, dim, data)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_points(n: usize, dim: usize) -> Vec<f32> {
        (0..n * dim).map(|i| i as f32).collect()
    }

    #[test]
    fn from_data_rows_are_dataset_points() {
        let pts = grid_points(10, 2);
        let w = init_codebook(InitMethod::FromData, 4, 2, &pts, 7);
        for i in 0..4 {
            let row = w.row(i);
            let found = pts.chunks_exact(2).any(|p| p == row);
            assert!(found, "row {i} not a dataset point");
        }
    }

    #[test]
    fn from_data_rows_are_distinct() {
        let pts = grid_points(8, 2);
        let w = init_codebook(InitMethod::FromData, 8, 2, &pts, 3);
        for i in 0..8 {
            for j in (i + 1)..8 {
                assert_ne!(w.row(i), w.row(j));
            }
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let pts = grid_points(32, 4);
        for m in [InitMethod::FromData, InitMethod::Gaussian, InitMethod::KmeansPlusPlus] {
            let a = init_codebook(m, 5, 4, &pts, 99);
            let b = init_codebook(m, 5, 4, &pts, 99);
            assert_eq!(a, b, "{m:?} not deterministic");
            let c = init_codebook(m, 5, 4, &pts, 100);
            assert_ne!(a, c, "{m:?} ignored the seed");
        }
    }

    #[test]
    fn gaussian_is_roughly_standard() {
        let w = init_codebook(InitMethod::Gaussian, 64, 64, &[], 1);
        let n = (64 * 64) as f64;
        let mean: f64 = w.flat().iter().map(|x| *x as f64).sum::<f64>() / n;
        let var: f64 =
            w.flat().iter().map(|x| (*x as f64 - mean).powi(2)).sum::<f64>() / n;
        assert!(mean.abs() < 0.1, "mean {mean}");
        assert!((var - 1.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn kmeanspp_spreads_centers() {
        // two tight clusters far apart: k-means++ with kappa=2 must pick
        // one center in each
        let mut pts = Vec::new();
        for i in 0..50 {
            pts.extend_from_slice(&[0.0 + (i % 5) as f32 * 0.01, 0.0]);
        }
        for i in 0..50 {
            pts.extend_from_slice(&[100.0 + (i % 5) as f32 * 0.01, 0.0]);
        }
        let w = init_codebook(InitMethod::KmeansPlusPlus, 2, 2, &pts, 5);
        let (a, b) = (w.row(0)[0], w.row(1)[0]);
        assert!((a < 50.0) != (b < 50.0), "centers {a}, {b} in same cluster");
    }
}
