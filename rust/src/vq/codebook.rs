//! The codebook: `κ` prototypes in `R^d`, stored row-major and flat.


use super::Delta;

/// `κ` prototypes `w = (w_1, …, w_κ) ∈ (R^d)^κ`, row-major.
///
/// This is the `w` of the paper: every scheme's *version* (`w^i`) and the
/// *shared version* (`w_srd`) are `Codebook`s.
#[derive(Debug, Clone, PartialEq)]
pub struct Codebook {
    kappa: usize,
    dim: usize,
    data: Vec<f32>,
}

impl Codebook {
    /// A codebook of zeros.
    pub fn zeros(kappa: usize, dim: usize) -> Self {
        assert!(kappa > 0 && dim > 0, "codebook must be non-empty");
        Self { kappa, dim, data: vec![0.0; kappa * dim] }
    }

    /// Build from a flat row-major buffer (length must be `kappa * dim`).
    pub fn from_flat(kappa: usize, dim: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), kappa * dim, "flat buffer length mismatch");
        Self { kappa, dim, data }
    }

    pub fn kappa(&self) -> usize {
        self.kappa
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Prototype `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// Prototype `i`, mutable.
    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.data[i * self.dim..(i + 1) * self.dim]
    }

    /// The flat row-major buffer (what the PJRT engine feeds to XLA).
    #[inline]
    pub fn flat(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn flat_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// `w ← w − Δ` — apply a displacement (the *delta merge* of schemes
    /// B/C: the reducer folds worker deltas into the shared version).
    pub fn apply_delta(&mut self, delta: &Delta) {
        assert_eq!(self.data.len(), delta.flat().len(), "delta shape mismatch");
        for (w, d) in self.data.iter_mut().zip(delta.flat()) {
            *w -= d;
        }
    }

    /// Element-wise average of versions — the *averaging merge* of
    /// scheme A (paper eq. 3): `w_srd = (1/M) Σ_i w^i`.
    pub fn average(versions: &[Codebook]) -> Codebook {
        assert!(!versions.is_empty(), "cannot average zero versions");
        let mut out = Codebook::zeros(versions[0].kappa, versions[0].dim);
        Self::average_into(versions, &mut out);
        out
    }

    /// [`Codebook::average`] into an existing buffer (the scheme-A hot
    /// loop calls this every reduce round; no allocation).
    pub fn average_into(versions: &[Codebook], out: &mut Codebook) {
        assert!(!versions.is_empty(), "cannot average zero versions");
        let (kappa, dim) = (versions[0].kappa, versions[0].dim);
        assert_eq!((out.kappa, out.dim), (kappa, dim), "output shape mismatch");
        out.data.iter_mut().for_each(|o| *o = 0.0);
        for v in versions {
            assert_eq!((v.kappa, v.dim), (kappa, dim), "version shape mismatch");
            for (o, x) in out.data.iter_mut().zip(&v.data) {
                *o += x;
            }
        }
        let inv = 1.0 / versions.len() as f32;
        for o in out.data.iter_mut() {
            *o *= inv;
        }
    }

    /// Max absolute element-wise difference to another codebook.
    pub fn max_abs_diff(&self, other: &Codebook) -> f32 {
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max)
    }

    /// Squared Frobenius norm of the codebook.
    pub fn norm_sq(&self) -> f64 {
        self.data.iter().map(|x| (*x as f64) * (*x as f64)).sum()
    }

    /// True iff every entry is finite.
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_are_views_into_flat() {
        let mut w = Codebook::zeros(3, 2);
        w.row_mut(1).copy_from_slice(&[5.0, 6.0]);
        assert_eq!(w.flat(), &[0.0, 0.0, 5.0, 6.0, 0.0, 0.0]);
        assert_eq!(w.row(1), &[5.0, 6.0]);
    }

    #[test]
    fn average_of_identical_is_identity() {
        let w = Codebook::from_flat(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let avg = Codebook::average(&[w.clone(), w.clone(), w.clone()]);
        assert_eq!(avg, w);
    }

    #[test]
    fn average_is_elementwise_mean() {
        let a = Codebook::from_flat(1, 2, vec![0.0, 2.0]);
        let b = Codebook::from_flat(1, 2, vec![4.0, 6.0]);
        let avg = Codebook::average(&[a, b]);
        assert_eq!(avg.flat(), &[2.0, 4.0]);
    }

    #[test]
    fn apply_delta_subtracts() {
        let mut w = Codebook::from_flat(1, 2, vec![1.0, 1.0]);
        let d = Delta::from_flat(1, 2, vec![0.25, -0.5]);
        w.apply_delta(&d);
        assert_eq!(w.flat(), &[0.75, 1.5]);
    }

    #[test]
    #[should_panic(expected = "flat buffer length mismatch")]
    fn from_flat_checks_length() {
        let _ = Codebook::from_flat(2, 2, vec![0.0; 3]);
    }
}
