//! The `Engine` abstraction and the buildable spec.

use std::path::PathBuf;

use anyhow::Result;

use crate::vq::{Codebook, Delta};

/// A compute backend for the exported entry points.
///
/// All methods take `&mut self`: engines may cache buffers or lazily
/// compile. Implementations must use **identical math** (squared Euclidean,
/// first-minimum tie break, update order of paper eq. 1) so that engines
/// are interchangeable to float tolerance.
pub trait Engine {
    /// Backend name for logs/metrics.
    fn name(&self) -> &'static str;

    /// Advance `w` by one `τ`-point sequential VQ walk over `chunk`
    /// (flat `τ·d`), with per-step rates `eps` (`τ`), **accumulating** the
    /// window displacement into `delta` (paper eq. 7).
    fn vq_chunk(
        &mut self,
        w: &mut Codebook,
        chunk: &[f32],
        eps: &[f32],
        delta: &mut Delta,
    ) -> Result<()>;

    /// Un-normalized empirical distortion `Σ min_ℓ ‖z − w_ℓ‖²` over flat
    /// `points`.
    fn distortion_sum(&mut self, w: &Codebook, points: &[f32]) -> Result<f64>;

    /// Fused batch nearest-prototype scan over flat row-major `points`:
    /// `(code, squared distance)` per point, first-minimum tie break —
    /// the serving read path's distance kernel. The native engine is
    /// bit-identical to the scalar per-point scan; the PJRT engine runs
    /// the matmul-form artifact and agrees to float tolerance (ties may
    /// resolve differently where the re-associated distances differ).
    fn nearest_chunk(
        &mut self,
        w: &Codebook,
        points: &[f32],
    ) -> Result<(Vec<u32>, Vec<f32>)>;

    /// One Lloyd iteration over `points` (empty clusters keep their
    /// prototype). Returns per-cluster counts.
    fn kmeans_step(&mut self, w: &mut Codebook, points: &[f32]) -> Result<Vec<f32>>;
}

/// A buildable, sendable description of an engine.
///
/// The PJRT client is thread-confined, so concurrent runtimes pass this
/// spec across threads and call [`EngineSpec::build`] on the destination
/// thread.
#[derive(Debug, Clone, PartialEq)]
pub enum EngineSpec {
    /// Pure-Rust mirror (tests, huge sweeps).
    Native,
    /// AOT artifacts executed through PJRT (the production path).
    Pjrt {
        /// Directory holding `manifest.json` + `*.hlo.txt`.
        artifacts_dir: PathBuf,
        /// Variant name from the manifest (e.g. `"k16d16"`).
        variant: String,
    },
}

impl EngineSpec {
    /// Default artifact location relative to the repo root.
    pub fn pjrt_default(variant: &str) -> Self {
        EngineSpec::Pjrt {
            artifacts_dir: PathBuf::from("artifacts"),
            variant: variant.to_string(),
        }
    }

    /// Construct the engine on the current thread.
    pub fn build(&self) -> Result<Box<dyn Engine>> {
        match self {
            EngineSpec::Native => Ok(Box::new(super::NativeEngine::new())),
            #[cfg(feature = "pjrt")]
            EngineSpec::Pjrt { artifacts_dir, variant } => Ok(Box::new(
                super::PjrtEngine::load(artifacts_dir, variant)?,
            )),
            #[cfg(not(feature = "pjrt"))]
            EngineSpec::Pjrt { .. } => Err(anyhow::anyhow!(
                "this build carries no PJRT engine (rebuild with \
                 `--features pjrt` and run `make artifacts`), or use the \
                 native engine"
            )),
        }
    }
}

impl Default for EngineSpec {
    fn default() -> Self {
        EngineSpec::Native
    }
}
