//! Execution engines: PJRT-loaded AOT artifacts and their native mirror.
//!
//! The compute hot spots (the fused `τ`-point VQ walk, the tiled distortion
//! criterion, the batch-k-means step) are authored once in Pallas/JAX and
//! lowered by `make artifacts` to HLO text. [`PjrtEngine`] loads those
//! artifacts through the `xla` crate (`PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `compile` → `execute`) — this is the
//! production path, with Python nowhere at run time.
//!
//! [`NativeEngine`] is a bit-mirrored pure-Rust implementation of the same
//! math (same tie-breaking, same update order). It exists so that property
//! tests can run millions of steps cheaply and so that very large
//! simulations aren't bounded by PJRT dispatch; the `native_vs_pjrt`
//! integration test pins the two together over long trajectories.
//!
//! `PjRtClient` is `Rc`-based and thus thread-confined; multi-threaded
//! callers (the cloud runtime) clone an [`EngineSpec`] per worker and build
//! a private engine on each worker's thread.

mod engine;
mod manifest;
mod native;
#[cfg(feature = "pjrt")]
mod pjrt;

pub use engine::{Engine, EngineSpec};
pub use manifest::{EntryManifest, Manifest, VariantManifest, VariantParams};
pub use native::NativeEngine;
#[cfg(feature = "pjrt")]
pub use pjrt::PjrtEngine;
