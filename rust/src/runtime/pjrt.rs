//! The PJRT engine: loads and executes the AOT HLO-text artifacts.
//!
//! Pattern (see /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO **text** is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that this XLA
//! rejects; the text parser reassigns ids.
//!
//! Shape discipline: HLO shapes are static. `vq_chunk` requires
//! `eps.len() == tau` of the loaded variant; the distortion and k-means
//! entry points consume `eval_batch`-point batches, and the (at most
//! `eval_batch − 1`-point) remainder of an evaluation batch goes through
//! the same math natively. Everything else is an error — silent shape
//! adaptation would invalidate the artifact path.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::vq::{self, Codebook, Delta};

use super::manifest::{Manifest, VariantParams};
use super::Engine;

/// An engine executing the lowered entry points of one variant.
pub struct PjrtEngine {
    params: VariantParams,
    vq_chunk_exe: xla::PjRtLoadedExecutable,
    multi_chunk_exe: xla::PjRtLoadedExecutable,
    distortion_exe: xla::PjRtLoadedExecutable,
    kmeans_exe: xla::PjRtLoadedExecutable,
    /// `None` when the artifact set predates the batched read path —
    /// training entries still work; `nearest_chunk` errors with a
    /// re-lower hint instead of failing the whole load.
    nearest_exe: Option<xla::PjRtLoadedExecutable>,
}

fn load_exe(
    client: &xla::PjRtClient,
    dir: &Path,
    file: &str,
) -> Result<xla::PjRtLoadedExecutable> {
    let path = dir.join(file);
    let proto = xla::HloModuleProto::from_text_file(&path)
        .map_err(|e| anyhow!("loading HLO text {}: {e:?}", path.display()))?;
    let comp = xla::XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
}

fn lit_2d(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal> {
    debug_assert_eq!(data.len(), rows * cols);
    xla::Literal::vec1(data)
        .reshape(&[rows as i64, cols as i64])
        .map_err(|e| anyhow!("reshape literal: {e:?}"))
}

fn lit_1d(data: &[f32]) -> Result<xla::Literal> {
    Ok(xla::Literal::vec1(data))
}

/// Execute and unwrap the single result literal (lowered with
/// `return_tuple=True`, so outputs arrive as one tuple literal).
fn run(exe: &xla::PjRtLoadedExecutable, args: &[xla::Literal]) -> Result<xla::Literal> {
    let out = exe
        .execute::<xla::Literal>(args)
        .map_err(|e| anyhow!("pjrt execute: {e:?}"))?;
    out[0][0]
        .to_literal_sync()
        .map_err(|e| anyhow!("fetching result: {e:?}"))
}

fn to_f32_vec(lit: xla::Literal) -> Result<Vec<f32>> {
    lit.to_vec::<f32>().map_err(|e| anyhow!("literal to_vec: {e:?}"))
}

impl PjrtEngine {
    /// Load all entry points of `variant` from `artifacts_dir` and compile
    /// them on a fresh CPU PJRT client.
    pub fn load(artifacts_dir: &Path, variant: &str) -> Result<Self> {
        let manifest = Manifest::load(artifacts_dir)?;
        let vm = manifest.variant(variant)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("creating PJRT CPU client: {e:?}"))?;
        let exe = |entry: &str| -> Result<xla::PjRtLoadedExecutable> {
            load_exe(&client, artifacts_dir, &vm.entry(entry)?.file)
                .with_context(|| format!("entry {entry:?} of variant {variant:?}"))
        };
        let nearest_exe = match vm.entry("nearest_batch") {
            Ok(e) => Some(
                load_exe(&client, artifacts_dir, &e.file).with_context(|| {
                    format!("entry \"nearest_batch\" of variant {variant:?}")
                })?,
            ),
            Err(_) => None,
        };
        Ok(Self {
            params: vm.params.clone(),
            vq_chunk_exe: exe("vq_chunk")?,
            multi_chunk_exe: exe("multi_chunk")?,
            distortion_exe: exe("distortion_sum")?,
            kmeans_exe: exe("batch_kmeans_step")?,
            nearest_exe,
        })
    }

    /// Static parameters of the loaded variant.
    pub fn params(&self) -> &VariantParams {
        &self.params
    }

    fn check_codebook(&self, w: &Codebook) -> Result<()> {
        if w.kappa() != self.params.kappa || w.dim() != self.params.dim {
            return Err(anyhow!(
                "codebook ({}, {}) does not match variant {:?} ({}, {})",
                w.kappa(),
                w.dim(),
                self.params.name,
                self.params.kappa,
                self.params.dim
            ));
        }
        Ok(())
    }

    /// `scan_chunks` consecutive walks in one dispatch (the `lax.scan`
    /// artifact) — used by long sequential stretches to amortize dispatch
    /// overhead. `chunks` is `(S·τ)·d` flat, `eps` is `S·τ`.
    pub fn multi_chunk(
        &mut self,
        w: &mut Codebook,
        chunks: &[f32],
        eps: &[f32],
        delta: &mut Delta,
    ) -> Result<()> {
        self.check_codebook(w)?;
        let (s, tau, d) =
            (self.params.scan_chunks, self.params.tau, self.params.dim);
        if eps.len() != s * tau || chunks.len() != s * tau * d {
            return Err(anyhow!(
                "multi_chunk expects S*tau = {} steps, got {}",
                s * tau,
                eps.len()
            ));
        }
        let w_lit = lit_2d(w.flat(), self.params.kappa, d)?;
        let z_lit = xla::Literal::vec1(chunks)
            .reshape(&[s as i64, tau as i64, d as i64])
            .map_err(|e| anyhow!("reshape zs: {e:?}"))?;
        let e_lit = xla::Literal::vec1(eps)
            .reshape(&[s as i64, tau as i64])
            .map_err(|e| anyhow!("reshape eps: {e:?}"))?;
        let result = run(&self.multi_chunk_exe, &[w_lit, z_lit, e_lit])?;
        let (w_out, d_out) = result
            .to_tuple2()
            .map_err(|e| anyhow!("unpacking multi_chunk tuple: {e:?}"))?;
        w.flat_mut().copy_from_slice(&to_f32_vec(w_out)?);
        let acc = Delta::from_flat(self.params.kappa, d, to_f32_vec(d_out)?);
        delta.accumulate(&acc);
        Ok(())
    }
}

impl Engine for PjrtEngine {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn vq_chunk(
        &mut self,
        w: &mut Codebook,
        chunk: &[f32],
        eps: &[f32],
        delta: &mut Delta,
    ) -> Result<()> {
        self.check_codebook(w)?;
        let (tau, d) = (self.params.tau, self.params.dim);
        if eps.len() != tau || chunk.len() != tau * d {
            return Err(anyhow!(
                "vq_chunk artifact is shape-static: expected tau = {tau}, got {} \
                 (pick a variant with matching tau or use the native engine)",
                eps.len()
            ));
        }
        let w_lit = lit_2d(w.flat(), self.params.kappa, d)?;
        let z_lit = lit_2d(chunk, tau, d)?;
        let e_lit = lit_1d(eps)?;
        let result = run(&self.vq_chunk_exe, &[w_lit, z_lit, e_lit])?;
        let (w_out, d_out) = result
            .to_tuple2()
            .map_err(|e| anyhow!("unpacking vq_chunk tuple: {e:?}"))?;
        w.flat_mut().copy_from_slice(&to_f32_vec(w_out)?);
        let acc = Delta::from_flat(self.params.kappa, d, to_f32_vec(d_out)?);
        delta.accumulate(&acc);
        Ok(())
    }

    fn distortion_sum(&mut self, w: &Codebook, points: &[f32]) -> Result<f64> {
        self.check_codebook(w)?;
        let (b, d) = (self.params.eval_batch, self.params.dim);
        let n = points.len() / d;
        let full_batches = n / b;
        let mut total = 0.0f64;
        for i in 0..full_batches {
            let batch = &points[i * b * d..(i + 1) * b * d];
            let w_lit = lit_2d(w.flat(), self.params.kappa, d)?;
            let z_lit = lit_2d(batch, b, d)?;
            let result = run(&self.distortion_exe, &[w_lit, z_lit])?;
            let scalar = result
                .to_tuple1()
                .map_err(|e| anyhow!("unpacking distortion tuple: {e:?}"))?;
            total += to_f32_vec(scalar)?[0] as f64;
        }
        // Remainder (< eval_batch points): same math, native path.
        let rem = &points[full_batches * b * d..];
        if !rem.is_empty() {
            total += vq::distortion_sum(w, rem);
        }
        Ok(total)
    }

    fn nearest_chunk(
        &mut self,
        w: &Codebook,
        points: &[f32],
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        self.check_codebook(w)?;
        let exe = self.nearest_exe.as_ref().ok_or_else(|| {
            anyhow!(
                "this artifact set predates the \"nearest_batch\" entry point \
                 — re-run `make artifacts` to lower it, or use the native \
                 engine"
            )
        })?;
        let (b, d) = (self.params.eval_batch, self.params.dim);
        if points.len() % d != 0 {
            return Err(anyhow!("points not a multiple of dim {d}"));
        }
        let n = points.len() / d;
        let full_batches = n / b;
        let mut codes = Vec::with_capacity(n);
        let mut dists = Vec::with_capacity(n);
        for i in 0..full_batches {
            let batch = &points[i * b * d..(i + 1) * b * d];
            let w_lit = lit_2d(w.flat(), self.params.kappa, d)?;
            let z_lit = lit_2d(batch, b, d)?;
            let result = run(exe, &[w_lit, z_lit])?;
            let (idx, dd) = result
                .to_tuple2()
                .map_err(|e| anyhow!("unpacking nearest_batch tuple: {e:?}"))?;
            // The kernel emits indices as f32 (one homogeneous tuple on
            // the wire); exact integers up to 2^24 ≫ any kappa here.
            codes.extend(to_f32_vec(idx)?.into_iter().map(|x| x as u32));
            dists.extend(to_f32_vec(dd)?);
        }
        // Remainder (< eval_batch points): same math, native path.
        let rem = &points[full_batches * b * d..];
        if !rem.is_empty() {
            let (c, dd) = vq::nearest_batch(w, rem);
            codes.extend(c);
            dists.extend(dd);
        }
        Ok((codes, dists))
    }

    fn kmeans_step(&mut self, w: &mut Codebook, points: &[f32]) -> Result<Vec<f32>> {
        self.check_codebook(w)?;
        let (b, d) = (self.params.eval_batch, self.params.dim);
        if points.len() != b * d {
            return Err(anyhow!(
                "batch_kmeans_step artifact consumes exactly eval_batch = {b} \
                 points, got {} (use the native engine for full-batch Lloyd)",
                points.len() / d
            ));
        }
        let w_lit = lit_2d(w.flat(), self.params.kappa, d)?;
        let z_lit = lit_2d(points, b, d)?;
        let result = run(&self.kmeans_exe, &[w_lit, z_lit])?;
        let (w_out, counts) = result
            .to_tuple2()
            .map_err(|e| anyhow!("unpacking kmeans tuple: {e:?}"))?;
        w.flat_mut().copy_from_slice(&to_f32_vec(w_out)?);
        to_f32_vec(counts)
    }
}
