//! The pure-Rust engine: a bit-mirror of the L1 kernels.

use anyhow::Result;

use crate::vq::{self, Codebook, Delta};

use super::Engine;

/// Native engine — same math as the Pallas kernels, no PJRT dispatch.
#[derive(Debug, Default, Clone, Copy)]
pub struct NativeEngine {
    _priv: (),
}

impl NativeEngine {
    pub fn new() -> Self {
        Self { _priv: () }
    }
}

impl Engine for NativeEngine {
    fn name(&self) -> &'static str {
        "native"
    }

    fn vq_chunk(
        &mut self,
        w: &mut Codebook,
        chunk: &[f32],
        eps: &[f32],
        delta: &mut Delta,
    ) -> Result<()> {
        vq::vq_chunk(w, chunk, eps, delta);
        Ok(())
    }

    fn distortion_sum(&mut self, w: &Codebook, points: &[f32]) -> Result<f64> {
        Ok(vq::distortion_sum(w, points))
    }

    fn nearest_chunk(
        &mut self,
        w: &Codebook,
        points: &[f32],
    ) -> Result<(Vec<u32>, Vec<f32>)> {
        Ok(vq::nearest_batch(w, points))
    }

    fn kmeans_step(&mut self, w: &mut Codebook, points: &[f32]) -> Result<Vec<f32>> {
        let dim = w.dim();
        let kappa = w.kappa();
        let mut sums = vec![0.0f64; kappa * dim];
        let mut counts = vec![0.0f32; kappa];
        for z in points.chunks_exact(dim) {
            let a = vq::nearest(w, z);
            counts[a] += 1.0;
            for k in 0..dim {
                sums[a * dim + k] += z[k] as f64;
            }
        }
        for i in 0..kappa {
            if counts[i] > 0.0 {
                let inv = 1.0 / counts[i] as f64;
                let row = w.row_mut(i);
                for k in 0..dim {
                    row[k] = (sums[i * dim + k] * inv) as f32;
                }
            } // empty cluster: prototype unchanged
        }
        Ok(counts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_step_moves_to_centroids() {
        let mut eng = NativeEngine::new();
        let mut w = Codebook::from_flat(2, 1, vec![0.0, 10.0]);
        // cluster A: {1, 3} -> centroid 2 ; cluster B: {9, 11} -> 10
        let counts = eng
            .kmeans_step(&mut w, &[1.0, 3.0, 9.0, 11.0])
            .unwrap();
        assert_eq!(counts, vec![2.0, 2.0]);
        assert_eq!(w.flat(), &[2.0, 10.0]);
    }

    #[test]
    fn kmeans_empty_cluster_keeps_prototype() {
        let mut eng = NativeEngine::new();
        let mut w = Codebook::from_flat(2, 1, vec![0.0, 1000.0]);
        let counts = eng.kmeans_step(&mut w, &[1.0, 2.0]).unwrap();
        assert_eq!(counts, vec![2.0, 0.0]);
        assert_eq!(w.row(1), &[1000.0]);
    }

    #[test]
    fn nearest_chunk_scans_the_block() {
        let mut eng = NativeEngine::new();
        let w = Codebook::from_flat(2, 1, vec![0.0, 10.0]);
        let (codes, dists) = eng.nearest_chunk(&w, &[1.0, 9.0]).unwrap();
        assert_eq!(codes, vec![0, 1]);
        assert_eq!(dists, vec![1.0, 1.0]);
    }

    #[test]
    fn vq_chunk_delegates_to_core() {
        let mut eng = NativeEngine::new();
        let mut w = Codebook::from_flat(1, 1, vec![0.0]);
        let mut d = Delta::zeros(1, 1);
        eng.vq_chunk(&mut w, &[2.0], &[0.5], &mut d).unwrap();
        assert_eq!(w.flat(), &[1.0]);
        assert_eq!(d.flat(), &[-1.0]);
    }
}
