//! `artifacts/manifest.json` — the contract between `aot.py` and the
//! Rust runtime. The Python side is the writer; this is the reader
//! (parsed with the in-tree JSON module).

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::Json;

/// Static shape parameters of one AOT variant (mirrors
/// `python/compile/variants.py`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VariantParams {
    pub name: String,
    pub kappa: usize,
    pub dim: usize,
    pub tau: usize,
    pub eval_batch: usize,
    pub eval_tile: usize,
    pub scan_chunks: usize,
}

/// One lowered entry point.
#[derive(Debug, Clone, PartialEq)]
pub struct EntryManifest {
    /// File name inside the artifacts directory.
    pub file: String,
    /// Input specs, in call order.
    pub inputs: Vec<InputSpec>,
}

#[derive(Debug, Clone, PartialEq)]
pub struct InputSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

/// One variant: parameters plus its entry points.
#[derive(Debug, Clone, PartialEq)]
pub struct VariantManifest {
    pub params: VariantParams,
    pub entries: BTreeMap<String, EntryManifest>,
}

/// The whole manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub format: String,
    pub variants: BTreeMap<String, VariantManifest>,
}

impl Manifest {
    /// Load `manifest.json` from an artifacts directory.
    pub fn load(artifacts_dir: &Path) -> Result<Self> {
        let path = artifacts_dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!(
                "reading {} — run `make artifacts` first",
                path.display()
            )
        })?;
        Self::parse(&text).context("parsing manifest.json")
    }

    /// Parse manifest JSON text.
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let format = j.req("format")?.as_str()?.to_string();
        if format != "hlo-text/return-tuple" {
            return Err(anyhow!(
                "unsupported artifact format {format:?} (runtime expects \
                 hlo-text/return-tuple)"
            ));
        }
        let mut variants = BTreeMap::new();
        for (name, v) in j.req("variants")?.as_obj()? {
            variants.insert(name.clone(), parse_variant(name, v)?);
        }
        Ok(Manifest { format, variants })
    }

    pub fn variant(&self, name: &str) -> Result<&VariantManifest> {
        self.variants.get(name).ok_or_else(|| {
            anyhow!(
                "variant {name:?} not in manifest (have: {:?})",
                self.variants.keys().collect::<Vec<_>>()
            )
        })
    }
}

fn parse_variant(name: &str, v: &Json) -> Result<VariantManifest> {
    let p = v.req("params").with_context(|| format!("variant {name}"))?;
    let params = VariantParams {
        name: p.req("name")?.as_str()?.to_string(),
        kappa: p.req("kappa")?.as_usize()?,
        dim: p.req("dim")?.as_usize()?,
        tau: p.req("tau")?.as_usize()?,
        eval_batch: p.req("eval_batch")?.as_usize()?,
        eval_tile: p.req("eval_tile")?.as_usize()?,
        scan_chunks: p.req("scan_chunks")?.as_usize()?,
    };
    let mut entries = BTreeMap::new();
    for (entry_name, e) in v.req("entries")?.as_obj()? {
        let mut inputs = Vec::new();
        for input in e.req("inputs")?.as_arr()? {
            let shape = input
                .req("shape")?
                .as_arr()?
                .iter()
                .map(|d| d.as_usize())
                .collect::<Result<Vec<_>>>()?;
            inputs.push(InputSpec {
                shape,
                dtype: input.req("dtype")?.as_str()?.to_string(),
            });
        }
        entries.insert(
            entry_name.clone(),
            EntryManifest { file: e.req("file")?.as_str()?.to_string(), inputs },
        );
    }
    Ok(VariantManifest { params, entries })
}

impl VariantManifest {
    pub fn entry(&self, name: &str) -> Result<&EntryManifest> {
        self.entries.get(name).ok_or_else(|| {
            anyhow!(
                "entry {name:?} missing from variant {:?}",
                self.params.name
            )
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "format": "hlo-text/return-tuple",
      "variants": {
        "k8d2": {
          "params": {"name": "k8d2", "kappa": 8, "dim": 2, "tau": 10,
                     "eval_batch": 1024, "eval_tile": 256, "scan_chunks": 16},
          "entries": {
            "vq_chunk": {"file": "vq_chunk__k8d2.hlo.txt",
                         "inputs": [{"shape": [8,2], "dtype": "float32"},
                                    {"shape": [10,2], "dtype": "float32"},
                                    {"shape": [10], "dtype": "float32"}]}
          }
        }
      }
    }"#;

    #[test]
    fn parses_python_emitted_shape() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let v = m.variant("k8d2").unwrap();
        assert_eq!(v.params.kappa, 8);
        let e = v.entry("vq_chunk").unwrap();
        assert_eq!(e.inputs[1].shape, vec![10, 2]);
        assert_eq!(e.inputs[2].dtype, "float32");
        assert!(v.entry("nope").is_err());
        assert!(m.variant("nope").is_err());
    }

    #[test]
    fn rejects_wrong_format() {
        let bad = SAMPLE.replace("hlo-text/return-tuple", "proto");
        assert!(Manifest::parse(&bad).is_err());
    }

    #[test]
    fn missing_key_is_reported() {
        let bad = SAMPLE.replace("\"tau\": 10,", "");
        let err = format!("{:#}", Manifest::parse(&bad).unwrap_err());
        assert!(err.contains("tau"), "{err}");
    }
}
