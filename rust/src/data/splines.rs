//! Functional synthetic data: noisy B-spline curves sampled on a grid.
//!
//! The paper's original experiments (Patra's PhD, §4.2 — the generator the
//! footnote points to) quantize *functional* data: smooth random curves
//! built from B-splines, sampled at `d` points to give vectors in `R^d`.
//! This module reproduces that family: `components` mean curves are drawn
//! as random control-coefficient vectors; every sample perturbs one mean's
//! coefficients with Gaussian noise and evaluates the cubic B-spline on a
//! uniform grid of `dim` points.
//!
//! Together with the Gaussian [`super::MixtureSpec`], this covers both
//! data regimes and backs the paper's remark that its conclusions are
//! “more sensitive to the loss function smoothness and convexity than to
//! the data choice” — the `functional_data` integration test reruns the
//! scheme comparison on splines and gets the same shapes.

use crate::util::Rng;

use super::Dataset;

/// Specification of the functional (B-spline) generator.
#[derive(Debug, Clone, PartialEq)]
pub struct SplineSpec {
    /// Number of mean curves (the “true” κ*).
    pub components: usize,
    /// Samples per curve = the vector dimension `d`.
    pub dim: usize,
    /// Number of cubic-spline control coefficients per curve (≥ 4).
    pub control_points: usize,
    /// Scale of the mean curves' control coefficients.
    pub amplitude: f32,
    /// Std of the per-sample Gaussian perturbation of the coefficients.
    pub coeff_std: f32,
}

impl Default for SplineSpec {
    fn default() -> Self {
        Self {
            components: 16,
            dim: 16,
            control_points: 8,
            amplitude: 5.0,
            coeff_std: 0.6,
        }
    }
}

impl SplineSpec {
    pub fn validate(&self) -> Result<(), String> {
        if self.components == 0 || self.dim == 0 {
            return Err("splines need components > 0 and dim > 0".into());
        }
        if self.control_points < 4 {
            return Err("cubic splines need at least 4 control points".into());
        }
        if !(self.amplitude > 0.0) || !(self.coeff_std > 0.0) {
            return Err("amplitude and coeff_std must be positive".into());
        }
        Ok(())
    }

    /// The `dim × control_points` cubic B-spline basis matrix on a uniform
    /// grid over the curve's domain (row-major).
    pub fn basis(&self) -> Vec<f32> {
        let (d, c) = (self.dim, self.control_points);
        let mut basis = vec![0.0f32; d * c];
        for (row, b) in basis.chunks_exact_mut(c).enumerate() {
            // map grid point into knot coordinates of a uniform cubic spline
            let t = row as f64 / (d - 1).max(1) as f64 * (c - 3) as f64;
            let seg = (t.floor() as usize).min(c - 4);
            let u = t - seg as f64;
            // cubic uniform B-spline segment weights (Cox–de Boor)
            let w0 = (1.0 - u).powi(3) / 6.0;
            let w1 = (3.0 * u.powi(3) - 6.0 * u.powi(2) + 4.0) / 6.0;
            let w2 = (-3.0 * u.powi(3) + 3.0 * u.powi(2) + 3.0 * u + 1.0) / 6.0;
            let w3 = u.powi(3) / 6.0;
            b[seg] = w0 as f32;
            b[seg + 1] = w1 as f32;
            b[seg + 2] = w2 as f32;
            b[seg + 3] = w3 as f32;
        }
        basis
    }

    /// Mean-curve control coefficients for a given seed (deterministic).
    pub fn mean_coeffs(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::from_seed_stream(seed, 0x5B11E5); // spline stream
        (0..self.components * self.control_points)
            .map(|_| rng.range_f32(-self.amplitude, self.amplitude))
            .collect()
    }

    /// Generate `n` sampled curves as a flat row-major buffer
    /// (splittable: independent stream per `(seed, stream_id)`).
    pub fn generate(&self, n: usize, seed: u64, stream_id: u64) -> Vec<f32> {
        let basis = self.basis();
        let means = self.mean_coeffs(seed);
        let c = self.control_points;
        let mut rng = Rng::from_seed_stream(seed ^ 0x51_1E5, stream_id);
        let mut coeffs = vec![0.0f32; c];
        let mut out = Vec::with_capacity(n * self.dim);
        for _ in 0..n {
            let k = rng.usize(self.components);
            for (j, co) in coeffs.iter_mut().enumerate() {
                *co = means[k * c + j] + self.coeff_std * rng.normal_f32();
            }
            for row in basis.chunks_exact(c) {
                let mut v = 0.0f32;
                for (b, co) in row.iter().zip(&coeffs) {
                    v += b * co;
                }
                out.push(v);
            }
        }
        out
    }

    pub fn dataset(&self, n: usize, seed: u64) -> Dataset {
        Dataset::new(self.generate(n, seed, 0), self.dim)
    }

    pub fn eval_sample(&self, n: usize, seed: u64) -> Vec<f32> {
        self.generate(n, seed, u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basis_rows_are_a_partition_of_unity() {
        let spec = SplineSpec::default();
        let basis = spec.basis();
        for (i, row) in basis.chunks_exact(spec.control_points).enumerate() {
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
            assert!(row.iter().all(|w| *w >= -1e-6), "negative weight row {i}");
        }
    }

    #[test]
    fn deterministic_and_splittable() {
        let spec = SplineSpec::default();
        assert_eq!(spec.generate(50, 1, 0), spec.generate(50, 1, 0));
        assert_ne!(spec.generate(50, 1, 0), spec.generate(50, 1, 1));
        assert_ne!(spec.generate(50, 1, 0), spec.generate(50, 2, 0));
    }

    #[test]
    fn curves_are_smooth() {
        // functional data: adjacent samples of a curve differ much less
        // than its overall amplitude (no white-noise vectors)
        let spec = SplineSpec { coeff_std: 0.1, ..Default::default() };
        let pts = spec.generate(100, 3, 0);
        for curve in pts.chunks_exact(spec.dim) {
            let amp = curve.iter().fold(0.0f32, |a, x| a.max(x.abs()));
            let max_step = curve
                .windows(2)
                .map(|w| (w[1] - w[0]).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_step < amp.max(0.5),
                "curve jumps by {max_step} with amplitude {amp}"
            );
        }
    }

    #[test]
    fn samples_cluster_around_their_mean_curves() {
        let spec = SplineSpec { coeff_std: 0.05, ..Default::default() };
        let basis = spec.basis();
        let means = spec.mean_coeffs(7);
        // evaluate the mean curves
        let c = spec.control_points;
        let mut mean_curves = Vec::new();
        for k in 0..spec.components {
            for row in basis.chunks_exact(c) {
                let v: f32 = row
                    .iter()
                    .zip(&means[k * c..(k + 1) * c])
                    .map(|(b, m)| b * m)
                    .sum();
                mean_curves.push(v);
            }
        }
        let pts = spec.generate(200, 7, 0);
        for z in pts.chunks_exact(spec.dim) {
            let min_d = mean_curves
                .chunks_exact(spec.dim)
                .map(|m| {
                    m.iter().zip(z).map(|(a, b)| (a - b).powi(2)).sum::<f32>()
                })
                .fold(f32::INFINITY, f32::min);
            assert!(min_d < 1.0, "sample {min_d} away from every mean curve");
        }
    }

    #[test]
    fn validation() {
        let mut s = SplineSpec::default();
        s.control_points = 3;
        assert!(s.validate().is_err());
        assert!(SplineSpec::default().validate().is_ok());
    }
}
