//! Synthetic data substrate.
//!
//! The paper evaluates on synthetic vector data (the generator from Patra's
//! PhD §4.2; the original URL is dead). Per the paper — “our conclusions
//! are more sensitive to the loss function smoothness and convexity than to
//! the data choice” — we substitute a configurable Gaussian-mixture
//! generator with controllable separation, imbalance and uniform background
//! noise (DESIGN.md §Substitutions). The generator is splittable: shard `i`
//! of a dataset is reproducible in isolation, which is what lets the cloud
//! runtime give every worker its own shard without materializing the whole
//! dataset on one node.

mod dataset;
mod mixture;
mod splines;

pub use dataset::{Dataset, Shard};
pub use mixture::MixtureSpec;
pub use splines::SplineSpec;
