//! Gaussian-mixture generator with separation / imbalance / noise controls.

use crate::util::Rng;

use super::Dataset;

/// Specification of the synthetic mixture.
///
/// `components` cluster centers are drawn uniformly in
/// `[-separation, separation]^dim`; each sample picks a component according
/// to (optionally imbalanced) weights and adds `N(0, std²)` noise; a
/// `noise_frac` fraction of samples is replaced by uniform background noise
/// over the bounding box — the non-convexity stressor.
#[derive(Debug, Clone, PartialEq)]
pub struct MixtureSpec {
    /// Number of mixture components (the “true” κ*).
    pub components: usize,
    /// Sample dimension `d`.
    pub dim: usize,
    /// Half-width of the center box.
    pub separation: f32,
    /// Per-component standard deviation.
    pub std: f32,
    /// Zipf-like imbalance exponent: weight_k ∝ 1/(k+1)^imbalance
    /// (0 = balanced).
    pub imbalance: f32,
    /// Fraction of points replaced by uniform background noise.
    pub noise_frac: f32,
}

impl Default for MixtureSpec {
    fn default() -> Self {
        // Paper-scale default: 16 well-separated clusters in R^16.
        Self {
            components: 16,
            dim: 16,
            separation: 5.0,
            std: 0.6,
            imbalance: 0.0,
            noise_frac: 0.02,
        }
    }
}

impl MixtureSpec {
    pub fn validate(&self) -> Result<(), String> {
        if self.components == 0 || self.dim == 0 {
            return Err("mixture needs components > 0 and dim > 0".into());
        }
        if !(self.separation > 0.0 && self.separation.is_finite()) {
            return Err("separation must be positive".into());
        }
        if !(self.std > 0.0 && self.std.is_finite()) {
            return Err("std must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.noise_frac) {
            return Err("noise_frac must be in [0, 1]".into());
        }
        if self.imbalance < 0.0 {
            return Err("imbalance must be >= 0".into());
        }
        Ok(())
    }

    /// Component centers for a given seed (deterministic).
    pub fn centers(&self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::from_seed_stream(seed, 0xC0FF_EE00);
        (0..self.components * self.dim)
            .map(|_| rng.range_f32(-self.separation, self.separation))
            .collect()
    }

    /// Component weights (normalized).
    pub fn weights(&self) -> Vec<f64> {
        let raw: Vec<f64> = (0..self.components)
            .map(|k| 1.0 / ((k + 1) as f64).powf(self.imbalance as f64))
            .collect();
        let total: f64 = raw.iter().sum();
        raw.into_iter().map(|w| w / total).collect()
    }

    /// Generate `n` points as a flat row-major buffer.
    ///
    /// Splittability: the stream for `(seed, stream_id)` is independent of
    /// any other stream id, so shard `i` regenerates identically whether or
    /// not the other shards were ever produced.
    pub fn generate(&self, n: usize, seed: u64, stream_id: u64) -> Vec<f32> {
        let centers = self.centers(seed);
        let weights = self.weights();
        // cumulative weights for inverse-CDF component sampling
        let mut cum = Vec::with_capacity(weights.len());
        let mut acc = 0.0f64;
        for w in &weights {
            acc += w;
            cum.push(acc);
        }
        let mut rng = Rng::from_seed_stream(seed, stream_id);
        let mut out = Vec::with_capacity(n * self.dim);
        let bound = self.separation + 3.0 * self.std;
        for _ in 0..n {
            if rng.bool(self.noise_frac as f64) {
                for _ in 0..self.dim {
                    out.push(rng.range_f32(-bound, bound));
                }
            } else {
                let u: f64 = rng.f64();
                let k = cum.iter().position(|c| u <= *c).unwrap_or(cum.len() - 1);
                let c = &centers[k * self.dim..(k + 1) * self.dim];
                for ck in c {
                    out.push(ck + self.std * rng.normal_f32());
                }
            }
        }
        out
    }

    /// Full dataset of `n` points (stream 0) plus a held-out evaluation
    /// sample (stream `u64::MAX`), both deterministic in `seed`.
    pub fn dataset(&self, n: usize, seed: u64) -> Dataset {
        Dataset::new(self.generate(n, seed, 0), self.dim)
    }

    /// Held-out evaluation sample (never overlaps the training streams).
    pub fn eval_sample(&self, n: usize, seed: u64) -> Vec<f32> {
        self.generate(n, seed, u64::MAX)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed_and_stream() {
        let spec = MixtureSpec::default();
        assert_eq!(spec.generate(100, 1, 0), spec.generate(100, 1, 0));
        assert_ne!(spec.generate(100, 1, 0), spec.generate(100, 2, 0));
        assert_ne!(spec.generate(100, 1, 0), spec.generate(100, 1, 1));
    }

    #[test]
    fn correct_length_and_finite() {
        let spec = MixtureSpec { dim: 3, ..Default::default() };
        let pts = spec.generate(50, 9, 4);
        assert_eq!(pts.len(), 150);
        assert!(pts.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn weights_normalized_and_imbalanced() {
        let bal = MixtureSpec { imbalance: 0.0, ..Default::default() };
        let w = bal.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((w[0] - w[15]).abs() < 1e-12);

        let imb = MixtureSpec { imbalance: 1.0, ..Default::default() };
        let w = imb.weights();
        assert!((w.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(w[0] > w[15] * 10.0);
    }

    #[test]
    fn points_cluster_near_centers_when_noiseless() {
        let spec = MixtureSpec {
            components: 4,
            dim: 2,
            separation: 10.0,
            std: 0.1,
            imbalance: 0.0,
            noise_frac: 0.0,
        };
        let centers = spec.centers(3);
        let pts = spec.generate(200, 3, 0);
        for z in pts.chunks_exact(2) {
            let min_d = centers
                .chunks_exact(2)
                .map(|c| (c[0] - z[0]).powi(2) + (c[1] - z[1]).powi(2))
                .fold(f32::INFINITY, f32::min);
            assert!(min_d < 1.0, "point {z:?} far from every center");
        }
    }

    #[test]
    fn eval_sample_differs_from_training_stream() {
        let spec = MixtureSpec::default();
        assert_ne!(spec.eval_sample(64, 7), spec.generate(64, 7, 0));
    }

    #[test]
    fn validate_catches_bad_specs() {
        let mut s = MixtureSpec::default();
        s.noise_frac = 1.5;
        assert!(s.validate().is_err());
        let mut s = MixtureSpec::default();
        s.components = 0;
        assert!(s.validate().is_err());
        assert!(MixtureSpec::default().validate().is_ok());
    }
}
