//! Flat datasets and the per-worker shards of the paper's setting.
//!
//! The paper splits the dataset “among the local memory of the computing
//! instances”, giving worker `i` the sequence `{z_t^i}_{t=1}^n` and cycling
//! it (`z_{t+1 mod n}` in eq. 1). [`Shard`] reproduces exactly that: a
//! contiguous slice of the dataset walked cyclically.

/// An in-memory dataset: `n` points of dimension `dim`, flat row-major.
#[derive(Debug, Clone)]
pub struct Dataset {
    points: Vec<f32>,
    dim: usize,
}

impl Dataset {
    pub fn new(points: Vec<f32>, dim: usize) -> Self {
        assert!(dim > 0, "dim must be positive");
        assert_eq!(points.len() % dim, 0, "buffer not a multiple of dim");
        Self { points, dim }
    }

    pub fn len(&self) -> usize {
        self.points.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn flat(&self) -> &[f32] {
        &self.points
    }

    /// Point `i` as a slice.
    pub fn point(&self, i: usize) -> &[f32] {
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    /// Split into `m` contiguous shards of (near-)equal size. The first
    /// `len % m` shards get one extra point — every point lands in exactly
    /// one shard.
    pub fn split(&self, m: usize) -> Vec<Shard> {
        assert!(m > 0, "need at least one shard");
        let n = self.len();
        assert!(n >= m, "fewer points than shards");
        let base = n / m;
        let extra = n % m;
        let mut shards = Vec::with_capacity(m);
        let mut start = 0usize;
        for i in 0..m {
            let size = base + usize::from(i < extra);
            let pts =
                self.points[start * self.dim..(start + size) * self.dim].to_vec();
            shards.push(Shard::new(pts, self.dim, i));
            start += size;
        }
        shards
    }
}

/// One worker's local data `{z_t^i}`, walked cyclically.
#[derive(Debug, Clone)]
pub struct Shard {
    points: Vec<f32>,
    dim: usize,
    worker_id: usize,
}

impl Shard {
    pub fn new(points: Vec<f32>, dim: usize, worker_id: usize) -> Self {
        assert_eq!(points.len() % dim, 0, "buffer not a multiple of dim");
        assert!(!points.is_empty(), "empty shard");
        Self { points, dim, worker_id }
    }

    pub fn len(&self) -> usize {
        self.points.len() / self.dim
    }

    pub fn is_empty(&self) -> bool {
        false // constructor rejects empty shards
    }

    pub fn dim(&self) -> usize {
        self.dim
    }

    pub fn worker_id(&self) -> usize {
        self.worker_id
    }

    pub fn flat(&self) -> &[f32] {
        &self.points
    }

    /// Point `t mod n` — the paper's cyclic walk.
    pub fn point_mod(&self, t: u64) -> &[f32] {
        let i = (t % self.len() as u64) as usize;
        &self.points[i * self.dim..(i + 1) * self.dim]
    }

    /// Copy the `count` points starting at global step `t0` (cyclically)
    /// into `out` (flat, `count * dim` long). This is the chunk the engines
    /// feed to the fused `vq_chunk` kernel.
    pub fn fill_chunk(&self, t0: u64, count: usize, out: &mut [f32]) {
        assert_eq!(out.len(), count * self.dim, "chunk buffer size mismatch");
        let n = self.len() as u64;
        for j in 0..count {
            let i = ((t0 + j as u64) % n) as usize;
            out[j * self.dim..(j + 1) * self.dim]
                .copy_from_slice(&self.points[i * self.dim..(i + 1) * self.dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ds(n: usize, dim: usize) -> Dataset {
        Dataset::new((0..n * dim).map(|i| i as f32).collect(), dim)
    }

    #[test]
    fn split_covers_all_points_once() {
        let d = ds(10, 2);
        let shards = d.split(3);
        assert_eq!(shards.iter().map(Shard::len).sum::<usize>(), 10);
        assert_eq!(shards[0].len(), 4); // 10 = 4 + 3 + 3
        let mut rebuilt = Vec::new();
        for s in &shards {
            rebuilt.extend_from_slice(s.flat());
        }
        assert_eq!(rebuilt, d.flat());
    }

    #[test]
    fn point_mod_wraps() {
        let d = ds(3, 2);
        let s = &d.split(1)[0];
        assert_eq!(s.point_mod(0), s.point_mod(3));
        assert_eq!(s.point_mod(2), s.point_mod(5));
        assert_ne!(s.point_mod(0), s.point_mod(1));
    }

    #[test]
    fn fill_chunk_wraps_cyclically() {
        let d = ds(3, 1); // points 0,1,2
        let s = &d.split(1)[0];
        let mut buf = [0.0f32; 5];
        s.fill_chunk(1, 5, &mut buf);
        assert_eq!(buf, [1.0, 2.0, 0.0, 1.0, 2.0]);
    }

    #[test]
    #[should_panic(expected = "fewer points than shards")]
    fn split_rejects_more_shards_than_points() {
        ds(2, 1).split(3);
    }

    #[test]
    fn shard_ids_are_positional() {
        let d = ds(9, 1);
        for (i, s) in d.split(3).iter().enumerate() {
            assert_eq!(s.worker_id(), i);
        }
    }
}
