//! Metrics: time series, run records, writers and speed-up summaries.
//!
//! Every figure in the paper is a set of `(wall-clock time, C_{n,M})`
//! curves; [`Series`] is that curve, [`FigureReport`] a set of them, and
//! [`time_to_threshold`] / [`speedup_table`] extract the quantities the
//! paper argues about — time to reach a distortion threshold and the
//! speed-up of `M` workers over one.

mod plot;
mod series;
mod summary;
mod writer;

pub use plot::{render_svg, write_svg};
pub use series::{FigureReport, Sample, Series};
pub use summary::{speedup_table, time_to_threshold, SpeedupRow};
pub use writer::{write_csv, write_json, write_report_csv};
