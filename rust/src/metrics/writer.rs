//! CSV / JSON persistence for figure reports.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;

use anyhow::{Context, Result};

use super::{FigureReport, Series};

/// Write one series as a two-column CSV (`wall,value`).
pub fn write_csv(series: &Series, path: &Path) -> Result<()> {
    let file = File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "wall,value")?;
    for s in &series.samples {
        writeln!(w, "{},{}", s.wall, s.value)?;
    }
    Ok(())
}

/// Write a whole figure as a long-format CSV (`series,wall,value`) —
/// directly plottable with any tool.
pub fn write_report_csv(report: &FigureReport, path: &Path) -> Result<()> {
    let file = File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    writeln!(w, "series,wall,value")?;
    for series in &report.series {
        for s in &series.samples {
            writeln!(w, "{},{},{}", series.name, s.wall, s.value)?;
        }
    }
    Ok(())
}

/// Full-fidelity JSON dump of a report (round-trips via
/// [`FigureReport::from_json`]).
pub fn write_json(report: &FigureReport, path: &Path) -> Result<()> {
    std::fs::write(path, report.to_json().to_pretty())
        .with_context(|| format!("creating {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_round_trip_shape() {
        let dir = std::env::temp_dir().join("dalvq_writer_test");
        std::fs::create_dir_all(&dir).unwrap();
        let mut s = Series::new("M=2");
        s.push(0.0, 1.5);
        s.push(1.0, 0.5);
        let path = dir.join("series.csv");
        write_csv(&s, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 3);
        assert!(text.starts_with("wall,value"));

        let mut report = FigureReport::new("figX", "t");
        report.series.push(s);
        let jpath = dir.join("report.json");
        write_json(&report, &jpath).unwrap();
        let back = FigureReport::from_json(
            &crate::util::Json::parse(&std::fs::read_to_string(&jpath).unwrap())
                .unwrap(),
        )
        .unwrap();
        assert_eq!(back.series[0].samples.len(), 2);
        assert_eq!(back.series[0].samples[1].value, 0.5);

        let cpath = dir.join("report.csv");
        write_report_csv(&report, &cpath).unwrap();
        assert!(std::fs::read_to_string(&cpath).unwrap().contains("M=2,1,0.5"));
    }
}
