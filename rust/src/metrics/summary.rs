//! Speed-up summaries — the paper's implicit headline metric.
//!
//! The paper defines speed-up as reduction of “the time needed to reach
//! some performance threshold, using more than one computing unit”
//! (Section 1). [`time_to_threshold`] extracts that time from a curve and
//! [`speedup_table`] tabulates `T(M=1) / T(M)` across curves.

use super::Series;

/// First wall time at which the curve reaches `threshold` (linear
/// interpolation between samples); `None` if it never does.
pub fn time_to_threshold(series: &Series, threshold: f64) -> Option<f64> {
    let mut prev: Option<(f64, f64)> = None;
    for s in &series.samples {
        if s.value <= threshold {
            return Some(match prev {
                Some((pw, pv)) if pv > threshold => {
                    // interpolate crossing between (pw, pv) and (s.wall, s.value)
                    let a = (pv - threshold) / (pv - s.value);
                    pw + a * (s.wall - pw)
                }
                _ => s.wall,
            });
        }
        prev = Some((s.wall, s.value));
    }
    None
}

/// One row of the speed-up table.
#[derive(Debug, Clone, PartialEq)]
pub struct SpeedupRow {
    pub name: String,
    pub time_to_threshold: Option<f64>,
    /// `T(baseline) / T(self)`; 1.0 for the baseline row.
    pub speedup: Option<f64>,
}

/// Tabulate time-to-threshold and speed-up versus the first series
/// (conventionally `M=1`).
pub fn speedup_table(series: &[Series], threshold: f64) -> Vec<SpeedupRow> {
    let base = series.first().and_then(|s| time_to_threshold(s, threshold));
    series
        .iter()
        .map(|s| {
            let t = time_to_threshold(s, threshold);
            let speedup = match (base, t) {
                (Some(b), Some(t)) if t > 0.0 => Some(b / t),
                _ => None,
            };
            SpeedupRow { name: s.name.clone(), time_to_threshold: t, speedup }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn line(name: &str, pts: &[(f64, f64)]) -> Series {
        let mut s = Series::new(name);
        for (w, v) in pts {
            s.push(*w, *v);
        }
        s
    }

    #[test]
    fn threshold_interpolates_crossing() {
        let s = line("x", &[(0.0, 10.0), (2.0, 0.0)]);
        assert_eq!(time_to_threshold(&s, 5.0), Some(1.0));
    }

    #[test]
    fn threshold_none_when_never_reached() {
        let s = line("x", &[(0.0, 10.0), (2.0, 6.0)]);
        assert_eq!(time_to_threshold(&s, 5.0), None);
    }

    #[test]
    fn threshold_immediate_when_starting_below() {
        let s = line("x", &[(0.5, 3.0), (2.0, 1.0)]);
        assert_eq!(time_to_threshold(&s, 5.0), Some(0.5));
    }

    #[test]
    fn threshold_on_empty_series_is_none() {
        let s = Series::new("empty");
        assert_eq!(time_to_threshold(&s, 5.0), None);
        // single sample above the threshold: also never crosses
        let s = line("one", &[(1.0, 10.0)]);
        assert_eq!(time_to_threshold(&s, 5.0), None);
    }

    #[test]
    fn speedup_table_without_a_crossing_reference_does_not_panic() {
        // the conventional M=1 reference never reaches the threshold:
        // every speedup must be None, including rows that do cross
        let rows = speedup_table(
            &[
                line("M=1", &[(0.0, 10.0), (4.0, 8.0)]),
                line("M=2", &[(0.0, 10.0), (2.0, 0.0)]),
            ],
            5.0,
        );
        assert_eq!(rows[0].speedup, None);
        assert_eq!(rows[0].time_to_threshold, None);
        assert_eq!(rows[1].speedup, None);
        assert!(rows[1].time_to_threshold.is_some());
    }

    #[test]
    fn speedup_table_of_no_series_is_empty() {
        assert!(speedup_table(&[], 1.0).is_empty());
    }

    #[test]
    fn speedup_table_with_empty_reference_series_does_not_panic() {
        let rows = speedup_table(
            &[Series::new("M=1"), line("M=2", &[(0.0, 10.0), (2.0, 0.0)])],
            5.0,
        );
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].speedup, None);
        assert_eq!(rows[1].speedup, None);
    }

    #[test]
    fn speedups_relative_to_first() {
        let rows = speedup_table(
            &[
                line("M=1", &[(0.0, 10.0), (4.0, 0.0)]),
                line("M=2", &[(0.0, 10.0), (2.0, 0.0)]),
                line("M=10", &[(0.0, 10.0), (10.0, 8.0)]),
            ],
            5.0,
        );
        assert_eq!(rows[0].speedup, Some(1.0));
        assert_eq!(rows[1].speedup, Some(2.0));
        assert_eq!(rows[2].speedup, None);
    }
}
