//! Time series of the quantization criterion.

use anyhow::Result;

use crate::util::Json;


/// One `(wall time, value)` observation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Sample {
    /// Wall-clock time (seconds — virtual for the simulator, real for the
    /// cloud runtime).
    pub wall: f64,
    /// Normalized empirical distortion `C_{n,M}(w_srd)` (paper eq. 2).
    pub value: f64,
}

/// A named performance curve — one line of a paper figure.
#[derive(Debug, Clone)]
pub struct Series {
    /// e.g. `"M=10"` — the legend label used by the paper.
    pub name: String,
    pub samples: Vec<Sample>,
    /// Total data points processed over the run (all workers).
    pub points_processed: u64,
    /// Number of merge/reduce events that occurred.
    pub merges: u64,
}

impl Series {
    pub fn new(name: impl Into<String>) -> Self {
        Self { name: name.into(), samples: Vec::new(), points_processed: 0, merges: 0 }
    }

    pub fn push(&mut self, wall: f64, value: f64) {
        self.samples.push(Sample { wall, value });
    }

    pub fn last_value(&self) -> f64 {
        self.samples.last().map(|s| s.value).unwrap_or(f64::NAN)
    }

    pub fn first_value(&self) -> f64 {
        self.samples.first().map(|s| s.value).unwrap_or(f64::NAN)
    }

    pub fn last_wall(&self) -> f64 {
        self.samples.last().map(|s| s.wall).unwrap_or(0.0)
    }

    /// Minimum value reached over the run.
    pub fn min_value(&self) -> f64 {
        self.samples.iter().map(|s| s.value).fold(f64::INFINITY, f64::min)
    }

    /// Wall times are strictly non-decreasing (sanity for the simulator).
    pub fn is_time_monotone(&self) -> bool {
        self.samples.windows(2).all(|w| w[0].wall <= w[1].wall)
    }

    /// Linear interpolation of the curve at `wall` (clamped to range).
    pub fn value_at(&self, wall: f64) -> f64 {
        if self.samples.is_empty() {
            return f64::NAN;
        }
        if wall <= self.samples[0].wall {
            return self.samples[0].value;
        }
        for w in self.samples.windows(2) {
            if wall <= w[1].wall {
                let span = w[1].wall - w[0].wall;
                if span <= 0.0 {
                    return w[1].value;
                }
                let a = (wall - w[0].wall) / span;
                return w[0].value * (1.0 - a) + w[1].value * a;
            }
        }
        self.last_value()
    }
}

/// A full figure: several curves plus metadata about the run.
#[derive(Debug, Clone)]
pub struct FigureReport {
    /// e.g. `"fig2"`.
    pub id: String,
    /// Human description, e.g. the paper caption.
    pub title: String,
    pub series: Vec<Series>,
    /// Free-form run parameters for reproducibility (tau, seed, ...).
    pub params: Vec<(String, String)>,
}

impl FigureReport {
    pub fn new(id: impl Into<String>, title: impl Into<String>) -> Self {
        Self { id: id.into(), title: title.into(), series: Vec::new(), params: Vec::new() }
    }

    pub fn param(&mut self, k: impl Into<String>, v: impl ToString) -> &mut Self {
        self.params.push((k.into(), v.to_string()));
        self
    }

    pub fn series_named(&self, name: &str) -> Option<&Series> {
        self.series.iter().find(|s| s.name == name)
    }
}


impl Series {
    /// Encode as JSON (for report persistence).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("name", self.name.clone())
            .set("points_processed", self.points_processed)
            .set("merges", self.merges)
            .set(
                "samples",
                Json::Arr(
                    self.samples
                        .iter()
                        .map(|s| {
                            Json::Arr(vec![Json::Num(s.wall), Json::Num(s.value)])
                        })
                        .collect(),
                ),
            )
    }

    pub fn from_json(j: &Json) -> Result<Series> {
        let mut series = Series::new(j.req("name")?.as_str()?);
        series.points_processed = j.req("points_processed")?.as_u64()?;
        series.merges = j.req("merges")?.as_u64()?;
        for pair in j.req("samples")?.as_arr()? {
            let pair = pair.as_arr()?;
            series.push(pair[0].as_f64()?, pair[1].as_f64()?);
        }
        Ok(series)
    }
}

impl FigureReport {
    /// Encode as JSON (round-trips via [`FigureReport::from_json`]).
    pub fn to_json(&self) -> Json {
        let params = self.params.iter().fold(Json::obj(), |acc, (k, v)| {
            acc.set(k, v.clone())
        });
        Json::obj()
            .set("id", self.id.clone())
            .set("title", self.title.clone())
            .set("params", params)
            .set(
                "series",
                Json::Arr(self.series.iter().map(Series::to_json).collect()),
            )
    }

    pub fn from_json(j: &Json) -> Result<FigureReport> {
        let mut report = FigureReport::new(
            j.req("id")?.as_str()?,
            j.req("title")?.as_str()?,
        );
        for (k, v) in j.req("params")?.as_obj()? {
            report.params.push((k.clone(), v.as_str()?.to_string()));
        }
        for s in j.req("series")?.as_arr()? {
            report.series.push(Series::from_json(s)?);
        }
        Ok(report)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_and_lookups() {
        let mut s = Series::new("M=1");
        s.push(0.0, 10.0);
        s.push(1.0, 4.0);
        s.push(2.0, 2.0);
        assert_eq!(s.first_value(), 10.0);
        assert_eq!(s.last_value(), 2.0);
        assert_eq!(s.min_value(), 2.0);
        assert!(s.is_time_monotone());
    }

    #[test]
    fn interpolation() {
        let mut s = Series::new("x");
        s.push(0.0, 10.0);
        s.push(2.0, 0.0);
        assert_eq!(s.value_at(1.0), 5.0);
        assert_eq!(s.value_at(-1.0), 10.0);
        assert_eq!(s.value_at(5.0), 0.0);
    }

    #[test]
    fn monotonicity_detects_violation() {
        let mut s = Series::new("x");
        s.push(1.0, 1.0);
        s.push(0.5, 1.0);
        assert!(!s.is_time_monotone());
    }

    #[test]
    fn report_lookup_by_name() {
        let mut r = FigureReport::new("fig1", "test");
        r.series.push(Series::new("M=1"));
        r.series.push(Series::new("M=10"));
        assert!(r.series_named("M=10").is_some());
        assert!(r.series_named("M=3").is_none());
    }
}
