//! SVG rendering of figure reports — the actual *figures* of the paper,
//! as standalone vector images (`results/<id>.svg`).
//!
//! Dependency-free: hand-written SVG with linear axes, automatic ranges,
//! tick labels, a legend, and one polyline per series. Log-scale on the
//! distortion axis is supported because the paper's interesting action
//! happens over an order of magnitude of `C`.

use std::fmt::Write as _;
use std::path::Path;

use anyhow::{Context, Result};

use super::{FigureReport, Series};

const W: f64 = 720.0;
const H: f64 = 440.0;
const ML: f64 = 70.0; // margins
const MR: f64 = 170.0;
const MT: f64 = 48.0;
const MB: f64 = 52.0;

const COLORS: [&str; 8] = [
    "#1f77b4", "#d62728", "#2ca02c", "#ff7f0e", "#9467bd", "#8c564b",
    "#e377c2", "#17becf",
];

/// Render a report as an SVG document.
pub fn render_svg(report: &FigureReport, log_y: bool) -> String {
    let (x0, x1) = x_range(&report.series);
    let (y0, y1) = y_range(&report.series, log_y);
    let xmap = |x: f64| ML + (x - x0) / (x1 - x0).max(1e-12) * (W - ML - MR);
    let ymap = |y: f64| {
        let v = if log_y { y.max(1e-12).log10() } else { y };
        H - MB - (v - y0) / (y1 - y0).max(1e-12) * (H - MT - MB)
    };

    let mut svg = String::new();
    let _ = write!(
        svg,
        r##"<svg xmlns="http://www.w3.org/2000/svg" width="{W}" height="{H}" viewBox="0 0 {W} {H}" font-family="Helvetica,Arial,sans-serif">"##
    );
    let _ = write!(svg, r##"<rect width="{W}" height="{H}" fill="white"/>"##);
    // title
    let _ = write!(
        svg,
        r##"<text x="{}" y="24" font-size="14" text-anchor="middle">{}</text>"##,
        (ML + W - MR) / 2.0,
        escape(&format!("{} — {}", report.id, truncate(&report.title, 80)))
    );
    // plot frame
    let _ = write!(
        svg,
        r##"<rect x="{ML}" y="{MT}" width="{}" height="{}" fill="none" stroke="#333" stroke-width="1"/>"##,
        W - ML - MR,
        H - MT - MB
    );
    // axis ticks: 5 on each axis
    for i in 0..=5 {
        let fx = i as f64 / 5.0;
        let x = x0 + fx * (x1 - x0);
        let px = xmap(x);
        let _ = write!(
            svg,
            r##"<line x1="{px}" y1="{}" x2="{px}" y2="{}" stroke="#333"/>"##,
            H - MB,
            H - MB + 4.0
        );
        let _ = write!(
            svg,
            r##"<text x="{px}" y="{}" font-size="11" text-anchor="middle">{}</text>"##,
            H - MB + 17.0,
            fmt_num(x)
        );
        let vy = y0 + fx * (y1 - y0);
        let y = if log_y { 10f64.powf(vy) } else { vy };
        let py = ymap(y);
        let _ = write!(
            svg,
            r##"<line x1="{}" y1="{py}" x2="{ML}" y2="{py}" stroke="#333"/>"##,
            ML - 4.0
        );
        let _ = write!(
            svg,
            r##"<text x="{}" y="{}" font-size="11" text-anchor="end">{}</text>"##,
            ML - 8.0,
            py + 4.0,
            fmt_num(y)
        );
        // light gridline
        let _ = write!(
            svg,
            r##"<line x1="{ML}" y1="{py}" x2="{}" y2="{py}" stroke="#eee"/>"##,
            W - MR
        );
    }
    // axis labels
    let _ = write!(
        svg,
        r##"<text x="{}" y="{}" font-size="12" text-anchor="middle">wall-clock time (s)</text>"##,
        (ML + W - MR) / 2.0,
        H - 14.0
    );
    let _ = write!(
        svg,
        r##"<text x="16" y="{}" font-size="12" text-anchor="middle" transform="rotate(-90 16 {})">normalized distortion C{}</text>"##,
        (MT + H - MB) / 2.0,
        (MT + H - MB) / 2.0,
        if log_y { " (log)" } else { "" }
    );
    // series
    for (si, s) in report.series.iter().enumerate() {
        let color = COLORS[si % COLORS.len()];
        let mut points = String::new();
        for sample in &s.samples {
            if !sample.value.is_finite() {
                continue; // divergent tails stay off the canvas
            }
            let _ = write!(
                points,
                "{:.2},{:.2} ",
                xmap(sample.wall),
                ymap(sample.value)
            );
        }
        let _ = write!(
            svg,
            r##"<polyline points="{}" fill="none" stroke="{color}" stroke-width="1.8"/>"##,
            points.trim_end()
        );
        // legend
        let ly = MT + 16.0 + si as f64 * 18.0;
        let _ = write!(
            svg,
            r##"<line x1="{}" y1="{ly}" x2="{}" y2="{ly}" stroke="{color}" stroke-width="3"/>"##,
            W - MR + 12.0,
            W - MR + 36.0
        );
        let _ = write!(
            svg,
            r##"<text x="{}" y="{}" font-size="12">{}</text>"##,
            W - MR + 42.0,
            ly + 4.0,
            escape(&s.name)
        );
    }
    svg.push_str("</svg>");
    svg
}

/// Write `<dir>/<id>.svg`.
pub fn write_svg(report: &FigureReport, dir: &Path, log_y: bool) -> Result<()> {
    let path = dir.join(format!("{}.svg", report.id));
    std::fs::write(&path, render_svg(report, log_y))
        .with_context(|| format!("writing {}", path.display()))?;
    Ok(())
}

fn x_range(series: &[Series]) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for s in series {
        for p in &s.samples {
            lo = lo.min(p.wall);
            hi = hi.max(p.wall);
        }
    }
    if !lo.is_finite() || lo >= hi {
        (0.0, 1.0)
    } else {
        (lo, hi)
    }
}

fn y_range(series: &[Series], log_y: bool) -> (f64, f64) {
    let mut lo = f64::INFINITY;
    let mut hi = f64::NEG_INFINITY;
    for s in series {
        for p in &s.samples {
            if p.value.is_finite() {
                lo = lo.min(p.value);
                hi = hi.max(p.value);
            }
        }
    }
    if !lo.is_finite() || lo >= hi {
        return (0.0, 1.0);
    }
    if log_y {
        (lo.max(1e-12).log10(), hi.max(1e-12).log10())
    } else {
        let pad = (hi - lo) * 0.05;
        ((lo - pad).max(0.0), hi + pad)
    }
}

fn fmt_num(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1000.0 || x.abs() < 0.01 {
        format!("{x:.1e}")
    } else if x.abs() >= 10.0 {
        format!("{x:.1}")
    } else {
        format!("{x:.3}")
    }
}

fn escape(s: &str) -> String {
    s.replace('&', "&amp;").replace('<', "&lt;").replace('>', "&gt;")
}

fn truncate(s: &str, n: usize) -> String {
    if s.chars().count() <= n {
        s.to_string()
    } else {
        let t: String = s.chars().take(n).collect();
        format!("{t}…")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::FigureReport;

    fn sample_report() -> FigureReport {
        let mut r = FigureReport::new("figX", "test <figure> & more");
        for m in [1usize, 10] {
            let mut s = Series::new(format!("M={m}"));
            for i in 0..50 {
                let t = i as f64 * 0.01;
                s.push(t, 100.0 * (-t * m as f64).exp() + 10.0);
            }
            r.series.push(s);
        }
        r
    }

    #[test]
    fn renders_well_formed_svg() {
        let svg = render_svg(&sample_report(), false);
        assert!(svg.starts_with("<svg"));
        assert!(svg.ends_with("</svg>"));
        assert_eq!(svg.matches("<polyline").count(), 2);
        assert!(svg.contains("M=10"));
        assert!(svg.contains("&lt;figure&gt;"), "title must be escaped");
        // balanced rects/texts parse as naive XML: every <tag is closed
        assert_eq!(svg.matches("<svg").count(), svg.matches("</svg>").count());
    }

    #[test]
    fn log_scale_handles_divergence() {
        let mut r = sample_report();
        r.series[0].push(0.6, f64::INFINITY); // divergent tail
        r.series[0].push(0.7, 1e30);
        let svg = render_svg(&r, true);
        assert!(svg.contains("log"));
        assert!(!svg.contains("inf"), "non-finite points must be dropped");
    }

    #[test]
    fn writes_file_named_after_report() {
        let dir = std::env::temp_dir().join("dalvq_plot_test");
        std::fs::create_dir_all(&dir).unwrap();
        write_svg(&sample_report(), &dir, false).unwrap();
        assert!(dir.join("figX.svg").exists());
    }

    #[test]
    fn empty_report_does_not_panic() {
        let r = FigureReport::new("empty", "no data");
        let svg = render_svg(&r, false);
        assert!(svg.contains("</svg>"));
    }
}
