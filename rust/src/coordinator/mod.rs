//! The orchestrator: run lifecycle, persistence and fault policies.
//!
//! [`Orchestrator`] is the high-level façade `main.rs` and the examples
//! drive: it validates configs, runs experiments or whole figures, writes
//! CSV/JSON outputs, and prints the report tables. Straggler policies
//! ([`inject_stragglers`]) model the paper's Section-4 observation that
//! “the unreliability of the cloud computing hardware introduces strong
//! straggler issues”.

use std::path::PathBuf;
use std::time::Instant;

use anyhow::Result;

use crate::config::{ExperimentConfig, FigureConfig};
use crate::harness;
use crate::metrics::{write_json, write_report_csv, write_svg, FigureReport};
use crate::schemes::{self, SchemeOutcome};
use crate::sim::CostModel;

/// Runs experiments and figures, optionally persisting results.
#[derive(Debug, Clone, Default)]
pub struct Orchestrator {
    /// If set, reports are written to `<out_dir>/<id>.{csv,json}`.
    pub out_dir: Option<PathBuf>,
    /// Suppress stdout reporting.
    pub quiet: bool,
}

impl Orchestrator {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_out_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.out_dir = Some(dir.into());
        self
    }

    /// Run a single experiment (one scheme, one `M`).
    pub fn run_experiment(&self, cfg: &ExperimentConfig) -> Result<SchemeOutcome> {
        cfg.validate()?;
        let start = Instant::now();
        let outcome = schemes::run_with_config(cfg)?;
        if !self.quiet {
            println!(
                "[{}] scheme={} M={} points={} merges={} C: {:.6} -> {:.6} \
                 ({:.2?} real)",
                cfg.scheme.label(),
                cfg.engine_label(),
                cfg.m,
                outcome.series.points_processed,
                outcome.series.merges,
                outcome.series.first_value(),
                outcome.series.last_value(),
                start.elapsed(),
            );
        }
        Ok(outcome)
    }

    /// Run a whole figure, print its report + speed-up table, persist if
    /// an output directory is configured.
    pub fn run_figure(&self, fig: &FigureConfig) -> Result<FigureReport> {
        let start = Instant::now();
        let report = harness::run_figure(fig)?;
        if !self.quiet {
            print!("{}", harness::format_report(&report));
            let (threshold, rows) = harness::speedups_at(&report, 0.9);
            print!("{}", harness::format_speedups(threshold, &rows));
            println!("(generated in {:.2?})", start.elapsed());
        }
        self.persist(&report)?;
        Ok(report)
    }

    /// Run several figures (e.g. an ablation family).
    pub fn run_figures(&self, figs: &[FigureConfig]) -> Result<Vec<FigureReport>> {
        figs.iter().map(|f| self.run_figure(f)).collect()
    }

    fn persist(&self, report: &FigureReport) -> Result<()> {
        if let Some(dir) = &self.out_dir {
            std::fs::create_dir_all(dir)?;
            write_report_csv(report, &dir.join(format!("{}.csv", report.id)))?;
            write_json(report, &dir.join(format!("{}.json", report.id)))?;
            write_svg(report, dir, true)?;
            if !self.quiet {
                println!(
                    "wrote {}/{}.{{csv,json,svg}}",
                    dir.display(),
                    report.id
                );
            }
        }
        Ok(())
    }
}

impl ExperimentConfig {
    /// Short engine label for logs.
    pub fn engine_label(&self) -> &'static str {
        match self.engine {
            crate::runtime::EngineSpec::Native => "native",
            crate::runtime::EngineSpec::Pjrt { .. } => "pjrt",
        }
    }
}

/// Make `slow_count` of the `m` workers run `factor`× slower — the
/// straggler injection used by the robustness tests and the ablations.
pub fn inject_stragglers(cost: &mut CostModel, m: usize, slow_count: usize, factor: f64) {
    assert!(slow_count <= m, "cannot slow more workers than exist");
    assert!(factor >= 1.0, "straggler factor must be >= 1");
    cost.speed_factors = (0..m)
        .map(|i| if i < slow_count { factor } else { 1.0 })
        .collect();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn straggler_injection_shapes_factors() {
        let mut cost = CostModel::default();
        inject_stragglers(&mut cost, 4, 2, 3.0);
        assert_eq!(cost.speed_factors, vec![3.0, 3.0, 1.0, 1.0]);
        assert!(cost.validate().is_ok());
    }

    #[test]
    fn orchestrator_runs_and_persists() {
        let dir = std::env::temp_dir().join("dalvq_orch_test");
        let _ = std::fs::remove_dir_all(&dir);
        let orch = Orchestrator { out_dir: Some(dir.clone()), quiet: true };
        let mut fig = crate::config::presets::fig2();
        fig.base.run.points_per_worker = 2_000;
        fig.base.data.n_total = 2_000;
        fig.base.data.eval_points = 256;
        fig.ms = vec![1, 2];
        let report = orch.run_figure(&fig).unwrap();
        assert_eq!(report.series.len(), 2);
        assert!(dir.join("fig2.csv").exists());
        assert!(dir.join("fig2.json").exists());
    }
}
