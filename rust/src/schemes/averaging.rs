//! Scheme A — the intuitive averaging scheme (paper eq. 3, Figure 1).
//!
//! Every worker runs sequential VQ on its shard; every `τ` points the
//! versions are synchronously **averaged** into a shared version which is
//! broadcast back:
//!
//! ```text
//! w_srd = (1/M) Σ_i w^i(τ)       (eq. 3)
//! ```
//!
//! The paper's Section 2/3 point — reproduced by Figure 1 of the harness —
//! is that this scheme brings **no wall-clock speed-up**: averaging the
//! versions divides the per-sample displacement by `M`, so the effective
//! learning rate *per processed data point* shrinks by `M` and the extra
//! data buys exploration, not convergence.

use anyhow::Result;

use crate::metrics::Series;
use crate::sim::TraceEvent;
use crate::vq::{Codebook, Delta};

use super::{SchemeInputs, SchemeOutcome};

/// Run scheme A with synchronization period `tau`.
pub fn run(inputs: &mut SchemeInputs<'_>, tau: usize) -> Result<SchemeOutcome> {
    let m = inputs.shards.len();
    let dim = inputs.shards[0].dim();
    let kappa = inputs.w0.kappa();
    let mut versions: Vec<Codebook> = vec![inputs.w0.clone(); m];
    let mut scratch = Delta::zeros(kappa, dim); // unused displacement sink
    let mut series = Series::new(format!("M={m}"));
    let mut chunk_buf = vec![0.0f32; tau * dim];
    let mut eps_buf = vec![0.0f32; tau];

    let mut wall = 0.0f64;
    let mut t: u64 = 0; // common local step count (workers are in lockstep)
    let mut w_srd = inputs.w0.clone();
    inputs.eval.force_record(inputs.engine, &mut series, wall, &w_srd)?;

    let rounds = inputs.points_per_worker / tau as u64;
    for round in 0..rounds {
        inputs.schedule.fill(t, &mut eps_buf);
        // Each worker advances tau points from its own shard (concurrently
        // in wall time: the round costs the *slowest* worker's time).
        let mut round_compute = 0.0f64;
        for (i, version) in versions.iter_mut().enumerate() {
            inputs.shards[i].fill_chunk(t, tau, &mut chunk_buf);
            scratch.clear();
            inputs.engine.vq_chunk(version, &chunk_buf, &eps_buf, &mut scratch)?;
            round_compute = round_compute.max(inputs.cost.compute_time(i, tau));
        }
        t += tau as u64;
        wall += round_compute
            + inputs.cost.merge_cost * m as f64
            + inputs.cost.broadcast_cost;
        // The reducing phase: average and broadcast (eq. 3).
        Codebook::average_into(&versions, &mut w_srd);
        for v in versions.iter_mut() {
            v.clone_from(&w_srd);
        }
        series.merges += 1;
        inputs.trace.record(TraceEvent::SyncMerge { wall, round });
        inputs.eval.maybe_record(inputs.engine, &mut series, wall, &w_srd)?;
    }
    inputs.eval.force_record(inputs.engine, &mut series, wall, &w_srd)?;
    series.points_processed = t * m as u64;
    Ok(SchemeOutcome { final_shared: w_srd, final_versions: versions, series })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::runtime::NativeEngine;
    use crate::sim::{CostModel, Evaluator, Trace};
    use crate::vq::{init_codebook, InitMethod, Schedule};

    fn setup(m: usize) -> (Vec<crate::data::Shard>, Codebook, Vec<f32>) {
        let spec = MixtureSpec {
            components: 4,
            dim: 2,
            separation: 4.0,
            std: 0.3,
            imbalance: 0.0,
            noise_frac: 0.0,
        };
        let ds = spec.dataset(4_000, 7);
        let shards = ds.split(m);
        let w0 = init_codebook(InitMethod::FromData, 4, 2, ds.flat(), 7);
        let eval = spec.eval_sample(512, 7);
        (shards, w0, eval)
    }

    #[test]
    fn averaging_m1_tracks_sequential_shape() {
        let (shards, w0, eval_pts) = setup(1);
        let mut engine = NativeEngine::new();
        let mut eval = Evaluator::new(eval_pts, 2, 1e-3);
        let mut trace = Trace::disabled();
        let mut inputs = SchemeInputs {
            engine: &mut engine,
            shards: &shards,
            w0,
            schedule: Schedule::paper_default(),
            cost: CostModel::default(),
            points_per_worker: 10_000,
            eval: &mut eval,
            trace: &mut trace,
            seed: 0,
        };
        let out = run(&mut inputs, 10).unwrap();
        assert!(out.series.last_value() < out.series.first_value());
        assert_eq!(out.series.merges, 1_000);
        assert_eq!(out.series.points_processed, 10_000);
    }

    #[test]
    fn versions_coincide_after_broadcast() {
        let (shards, w0, eval_pts) = setup(3);
        let mut engine = NativeEngine::new();
        let mut eval = Evaluator::new(eval_pts, 2, 1e-3);
        let mut trace = Trace::disabled();
        let mut inputs = SchemeInputs {
            engine: &mut engine,
            shards: &shards,
            w0,
            schedule: Schedule::paper_default(),
            cost: CostModel::default(),
            points_per_worker: 1_000,
            eval: &mut eval,
            trace: &mut trace,
            seed: 0,
        };
        let out = run(&mut inputs, 10).unwrap();
        for v in &out.final_versions {
            assert_eq!(v, &out.final_shared);
        }
    }
}
