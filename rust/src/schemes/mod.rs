//! The paper's parallelization schemes.
//!
//! | module | paper | merge rule |
//! |--------|-------|-----------|
//! | [`sequential`] | eq. 1 | none (the `M = 1` reference) |
//! | [`averaging`] | eq. 3 | `w_srd = (1/M) Σ_i w^i`, synchronous — **no speed-up** (Figure 1) |
//! | [`delta_sync`] | eq. 8 | `w_srd ← w_srd − Σ_j Δ^j`, synchronous — speed-up (Figure 2) |
//! | [`async_delta`] | eq. 9 | same merge, no barrier, stochastic delays (Figure 3) |
//!
//! All schemes run against the deterministic virtual-time [`crate::sim`]
//! substrate and any [`crate::runtime::Engine`]. The cloud runtime
//! ([`crate::cloud`]) re-implements the eq. 9 protocol on real concurrency
//! for Figure 4.

pub mod async_delta;
pub mod averaging;
pub mod delta_sync;
pub mod sequential;

use anyhow::Result;

use crate::config::{ExperimentConfig, SchemeConfig};
use crate::data::Shard;
use crate::metrics::Series;
use crate::runtime::Engine;
use crate::sim::{CostModel, Evaluator, Trace};
use crate::vq::{Codebook, Schedule};

/// Everything a scheme needs to run, prepared by [`run_with_config`] (or
/// by a test directly).
pub struct SchemeInputs<'a> {
    pub engine: &'a mut dyn Engine,
    /// One shard per worker (`shards.len() == M`).
    pub shards: &'a [Shard],
    /// The common initial version `w^1(0) = … = w^M(0)`.
    pub w0: Codebook,
    pub schedule: Schedule,
    pub cost: CostModel,
    /// Points each worker processes over the run.
    pub points_per_worker: u64,
    pub eval: &'a mut Evaluator,
    pub trace: &'a mut Trace,
    /// Seed for scheme-internal randomness (delay sampling).
    pub seed: u64,
}

/// What a scheme run produces.
pub struct SchemeOutcome {
    /// `(virtual wall time, C)` curve of the shared version.
    pub series: Series,
    /// The shared version at the end of the run.
    pub final_shared: Codebook,
    /// Per-worker versions at the end of the run.
    pub final_versions: Vec<Codebook>,
}

/// Run the scheme selected by `cfg` end to end: generate data, shard it,
/// initialize the common version, build the engine, run, return the curve.
pub fn run_with_config(cfg: &ExperimentConfig) -> Result<SchemeOutcome> {
    cfg.validate()?;
    let mut engine = cfg.engine.build()?;
    run_with_engine(cfg, engine.as_mut())
}

/// Like [`run_with_config`] but on a caller-provided engine (lets tests and
/// benches reuse a compiled PJRT engine across runs).
pub fn run_with_engine(
    cfg: &ExperimentConfig,
    engine: &mut dyn Engine,
) -> Result<SchemeOutcome> {
    let dataset = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);
    let shards = dataset.split(cfg.m);
    let w0 = crate::vq::init_codebook(
        cfg.vq.init,
        cfg.vq.kappa,
        cfg.dim(),
        dataset.flat(),
        cfg.seed,
    );
    let eval_points = cfg.data.mixture.eval_sample(cfg.data.eval_points, cfg.seed);
    let mut eval = Evaluator::new(eval_points, cfg.dim(), cfg.run.eval_interval);
    let mut trace = Trace::with_capacity(cfg.run.trace_capacity);
    let mut inputs = SchemeInputs {
        engine,
        shards: &shards,
        w0,
        schedule: cfg.vq.schedule,
        cost: cfg.cost.clone(),
        points_per_worker: cfg.run.points_per_worker,
        eval: &mut eval,
        trace: &mut trace,
        seed: cfg.seed,
    };
    match &cfg.scheme {
        SchemeConfig::Sequential => sequential::run(&mut inputs),
        SchemeConfig::Averaging { tau } => averaging::run(&mut inputs, *tau),
        SchemeConfig::DeltaSync { tau } => delta_sync::run(&mut inputs, *tau),
        SchemeConfig::AsyncDelta { tau, up_delay, down_delay } => {
            async_delta::run(&mut inputs, *tau, *up_delay, *down_delay)
        }
    }
}
