//! Scheme C — asynchronous delta merge with stochastic delays
//! (paper eq. 9, Figure 3).
//!
//! Section 4 removes the synchronization barrier of scheme B: “each machine
//! uploads its updates and downloads the shared version as soon as its
//! previous uploads and downloads are completed. A dedicated unit
//! permanently modifies the shared version with the latest updates received
//! from the other machines without any synchronization barrier.”
//!
//! Implementation as a discrete-event simulation:
//!
//! * each worker alternates `τ`-point compute chunks (cost-model time) with
//!   back-to-back *exchanges*: upload the displacement `Δ` accumulated over
//!   the window since the previous exchange began, then download the shared
//!   version;
//! * one-way delays are drawn per message from the configured
//!   [`DelayModel`] (geometric in the paper's Section 4 model);
//! * on upload arrival the reducer folds `w_srd ← w_srd − Δ` (eq. 9's last
//!   line);
//! * on download arrival the worker rebases:
//!   `w^i ← w_snap − Δ_cur` where `Δ_cur` is the displacement it
//!   accumulated while the exchange was in flight (eq. 9's third line).
//!
//! Fidelity note (DESIGN.md §Substitutions): eq. 9 models the downloaded
//! version as the server state at the *start* of the exchange; we return
//! the state at upload-arrival time (after folding that worker's own
//! delta), which is what a real blob-storage round trip does — the
//! CloudDALVQ behaviour the equation abstracts. Both keep the defining
//! property: merges are barrier-free and versions are stale by one
//! round-trip.
//!
//! At the end of its point budget a worker performs one final flush
//! exchange, so **every** local displacement is eventually folded into the
//! shared version exactly once (DESIGN.md invariant 9, property-tested).

use anyhow::Result;

use crate::util::Rng;

use crate::metrics::Series;
use crate::sim::{DelayModel, EventQueue, TraceEvent};
use crate::vq::{Codebook, Delta};

use super::{SchemeInputs, SchemeOutcome};

enum Event {
    /// Worker finished computing a `τ`-point chunk.
    ChunkDone { worker: usize },
    /// A worker's delta reached the reducer.
    UploadArrive { worker: usize, delta: Delta },
    /// The shared version reached the worker.
    DownloadArrive { worker: usize, w_snap: Codebook },
}

struct WorkerState {
    w: Codebook,
    /// Displacement accumulated since the current/last exchange started.
    delta_cur: Delta,
    /// Local step count (indexes the learning-rate schedule).
    t: u64,
    exchange_in_flight: bool,
    /// Whether the final flush exchange has been issued.
    flushed: bool,
    rng: Rng,
}

/// Run scheme C with chunk/window size `tau` and the given one-way delay
/// models.
pub fn run(
    inputs: &mut SchemeInputs<'_>,
    tau: usize,
    up_delay: DelayModel,
    down_delay: DelayModel,
) -> Result<SchemeOutcome> {
    let m = inputs.shards.len();
    let dim = inputs.shards[0].dim();
    let kappa = inputs.w0.kappa();
    let budget = inputs.points_per_worker;

    let mut w_srd = inputs.w0.clone();
    let mut workers: Vec<WorkerState> = (0..m)
        .map(|i| WorkerState {
            w: inputs.w0.clone(),
            delta_cur: Delta::zeros(kappa, dim),
            t: 0,
            exchange_in_flight: false,
            flushed: false,
            rng: Rng::from_seed_stream(inputs.seed, 0xA5 + i as u64),
        })
        .collect();

    let mut series = Series::new(format!("M={m}"));
    let mut chunk_buf = vec![0.0f32; tau * dim];
    let mut eps_buf = vec![0.0f32; tau];
    let mut queue: EventQueue<Event> = EventQueue::new();

    inputs.eval.force_record(inputs.engine, &mut series, 0.0, &w_srd)?;
    for i in 0..m {
        queue.schedule_in(inputs.cost.compute_time(i, tau), Event::ChunkDone {
            worker: i,
        });
    }

    while let Some(ev) = queue.pop() {
        let now = queue.now();
        match ev.payload {
            Event::ChunkDone { worker } => {
                let ws = &mut workers[worker];
                inputs.shards[worker].fill_chunk(ws.t, tau, &mut chunk_buf);
                inputs.schedule.fill(ws.t, &mut eps_buf);
                inputs
                    .engine
                    .vq_chunk(&mut ws.w, &chunk_buf, &eps_buf, &mut ws.delta_cur)?;
                ws.t += tau as u64;
                inputs.trace.record(TraceEvent::Chunk {
                    wall: now,
                    worker,
                    t: ws.t,
                    count: tau,
                });
                if ws.t < budget {
                    queue.schedule_in(
                        inputs.cost.compute_time(worker, tau),
                        Event::ChunkDone { worker },
                    );
                }
                // Exchange as soon as the previous one completed.
                maybe_start_exchange(
                    &mut workers[worker],
                    worker,
                    &mut queue,
                    up_delay,
                    budget,
                );
            }
            Event::UploadArrive { worker, delta } => {
                // The dedicated reducer folds the update immediately —
                // no barrier (eq. 9, last line).
                w_srd.apply_delta(&delta);
                series.merges += 1;
                inputs.trace.record(TraceEvent::Upload {
                    wall: now,
                    worker,
                    delta_norm_sq_bits: delta.norm_sq().to_bits(),
                });
                let ws = &mut workers[worker];
                let delay =
                    inputs.cost.merge_cost + down_delay.sample(&mut ws.rng);
                queue.schedule_in(delay, Event::DownloadArrive {
                    worker,
                    w_snap: w_srd.clone(),
                });
            }
            Event::DownloadArrive { worker, w_snap } => {
                let ws = &mut workers[worker];
                // Rebase: downloaded shared version minus the displacement
                // accumulated while the exchange was in flight (eq. 9).
                ws.w = w_snap;
                ws.w.apply_delta(&ws.delta_cur);
                ws.exchange_in_flight = false;
                inputs.trace.record(TraceEvent::Download { wall: now, worker });
                // Finished workers flush their tail displacement.
                maybe_start_exchange(
                    &mut workers[worker],
                    worker,
                    &mut queue,
                    up_delay,
                    budget,
                );
            }
        }
        inputs.eval.maybe_record(inputs.engine, &mut series, now, &w_srd)?;
    }
    let final_wall = queue.now();
    inputs.eval.force_record(inputs.engine, &mut series, final_wall, &w_srd)?;
    series.points_processed = workers.iter().map(|w| w.t).sum();
    Ok(SchemeOutcome {
        final_shared: w_srd,
        final_versions: workers.into_iter().map(|w| w.w).collect(),
        series,
    })
}

/// Start an exchange if none is in flight and there is something to report
/// (or the worker is mid-run and wants a fresher shared version).
fn maybe_start_exchange(
    ws: &mut WorkerState,
    worker: usize,
    queue: &mut EventQueue<Event>,
    up_delay: DelayModel,
    budget: u64,
) {
    if ws.exchange_in_flight {
        return;
    }
    let active = ws.t < budget;
    if !active {
        if ws.flushed || ws.delta_cur.is_zero() {
            return; // fully drained
        }
        ws.flushed = true;
    } else if ws.delta_cur.is_zero() {
        // Nothing to report yet (e.g. zero-delay exchanges completing
        // between chunks): wait for the next chunk instead of spinning
        // empty exchanges at the same virtual instant.
        return;
    }
    // Snapshot-and-reset: the displacement window [prev exchange start, now]
    // rides up; a fresh window starts accumulating immediately.
    let delta_snd = std::mem::replace(
        &mut ws.delta_cur,
        Delta::zeros(ws.w.kappa(), ws.w.dim()),
    );
    ws.exchange_in_flight = true;
    let delay = up_delay.sample(&mut ws.rng);
    queue.schedule_in(delay, Event::UploadArrive { worker, delta: delta_snd });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::runtime::NativeEngine;
    use crate::sim::{CostModel, Evaluator, Trace};
    use crate::vq::{init_codebook, InitMethod, Schedule};

    fn run_async(
        m: usize,
        points: u64,
        up: DelayModel,
        down: DelayModel,
        seed: u64,
    ) -> SchemeOutcome {
        let spec = MixtureSpec {
            components: 4,
            dim: 2,
            separation: 4.0,
            std: 0.3,
            imbalance: 0.0,
            noise_frac: 0.0,
        };
        let ds = spec.dataset(4_000, seed);
        let shards = ds.split(m);
        let w0 = init_codebook(InitMethod::FromData, 4, 2, ds.flat(), seed);
        let mut engine = NativeEngine::new();
        let mut eval = Evaluator::new(spec.eval_sample(512, seed), 2, 1e-3);
        let mut trace = Trace::disabled();
        let mut inputs = SchemeInputs {
            engine: &mut engine,
            shards: &shards,
            w0,
            // kappa=4 fixture: keep M*window*eps/kappa inside the
            // stability envelope (see Schedule::paper_default docs)
            schedule: Schedule::InverseTime { eps0: 0.01, half_life: 5000.0 },
            cost: CostModel::default(),
            points_per_worker: points,
            eval: &mut eval,
            trace: &mut trace,
            seed,
        };
        run(&mut inputs, 10, up, down).unwrap()
    }

    #[test]
    fn async_converges_with_delays() {
        let out = run_async(
            4,
            10_000,
            DelayModel::Geometric { p: 0.5, unit: 1e-4 },
            DelayModel::Geometric { p: 0.5, unit: 1e-4 },
            3,
        );
        assert!(out.series.last_value() < out.series.first_value() * 0.5);
        assert!(out.series.is_time_monotone());
        assert_eq!(out.series.points_processed, 40_000);
        assert!(out.final_shared.is_finite());
    }

    #[test]
    fn all_deltas_folded_exactly_once() {
        // With zero delays the exchanges serialize cleanly; the shared
        // version must equal w0 minus the sum of every uploaded delta —
        // which is w0 - Σ_i (w0 - w_i_contributions). We verify through the
        // merge count: every chunk's displacement gets uploaded in some
        // exchange, and the final flush drains the tails.
        let out = run_async(3, 1_000, DelayModel::Instant, DelayModel::Instant, 5);
        assert!(out.series.merges > 0);
        // after the final flush every worker's delta_cur was zero, so the
        // shared version contains all displacement mass; each worker's own
        // version equals a rebase of w_srd (stale by at most one exchange)
        for v in &out.final_versions {
            assert!(v.is_finite());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = run_async(
            4,
            2_000,
            DelayModel::Geometric { p: 0.3, unit: 2e-4 },
            DelayModel::Geometric { p: 0.3, unit: 2e-4 },
            9,
        );
        let b = run_async(
            4,
            2_000,
            DelayModel::Geometric { p: 0.3, unit: 2e-4 },
            DelayModel::Geometric { p: 0.3, unit: 2e-4 },
            9,
        );
        assert_eq!(a.final_shared, b.final_shared);
        assert_eq!(a.series.samples.len(), b.series.samples.len());
        assert_eq!(a.series.merges, b.series.merges);
    }

    #[test]
    fn small_delays_only_slightly_impact_convergence() {
        // The paper's Figure-3 claim, as a coarse assertion.
        let no_delay =
            run_async(10, 10_000, DelayModel::Instant, DelayModel::Instant, 13);
        let small_delay = run_async(
            10,
            10_000,
            DelayModel::Geometric { p: 0.5, unit: 2e-5 },
            DelayModel::Geometric { p: 0.5, unit: 2e-5 },
            13,
        );
        let horizon = no_delay
            .series
            .last_wall()
            .min(small_delay.series.last_wall());
        let a = no_delay.series.value_at(horizon);
        let b = small_delay.series.value_at(horizon);
        assert!(
            (b - a).abs() / a.max(1e-9) < 0.5,
            "delayed ({b:.5}) should be within 50% of undelayed ({a:.5})"
        );
    }
}
