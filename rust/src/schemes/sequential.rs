//! The sequential VQ reference (paper eq. 1, `M = 1`).
//!
//! Processes the first shard cyclically, chunked only for engine-dispatch
//! efficiency (the trajectory is chunking-invariant because the fused
//! kernel replays eq. 1 point by point).

use anyhow::Result;

use crate::metrics::Series;
use crate::sim::TraceEvent;
use crate::vq::Delta;

use super::{SchemeInputs, SchemeOutcome};

/// Engine-dispatch chunk size (pure batching; no algorithmic meaning).
const CHUNK: usize = 10;

/// Run sequential VQ on `inputs.shards[0]`.
pub fn run(inputs: &mut SchemeInputs<'_>) -> Result<SchemeOutcome> {
    let shard = &inputs.shards[0];
    let dim = shard.dim();
    let mut w = inputs.w0.clone();
    let mut delta = Delta::zeros(w.kappa(), dim);
    let mut series = Series::new("M=1");
    let mut chunk_buf = vec![0.0f32; CHUNK * dim];
    let mut eps_buf = vec![0.0f32; CHUNK];

    let mut wall = 0.0f64;
    let mut t: u64 = 0;
    inputs.eval.force_record(inputs.engine, &mut series, wall, &w)?;
    while t < inputs.points_per_worker {
        let count = CHUNK.min((inputs.points_per_worker - t) as usize);
        shard.fill_chunk(t, count, &mut chunk_buf[..count * dim]);
        inputs.schedule.fill(t, &mut eps_buf[..count]);
        delta.clear();
        inputs.engine.vq_chunk(
            &mut w,
            &chunk_buf[..count * dim],
            &eps_buf[..count],
            &mut delta,
        )?;
        t += count as u64;
        wall += inputs.cost.compute_time(0, count);
        inputs.trace.record(TraceEvent::Chunk { wall, worker: 0, t, count });
        inputs.eval.maybe_record(inputs.engine, &mut series, wall, &w)?;
    }
    inputs.eval.force_record(inputs.engine, &mut series, wall, &w)?;
    series.points_processed = t;
    Ok(SchemeOutcome { final_shared: w.clone(), final_versions: vec![w], series })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Dataset;
    use crate::runtime::NativeEngine;
    use crate::sim::{CostModel, Evaluator, Trace};
    use crate::vq::{Codebook, Schedule};

    #[test]
    fn sequential_converges_on_two_clusters() {
        // points at 0 and 10; two prototypes must land near them
        let mut pts = Vec::new();
        for i in 0..50 {
            pts.push((i % 2) as f32 * 10.0 + 0.01 * (i as f32 % 5.0));
        }
        let dataset = Dataset::new(pts, 1);
        let shards = dataset.split(1);
        let mut engine = NativeEngine::new();
        let mut eval = Evaluator::new(dataset.flat().to_vec(), 1, 1e-3);
        let mut trace = Trace::disabled();
        let mut inputs = SchemeInputs {
            engine: &mut engine,
            shards: &shards,
            w0: Codebook::from_flat(2, 1, vec![4.0, 6.0]),
            schedule: Schedule::InverseTime { eps0: 0.5, half_life: 100.0 },
            cost: CostModel::default(),
            points_per_worker: 5_000,
            eval: &mut eval,
            trace: &mut trace,
            seed: 0,
        };
        let out = run(&mut inputs).unwrap();
        assert!(out.series.last_value() < out.series.first_value() * 0.2,
            "distortion should drop: {} -> {}",
            out.series.first_value(), out.series.last_value());
        assert!(out.series.is_time_monotone());
        assert_eq!(out.series.points_processed, 5_000);
        // prototypes near 0 and 10 (order unknown)
        let mut protos = [out.final_shared.row(0)[0], out.final_shared.row(1)[0]];
        protos.sort_by(f32::total_cmp);
        assert!(protos[0].abs() < 0.5, "{protos:?}");
        assert!((protos[1] - 10.0).abs() < 0.5, "{protos:?}");
    }
}
