//! # dalvq — Distributed Asynchronous Learning Vector Quantization
//!
//! A full reproduction of *“A Discussion on Parallelization Schemes for
//! Stochastic Vector Quantization Algorithms”* (Durut, Patra & Rossi, 2012).
//!
//! The paper studies how to parallelize *online* k-means (stochastic VQ,
//! paper eq. 1) across `M` computing entities and shows:
//!
//! * **Scheme A** (eq. 3) — averaging local versions every `τ` points —
//!   brings **no** wall-clock speed-up ([`schemes::averaging`]).
//! * **Scheme B** (eq. 8) — *adding* every worker's accumulated
//!   displacement `Δ` to a shared version — brings real speed-ups
//!   ([`schemes::delta_sync`]).
//! * **Scheme C** (eq. 9) — the asynchronous, delay-tolerant variant of B —
//!   keeps those speed-ups on slow-communication architectures
//!   ([`schemes::async_delta`] on the event-driven [`sim`]ulator, and
//!   [`cloud`] for the real-concurrency CloudDALVQ analogue that scales to
//!   32 workers).
//!
//! ## Architecture (three layers, Python never at run time)
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): the fused
//!   `τ`-point VQ walk, tiled distortion, batch-k-means partials.
//! * **L2** — JAX entry points (`python/compile/model.py`), lowered once by
//!   `make artifacts` to HLO text in `artifacts/`.
//! * **L3** — this crate: the coordination layer the paper actually
//!   contributes, plus every substrate it needs (synthetic data, virtual
//!   time simulator, latency-injected cloud services, metrics, config),
//!   and the [`serve`] subsystem that keeps an eq.-9 fleet learning while
//!   a TCP read path answers encode/nearest/distortion queries against
//!   atomically published codebook snapshots — sharded across `S`
//!   independent fleets behind a versioned router epoch that [`persist`]
//!   checkpoints, warm-restarts, and live-rebalances when ingest load
//!   skews.
//!
//! The [`runtime`] module loads the artifacts through PJRT (the `xla`
//! crate) and exposes them behind the [`runtime::Engine`] trait; a
//! bit-mirrored pure-Rust [`runtime::NativeEngine`] backs property tests
//! and very large sweeps (cross-checked against PJRT in integration tests).
//!
//! ## Quick start
//!
//! ```no_run
//! use dalvq::config::presets;
//! use dalvq::harness;
//!
//! // Regenerate paper Figure 2 (scheme B, tau = 10, M in {1, 2, 10}):
//! let cfg = presets::fig2();
//! let report = harness::run_figure(&cfg).unwrap();
//! for series in &report.series {
//!     println!("{}: final C = {:.4}", series.name, series.last_value());
//! }
//! ```

pub mod baselines;
pub mod cloud;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod harness;
pub mod metrics;
pub mod obs;
pub mod persist;
pub mod runtime;
pub mod schemes;
pub mod serve;
pub mod sim;
pub mod util;
pub mod vq;

pub use anyhow::{anyhow, Context, Result};
