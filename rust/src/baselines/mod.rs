//! Baselines the paper positions itself against.
//!
//! The introduction contrasts online VQ with “the embarrassing parallelism
//! of the (batch) k-means”. To make that contrast measurable, the crate
//! ships both the full-batch Lloyd iteration ([`batch_kmeans`]) and the
//! minibatch variant ([`minibatch_kmeans`]) over the same engines and
//! datasets, with the same wall-time cost accounting as the schemes.

mod kmeans;

pub use kmeans::{batch_kmeans, minibatch_kmeans, KmeansOutcome};
