//! Batch and minibatch k-means baselines.

use anyhow::Result;

use crate::metrics::Series;
use crate::runtime::Engine;
use crate::sim::{CostModel, Evaluator};
use crate::vq::Codebook;

/// Result of a k-means baseline run.
pub struct KmeansOutcome {
    pub series: Series,
    pub final_w: Codebook,
    pub iterations: u64,
}

/// Full-batch Lloyd iteration, parallelized over `m` virtual workers.
///
/// Each iteration scans the entire dataset; with `m` workers the scan
/// parallelizes perfectly (the “embarrassing parallelism” of batch
/// k-means), so one iteration costs `n/m · point_compute` of virtual wall
/// time plus the merge cost. Runs until `iters` iterations or until the
/// assignment energy stops improving by `rel_tol`.
pub fn batch_kmeans(
    engine: &mut dyn Engine,
    w0: &Codebook,
    points: &[f32],
    m: usize,
    cost: &CostModel,
    eval: &mut Evaluator,
    iters: u64,
    rel_tol: f64,
) -> Result<KmeansOutcome> {
    assert!(m >= 1);
    let n = points.len() / w0.dim();
    let mut w = w0.clone();
    let mut series = Series::new(format!("kmeans M={m}"));
    let mut wall = 0.0f64;
    eval.force_record(engine, &mut series, wall, &w)?;
    let mut prev = f64::INFINITY;
    let mut done = 0;
    for _ in 0..iters {
        engine.kmeans_step(&mut w, points)?;
        done += 1;
        // perfect data-parallel scan + reduce
        wall += cost.point_compute * (n as f64 / m as f64)
            + cost.merge_cost * m as f64
            + cost.broadcast_cost;
        series.merges += 1;
        eval.force_record(engine, &mut series, wall, &w)?;
        let cur = series.last_value();
        if prev.is_finite() && (prev - cur).abs() <= rel_tol * prev.abs() {
            break;
        }
        prev = cur;
    }
    series.points_processed = done * n as u64;
    Ok(KmeansOutcome { series, final_w: w, iterations: done })
}

/// Minibatch k-means: one Lloyd step per `batch`-point minibatch, cycled
/// through the dataset. This is the batch-flavoured analogue of the online
/// scheme (and the only k-means shape the fixed-batch PJRT artifact can
/// run directly).
pub fn minibatch_kmeans(
    engine: &mut dyn Engine,
    w0: &Codebook,
    points: &[f32],
    batch: usize,
    m: usize,
    cost: &CostModel,
    eval: &mut Evaluator,
    steps: u64,
) -> Result<KmeansOutcome> {
    let dim = w0.dim();
    let n = points.len() / dim;
    assert!(batch <= n, "minibatch larger than dataset");
    let mut w = w0.clone();
    let mut series = Series::new(format!("minibatch-kmeans M={m}"));
    let mut wall = 0.0f64;
    eval.force_record(engine, &mut series, wall, &w)?;
    let mut offset = 0usize;
    let mut buf = vec![0.0f32; batch * dim];
    for _ in 0..steps {
        // cyclic minibatch (wraps around the dataset)
        for j in 0..batch {
            let i = (offset + j) % n;
            buf[j * dim..(j + 1) * dim]
                .copy_from_slice(&points[i * dim..(i + 1) * dim]);
        }
        offset = (offset + batch) % n;
        engine.kmeans_step(&mut w, &buf)?;
        wall += cost.point_compute * (batch as f64 / m as f64)
            + cost.merge_cost * m as f64;
        series.merges += 1;
        eval.maybe_record(engine, &mut series, wall, &w)?;
    }
    eval.force_record(engine, &mut series, wall, &w)?;
    series.points_processed = steps * batch as u64;
    Ok(KmeansOutcome { series, final_w: w, iterations: steps })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::MixtureSpec;
    use crate::runtime::NativeEngine;
    use crate::vq::{init_codebook, InitMethod};

    fn spec() -> MixtureSpec {
        MixtureSpec {
            components: 4,
            dim: 2,
            separation: 5.0,
            std: 0.2,
            imbalance: 0.0,
            noise_frac: 0.0,
        }
    }

    #[test]
    fn batch_kmeans_converges_and_stops_early() {
        let s = spec();
        let ds = s.dataset(2_000, 3);
        let w0 = init_codebook(InitMethod::KmeansPlusPlus, 4, 2, ds.flat(), 3);
        let mut eng = NativeEngine::new();
        let mut eval = Evaluator::new(s.eval_sample(512, 3), 2, 1e-6);
        let out = batch_kmeans(
            &mut eng, &w0, ds.flat(), 4, &CostModel::default(), &mut eval,
            100, 1e-6,
        )
        .unwrap();
        assert!(out.iterations < 100, "should hit the tolerance early");
        assert!(out.series.last_value() < out.series.first_value() * 0.9);
        // well-separated tight clusters: near-zero distortion
        assert!(out.series.last_value() < 0.2, "{}", out.series.last_value());
    }

    #[test]
    fn minibatch_kmeans_reduces_distortion() {
        let s = spec();
        let ds = s.dataset(2_000, 4);
        let w0 = init_codebook(InitMethod::FromData, 4, 2, ds.flat(), 4);
        let mut eng = NativeEngine::new();
        let mut eval = Evaluator::new(s.eval_sample(512, 4), 2, 1e-6);
        let out = minibatch_kmeans(
            &mut eng, &w0, ds.flat(), 128, 1, &CostModel::default(), &mut eval,
            50,
        )
        .unwrap();
        assert!(out.series.last_value() <= out.series.first_value());
        assert_eq!(out.iterations, 50);
    }

    #[test]
    fn batch_kmeans_more_workers_is_faster_in_wall_time() {
        let s = spec();
        let ds = s.dataset(2_000, 5);
        let w0 = init_codebook(InitMethod::KmeansPlusPlus, 4, 2, ds.flat(), 5);
        let cost = CostModel::default();
        let mut eng = NativeEngine::new();
        let mut ev1 = Evaluator::new(s.eval_sample(256, 5), 2, 1e-6);
        let mut ev8 = Evaluator::new(s.eval_sample(256, 5), 2, 1e-6);
        let a = batch_kmeans(&mut eng, &w0, ds.flat(), 1, &cost, &mut ev1, 10, 0.0)
            .unwrap();
        let b = batch_kmeans(&mut eng, &w0, ds.flat(), 8, &cost, &mut ev8, 10, 0.0)
            .unwrap();
        // same iterations, same trajectory, 8x less wall time per iteration
        assert!(b.series.last_wall() < a.series.last_wall() / 4.0);
    }
}
