//! Durable state for the serving fleet: per-shard checkpoint/restore.
//!
//! The paper's Azure deployment survives VM churn because worker state
//! lives *outside* the process (blob storage holds the shared version);
//! CloudDALVQ workers are restartable by construction. This subsystem
//! gives `dalvq serve` the same property on a plain filesystem: a
//! versioned on-disk store the fleet checkpoints into and restarts from,
//! so a restarted service resumes at the saved shard versions instead of
//! retraining from scratch. Patra's convergence result for distributed
//! asynchronous LVQ makes resuming from a saved iterate sound — the
//! algorithm's state *is* the codebook plus its schedule position.
//!
//! Pieces, one module each:
//!
//! * [`codec`] — self-describing binary files (magic, format version,
//!   FNV-1a checksum) for shard state (codebook + shard id + version +
//!   merge count + RNG cursor) and the frozen router.
//! * [`manifest`] — the state directory's table of contents and the
//!   atomic write protocol (temp + fsync + rename) every file goes
//!   through, so a crash mid-checkpoint can never corrupt saved state.
//! * [`checkpointer`] — the background thread that snapshots each shard
//!   every `checkpoint_every` folds without blocking the read path (a
//!   checkpoint is an `Arc` clone of the published epoch, not a copy).
//! * [`restore`] — warm-start loading with strict validation: stale
//!   `.tmp` leftovers ignored, corrupt or mismatched files rejected
//!   loudly before any fleet is seeded from them.
//!
//! * [`rebalance`] — the offline re-partitioner: retrains the coarse
//!   quantizer from the checkpointed codebooks (rows weighted by each
//!   shard's persisted ingest counters) and migrates prototype rows
//!   across the shard files at a bumped router version. The state dir —
//!   not any live fleet — is the data source for a rebalance.
//! * [`ship`] — checkpoint shipping for replication: a consistent,
//!   generation-stamped read of a live state dir as one raw-byte bundle
//!   ([`ship::read_bundle`]), plus decoding and mirroring it on the far
//!   side — how a read-only follower warm-starts, and keeps re-syncing,
//!   from a leader's checkpoints. Replication v2 adds the delta codec
//!   ([`ship::delta_files`] / [`ship::apply_delta`]: ship only the
//!   shard files whose version advanced) and bounded chunking
//!   ([`ship::chunk_files`] / [`ship::reassemble_chunks`]) so a cut of
//!   any size fits the wire's frame cap.
//!
//! The shard is the save/restore/migrate unit (the `ShardOutcome` /
//! `shard_versions` granularity): shards checkpoint independently, a
//! rebalance is a split/merge of exactly these files, and a shipped
//! bundle is exactly these files cut at one checkpoint generation.

/// Self-describing binary files for shard and router state.
pub mod codec;
/// The state directory's table of contents + the atomic write protocol.
pub mod manifest;
/// The background thread that drains shard epochs to disk.
pub mod checkpointer;
/// The offline re-partitioner (router retrain + row migration).
pub mod rebalance;
/// Warm-start loading with strict validation.
pub mod restore;
/// Checkpoint shipping for leader/follower replication.
pub mod ship;

pub use checkpointer::{CheckpointSpec, Checkpointer, ShardSource};
pub use codec::{RouterState, ShardState, FORMAT};
pub use manifest::{
    shard_file, sweep_tmp, write_atomic, Manifest, MANIFEST_FILE, ROUTER_FILE,
};
pub use rebalance::{rebalance_state_dir, RebalanceReport};
pub use restore::{decode_state, load_state, RestoredState};
pub use ship::{
    apply_delta, chunk_files, decode_bundle, delta_files, read_bundle,
    reassemble_chunks, write_bundle, FilePart, StateBundle,
};
