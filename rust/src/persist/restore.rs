//! Restore-on-startup: read a state directory back into the structures a
//! serving fleet is seeded from.
//!
//! The contract with interrupted checkpoints: only files the atomic
//! rename completed are ever read — `*.tmp` leftovers are never opened
//! (sweeping them is the *serving* startup's job, via
//! [`super::manifest::sweep_tmp`]; this loader is also behind the
//! read-only `dalvq state inspect`, which must not unlink a live
//! checkpointer's in-flight temp file). A missing manifest means a cold
//! start; a *corrupt* manifest, router or shard file is a hard error —
//! silently retraining over saved state the operator asked us to keep
//! would be data loss with no symptom.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::codec::{RouterState, ShardState};
use super::manifest::{shard_path, Manifest, ROUTER_FILE};

/// Everything a warm start restores.
#[derive(Debug, Clone)]
pub struct RestoredState {
    /// The manifest the state was validated against.
    pub manifest: Manifest,
    /// The frozen coarse quantizer, verbatim.
    pub router: RouterState,
    /// Per-shard state, shard order (`shards[s].shard == s`).
    pub shards: Vec<ShardState>,
}

/// Load saved state from `dir`. `Ok(None)` when the directory holds no
/// manifest (first run — a cold start that will begin checkpointing into
/// it). `*.tmp` leftovers are ignored by construction (nothing here opens
/// them) but NOT removed — this loader must stay read-only so `dalvq
/// state inspect` is safe against a live serve process.
pub fn load_state(dir: &Path) -> Result<Option<RestoredState>> {
    let Some(manifest) = Manifest::load(dir)? else {
        return Ok(None);
    };
    let router_path = dir.join(ROUTER_FILE);
    let router_bytes = std::fs::read(&router_path)
        .with_context(|| format!("reading {}", router_path.display()))?;
    let mut shard_bytes = Vec::with_capacity(manifest.shards);
    for s in 0..manifest.shards {
        let path = shard_path(dir, s);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        shard_bytes.push((path.display().to_string(), bytes));
    }
    decode_state(
        manifest,
        &router_path.display().to_string(),
        &router_bytes,
        &shard_bytes,
    )
    .map(Some)
}

/// Decode and cross-validate raw state bytes against their manifest —
/// the validation core shared by [`load_state`] (bytes read off a local
/// directory) and [`super::ship::decode_bundle`] (bytes shipped over the
/// wire from a leader). Each byte string comes with a label (a file
/// path, or a bundle entry name) used in error messages; the byte
/// container is generic so callers can pass owned buffers or borrows of
/// a wire frame without copying. Every cross-check lives here so a
/// shipped bundle is held to exactly the standard a local restore is.
pub fn decode_state<B: AsRef<[u8]>>(
    manifest: Manifest,
    router_label: &str,
    router_bytes: &[u8],
    shard_bytes: &[(String, B)],
) -> Result<RestoredState> {
    let router = RouterState::decode(router_bytes)
        .with_context(|| format!("decoding {router_label}"))?;
    if router.centroids.kappa() != manifest.shards
        || router.centroids.dim() != manifest.dim
    {
        bail!(
            "router file is {} centroids x dim {}, manifest says {} x {}",
            router.centroids.kappa(),
            router.centroids.dim(),
            manifest.shards,
            manifest.dim
        );
    }
    if router.version != manifest.router_version {
        bail!(
            "router file is partition version {}, manifest says {} — a \
             rebalance was interrupted between writing the router and the \
             manifest; re-run `dalvq state rebalance` on this directory",
            router.version,
            manifest.router_version
        );
    }
    if shard_bytes.len() != manifest.shards {
        bail!(
            "{} shard payload(s) for a manifest listing {} shards",
            shard_bytes.len(),
            manifest.shards
        );
    }
    let kappa_shard = manifest.kappa / manifest.shards;
    let mut shards = Vec::with_capacity(manifest.shards);
    for (s, (label, bytes)) in shard_bytes.iter().enumerate() {
        let state = ShardState::decode(bytes.as_ref())
            .with_context(|| format!("decoding {label}"))?;
        if state.shard as usize != s {
            bail!("{label} claims to be shard {}, expected {s}", state.shard);
        }
        if state.router_version != manifest.router_version {
            bail!(
                "{label} belongs to partition version {}, manifest says {} \
                 — a rebalance was interrupted mid-migration; re-run `dalvq \
                 state rebalance` on this directory",
                state.router_version,
                manifest.router_version
            );
        }
        if state.codebook.kappa() != kappa_shard
            || state.codebook.dim() != manifest.dim
        {
            bail!(
                "{label} holds a {} x {} codebook, manifest expects {} x {}",
                state.codebook.kappa(),
                state.codebook.dim(),
                kappa_shard,
                manifest.dim
            );
        }
        shards.push(state);
    }
    Ok(RestoredState { manifest, router, shards })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::manifest::{shard_file, write_atomic, MANIFEST_FILE};
    use crate::vq::Codebook;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dalvq-restore-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn write_good_state(dir: &Path) {
        Manifest {
            format: 1,
            shards: 2,
            kappa: 4,
            dim: 2,
            points_per_exchange: 50,
            router_version: 1,
            generation: 1,
            shard_versions: vec![5, 7],
        }
        .save(dir)
        .unwrap();
        let router = RouterState {
            version: 1,
            centroids: Codebook::from_flat(2, 2, vec![0.0, 0.0, 10.0, 10.0]),
        };
        write_atomic(dir, ROUTER_FILE, &router.encode()).unwrap();
        for (s, v) in [(0usize, 5u64), (1, 7)] {
            let state = ShardState {
                shard: s as u32,
                version: v,
                merges: v,
                rng_cursor: v * 50,
                ingested: 10 * v,
                shed: v,
                router_version: 1,
                codebook: Codebook::from_flat(
                    2,
                    2,
                    vec![s as f32; 4],
                ),
            };
            write_atomic(dir, &shard_file(s), &state.encode()).unwrap();
        }
    }

    #[test]
    fn empty_dir_is_a_cold_start() {
        let dir = tmp_dir("cold");
        assert!(load_state(&dir).unwrap().is_none());
    }

    #[test]
    fn good_state_loads_and_tmp_leftovers_are_ignored_not_removed() {
        let dir = tmp_dir("good");
        write_good_state(&dir);
        // an interrupted checkpoint left garbage behind
        std::fs::write(dir.join("shard-0.state.tmp"), b"half a write").unwrap();
        std::fs::write(dir.join(format!("{MANIFEST_FILE}.tmp")), b"{").unwrap();
        let state = load_state(&dir).unwrap().unwrap();
        assert_eq!(state.shards.len(), 2);
        assert_eq!(state.shards[1].version, 7);
        assert_eq!(state.shards[1].ingested, 70);
        assert_eq!(state.shards[1].shed, 7);
        assert_eq!(state.router.centroids.kappa(), 2);
        assert_eq!(state.router.version, 1);
        assert_eq!(state.manifest.router_version, 1);
        // this loader is read-only (the inspect CLI uses it against
        // possibly-live dirs): the tmp junk is ignored but left in place
        assert!(dir.join("shard-0.state.tmp").exists(), "loader must not unlink");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_shard_file_is_a_hard_error() {
        let dir = tmp_dir("corrupt");
        write_good_state(&dir);
        let path = shard_path(&dir, 1);
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xFF;
        std::fs::write(&path, bytes).unwrap();
        let err = format!("{:#}", load_state(&dir).unwrap_err());
        assert!(err.contains("shard-1.state"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_shard_file_is_a_hard_error() {
        let dir = tmp_dir("missing");
        write_good_state(&dir);
        std::fs::remove_file(shard_path(&dir, 0)).unwrap();
        assert!(load_state(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_id_mismatch_is_rejected() {
        let dir = tmp_dir("id");
        write_good_state(&dir);
        // shard 1's file copied over shard 0's slot
        std::fs::copy(shard_path(&dir, 1), shard_path(&dir, 0)).unwrap();
        let err = format!("{:#}", load_state(&dir).unwrap_err());
        assert!(err.contains("claims to be shard"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_migration_shard_file_is_rejected() {
        // A rebalance killed mid-migration: one shard file already
        // rewritten at the bumped partition version, router + manifest
        // still at the old one. The shard-level stamp must catch it —
        // shapes alone all match.
        let dir = tmp_dir("torn");
        write_good_state(&dir);
        let migrated = ShardState {
            shard: 0,
            version: 7,
            merges: 7,
            rng_cursor: 350,
            ingested: 0,
            shed: 0,
            router_version: 2, // manifest + router say 1
            codebook: Codebook::from_flat(2, 2, vec![9.0; 4]),
        };
        write_atomic(&dir, &shard_file(0), &migrated.encode()).unwrap();
        let err = format!("{:#}", load_state(&dir).unwrap_err());
        assert!(err.contains("interrupted mid-migration"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn router_partition_version_mismatch_is_rejected() {
        // A rebalance interrupted between the router write and the
        // manifest write leaves the two at different partition versions —
        // restore must refuse rather than route with the wrong epoch.
        let dir = tmp_dir("rv");
        write_good_state(&dir);
        let router = RouterState {
            version: 2, // manifest says 1
            centroids: Codebook::from_flat(2, 2, vec![0.0, 0.0, 10.0, 10.0]),
        };
        write_atomic(&dir, ROUTER_FILE, &router.encode()).unwrap();
        let err = format!("{:#}", load_state(&dir).unwrap_err());
        assert!(err.contains("partition version"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn wrong_shape_shard_file_is_rejected() {
        let dir = tmp_dir("shape");
        write_good_state(&dir);
        let state = ShardState {
            shard: 0,
            version: 5,
            merges: 5,
            rng_cursor: 250,
            ingested: 0,
            shed: 0,
            router_version: 1,
            // dim 3 where the manifest says 2
            codebook: Codebook::from_flat(2, 3, vec![0.0; 6]),
        };
        write_atomic(&dir, &shard_file(0), &state.encode()).unwrap();
        let err = format!("{:#}", load_state(&dir).unwrap_err());
        assert!(err.contains("manifest expects"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
