//! The manifest: the state directory's table of contents, plus the atomic
//! write protocol every durable file goes through.
//!
//! Layout under `--state-dir`:
//!
//! ```text
//! state/
//!   manifest.json      deployment shape + per-shard checkpoint versions
//!   router.bin         the frozen coarse quantizer (codec::RouterState)
//!   shard-0.state      per-shard codebook + metadata (codec::ShardState)
//!   shard-1.state      …one per shard…
//!   *.tmp              in-flight writes; IGNORED by restore (a crash
//!                      mid-checkpoint must never corrupt saved state)
//! ```
//!
//! Every file lands via **temp + fsync + rename**: bytes are written to
//! `<name>.tmp`, fsynced, then renamed over the final name (atomic on
//! POSIX), and the directory is fsynced so the rename itself is durable.
//! A reader therefore sees either the old complete file or the new
//! complete file, never a prefix — the same discipline the paper's Azure
//! deployment leans on blob storage for.

use std::fs::File;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::Json;

/// Manifest file name inside the state dir.
pub const MANIFEST_FILE: &str = "manifest.json";
/// Router file name inside the state dir.
pub const ROUTER_FILE: &str = "router.bin";
/// Suffix of in-flight writes; restore ignores these.
pub const TMP_SUFFIX: &str = ".tmp";

/// File name of shard `s`'s state.
pub fn shard_file(s: usize) -> String {
    format!("shard-{s}.state")
}

/// What the manifest records: enough to validate a restore against the
/// deployment config before any shard file is opened.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    /// On-disk format version (mirrors `codec::FORMAT`).
    pub format: u32,
    /// Shard count `S` of the deployment that wrote this state.
    pub shards: usize,
    /// Total prototypes across shards.
    pub kappa: usize,
    /// Prototype dimension.
    pub dim: usize,
    /// Points per exchange of the writing deployment (documents the unit
    /// of each shard's `rng_cursor`).
    pub points_per_exchange: usize,
    /// Partition version of the router the shard files were written
    /// under: 0 for the bootstrap partition, bumped by every rebalance.
    /// Restore cross-checks this against the router file so a torn
    /// rebalance (new shards, old router or vice versa) is rejected.
    pub router_version: u64,
    /// Checkpoint generation: a counter bumped by **every** manifest
    /// write (periodic checkpoints, forced flushes, rebalances, heals).
    /// This is the clock replication polls: a follower that has adopted
    /// generation `g` re-fetches only when the leader's manifest carries
    /// a different one, and [`super::ship::read_bundle`] uses its
    /// stability across a read pass as the consistent-cut check.
    /// Directories written before this field existed read back as
    /// generation 0.
    pub generation: u64,
    /// Last checkpointed snapshot version per shard, shard order.
    pub shard_versions: Vec<u64>,
}

impl Manifest {
    /// The manifest's JSON object form (what [`Manifest::save`] writes).
    pub fn to_json(&self) -> Json {
        Json::obj()
            .set("format", self.format as u64)
            .set("shards", self.shards)
            .set("kappa", self.kappa)
            .set("dim", self.dim)
            .set("points_per_exchange", self.points_per_exchange)
            .set("router_version", self.router_version)
            .set("generation", self.generation)
            .set(
                "shard_versions",
                Json::Arr(
                    self.shard_versions
                        .iter()
                        .map(|v| Json::Num(*v as f64))
                        .collect(),
                ),
            )
    }

    /// Parse and shape-check a manifest object ([`Manifest::load`]'s
    /// core; total like the binary decoders).
    pub fn from_json(j: &Json) -> Result<Manifest> {
        let m = Manifest {
            format: j.req("format")?.as_u64()? as u32,
            shards: j.req("shards")?.as_usize()?,
            kappa: j.req("kappa")?.as_usize()?,
            dim: j.req("dim")?.as_usize()?,
            points_per_exchange: j.req("points_per_exchange")?.as_usize()?,
            router_version: j.req("router_version")?.as_u64()?,
            // Optional for manifests written before checkpoint shipping
            // existed: they read back as generation 0 and the first
            // checkpoint bumps from there.
            generation: match j.get("generation") {
                Some(g) => g.as_u64()?,
                None => 0,
            },
            shard_versions: j
                .req("shard_versions")?
                .as_arr()?
                .iter()
                .map(|v| v.as_u64())
                .collect::<Result<Vec<_>>>()?,
        };
        if m.shards == 0 || m.shard_versions.len() != m.shards {
            bail!(
                "manifest lists {} shard versions for {} shards",
                m.shard_versions.len(),
                m.shards
            );
        }
        // Every consumer of the manifest divides kappa across shards
        // (restore, rebalance, shipped-bundle adoption); a manifest that
        // cannot be divided evenly is corrupt, not a deployment choice.
        if m.kappa == 0 || m.kappa % m.shards != 0 {
            bail!(
                "manifest kappa = {} does not divide across {} shards",
                m.kappa,
                m.shards
            );
        }
        Ok(m)
    }

    /// Write the manifest atomically into `dir`.
    pub fn save(&self, dir: &Path) -> Result<()> {
        write_atomic(dir, MANIFEST_FILE, self.to_json().to_pretty().as_bytes())
    }

    /// Load the manifest from `dir`. `Ok(None)` when no manifest exists
    /// (a cold start); any present-but-unreadable manifest is an error —
    /// silently retraining over saved state would be data loss.
    pub fn load(dir: &Path) -> Result<Option<Manifest>> {
        let path = dir.join(MANIFEST_FILE);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => {
                return Err(anyhow!(e))
                    .with_context(|| format!("reading {}", path.display()))
            }
        };
        let j = Json::parse(&text)
            .with_context(|| format!("parsing {}", path.display()))?;
        Self::from_json(&j)
            .with_context(|| format!("validating {}", path.display()))
            .map(Some)
    }
}

/// Atomic durable write: `dir/<name>.tmp` → fsync → rename to
/// `dir/<name>` → fsync the directory. A crash at any point leaves either
/// the previous complete file or the new complete file (plus at worst a
/// stale `.tmp`, which restore ignores).
pub fn write_atomic(dir: &Path, name: &str, bytes: &[u8]) -> Result<()> {
    std::fs::create_dir_all(dir)
        .with_context(|| format!("creating state dir {}", dir.display()))?;
    let tmp = dir.join(format!("{name}{TMP_SUFFIX}"));
    let dst = dir.join(name);
    {
        let mut f = File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(bytes)?;
        f.sync_all()
            .with_context(|| format!("fsyncing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, &dst).with_context(|| {
        format!("renaming {} -> {}", tmp.display(), dst.display())
    })?;
    // Durability of the rename itself: fsync the directory. Some
    // platforms refuse to open a directory for writing — best effort
    // there (the rename is still atomic; only its durability window
    // widens).
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
    Ok(())
}

/// Remove stale `.tmp` leftovers from interrupted checkpoints (best
/// effort — a tmp file we cannot remove is still ignored by restore).
pub fn sweep_tmp(dir: &Path) -> usize {
    let Ok(entries) = std::fs::read_dir(dir) else { return 0 };
    let mut swept = 0;
    for entry in entries.flatten() {
        let name = entry.file_name();
        if name.to_string_lossy().ends_with(TMP_SUFFIX)
            && std::fs::remove_file(entry.path()).is_ok()
        {
            swept += 1;
        }
    }
    swept
}

/// `dir/<file name of shard s>`.
pub fn shard_path(dir: &Path, s: usize) -> PathBuf {
    dir.join(shard_file(s))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "dalvq-manifest-{tag}-{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    #[test]
    fn manifest_roundtrips_through_disk() {
        let dir = tmp_dir("roundtrip");
        let m = Manifest {
            format: 1,
            shards: 4,
            kappa: 8,
            dim: 2,
            points_per_exchange: 50,
            router_version: 3,
            generation: 11,
            shard_versions: vec![6, 6, 7, 6],
        };
        m.save(&dir).unwrap();
        assert_eq!(Manifest::load(&dir).unwrap().unwrap(), m);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn manifest_without_generation_reads_as_generation_zero() {
        // Directories checkpointed before checkpoint shipping existed
        // carry no `generation` key; they must load (as generation 0),
        // not error — replication is additive to the on-disk format.
        let dir = tmp_dir("pre-generation");
        let mut m = Manifest {
            format: 2,
            shards: 1,
            kappa: 4,
            dim: 2,
            points_per_exchange: 50,
            router_version: 0,
            generation: 7,
            shard_versions: vec![3],
        };
        let mut j = m.to_json();
        if let crate::util::Json::Obj(pairs) = &mut j {
            pairs.retain(|(k, _)| k != "generation");
        }
        write_atomic(&dir, MANIFEST_FILE, j.to_pretty().as_bytes()).unwrap();
        m.generation = 0;
        assert_eq!(Manifest::load(&dir).unwrap().unwrap(), m);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn missing_manifest_is_a_cold_start_not_an_error() {
        let dir = tmp_dir("cold");
        assert!(Manifest::load(&dir).unwrap().is_none());
    }

    #[test]
    fn corrupt_manifest_is_an_error_not_a_cold_start() {
        let dir = tmp_dir("corrupt");
        write_atomic(&dir, MANIFEST_FILE, b"{ not json").unwrap();
        assert!(Manifest::load(&dir).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn inconsistent_shard_counts_are_rejected() {
        let m = Manifest {
            format: 1,
            shards: 2,
            kappa: 8,
            dim: 2,
            points_per_exchange: 50,
            router_version: 0,
            generation: 0,
            shard_versions: vec![1, 2, 3],
        };
        assert!(Manifest::from_json(&m.to_json()).is_err());
    }

    #[test]
    fn write_atomic_replaces_and_leaves_no_tmp() {
        let dir = tmp_dir("atomic");
        write_atomic(&dir, "x.bin", b"old").unwrap();
        write_atomic(&dir, "x.bin", b"new").unwrap();
        assert_eq!(std::fs::read(dir.join("x.bin")).unwrap(), b"new");
        assert!(!dir.join("x.bin.tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn sweep_tmp_removes_only_tmp_files() {
        let dir = tmp_dir("sweep");
        write_atomic(&dir, "keep.state", b"real").unwrap();
        std::fs::write(dir.join("stale.state.tmp"), b"junk").unwrap();
        assert_eq!(sweep_tmp(&dir), 1);
        assert!(dir.join("keep.state").exists());
        assert!(!dir.join("stale.state.tmp").exists());
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
