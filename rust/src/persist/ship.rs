//! Checkpoint shipping: reading a state directory as one consistent,
//! generation-stamped bundle of raw file bytes, and adopting such a
//! bundle on the far side.
//!
//! This is the persistence half of leader/follower replication
//! (`serve`'s `FetchState` wire op): the leader snapshots its live state
//! dir into a [`StateBundle`] with [`read_bundle`], the bytes travel the
//! wire verbatim, and a follower turns them back into the structures a
//! read path serves from with [`decode_bundle`] — optionally mirroring
//! them to its own directory with [`write_bundle`], byte-identical, so a
//! follower restart (or a promotion) warm-starts like any other
//! `--state-dir` process.
//!
//! ## The consistent cut
//!
//! The state dir has one writer (the epoch's checkpointer, or an offline
//! rebalance) writing multiple files; a reader racing it could pair shard
//! files from one checkpoint with a manifest from another. Two mechanisms
//! make [`read_bundle`]'s snapshot consistent without any coordination
//! with the writer:
//!
//! 1. **Generation seqlock.** Every manifest write bumps
//!    [`Manifest::generation`]. `read_bundle` loads the manifest, reads
//!    every file, then re-loads the manifest: if the generation moved,
//!    the pass raced a writer and retries.
//! 2. **Decode validation.** The assembled bytes are decoded and
//!    cross-checked ([`super::restore::decode_state`]) before they are
//!    returned — the same partition-version checks that catch a torn
//!    rebalance on restart catch a mid-migration read here, and a failed
//!    check retries rather than erroring (the writer finishes in bounded
//!    time; every file write is individually atomic).
//!
//! Shard files can still be *newer* than the manifest of the same pass
//! (the checkpointer writes shards before the manifest); that skew is
//! harmless — a bundle's authority is its shard files, and the follower
//! resumes from their versions exactly as a local warm restart would.
//!
//! ## Tracing
//!
//! In the serving stack, the whole of [`read_bundle`] — seqlock retries
//! included — runs inside the leader's `state.cut` trace span (see
//! `serve`'s `fetch_state`), and bundle-to-wire assembly inside
//! `state.ship`. When a follower's `sync.cycle` trace shows a fat
//! `state.cut`, the leader's cut raced its checkpointer through
//! several `READ_ATTEMPTS` backoffs; a fat `state.ship` is payload
//! size ([`StateBundle::total_bytes`]).

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::manifest::{shard_file, write_atomic, Manifest, MANIFEST_FILE, ROUTER_FILE};
use super::restore::{decode_state, RestoredState};

/// How many racing read passes [`read_bundle`] attempts before giving
/// up. Each retry backs off briefly, so even a checkpoint-per-fold
/// writer yields a stable window within the budget.
const READ_ATTEMPTS: usize = 8;

/// One consistent snapshot of a state directory: the raw bytes of every
/// durable file, cut at a single checkpoint generation.
#[derive(Debug, Clone)]
pub struct StateBundle {
    /// The checkpoint generation the cut was taken at (the manifest's
    /// [`Manifest::generation`]).
    pub generation: u64,
    /// The parsed manifest of the cut (decoded from the bytes also
    /// present in `files` — kept so callers can read the deployment
    /// shape without re-parsing).
    pub manifest: Manifest,
    /// `(file name, raw bytes)` for every file of the directory:
    /// `manifest.json`, `router.bin`, and `shard-<s>.state` in shard
    /// order. Byte-identical to the files on disk, so a mirror written
    /// from this bundle restores exactly like the original.
    pub files: Vec<(String, Vec<u8>)>,
}

impl StateBundle {
    /// Total payload size of the cut — what a `state.ship` telemetry
    /// event reports.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|(_, bytes)| bytes.len() as u64).sum()
    }
}

/// Read `dir` as one consistent [`StateBundle`]. `Ok(None)` when the
/// directory holds no manifest yet (the leader is cold and has not
/// checkpointed — nothing to ship). Strictly read-only, like
/// [`super::load_state`]: safe against a live checkpointer.
pub fn read_bundle(dir: &Path) -> Result<Option<StateBundle>> {
    let mut last_err = None;
    for attempt in 0..READ_ATTEMPTS {
        if attempt > 0 {
            std::thread::sleep(Duration::from_millis(10 * attempt as u64));
        }
        let Some(m1) = Manifest::load(dir)? else {
            return Ok(None);
        };
        let read = |name: &str| -> Result<Vec<u8>> {
            let path = dir.join(name);
            std::fs::read(&path)
                .with_context(|| format!("reading {}", path.display()))
        };
        // Gather every file of the cut — the manifest as raw bytes too,
        // so the shipped bundle is byte-identical to the directory. A
        // read error here may just be the race (e.g. a shard file not
        // yet written after a shard count change) — treat it as
        // retryable like a failed validation.
        let gathered =
            (|| -> Result<(Vec<u8>, Vec<u8>, Vec<(String, Vec<u8>)>)> {
                let manifest_raw = read(MANIFEST_FILE)?;
                let router = read(ROUTER_FILE)?;
                let mut shards = Vec::with_capacity(m1.shards);
                for s in 0..m1.shards {
                    let name = shard_file(s);
                    let bytes = read(&name)?;
                    shards.push((name, bytes));
                }
                Ok((manifest_raw, router, shards))
            })();
        let (manifest_raw, router_bytes, shard_bytes) = match gathered {
            Ok(g) => g,
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        // The raw manifest bytes must belong to the same cut as `m1`
        // (the raw read may have landed after a racing writer's rename).
        let manifest = match parse_manifest_bytes(&manifest_raw) {
            Ok(m) => m,
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        if manifest.generation != m1.generation {
            last_err = Some(anyhow::anyhow!(
                "manifest advanced from generation {} to {} mid-read",
                m1.generation,
                manifest.generation
            ));
            continue;
        }
        // Seqlock check: a manifest write during the pass means the
        // files may span two checkpoints — retry.
        let Some(m2) = Manifest::load(dir)? else {
            last_err = Some(anyhow::anyhow!("manifest vanished mid-read"));
            continue;
        };
        if m2.generation != m1.generation {
            last_err = Some(anyhow::anyhow!(
                "state dir advanced from generation {} to {} mid-read",
                m1.generation,
                m2.generation
            ));
            continue;
        }
        // Full decode validation: the cut must restore. A failure here
        // is either a race with a multi-file writer (retry) or real
        // corruption (the final attempt surfaces it).
        match decode_state(
            manifest.clone(),
            ROUTER_FILE,
            &router_bytes,
            &shard_bytes,
        ) {
            Ok(_) => {
                let mut files = Vec::with_capacity(2 + shard_bytes.len());
                files.push((MANIFEST_FILE.to_string(), manifest_raw));
                files.push((ROUTER_FILE.to_string(), router_bytes));
                files.extend(shard_bytes);
                return Ok(Some(StateBundle {
                    generation: manifest.generation,
                    manifest,
                    files,
                }));
            }
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        }
    }
    Err(last_err.expect("READ_ATTEMPTS > 0 implies an error was recorded"))
        .with_context(|| {
            format!(
                "no consistent read of {} in {READ_ATTEMPTS} attempts \
                 (is a writer wedged mid-migration?)",
                dir.display()
            )
        })
}

/// Decode a shipped file set back into the structures a serving process
/// restores from, applying every cross-check a local restore applies.
/// The bundle must contain `manifest.json`, `router.bin`, and exactly
/// the `shard-<s>.state` files the manifest lists, in any order;
/// unknown names are rejected (a lying peer must not smuggle bytes into
/// a follower's mirror directory).
pub fn decode_bundle(files: &[(String, Vec<u8>)]) -> Result<RestoredState> {
    let mut manifest_bytes: Option<&Vec<u8>> = None;
    let mut router_bytes: Option<&Vec<u8>> = None;
    let mut shard_slots: Vec<Option<&Vec<u8>>> = Vec::new();
    // First pass just to find the manifest (it sizes the shard table).
    for (name, bytes) in files {
        if name == MANIFEST_FILE && manifest_bytes.replace(bytes).is_some() {
            bail!("bundle carries {MANIFEST_FILE} twice");
        }
    }
    let manifest_bytes = manifest_bytes
        .ok_or_else(|| anyhow::anyhow!("bundle carries no {MANIFEST_FILE}"))?;
    let manifest = parse_manifest_bytes(manifest_bytes)
        .context("bundled manifest")?;
    shard_slots.resize(manifest.shards, None);
    for (name, bytes) in files {
        if name == MANIFEST_FILE {
            continue;
        } else if name == ROUTER_FILE {
            if router_bytes.replace(bytes).is_some() {
                bail!("bundle carries {ROUTER_FILE} twice");
            }
        } else if let Some(s) = parse_shard_name(name, manifest.shards) {
            if shard_slots[s].replace(bytes).is_some() {
                bail!("bundle carries {name} twice");
            }
        } else {
            bail!("bundle carries unexpected file {name:?}");
        }
    }
    let router_bytes = router_bytes
        .ok_or_else(|| anyhow::anyhow!("bundle carries no {ROUTER_FILE}"))?;
    // Borrow the shard payloads straight out of the bundle — decoding
    // owns nothing it doesn't have to (a bundle can approach the frame
    // cap, and adoption runs on every new generation).
    let mut shard_bytes: Vec<(String, &Vec<u8>)> =
        Vec::with_capacity(manifest.shards);
    for (s, slot) in shard_slots.into_iter().enumerate() {
        let bytes = slot.ok_or_else(|| {
            anyhow::anyhow!("bundle carries no {}", shard_file(s))
        })?;
        shard_bytes.push((shard_file(s), bytes));
    }
    decode_state(manifest, ROUTER_FILE, router_bytes, &shard_bytes)
}

/// Parse manifest bytes (UTF-8 JSON) through exactly the validation
/// [`Manifest::load`] applies to the on-disk file.
fn parse_manifest_bytes(bytes: &[u8]) -> Result<Manifest> {
    let text =
        std::str::from_utf8(bytes).context("manifest bytes are not UTF-8")?;
    Manifest::from_json(
        &crate::util::Json::parse(text).context("parsing manifest bytes")?,
    )
    .context("validating manifest bytes")
}

/// `Some(s)` when `name` is the manifest-listed shard file `shard-s.state`
/// with `s < shards`.
fn parse_shard_name(name: &str, shards: usize) -> Option<usize> {
    let idx: usize = name
        .strip_prefix("shard-")?
        .strip_suffix(".state")?
        .parse()
        .ok()?;
    (idx < shards && shard_file(idx) == name).then_some(idx)
}

/// Mirror a shipped file set into `dir`, byte-identical, through the
/// atomic write protocol. The manifest lands **last**, so a follower
/// killed mid-mirror leaves either the previous complete image or a
/// directory whose manifest still describes it — never a manifest
/// pointing at half-adopted shard files. Callers validate with
/// [`decode_bundle`] first; this function only moves bytes.
pub fn write_bundle(dir: &Path, files: &[(String, Vec<u8>)]) -> Result<()> {
    for (name, bytes) in files.iter().filter(|(n, _)| n != MANIFEST_FILE) {
        write_atomic(dir, name, bytes)?;
    }
    for (name, bytes) in files.iter().filter(|(n, _)| n == MANIFEST_FILE) {
        write_atomic(dir, name, bytes)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::codec::{RouterState, ShardState};
    use crate::persist::load_state;
    use crate::vq::Codebook;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dalvq-ship-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn write_good_state(dir: &Path) {
        Manifest {
            format: crate::persist::FORMAT,
            shards: 2,
            kappa: 4,
            dim: 2,
            points_per_exchange: 50,
            router_version: 1,
            generation: 9,
            shard_versions: vec![5, 7],
        }
        .save(dir)
        .unwrap();
        let router = RouterState {
            version: 1,
            centroids: Codebook::from_flat(2, 2, vec![0.0, 0.0, 10.0, 10.0]),
        };
        write_atomic(dir, ROUTER_FILE, &router.encode()).unwrap();
        for (s, v) in [(0usize, 5u64), (1, 7)] {
            let state = ShardState {
                shard: s as u32,
                version: v,
                merges: v,
                rng_cursor: v * 50,
                ingested: 10 * v,
                shed: 0,
                router_version: 1,
                codebook: Codebook::from_flat(2, 2, vec![s as f32; 4]),
            };
            write_atomic(dir, &shard_file(s), &state.encode()).unwrap();
        }
    }

    #[test]
    fn bundle_roundtrips_byte_identically_through_a_mirror() {
        let src = tmp_dir("roundtrip-src");
        let dst = tmp_dir("roundtrip-dst");
        write_good_state(&src);
        let bundle = read_bundle(&src).unwrap().unwrap();
        assert_eq!(bundle.generation, 9);
        assert_eq!(bundle.manifest.shards, 2);
        assert_eq!(bundle.files.len(), 4); // manifest + router + 2 shards
        let expected: u64 =
            bundle.files.iter().map(|(_, b)| b.len() as u64).sum();
        assert!(expected > 0);
        assert_eq!(bundle.total_bytes(), expected);

        // the bundle decodes to the same state a local restore sees
        let shipped = decode_bundle(&bundle.files).unwrap();
        let local = load_state(&src).unwrap().unwrap();
        assert_eq!(shipped.manifest, local.manifest);
        assert_eq!(shipped.router, local.router);
        assert_eq!(shipped.shards, local.shards);

        // a mirror written from the bundle is byte-identical file by file
        write_bundle(&dst, &bundle.files).unwrap();
        for (name, bytes) in &bundle.files {
            assert_eq!(&std::fs::read(dst.join(name)).unwrap(), bytes, "{name}");
        }
        // and warm-restarts like the original
        let mirrored = load_state(&dst).unwrap().unwrap();
        assert_eq!(mirrored.shards, local.shards);
        std::fs::remove_dir_all(&src).unwrap();
        std::fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn cold_dir_ships_nothing() {
        let dir = tmp_dir("cold");
        assert!(read_bundle(&dir).unwrap().is_none());
    }

    #[test]
    fn torn_migration_never_yields_a_bundle() {
        // One shard file rewritten at a bumped partition version, router
        // and manifest still at the old one: every read pass fails the
        // decode validation, so read_bundle errors instead of shipping a
        // mix a follower would refuse (or worse, serve).
        let dir = tmp_dir("torn");
        write_good_state(&dir);
        let migrated = ShardState {
            shard: 0,
            version: 7,
            merges: 7,
            rng_cursor: 350,
            ingested: 0,
            shed: 0,
            router_version: 2, // manifest + router say 1
            codebook: Codebook::from_flat(2, 2, vec![9.0; 4]),
        };
        write_atomic(&dir, &shard_file(0), &migrated.encode()).unwrap();
        let err = format!("{:#}", read_bundle(&dir).unwrap_err());
        assert!(err.contains("no consistent read"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn decode_bundle_rejects_missing_extra_and_duplicate_files() {
        let dir = tmp_dir("reject");
        write_good_state(&dir);
        let bundle = read_bundle(&dir).unwrap().unwrap();

        // missing shard
        let missing: Vec<_> = bundle
            .files
            .iter()
            .filter(|(n, _)| n != "shard-1.state")
            .cloned()
            .collect();
        let err = format!("{:#}", decode_bundle(&missing).unwrap_err());
        assert!(err.contains("shard-1.state"), "{err}");

        // smuggled extra file
        let mut extra = bundle.files.clone();
        extra.push(("../escape".into(), b"junk".to_vec()));
        let err = format!("{:#}", decode_bundle(&extra).unwrap_err());
        assert!(err.contains("unexpected file"), "{err}");

        // duplicate router
        let mut dup = bundle.files.clone();
        dup.push((ROUTER_FILE.into(), bundle.files[1].1.clone()));
        let err = format!("{:#}", decode_bundle(&dup).unwrap_err());
        assert!(err.contains("twice"), "{err}");

        // no manifest at all
        let headless: Vec<_> = bundle
            .files
            .iter()
            .filter(|(n, _)| n != MANIFEST_FILE)
            .cloned()
            .collect();
        assert!(decode_bundle(&headless).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn shard_names_parse_strictly() {
        assert_eq!(parse_shard_name("shard-0.state", 2), Some(0));
        assert_eq!(parse_shard_name("shard-1.state", 2), Some(1));
        assert_eq!(parse_shard_name("shard-2.state", 2), None); // out of range
        assert_eq!(parse_shard_name("shard-01.state", 2), None); // not canonical
        assert_eq!(parse_shard_name("shard-x.state", 2), None);
        assert_eq!(parse_shard_name("router.bin", 2), None);
    }
}
