//! Checkpoint shipping: reading a state directory as one consistent,
//! generation-stamped bundle of raw file bytes, and adopting such a
//! bundle on the far side.
//!
//! This is the persistence half of leader/follower replication
//! (`serve`'s `FetchState` wire op): the leader snapshots its live state
//! dir into a [`StateBundle`] with [`read_bundle`], the bytes travel the
//! wire verbatim, and a follower turns them back into the structures a
//! read path serves from with [`decode_bundle`] — optionally mirroring
//! them to its own directory with [`write_bundle`], byte-identical, so a
//! follower restart (or a promotion) warm-starts like any other
//! `--state-dir` process.
//!
//! ## The consistent cut
//!
//! The state dir has one writer (the epoch's checkpointer, or an offline
//! rebalance) writing multiple files; a reader racing it could pair shard
//! files from one checkpoint with a manifest from another. Two mechanisms
//! make [`read_bundle`]'s snapshot consistent without any coordination
//! with the writer:
//!
//! 1. **Generation seqlock.** Every manifest write bumps
//!    [`Manifest::generation`]. `read_bundle` loads the manifest, reads
//!    every file, then re-loads the manifest: if the generation moved,
//!    the pass raced a writer and retries.
//! 2. **Decode validation.** The assembled bytes are decoded and
//!    cross-checked ([`super::restore::decode_state`]) before they are
//!    returned — the same partition-version checks that catch a torn
//!    rebalance on restart catch a mid-migration read here, and a failed
//!    check retries rather than erroring (the writer finishes in bounded
//!    time; every file write is individually atomic).
//!
//! Shard files can still be *newer* than the manifest of the same pass
//! (the checkpointer writes shards before the manifest); that skew is
//! harmless — a bundle's authority is its shard files, and the follower
//! resumes from their versions exactly as a local warm restart would.
//!
//! ## Deltas and chunks
//!
//! Replication v2 ships *less* and ships it in *pieces*. A shipper that
//! knows what cut the requester already holds can send only the files
//! that changed ([`delta_files`]); the receiver merges them over its
//! held set with [`apply_delta`], which reproduces the full bundle
//! byte-for-byte (property-tested below). Independently, a file set of
//! any size can be split into bounded chunks ([`chunk_files`]) and
//! reassembled ([`reassemble_chunks`]) with strict contiguity checks,
//! so a shipment never has to fit one wire frame.
//!
//! ## Tracing
//!
//! In the serving stack, the whole of [`read_bundle`] — seqlock retries
//! included — runs inside the leader's `state.cut` trace span (see
//! `serve`'s `fetch_state`), and bundle-to-wire assembly inside
//! `state.ship`. When a follower's `sync.cycle` trace shows a fat
//! `state.cut`, the leader's cut raced its checkpointer through
//! several `READ_ATTEMPTS` backoffs; a fat `state.ship` is payload
//! size ([`StateBundle::total_bytes`]).

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use super::manifest::{shard_file, write_atomic, Manifest, MANIFEST_FILE, ROUTER_FILE};
use super::restore::{decode_state, RestoredState};

/// How many racing read passes [`read_bundle`] attempts before giving
/// up. Each retry backs off briefly, so even a checkpoint-per-fold
/// writer yields a stable window within the budget.
const READ_ATTEMPTS: usize = 8;

/// One consistent snapshot of a state directory: the raw bytes of every
/// durable file, cut at a single checkpoint generation.
#[derive(Debug, Clone)]
pub struct StateBundle {
    /// The checkpoint generation the cut was taken at (the manifest's
    /// [`Manifest::generation`]).
    pub generation: u64,
    /// The parsed manifest of the cut (decoded from the bytes also
    /// present in `files` — kept so callers can read the deployment
    /// shape without re-parsing).
    pub manifest: Manifest,
    /// `(file name, raw bytes)` for every file of the directory:
    /// `manifest.json`, `router.bin`, and `shard-<s>.state` in shard
    /// order. Byte-identical to the files on disk, so a mirror written
    /// from this bundle restores exactly like the original.
    pub files: Vec<(String, Vec<u8>)>,
}

impl StateBundle {
    /// Total payload size of the cut — what a `state.ship` telemetry
    /// event reports.
    pub fn total_bytes(&self) -> u64 {
        self.files.iter().map(|(_, bytes)| bytes.len() as u64).sum()
    }
}

/// Read `dir` as one consistent [`StateBundle`]. `Ok(None)` when the
/// directory holds no manifest yet (the leader is cold and has not
/// checkpointed — nothing to ship). Strictly read-only, like
/// [`super::load_state`]: safe against a live checkpointer.
pub fn read_bundle(dir: &Path) -> Result<Option<StateBundle>> {
    let mut last_err = None;
    for attempt in 0..READ_ATTEMPTS {
        if attempt > 0 {
            std::thread::sleep(Duration::from_millis(10 * attempt as u64));
        }
        let Some(m1) = Manifest::load(dir)? else {
            return Ok(None);
        };
        let read = |name: &str| -> Result<Vec<u8>> {
            let path = dir.join(name);
            std::fs::read(&path)
                .with_context(|| format!("reading {}", path.display()))
        };
        // Gather every file of the cut — the manifest as raw bytes too,
        // so the shipped bundle is byte-identical to the directory. A
        // read error here may just be the race (e.g. a shard file not
        // yet written after a shard count change) — treat it as
        // retryable like a failed validation.
        let gathered =
            (|| -> Result<(Vec<u8>, Vec<u8>, Vec<(String, Vec<u8>)>)> {
                let manifest_raw = read(MANIFEST_FILE)?;
                let router = read(ROUTER_FILE)?;
                let mut shards = Vec::with_capacity(m1.shards);
                for s in 0..m1.shards {
                    let name = shard_file(s);
                    let bytes = read(&name)?;
                    shards.push((name, bytes));
                }
                Ok((manifest_raw, router, shards))
            })();
        let (manifest_raw, router_bytes, shard_bytes) = match gathered {
            Ok(g) => g,
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        // The raw manifest bytes must belong to the same cut as `m1`
        // (the raw read may have landed after a racing writer's rename).
        let manifest = match parse_manifest_bytes(&manifest_raw) {
            Ok(m) => m,
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        };
        if manifest.generation != m1.generation {
            last_err = Some(anyhow::anyhow!(
                "manifest advanced from generation {} to {} mid-read",
                m1.generation,
                manifest.generation
            ));
            continue;
        }
        // Seqlock check: a manifest write during the pass means the
        // files may span two checkpoints — retry.
        let Some(m2) = Manifest::load(dir)? else {
            last_err = Some(anyhow::anyhow!("manifest vanished mid-read"));
            continue;
        };
        if m2.generation != m1.generation {
            last_err = Some(anyhow::anyhow!(
                "state dir advanced from generation {} to {} mid-read",
                m1.generation,
                m2.generation
            ));
            continue;
        }
        // Full decode validation: the cut must restore. A failure here
        // is either a race with a multi-file writer (retry) or real
        // corruption (the final attempt surfaces it).
        match decode_state(
            manifest.clone(),
            ROUTER_FILE,
            &router_bytes,
            &shard_bytes,
        ) {
            Ok(_) => {
                let mut files = Vec::with_capacity(2 + shard_bytes.len());
                files.push((MANIFEST_FILE.to_string(), manifest_raw));
                files.push((ROUTER_FILE.to_string(), router_bytes));
                files.extend(shard_bytes);
                return Ok(Some(StateBundle {
                    generation: manifest.generation,
                    manifest,
                    files,
                }));
            }
            Err(e) => {
                last_err = Some(e);
                continue;
            }
        }
    }
    Err(last_err.expect("READ_ATTEMPTS > 0 implies an error was recorded"))
        .with_context(|| {
            format!(
                "no consistent read of {} in {READ_ATTEMPTS} attempts \
                 (is a writer wedged mid-migration?)",
                dir.display()
            )
        })
}

/// Decode a shipped file set back into the structures a serving process
/// restores from, applying every cross-check a local restore applies.
/// The bundle must contain `manifest.json`, `router.bin`, and exactly
/// the `shard-<s>.state` files the manifest lists, in any order;
/// unknown names are rejected (a lying peer must not smuggle bytes into
/// a follower's mirror directory).
pub fn decode_bundle(files: &[(String, Vec<u8>)]) -> Result<RestoredState> {
    let mut manifest_bytes: Option<&Vec<u8>> = None;
    let mut router_bytes: Option<&Vec<u8>> = None;
    let mut shard_slots: Vec<Option<&Vec<u8>>> = Vec::new();
    // First pass just to find the manifest (it sizes the shard table).
    for (name, bytes) in files {
        if name == MANIFEST_FILE && manifest_bytes.replace(bytes).is_some() {
            bail!("bundle carries {MANIFEST_FILE} twice");
        }
    }
    let manifest_bytes = manifest_bytes
        .ok_or_else(|| anyhow::anyhow!("bundle carries no {MANIFEST_FILE}"))?;
    let manifest = parse_manifest_bytes(manifest_bytes)
        .context("bundled manifest")?;
    shard_slots.resize(manifest.shards, None);
    for (name, bytes) in files {
        if name == MANIFEST_FILE {
            continue;
        } else if name == ROUTER_FILE {
            if router_bytes.replace(bytes).is_some() {
                bail!("bundle carries {ROUTER_FILE} twice");
            }
        } else if let Some(s) = parse_shard_name(name, manifest.shards) {
            if shard_slots[s].replace(bytes).is_some() {
                bail!("bundle carries {name} twice");
            }
        } else {
            bail!("bundle carries unexpected file {name:?}");
        }
    }
    let router_bytes = router_bytes
        .ok_or_else(|| anyhow::anyhow!("bundle carries no {ROUTER_FILE}"))?;
    // Borrow the shard payloads straight out of the bundle — decoding
    // owns nothing it doesn't have to (a bundle can approach the frame
    // cap, and adoption runs on every new generation).
    let mut shard_bytes: Vec<(String, &Vec<u8>)> =
        Vec::with_capacity(manifest.shards);
    for (s, slot) in shard_slots.into_iter().enumerate() {
        let bytes = slot.ok_or_else(|| {
            anyhow::anyhow!("bundle carries no {}", shard_file(s))
        })?;
        shard_bytes.push((shard_file(s), bytes));
    }
    decode_state(manifest, ROUTER_FILE, router_bytes, &shard_bytes)
}

/// Parse manifest bytes (UTF-8 JSON) through exactly the validation
/// [`Manifest::load`] applies to the on-disk file.
fn parse_manifest_bytes(bytes: &[u8]) -> Result<Manifest> {
    let text =
        std::str::from_utf8(bytes).context("manifest bytes are not UTF-8")?;
    Manifest::from_json(
        &crate::util::Json::parse(text).context("parsing manifest bytes")?,
    )
    .context("validating manifest bytes")
}

/// `Some(s)` when `name` is the manifest-listed shard file `shard-s.state`
/// with `s < shards`.
fn parse_shard_name(name: &str, shards: usize) -> Option<usize> {
    let idx: usize = name
        .strip_prefix("shard-")?
        .strip_suffix(".state")?
        .parse()
        .ok()?;
    (idx < shards && shard_file(idx) == name).then_some(idx)
}

/// Mirror a shipped file set into `dir`, byte-identical, through the
/// atomic write protocol. The manifest lands **last**, so a follower
/// killed mid-mirror leaves either the previous complete image or a
/// directory whose manifest still describes it — never a manifest
/// pointing at half-adopted shard files. Callers validate with
/// [`decode_bundle`] first; this function only moves bytes.
pub fn write_bundle(dir: &Path, files: &[(String, Vec<u8>)]) -> Result<()> {
    for (name, bytes) in files.iter().filter(|(n, _)| n != MANIFEST_FILE) {
        write_atomic(dir, name, bytes)?;
    }
    for (name, bytes) in files.iter().filter(|(n, _)| n == MANIFEST_FILE) {
        write_atomic(dir, name, bytes)?;
    }
    Ok(())
}

/// The subset of `bundle` a requester already holding a consistent cut
/// at `have_router_version` / `have_shard_versions` still needs: the
/// manifest (every shipment names its cut) plus exactly the shard
/// files whose version advanced. `None` when no delta is expressible —
/// the router epoch or the shard count changed, so the full bundle
/// must ship (the shipper falls back rather than guessing).
pub fn delta_files(
    bundle: &StateBundle,
    have_router_version: u64,
    have_shard_versions: &[u64],
) -> Option<Vec<(String, Vec<u8>)>> {
    let m = &bundle.manifest;
    if m.router_version != have_router_version
        || m.shard_versions.len() != have_shard_versions.len()
    {
        return None;
    }
    let mut out = Vec::new();
    for (name, bytes) in &bundle.files {
        let keep = if name == MANIFEST_FILE {
            true
        } else if name == ROUTER_FILE {
            // Same router version ⇒ byte-identical router file (the
            // router is only rewritten on an epoch bump).
            false
        } else if let Some(s) = parse_shard_name(name, m.shards) {
            m.shard_versions[s] != have_shard_versions[s]
        } else {
            return None;
        };
        if keep {
            out.push((name.clone(), bytes.clone()));
        }
    }
    Some(out)
}

/// Merge a delta shipment over the file set of the cut the receiver
/// already holds, reproducing the shipper's full bundle byte-for-byte
/// in canonical order (manifest, router, shards). The delta must carry
/// a manifest; names outside the manifest's file set are rejected in
/// both inputs — a lying peer must not smuggle bytes through the merge
/// any more than through [`decode_bundle`]. Callers still validate the
/// merged set with `decode_bundle` before adopting it.
pub fn apply_delta(
    held: &[(String, Vec<u8>)],
    delta: &[(String, Vec<u8>)],
) -> Result<Vec<(String, Vec<u8>)>> {
    let mut manifest_bytes: Option<&Vec<u8>> = None;
    for (name, bytes) in delta {
        if name == MANIFEST_FILE && manifest_bytes.replace(bytes).is_some() {
            bail!("delta carries {MANIFEST_FILE} twice");
        }
    }
    let manifest_bytes = manifest_bytes.ok_or_else(|| {
        anyhow::anyhow!(
            "delta carries no {MANIFEST_FILE} (every shipment names its cut)"
        )
    })?;
    let manifest =
        parse_manifest_bytes(manifest_bytes).context("delta manifest")?;
    let mut router_slot: Option<Vec<u8>> = None;
    let mut shard_slots: Vec<Option<Vec<u8>>> = vec![None; manifest.shards];
    // Later sources overwrite earlier ones by name; duplicates *within*
    // one source are a protocol violation.
    let mut merge = |files: &[(String, Vec<u8>)],
                     source: &str,
                     router_slot: &mut Option<Vec<u8>>,
                     shard_slots: &mut Vec<Option<Vec<u8>>>|
     -> Result<()> {
        let mut seen = std::collections::HashSet::new();
        for (name, bytes) in files {
            if !seen.insert(name.as_str()) {
                bail!("{source} carries {name:?} twice");
            }
            if name == MANIFEST_FILE {
                // The merged manifest is always the delta's.
            } else if name == ROUTER_FILE {
                *router_slot = Some(bytes.clone());
            } else if let Some(s) = parse_shard_name(name, manifest.shards) {
                shard_slots[s] = Some(bytes.clone());
            } else {
                bail!("{source} carries unexpected file {name:?}");
            }
        }
        Ok(())
    };
    merge(held, "held state", &mut router_slot, &mut shard_slots)?;
    merge(delta, "delta", &mut router_slot, &mut shard_slots)?;
    let mut out = Vec::with_capacity(2 + manifest.shards);
    out.push((MANIFEST_FILE.to_string(), manifest_bytes.clone()));
    out.push((
        ROUTER_FILE.to_string(),
        router_slot.ok_or_else(|| {
            anyhow::anyhow!(
                "neither held state nor delta carries {ROUTER_FILE}"
            )
        })?,
    ));
    for (s, slot) in shard_slots.into_iter().enumerate() {
        out.push((
            shard_file(s),
            slot.ok_or_else(|| {
                anyhow::anyhow!(
                    "neither held state nor delta carries {}",
                    shard_file(s)
                )
            })?,
        ));
    }
    Ok(out)
}

/// One piece of one file in a chunked shipment: `bytes` is the
/// `[offset, offset + bytes.len())` range of a file whose complete
/// length is `file_len`. A zero-length file ships as a single empty
/// part (its name must still travel).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FilePart {
    pub name: String,
    pub offset: u64,
    pub file_len: u64,
    pub bytes: Vec<u8>,
}

/// Split a file set into chunks whose *payload* (file bytes, not
/// framing) stays within `max_bytes` each, splitting large files
/// across chunks by byte range. Deterministic: the same input and
/// budget always yield the same chunks, so a requester can fetch chunk
/// `k` of a cut it started on and get the same bytes. Returns no
/// chunks for an empty file set.
pub fn chunk_files(
    files: &[(String, Vec<u8>)],
    max_bytes: usize,
) -> Vec<Vec<FilePart>> {
    let budget = max_bytes.max(1);
    let mut chunks: Vec<Vec<FilePart>> = Vec::new();
    let mut cur: Vec<FilePart> = Vec::new();
    let mut cur_bytes = 0usize;
    for (name, bytes) in files {
        let mut offset = 0usize;
        loop {
            let room = budget - cur_bytes;
            let rest = bytes.len() - offset;
            if rest > 0 && room == 0 {
                chunks.push(std::mem::take(&mut cur));
                cur_bytes = 0;
                continue;
            }
            let take = rest.min(room);
            cur.push(FilePart {
                name: name.clone(),
                offset: offset as u64,
                file_len: bytes.len() as u64,
                bytes: bytes[offset..offset + take].to_vec(),
            });
            cur_bytes += take;
            offset += take;
            if offset == bytes.len() {
                break;
            }
        }
    }
    if !cur.is_empty() {
        chunks.push(cur);
    }
    chunks
}

/// Reassemble the parts of a chunked shipment back into whole files,
/// in first-appearance order. Strict: for every named file the parts
/// must agree on its length, tile it contiguously from offset zero
/// with no gap, overlap, or spill past the end, and a zero-length file
/// must arrive as exactly one empty part — so adversarial reordering,
/// truncation, or duplication of parts is an error, never silent
/// corruption. (A *whole missing* zero-length or never-mentioned file
/// is invisible here; [`decode_bundle`] catches absent files.)
pub fn reassemble_chunks(parts: &[FilePart]) -> Result<Vec<(String, Vec<u8>)>> {
    let mut order: Vec<&str> = Vec::new();
    let mut groups: std::collections::HashMap<&str, Vec<&FilePart>> =
        std::collections::HashMap::new();
    for part in parts {
        groups
            .entry(part.name.as_str())
            .or_insert_with(|| {
                order.push(part.name.as_str());
                Vec::new()
            })
            .push(part);
    }
    let mut out = Vec::with_capacity(order.len());
    for name in order {
        let mut group = groups.remove(name).expect("grouped above");
        let file_len = group[0].file_len;
        if group.iter().any(|p| p.file_len != file_len) {
            bail!("parts of {name:?} disagree on its length");
        }
        if file_len == 0 {
            if group.len() != 1 || !group[0].bytes.is_empty() {
                bail!("zero-length {name:?} must ship as one empty part");
            }
            out.push((name.to_string(), Vec::new()));
            continue;
        }
        group.sort_by_key(|p| p.offset);
        let mut bytes = Vec::with_capacity(file_len as usize);
        for part in &group {
            if part.bytes.is_empty() {
                bail!("empty part of non-empty {name:?}");
            }
            let covered = bytes.len() as u64;
            if part.offset < covered {
                bail!(
                    "parts of {name:?} overlap at offset {}",
                    part.offset
                );
            }
            if part.offset > covered {
                bail!(
                    "parts of {name:?} leave a gap at offset {covered}"
                );
            }
            if part.offset + part.bytes.len() as u64 > file_len {
                bail!("part of {name:?} runs past its declared length");
            }
            bytes.extend_from_slice(&part.bytes);
        }
        if bytes.len() as u64 != file_len {
            bail!(
                "{name:?} truncated: {} of {file_len} bytes arrived",
                bytes.len()
            );
        }
        out.push((name.to_string(), bytes));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::codec::{RouterState, ShardState};
    use crate::persist::load_state;
    use crate::vq::Codebook;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dalvq-ship-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn write_good_state(dir: &Path) {
        Manifest {
            format: crate::persist::FORMAT,
            shards: 2,
            kappa: 4,
            dim: 2,
            points_per_exchange: 50,
            router_version: 1,
            generation: 9,
            shard_versions: vec![5, 7],
        }
        .save(dir)
        .unwrap();
        let router = RouterState {
            version: 1,
            centroids: Codebook::from_flat(2, 2, vec![0.0, 0.0, 10.0, 10.0]),
        };
        write_atomic(dir, ROUTER_FILE, &router.encode()).unwrap();
        for (s, v) in [(0usize, 5u64), (1, 7)] {
            let state = ShardState {
                shard: s as u32,
                version: v,
                merges: v,
                rng_cursor: v * 50,
                ingested: 10 * v,
                shed: 0,
                router_version: 1,
                codebook: Codebook::from_flat(2, 2, vec![s as f32; 4]),
            };
            write_atomic(dir, &shard_file(s), &state.encode()).unwrap();
        }
    }

    #[test]
    fn bundle_roundtrips_byte_identically_through_a_mirror() {
        let src = tmp_dir("roundtrip-src");
        let dst = tmp_dir("roundtrip-dst");
        write_good_state(&src);
        let bundle = read_bundle(&src).unwrap().unwrap();
        assert_eq!(bundle.generation, 9);
        assert_eq!(bundle.manifest.shards, 2);
        assert_eq!(bundle.files.len(), 4); // manifest + router + 2 shards
        let expected: u64 =
            bundle.files.iter().map(|(_, b)| b.len() as u64).sum();
        assert!(expected > 0);
        assert_eq!(bundle.total_bytes(), expected);

        // the bundle decodes to the same state a local restore sees
        let shipped = decode_bundle(&bundle.files).unwrap();
        let local = load_state(&src).unwrap().unwrap();
        assert_eq!(shipped.manifest, local.manifest);
        assert_eq!(shipped.router, local.router);
        assert_eq!(shipped.shards, local.shards);

        // a mirror written from the bundle is byte-identical file by file
        write_bundle(&dst, &bundle.files).unwrap();
        for (name, bytes) in &bundle.files {
            assert_eq!(&std::fs::read(dst.join(name)).unwrap(), bytes, "{name}");
        }
        // and warm-restarts like the original
        let mirrored = load_state(&dst).unwrap().unwrap();
        assert_eq!(mirrored.shards, local.shards);
        std::fs::remove_dir_all(&src).unwrap();
        std::fs::remove_dir_all(&dst).unwrap();
    }

    #[test]
    fn cold_dir_ships_nothing() {
        let dir = tmp_dir("cold");
        assert!(read_bundle(&dir).unwrap().is_none());
    }

    #[test]
    fn torn_migration_never_yields_a_bundle() {
        // One shard file rewritten at a bumped partition version, router
        // and manifest still at the old one: every read pass fails the
        // decode validation, so read_bundle errors instead of shipping a
        // mix a follower would refuse (or worse, serve).
        let dir = tmp_dir("torn");
        write_good_state(&dir);
        let migrated = ShardState {
            shard: 0,
            version: 7,
            merges: 7,
            rng_cursor: 350,
            ingested: 0,
            shed: 0,
            router_version: 2, // manifest + router say 1
            codebook: Codebook::from_flat(2, 2, vec![9.0; 4]),
        };
        write_atomic(&dir, &shard_file(0), &migrated.encode()).unwrap();
        let err = format!("{:#}", read_bundle(&dir).unwrap_err());
        assert!(err.contains("no consistent read"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn decode_bundle_rejects_missing_extra_and_duplicate_files() {
        let dir = tmp_dir("reject");
        write_good_state(&dir);
        let bundle = read_bundle(&dir).unwrap().unwrap();

        // missing shard
        let missing: Vec<_> = bundle
            .files
            .iter()
            .filter(|(n, _)| n != "shard-1.state")
            .cloned()
            .collect();
        let err = format!("{:#}", decode_bundle(&missing).unwrap_err());
        assert!(err.contains("shard-1.state"), "{err}");

        // smuggled extra file
        let mut extra = bundle.files.clone();
        extra.push(("../escape".into(), b"junk".to_vec()));
        let err = format!("{:#}", decode_bundle(&extra).unwrap_err());
        assert!(err.contains("unexpected file"), "{err}");

        // duplicate router
        let mut dup = bundle.files.clone();
        dup.push((ROUTER_FILE.into(), bundle.files[1].1.clone()));
        let err = format!("{:#}", decode_bundle(&dup).unwrap_err());
        assert!(err.contains("twice"), "{err}");

        // no manifest at all
        let headless: Vec<_> = bundle
            .files
            .iter()
            .filter(|(n, _)| n != MANIFEST_FILE)
            .cloned()
            .collect();
        assert!(decode_bundle(&headless).is_err());
        std::fs::remove_dir_all(&dir).unwrap();
    }

    /// Deterministic xorshift64* for property rounds — no external
    /// crates, reproducible failures.
    fn xorshift(state: &mut u64) -> u64 {
        let mut x = *state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        *state = x;
        x.wrapping_mul(0x2545F4914F6CDD1D)
    }

    /// Like `write_good_state` but parameterized: one shard per entry
    /// of `versions`, codebook bytes salted by the version so a shard
    /// file actually changes when its version does.
    fn write_state_at(dir: &Path, router_version: u64, versions: &[u64]) {
        let shards = versions.len();
        let dim = 2usize;
        Manifest {
            format: crate::persist::FORMAT,
            shards,
            kappa: 2 * shards,
            dim,
            points_per_exchange: 50,
            router_version,
            generation: versions.iter().sum::<u64>() + 10 * router_version,
            shard_versions: versions.to_vec(),
        }
        .save(dir)
        .unwrap();
        let centroids: Vec<f32> =
            (0..shards * dim).map(|i| i as f32 * 10.0).collect();
        let router = RouterState {
            version: router_version,
            centroids: Codebook::from_flat(shards, dim, centroids),
        };
        write_atomic(dir, ROUTER_FILE, &router.encode()).unwrap();
        for (s, &v) in versions.iter().enumerate() {
            let state = ShardState {
                shard: s as u32,
                version: v,
                merges: v,
                rng_cursor: v * 50,
                ingested: v,
                shed: 0,
                router_version,
                codebook: Codebook::from_flat(
                    2,
                    dim,
                    vec![s as f32 + v as f32 * 0.25; 2 * dim],
                ),
            };
            write_atomic(dir, &shard_file(s), &state.encode()).unwrap();
        }
    }

    #[test]
    fn delta_applied_to_held_equals_the_full_bundle_byte_for_byte() {
        let dir = tmp_dir("delta-prop");
        let mut rng = 0x9E3779B97F4A7C15u64;
        for round in 0..20 {
            let shards = 2 + (xorshift(&mut rng) % 3) as usize;
            let have: Vec<u64> =
                (0..shards).map(|_| 1 + xorshift(&mut rng) % 8).collect();
            let want: Vec<u64> = have
                .iter()
                .map(|&v| v + xorshift(&mut rng) % 4)
                .collect();
            let _ = std::fs::remove_dir_all(&dir);
            write_state_at(&dir, 3, &have);
            let held = read_bundle(&dir).unwrap().unwrap();
            write_state_at(&dir, 3, &want);
            let full = read_bundle(&dir).unwrap().unwrap();
            let delta = delta_files(
                &full,
                held.manifest.router_version,
                &held.manifest.shard_versions,
            )
            .expect("same router version and shard count must delta");
            let changed =
                want.iter().zip(&have).filter(|(w, h)| w != h).count();
            assert_eq!(
                delta.len(),
                1 + changed,
                "round {round}: manifest + advanced shards only"
            );
            let merged = apply_delta(&held.files, &delta).unwrap();
            assert_eq!(merged, full.files, "round {round}");
            decode_bundle(&merged).unwrap();
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn router_or_shape_changes_force_a_full_bundle() {
        let dir = tmp_dir("delta-full");
        write_state_at(&dir, 3, &[4, 6]);
        let bundle = read_bundle(&dir).unwrap().unwrap();
        // router epoch moved ⇒ no delta
        assert!(delta_files(&bundle, 2, &[4, 6]).is_none());
        // shard count changed ⇒ no delta
        assert!(delta_files(&bundle, 3, &[4, 6, 1]).is_none());
        // nothing advanced ⇒ manifest-only delta
        let same = delta_files(&bundle, 3, &[4, 6]).unwrap();
        assert_eq!(same.len(), 1);
        assert_eq!(same[0].0, MANIFEST_FILE);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn apply_delta_rejects_smuggled_duplicate_and_headless_deltas() {
        let dir = tmp_dir("delta-hygiene");
        write_state_at(&dir, 3, &[4, 6]);
        let held = read_bundle(&dir).unwrap().unwrap();
        write_state_at(&dir, 3, &[5, 6]);
        let full = read_bundle(&dir).unwrap().unwrap();
        let delta = delta_files(&full, 3, &[4, 6]).unwrap();

        // a delta without a manifest names no cut
        let headless: Vec<_> = delta
            .iter()
            .filter(|(n, _)| n != MANIFEST_FILE)
            .cloned()
            .collect();
        let err =
            format!("{:#}", apply_delta(&held.files, &headless).unwrap_err());
        assert!(err.contains(MANIFEST_FILE), "{err}");

        // smuggled names are rejected in either input
        let mut smuggled = delta.clone();
        smuggled.push(("../escape".into(), b"junk".to_vec()));
        let err =
            format!("{:#}", apply_delta(&held.files, &smuggled).unwrap_err());
        assert!(err.contains("unexpected file"), "{err}");
        let mut bad_held = held.files.clone();
        bad_held.push(("shard-9.state".into(), b"junk".to_vec()));
        let err = format!("{:#}", apply_delta(&bad_held, &delta).unwrap_err());
        assert!(err.contains("unexpected file"), "{err}");

        // duplicates within one source are rejected
        let mut dup = delta.clone();
        dup.push(delta[1].clone());
        let err = format!("{:#}", apply_delta(&held.files, &dup).unwrap_err());
        assert!(err.contains("twice"), "{err}");

        // a delta over nothing must still be complete
        let err = format!("{:#}", apply_delta(&[], &delta).unwrap_err());
        assert!(err.contains("neither held state nor delta"), "{err}");
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn chunks_reassemble_under_adversarial_ordering() {
        let mut rng = 0xDEADBEEFCAFEF00Du64;
        for round in 0..30 {
            let nfiles = 1 + (xorshift(&mut rng) % 4) as usize;
            let files: Vec<(String, Vec<u8>)> = (0..nfiles)
                .map(|i| {
                    let len = (xorshift(&mut rng) % 40) as usize;
                    (
                        format!("f{i}"),
                        (0..len).map(|_| xorshift(&mut rng) as u8).collect(),
                    )
                })
                .collect();
            for budget in [1usize, 3, 7, 64, 1 << 20] {
                let chunks = chunk_files(&files, budget);
                for chunk in &chunks {
                    let payload: usize =
                        chunk.iter().map(|p| p.bytes.len()).sum();
                    assert!(payload <= budget, "round {round}");
                }
                let mut parts: Vec<FilePart> =
                    chunks.into_iter().flatten().collect();
                // deterministic Fisher-Yates shuffle: reassembly must
                // not depend on arrival order
                for i in (1..parts.len()).rev() {
                    let j = (xorshift(&mut rng) % (i as u64 + 1)) as usize;
                    parts.swap(i, j);
                }
                let mut got = reassemble_chunks(&parts).unwrap();
                got.sort();
                let mut want = files.clone();
                want.sort();
                assert_eq!(got, want, "round {round} budget {budget}");
            }
        }
    }

    #[test]
    fn reassembly_rejects_truncation_duplicates_and_lies() {
        let files = vec![
            ("a".to_string(), vec![1u8; 10]),
            ("b".to_string(), Vec::new()),
            ("c".to_string(), vec![7u8; 5]),
        ];
        let parts: Vec<FilePart> =
            chunk_files(&files, 4).into_iter().flatten().collect();
        assert!(parts.len() > 4);
        assert_eq!(reassemble_chunks(&parts).unwrap(), files);

        for i in 0..parts.len() {
            // dropping a part of a non-empty file is a detected
            // truncation; a dropped zero-length part just omits the
            // file (decode_bundle catches wholly absent files)
            let mut cut = parts.clone();
            let dropped = cut.remove(i);
            match reassemble_chunks(&cut) {
                Ok(got) => {
                    assert_eq!(dropped.file_len, 0, "part {i}");
                    assert!(got.iter().all(|(n, _)| *n != dropped.name));
                }
                Err(_) => assert!(dropped.file_len > 0, "part {i}"),
            }
            // duplicating any part is always rejected
            let mut dup = parts.clone();
            dup.push(parts[i].clone());
            assert!(
                reassemble_chunks(&dup).is_err(),
                "duplicated part {i}"
            );
        }

        // a part lying about its file's length
        let mut lies = parts.clone();
        lies[0].file_len += 1;
        assert!(reassemble_chunks(&lies).is_err());
        // a part claiming bytes past the declared end
        let mut past = parts.clone();
        let last = past
            .iter_mut()
            .filter(|p| p.name == "a")
            .next_back()
            .unwrap();
        last.bytes.push(0);
        assert!(reassemble_chunks(&past).is_err());
        // an empty part of a non-empty file
        let mut hollow = parts.clone();
        hollow.push(FilePart {
            name: "a".into(),
            offset: 10,
            file_len: 10,
            bytes: Vec::new(),
        });
        assert!(reassemble_chunks(&hollow).is_err());
    }

    #[test]
    fn shard_names_parse_strictly() {
        assert_eq!(parse_shard_name("shard-0.state", 2), Some(0));
        assert_eq!(parse_shard_name("shard-1.state", 2), Some(1));
        assert_eq!(parse_shard_name("shard-2.state", 2), None); // out of range
        assert_eq!(parse_shard_name("shard-01.state", 2), None); // not canonical
        assert_eq!(parse_shard_name("shard-x.state", 2), None);
        assert_eq!(parse_shard_name("router.bin", 2), None);
    }
}
