//! The background checkpointer: one thread that drains shard epochs to
//! disk without ever blocking the read or fold paths.
//!
//! The thread polls each shard's published version (a lock-free atomic
//! read) and, whenever a shard has advanced `checkpoint_every` folds past
//! its last checkpoint, clones the shard's current `Arc<Snapshot>` (O(1)
//! — the epoch-swap design means a checkpoint shares the codebook with
//! in-flight queries instead of copying it under a lock) and writes it
//! through the atomic temp+fsync+rename protocol. Reducers and readers
//! never wait on the disk: a slow volume only makes checkpoints less
//! frequent, exactly the paper's slow-blob-storage tolerance.
//!
//! A `flush` request (the protocol's `Checkpoint` op, and shutdown)
//! synchronously checkpoints every shard that has advanced at all and
//! acks with the per-shard checkpointed versions.
//!
//! A checkpointer belongs to one **router epoch**: it is spawned against
//! that epoch's shard fleets and carries the epoch's partition version
//! into every manifest it writes. A rebalance stops the old epoch's
//! checkpointer (final flush), migrates the files, and spawns a fresh one
//! over the new fleets — the state dir is never written by two epochs at
//! once.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::obs::Journal;
use crate::serve::SnapshotStore;

use super::codec::{encode_shard, FORMAT};
use super::manifest::{shard_file, write_atomic, Manifest};

/// How often the checkpointer polls shard versions when idle.
const POLL: Duration = Duration::from_millis(25);

enum Msg {
    /// Checkpoint every shard that advanced; ack with per-shard versions.
    Flush(mpsc::Sender<Result<Vec<u64>>>),
    /// Final flush, then exit.
    Stop,
}

/// Handle to the running checkpointer thread.
pub struct Checkpointer {
    tx: mpsc::Sender<Msg>,
    join: Option<JoinHandle<Result<()>>>,
}

/// Everything the checkpointer thread reads about one shard: the
/// epoch-swapped snapshot store plus the live counters persisted next to
/// the codebook (fold count for diagnostics, ingest/shed so a restart —
/// and the rebalance retrainer — sees the load each shard absorbed).
pub struct ShardSource {
    /// The shard's epoch-swapped publication cell.
    pub store: Arc<SnapshotStore>,
    /// The shard reducer's live fold counter.
    pub merges: Arc<AtomicU64>,
    /// Points accepted by the shard this router epoch.
    pub ingested: Arc<AtomicU64>,
    /// Points shed by the shard this router epoch.
    pub shed: Arc<AtomicU64>,
}

/// The static shape the checkpointer stamps into every file it writes.
#[derive(Debug, Clone)]
pub struct CheckpointSpec {
    /// The state directory every file lands in.
    pub dir: PathBuf,
    /// Reducer folds between automatic checkpoints of a shard.
    pub checkpoint_every: u64,
    /// Exchange window of the writing deployment (manifest field).
    pub points_per_exchange: usize,
    /// Total prototypes across shards (manifest field).
    pub kappa: usize,
    /// Prototype dimension (manifest field).
    pub dim: usize,
    /// Partition version of the router epoch this checkpointer serves.
    pub router_version: u64,
    /// The service-wide checkpoint-generation clock: holds the generation
    /// the manifest on disk currently carries, shared with the owning
    /// service (which re-seeds it across rebalances). Every manifest this
    /// checkpointer writes bumps it first, so replication's pollers see a
    /// new generation exactly when the directory's contents changed.
    pub generation: Arc<AtomicU64>,
    /// The owning service's event journal, when it has a telemetry
    /// plane: every flush (explicit or periodic) lands a
    /// `checkpoint.flush` event, every failure a `checkpoint.error`.
    pub journal: Option<Arc<Journal>>,
}

impl Checkpointer {
    /// Spawn the thread. `last_checkpoint[s]` must already hold the
    /// version shard `s`'s on-disk state carries (the restored version on
    /// a warm start, 0 on a cold one); it is updated after every
    /// successful write and is what `StatsReply::last_checkpoint`
    /// reports.
    pub fn spawn(
        spec: CheckpointSpec,
        sources: Vec<ShardSource>,
        last_checkpoint: Arc<Vec<AtomicU64>>,
    ) -> Checkpointer {
        assert_eq!(sources.len(), last_checkpoint.len());
        let (tx, rx) = mpsc::channel::<Msg>();
        let join = std::thread::Builder::new()
            .name("dalvq-checkpointer".into())
            .spawn(move || run(rx, spec, sources, last_checkpoint))
            .expect("spawning checkpointer thread");
        Checkpointer { tx, join: Some(join) }
    }

    /// Force a checkpoint of every advanced shard; blocks until the files
    /// are durable. Returns the per-shard last-checkpointed versions.
    pub fn flush(&self) -> Result<Vec<u64>> {
        let (ack_tx, ack_rx) = mpsc::channel();
        self.tx
            .send(Msg::Flush(ack_tx))
            .map_err(|_| anyhow!("checkpointer thread is gone"))?;
        ack_rx.recv().map_err(|_| anyhow!("checkpointer died mid-flush"))?
    }

    /// Final flush and join. Called by the service at shutdown (and by a
    /// rebalance, which retires this epoch's checkpointer), after the
    /// fleets have published their final epochs.
    pub fn stop(mut self) -> Result<()> {
        let _ = self.tx.send(Msg::Stop);
        match self.join.take() {
            Some(j) => j.join().map_err(|_| anyhow!("checkpointer panicked"))?,
            None => Ok(()),
        }
    }
}

fn run(
    rx: mpsc::Receiver<Msg>,
    spec: CheckpointSpec,
    sources: Vec<ShardSource>,
    last_checkpoint: Arc<Vec<AtomicU64>>,
) -> Result<()> {
    let write_shard = |s: usize| -> Result<u64> {
        // Taking the checkpoint is an O(1) Arc clone of the published
        // epoch; serialization then reads the codebook through the Arc —
        // it is never deep-copied into an intermediate struct.
        let snap = sources[s].store.load();
        let bytes = encode_shard(
            s as u32,
            snap.version,
            sources[s].merges.load(Ordering::Relaxed),
            snap.version * spec.points_per_exchange as u64,
            sources[s].ingested.load(Ordering::Relaxed),
            sources[s].shed.load(Ordering::Relaxed),
            spec.router_version,
            &snap.codebook,
        );
        write_atomic(&spec.dir, &shard_file(s), &bytes)?;
        last_checkpoint[s].store(snap.version, Ordering::Release);
        Ok(snap.version)
    };
    let write_manifest = || -> Result<()> {
        // Bump-then-write: the generation counter advances exactly when
        // the directory's contents change, so a replication poller that
        // sees an unchanged generation can skip re-fetching. A failed
        // save leaves a gap in the sequence, which is harmless — pollers
        // compare for inequality, not succession.
        let generation = spec.generation.fetch_add(1, Ordering::AcqRel) + 1;
        Manifest {
            format: FORMAT,
            shards: sources.len(),
            kappa: spec.kappa,
            dim: spec.dim,
            points_per_exchange: spec.points_per_exchange,
            router_version: spec.router_version,
            generation,
            shard_versions: last_checkpoint
                .iter()
                .map(|v| v.load(Ordering::Acquire))
                .collect(),
        }
        .save(&spec.dir)
    };
    // Checkpoint every shard that moved past its last checkpoint;
    // `min_advance` is the fold distance that triggers a write (1 for a
    // flush, `checkpoint_every` for the periodic pass).
    let pass = |min_advance: u64| -> Result<bool> {
        let mut wrote = false;
        for s in 0..sources.len() {
            let last = last_checkpoint[s].load(Ordering::Acquire);
            if sources[s].store.version() >= last.saturating_add(min_advance) {
                write_shard(s)?;
                wrote = true;
            }
        }
        if wrote {
            write_manifest()?;
        }
        Ok(wrote)
    };

    let versions = || -> Vec<u64> {
        last_checkpoint.iter().map(|v| v.load(Ordering::Acquire)).collect()
    };

    loop {
        match rx.recv_timeout(POLL) {
            Ok(Msg::Flush(ack)) => {
                let t0 = Instant::now();
                let result = pass(1).map(|_| versions());
                if let Some(j) = &spec.journal {
                    match &result {
                        Ok(v) => j.info(
                            "checkpoint.flush",
                            format!(
                                "flushed shard versions {v:?} in {} ms",
                                t0.elapsed().as_millis()
                            ),
                        ),
                        Err(e) => j.warn(
                            "checkpoint.error",
                            format!("explicit flush failed: {e:#}"),
                        ),
                    }
                }
                let _ = ack.send(result);
            }
            Ok(Msg::Stop) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                // Final drain: anything published since the last write.
                // This one is fresh and actionable, so it propagates (the
                // service surfaces it from shutdown).
                pass(1)?;
                return Ok(());
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // A transient write failure (disk momentarily full, one
                // EIO) must not kill durability for the rest of the run:
                // log it and retry on the next pass — `last_checkpoint`
                // only advances on successful writes, so nothing is
                // skipped. Explicit flushes still report their errors to
                // the caller through the ack channel.
                let t0 = Instant::now();
                match pass(spec.checkpoint_every.max(1)) {
                    Ok(false) => {}
                    Ok(true) => {
                        if let Some(j) = &spec.journal {
                            j.info(
                                "checkpoint.flush",
                                format!(
                                    "periodic checkpoint reached shard \
                                     versions {:?} in {} ms",
                                    versions(),
                                    t0.elapsed().as_millis()
                                ),
                            );
                        }
                    }
                    Err(e) => {
                        if let Some(j) = &spec.journal {
                            j.warn(
                                "checkpoint.error",
                                format!(
                                    "periodic checkpoint failed (will \
                                     retry): {e:#}"
                                ),
                            );
                        }
                        eprintln!(
                            "dalvq checkpointer: periodic checkpoint failed \
                             (will retry): {e:#}"
                        );
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::persist::restore::load_state;
    use crate::vq::Codebook;
    use std::path::Path;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dalvq-ckpt-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn write_router(dir: &Path, dim: usize) {
        let state = super::super::codec::RouterState {
            version: 0,
            centroids: Codebook::zeros(1, dim),
        };
        write_atomic(dir, super::super::manifest::ROUTER_FILE, &state.encode())
            .unwrap();
    }

    fn source(store: &Arc<SnapshotStore>) -> ShardSource {
        ShardSource {
            store: Arc::clone(store),
            merges: Arc::new(AtomicU64::new(0)),
            ingested: Arc::new(AtomicU64::new(0)),
            shed: Arc::new(AtomicU64::new(0)),
        }
    }

    fn spec(
        dir: &Path,
        checkpoint_every: u64,
        points_per_exchange: usize,
        kappa: usize,
        dim: usize,
    ) -> CheckpointSpec {
        CheckpointSpec {
            dir: dir.to_path_buf(),
            checkpoint_every,
            points_per_exchange,
            kappa,
            dim,
            router_version: 0,
            generation: Arc::new(AtomicU64::new(0)),
            journal: None,
        }
    }

    #[test]
    fn flush_writes_advanced_shards_and_manifest() {
        let dir = tmp_dir("flush");
        let store = SnapshotStore::new(Codebook::zeros(2, 2));
        let src = source(&store);
        let merges = Arc::clone(&src.merges);
        let ingested = Arc::clone(&src.ingested);
        let last = Arc::new(vec![AtomicU64::new(0)]);
        let ckpt = Checkpointer::spawn(
            spec(&dir, 1_000_000, 50, 2, 2), // periodic path effectively off
            vec![src],
            Arc::clone(&last),
        );
        write_router(&dir, 2);

        // nothing advanced: flush writes nothing, reports version 0
        assert_eq!(ckpt.flush().unwrap(), vec![0]);
        assert!(!dir.join(shard_file(0)).exists());

        store.publish(Codebook::from_flat(2, 2, vec![1.0, 2.0, 3.0, 4.0]), 3);
        merges.store(3, Ordering::Relaxed);
        ingested.store(96, Ordering::Relaxed);
        assert_eq!(ckpt.flush().unwrap(), vec![3]);
        assert_eq!(last[0].load(Ordering::Acquire), 3);

        let restored = load_state(&dir).unwrap().unwrap();
        assert_eq!(restored.shards[0].version, 3);
        assert_eq!(restored.shards[0].rng_cursor, 150);
        assert_eq!(restored.shards[0].ingested, 96);
        assert_eq!(restored.manifest.router_version, 0);
        // the flush's manifest write bumped the generation clock
        assert_eq!(restored.manifest.generation, 1);
        assert_eq!(
            restored.shards[0].codebook.flat(),
            &[1.0, 2.0, 3.0, 4.0]
        );
        ckpt.stop().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn flush_emits_a_journal_event() {
        let dir = tmp_dir("journal");
        let store = SnapshotStore::new(Codebook::zeros(1, 1));
        let src = source(&store);
        let merges = Arc::clone(&src.merges);
        let last = Arc::new(vec![AtomicU64::new(0)]);
        let journal = Arc::new(Journal::new(8));
        let mut spec = spec(&dir, 1_000_000, 10, 1, 1);
        spec.journal = Some(Arc::clone(&journal));
        let ckpt = Checkpointer::spawn(spec, vec![src], Arc::clone(&last));
        write_router(&dir, 1);
        store.publish(Codebook::from_flat(1, 1, vec![1.0]), 2);
        merges.store(2, Ordering::Relaxed);
        ckpt.flush().unwrap();
        let events = journal.recent(8);
        assert!(
            events.iter().any(|e| e.kind == "checkpoint.flush"),
            "{events:?}"
        );
        ckpt.stop().unwrap();
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn periodic_pass_waits_for_checkpoint_every() {
        let dir = tmp_dir("periodic");
        let store = SnapshotStore::new(Codebook::zeros(1, 1));
        let src = source(&store);
        let merges = Arc::clone(&src.merges);
        let last = Arc::new(vec![AtomicU64::new(0)]);
        let ckpt =
            Checkpointer::spawn(spec(&dir, 5, 10, 1, 1), vec![src], Arc::clone(&last));
        write_router(&dir, 1);
        store.publish(Codebook::from_flat(1, 1, vec![1.0]), 3);
        merges.store(3, Ordering::Relaxed);
        std::thread::sleep(Duration::from_millis(120));
        // 3 < checkpoint_every = 5: the periodic pass must not have fired
        assert_eq!(last[0].load(Ordering::Acquire), 0);
        store.publish(Codebook::from_flat(1, 1, vec![2.0]), 6);
        merges.store(6, Ordering::Relaxed);
        let deadline = std::time::Instant::now() + Duration::from_secs(5);
        while last[0].load(Ordering::Acquire) < 6 {
            assert!(
                std::time::Instant::now() < deadline,
                "periodic checkpoint never fired"
            );
            std::thread::sleep(Duration::from_millis(10));
        }
        // stop performs a final drain and leaves a consistent manifest
        ckpt.stop().unwrap();
        let m = Manifest::load(&dir).unwrap().unwrap();
        assert_eq!(m.shard_versions, vec![6]);
        std::fs::remove_dir_all(&dir).unwrap();
    }
}
