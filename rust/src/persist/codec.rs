//! Binary codec for durable serve state: one self-describing file per
//! shard, plus one for the frozen router.
//!
//! Layout discipline mirrors the wire protocol (`serve::protocol`):
//! fixed-width little-endian fields, hand-rolled (the offline build
//! carries no serde), every decode total — any byte string either decodes
//! to exactly the state that produced it or returns `Err`. On top of the
//! protocol's bounds checks, files add what a disk needs and a socket
//! doesn't: a magic number (is this even ours?), a format version (can
//! this build read it?), and a trailing FNV-1a checksum (did the bytes
//! survive the disk?). A truncated, bit-flipped or foreign file is
//! rejected before any of it reaches a fleet.

use anyhow::{bail, Result};

use crate::vq::Codebook;

/// Magic prefix of a shard-state file.
pub const SHARD_MAGIC: [u8; 4] = *b"DVQS";
/// Magic prefix of a router-state file.
pub const ROUTER_MAGIC: [u8; 4] = *b"DVQR";
/// On-disk format version this build reads and writes. Format 2 added the
/// per-shard ingest counters (`ingested`/`shed`) that the rebalance
/// retrainer weights by, and the router's partition version.
pub const FORMAT: u32 = 2;

/// One shard's durable state: everything a restarted service needs to
/// resume this shard where the checkpoint left it.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardState {
    /// Shard index within the deployment.
    pub shard: u32,
    /// Published snapshot version at checkpoint — the fold count the
    /// saved codebook actually contains. Restore resumes the shard's
    /// fold clock from this.
    pub version: u64,
    /// Reducer fold counter observed at checkpoint (diagnostic only: it
    /// may run ahead of `version` — unpublished folds, or a counter
    /// sample racing the live reducer — so restore never seeds from it).
    pub merges: u64,
    /// Training-step cursor: total points this shard's fold sequence
    /// represents (`version * points_per_exchange`). Restore seeds the
    /// workers' schedule position from it, so a decaying learning rate
    /// resumes instead of restarting hot.
    pub rng_cursor: u64,
    /// Points this shard's fleet accepted from ingest during the current
    /// router epoch. The rebalance retrainer weights the shard's
    /// prototype rows by this, so the new partition splits observed load,
    /// not just prototype geometry. Reset to 0 by a rebalance.
    pub ingested: u64,
    /// Points routed to this shard but shed (full worker queues) during
    /// the current router epoch.
    pub shed: u64,
    /// Partition version this shard file belongs to. Restore requires it
    /// to match the manifest's, so a rebalance interrupted mid-migration
    /// (some shard files rewritten, router/manifest not yet) is rejected
    /// loudly instead of serving a mispartitioned mix. Within an epoch
    /// the value never changes, so a crash mid-*checkpoint* still
    /// restores cleanly.
    pub router_version: u64,
    /// The shard's published codebook (`kappa/S` prototypes).
    pub codebook: Codebook,
}

/// The frozen coarse quantizer, persisted so a restarted service routes
/// identically to the one that wrote the checkpoints (retraining the
/// router from a fresh bootstrap sample would repartition the space and
/// orphan every saved shard codebook).
#[derive(Debug, Clone, PartialEq)]
pub struct RouterState {
    /// Partition version: 0 for the bootstrap router, bumped by every
    /// rebalance. A restarted service must resume the *same* partition
    /// epoch the shard files were written under (the manifest carries the
    /// matching value; restore cross-checks them).
    pub version: u64,
    /// The coarse centroids, one per shard (`S x dim`).
    pub centroids: Codebook,
}

// ------------------------------------------------------------- checksum

/// FNV-1a 64 over `bytes` — cheap, dependency-free corruption detection
/// (not cryptographic; the threat model is torn writes and bit rot, not
/// an adversary with write access to the state dir).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

// ------------------------------------------------------------- encoding

fn put_codebook(out: &mut Vec<u8>, w: &Codebook) {
    out.extend_from_slice(&(w.kappa() as u32).to_le_bytes());
    out.extend_from_slice(&(w.dim() as u32).to_le_bytes());
    for x in w.flat() {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn seal(mut out: Vec<u8>) -> Vec<u8> {
    let sum = fnv1a64(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Encode shard state straight from a borrowed codebook. This is what
/// the checkpointer calls with the published epoch's codebook behind its
/// `Arc` — the serialization writes bytes but never deep-copies the
/// codebook into an intermediate `ShardState`.
#[allow(clippy::too_many_arguments)]
pub fn encode_shard(
    shard: u32,
    version: u64,
    merges: u64,
    rng_cursor: u64,
    ingested: u64,
    shed: u64,
    router_version: u64,
    codebook: &Codebook,
) -> Vec<u8> {
    let mut out = Vec::with_capacity(
        4 + 4 + 4 + 8 * 6 + 8 + codebook.flat().len() * 4 + 8,
    );
    out.extend_from_slice(&SHARD_MAGIC);
    out.extend_from_slice(&FORMAT.to_le_bytes());
    out.extend_from_slice(&shard.to_le_bytes());
    out.extend_from_slice(&version.to_le_bytes());
    out.extend_from_slice(&merges.to_le_bytes());
    out.extend_from_slice(&rng_cursor.to_le_bytes());
    out.extend_from_slice(&ingested.to_le_bytes());
    out.extend_from_slice(&shed.to_le_bytes());
    out.extend_from_slice(&router_version.to_le_bytes());
    put_codebook(&mut out, codebook);
    seal(out)
}

impl ShardState {
    /// Serialize to the self-describing shard-file format (via
    /// [`encode_shard`], which the checkpointer also calls with a
    /// borrowed codebook).
    pub fn encode(&self) -> Vec<u8> {
        encode_shard(
            self.shard,
            self.version,
            self.merges,
            self.rng_cursor,
            self.ingested,
            self.shed,
            self.router_version,
            &self.codebook,
        )
    }

    /// Total decode: magic, format and checksum are verified before any
    /// field is read, and a non-finite codebook is rejected.
    pub fn decode(bytes: &[u8]) -> Result<ShardState> {
        let mut c = Cursor::open(bytes, &SHARD_MAGIC, "shard state")?;
        let state = ShardState {
            shard: c.u32()?,
            version: c.u64()?,
            merges: c.u64()?,
            rng_cursor: c.u64()?,
            ingested: c.u64()?,
            shed: c.u64()?,
            router_version: c.u64()?,
            codebook: c.codebook()?,
        };
        c.finish()?;
        if !state.codebook.is_finite() {
            bail!("shard state carries a non-finite codebook");
        }
        Ok(state)
    }
}

impl RouterState {
    /// Serialize to the self-describing router-file format.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(
            4 + 4 + 8 + 8 + self.centroids.flat().len() * 4 + 8,
        );
        out.extend_from_slice(&ROUTER_MAGIC);
        out.extend_from_slice(&FORMAT.to_le_bytes());
        out.extend_from_slice(&self.version.to_le_bytes());
        put_codebook(&mut out, &self.centroids);
        seal(out)
    }

    /// Total decode, mirroring [`ShardState::decode`]'s guarantees.
    pub fn decode(bytes: &[u8]) -> Result<RouterState> {
        let mut c = Cursor::open(bytes, &ROUTER_MAGIC, "router state")?;
        let state = RouterState { version: c.u64()?, centroids: c.codebook()? };
        c.finish()?;
        if !state.centroids.is_finite() {
            bail!("router state carries non-finite centroids");
        }
        Ok(state)
    }
}

// ------------------------------------------------------------- decoding

/// A bounds-checked little-endian reader over a checksummed file body.
/// `open` verifies length, checksum, magic and format before any field is
/// read, so a corrupt file never partially decodes.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn open(bytes: &'a [u8], magic: &[u8; 4], what: &str) -> Result<Cursor<'a>> {
        if bytes.len() < 4 + 4 + 8 {
            bail!("{what} file truncated: {} bytes", bytes.len());
        }
        let (body, tail) = bytes.split_at(bytes.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let actual = fnv1a64(body);
        if stored != actual {
            bail!(
                "{what} checksum mismatch: stored {stored:#018x}, \
                 computed {actual:#018x} (torn write or bit rot)"
            );
        }
        if &body[..4] != magic {
            bail!("{what} magic mismatch: {:02x?}", &body[..4]);
        }
        let format = u32::from_le_bytes(body[4..8].try_into().unwrap());
        if format != FORMAT {
            bail!("{what} format {format} unsupported (this build reads {FORMAT})");
        }
        Ok(Cursor { buf: body, pos: 8 })
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| {
                anyhow::anyhow!("state file truncated at byte {}", self.pos)
            })?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn codebook(&mut self) -> Result<Codebook> {
        let kappa = self.u32()? as usize;
        let dim = self.u32()? as usize;
        if kappa == 0 || dim == 0 {
            bail!("state file declares an empty codebook ({kappa} x {dim})");
        }
        // Bounds-check before allocating: a lying shape must not become a
        // huge Vec (same discipline as the wire cursors).
        let n = kappa
            .checked_mul(dim)
            .and_then(|n| n.checked_mul(4))
            .ok_or_else(|| anyhow::anyhow!("codebook shape overflows"))?;
        let raw = self.bytes(n)?;
        let flat: Vec<f32> = raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        Ok(Codebook::from_flat(kappa, dim, flat))
    }

    fn finish(self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            bail!("{} trailing bytes in state file", self.buf.len() - self.pos)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn rand_shard_state(rng: &mut Rng) -> ShardState {
        let kappa = 1 + rng.usize(6);
        let dim = 1 + rng.usize(5);
        let flat: Vec<f32> =
            (0..kappa * dim).map(|_| rng.range_f32(-1e4, 1e4)).collect();
        ShardState {
            shard: rng.next_u64() as u32,
            version: rng.next_u64(),
            merges: rng.next_u64(),
            rng_cursor: rng.next_u64(),
            ingested: rng.next_u64(),
            shed: rng.next_u64(),
            router_version: rng.next_u64(),
            codebook: Codebook::from_flat(kappa, dim, flat),
        }
    }

    #[test]
    fn shard_state_roundtrips_exactly() {
        let mut rng = Rng::from_seed(0x5A4E);
        for _ in 0..200 {
            let state = rand_shard_state(&mut rng);
            let back = ShardState::decode(&state.encode()).unwrap();
            assert_eq!(state.shard, back.shard);
            assert_eq!(state.version, back.version);
            assert_eq!(state.merges, back.merges);
            assert_eq!(state.rng_cursor, back.rng_cursor);
            assert_eq!(state.ingested, back.ingested);
            assert_eq!(state.shed, back.shed);
            assert_eq!(state.router_version, back.router_version);
            // byte-identical codebook, not just approximately equal
            assert!(state
                .codebook
                .flat()
                .iter()
                .zip(back.codebook.flat())
                .all(|(a, b)| a.to_bits() == b.to_bits()));
        }
    }

    #[test]
    fn router_state_roundtrips_exactly() {
        let mut rng = Rng::from_seed(0x2007);
        for _ in 0..100 {
            let shards = 1 + rng.usize(8);
            let dim = 1 + rng.usize(4);
            let flat: Vec<f32> =
                (0..shards * dim).map(|_| rng.range_f32(-50.0, 50.0)).collect();
            let state = RouterState {
                version: rng.next_u64(),
                centroids: Codebook::from_flat(shards, dim, flat),
            };
            assert_eq!(RouterState::decode(&state.encode()).unwrap(), state);
        }
    }

    #[test]
    fn every_truncation_errs() {
        let mut rng = Rng::from_seed(0x7C01);
        for _ in 0..20 {
            let wire = rand_shard_state(&mut rng).encode();
            for cut in 0..wire.len() {
                assert!(
                    ShardState::decode(&wire[..cut]).is_err(),
                    "prefix {cut}/{} decoded",
                    wire.len()
                );
            }
        }
    }

    #[test]
    fn single_byte_corruption_is_always_caught() {
        // Unlike the wire protocol (where a flipped payload float still
        // decodes), a state file carries a checksum: EVERY one-byte
        // corruption must be rejected, not just structural ones.
        let mut rng = Rng::from_seed(0xC0DE);
        for _ in 0..10 {
            let wire = rand_shard_state(&mut rng).encode();
            for i in 0..wire.len() {
                let mut bad = wire.clone();
                bad[i] ^= 1 << rng.usize(8);
                assert!(
                    ShardState::decode(&bad).is_err(),
                    "corruption at byte {i} went undetected"
                );
            }
        }
    }

    #[test]
    fn wrong_magic_and_format_are_rejected() {
        let mut rng = Rng::from_seed(0x3A61);
        let state = rand_shard_state(&mut rng);
        // a router file is not a shard file, even though both checksum
        let router =
            RouterState { version: 0, centroids: state.codebook.clone() };
        let err =
            format!("{:#}", ShardState::decode(&router.encode()).unwrap_err());
        assert!(err.contains("magic"), "{err}");
        // a future format is refused with a clear message (re-sealed so
        // only the format field differs from a valid file)
        let mut wire = state.encode();
        wire.truncate(wire.len() - 8);
        wire[4..8].copy_from_slice(&(FORMAT + 1).to_le_bytes());
        let wire = seal(wire);
        let err = format!("{:#}", ShardState::decode(&wire).unwrap_err());
        assert!(err.contains("format"), "{err}");
    }

    #[test]
    fn lying_codebook_shape_errs_without_overallocating() {
        let state = ShardState {
            shard: 0,
            version: 1,
            merges: 1,
            rng_cursor: 50,
            ingested: 0,
            shed: 0,
            router_version: 0,
            codebook: Codebook::from_flat(1, 2, vec![1.0, 2.0]),
        };
        let mut wire = state.encode();
        wire.truncate(wire.len() - 8);
        // kappa field sits after magic(4) format(4) shard(4) and the six
        // u64s (version, merges, cursor, ingested, shed, router_version)
        wire[60..64].copy_from_slice(&u32::MAX.to_le_bytes());
        let wire = seal(wire);
        assert!(ShardState::decode(&wire).is_err());
    }

    #[test]
    fn non_finite_codebooks_are_rejected() {
        let state = ShardState {
            shard: 0,
            version: 1,
            merges: 1,
            rng_cursor: 0,
            ingested: 0,
            shed: 0,
            router_version: 0,
            codebook: Codebook::from_flat(1, 2, vec![f32::NAN, 0.0]),
        };
        assert!(ShardState::decode(&state.encode()).is_err());
    }

    #[test]
    fn random_bytes_never_panic() {
        let mut rng = Rng::from_seed(0xF12E);
        for _ in 0..2_000 {
            let len = rng.usize(128);
            let buf: Vec<u8> = (0..len).map(|_| rng.next_u64() as u8).collect();
            let _ = ShardState::decode(&buf);
            let _ = RouterState::decode(&buf);
        }
    }
}
