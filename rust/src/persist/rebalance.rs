//! Offline rebalance: retrain the coarse quantizer from *checkpointed*
//! shard codebooks and migrate prototype rows across the `S` shards.
//!
//! The serving router is frozen within a partition epoch (Patra's
//! asynchronous-LVQ analysis needs each shard's fleet to train
//! undisturbed), so adapting the partition to observed load — Kamp et
//! al.'s effective-parallelisation argument — happens *between* epochs,
//! and the durable state is the data source: everything here operates on
//! a state directory, never on live fleets. The live service quiesces,
//! flushes a checkpoint, runs this, and restarts its fleets from the
//! rewritten directory; `dalvq state rebalance` runs the identical code
//! against a quiesced directory.
//!
//! The retrain is a small **weighted** k-means over the `kappa` prototype
//! rows: each row carries its shard's observed ingest mass, so a shard
//! that absorbed most of the stream contributes heavy rows and the new
//! coarse cells split its region, while idle regions collapse into fewer
//! cells. Rows are then re-assigned under an exact capacity of `kappa/S`
//! per shard (greedy nearest-first with capacities), so every fleet keeps
//! the same codebook shape and the global code formula
//! `shard * kappa/S + local` survives — only the *mapping* of rows to
//! shards changes, which is exactly what [`RebalanceReport::remap`]
//! records.

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::Rng;
use crate::vq::{self, Codebook};

use super::codec::{RouterState, ShardState, FORMAT};
use super::manifest::{shard_file, write_atomic, Manifest, ROUTER_FILE};
use super::restore::{load_state, RestoredState};

/// A computed re-partition: new coarse centroids plus the row migration.
#[derive(Debug, Clone)]
pub struct RebalancePlan {
    /// The retrained coarse centroids (`S x dim`).
    pub centroids: Codebook,
    /// For each global prototype row (old order), its new shard.
    pub assignment: Vec<usize>,
    /// Per new shard, the old global row indices it receives (ascending —
    /// within-shard order is stable in the old global order).
    pub placement: Vec<Vec<usize>>,
    /// Rows whose owning shard changed.
    pub moved_rows: usize,
}

/// What a rebalance did to a state directory.
#[derive(Debug, Clone)]
pub struct RebalanceReport {
    /// The bumped partition version the directory now carries.
    pub router_version: u64,
    /// Rows that changed shard.
    pub moved_rows: usize,
    /// The (uniform) per-shard version the migrated fleets resume at:
    /// `max` over the old shard versions, so every per-shard clock and
    /// their sum stay monotone across the migration.
    pub resume_version: u64,
    /// Global-code remapping: `remap[old_code] = new_code`. Codes are
    /// `shard * kappa/S + local`; a migration permutes which shard owns
    /// each row, so cached codes from the previous epoch translate
    /// through this table.
    pub remap: Vec<u32>,
}

/// Compute a re-partition of `rows` (the concatenated shard codebooks,
/// `kappa x dim`) into `shards` cells of exactly `kappa / shards` rows.
/// `weights[r]` is the ingest mass behind row `r` (any non-negative
/// scale); uniform weights reduce to a pure-geometry split. Deterministic
/// in `seed`.
pub fn plan_rebalance(
    rows: &Codebook,
    shards: usize,
    weights: &[f64],
    iters: usize,
    seed: u64,
) -> RebalancePlan {
    let kappa = rows.kappa();
    assert!(shards >= 1, "rebalance needs at least one shard");
    assert_eq!(kappa % shards, 0, "kappa must divide evenly across shards");
    assert_eq!(weights.len(), kappa, "one weight per prototype row");
    assert!(
        weights.iter().all(|w| w.is_finite() && *w >= 0.0),
        "row weights must be finite and non-negative"
    );
    let cap = kappa / shards;
    let centroids = weighted_kmeans(rows, shards, weights, iters, seed);
    let assignment = balanced_assignment(rows, &centroids, cap);
    let mut placement: Vec<Vec<usize>> = vec![Vec::with_capacity(cap); shards];
    for (r, &s) in assignment.iter().enumerate() {
        placement[s].push(r); // ascending in r by construction
    }
    let moved_rows =
        assignment.iter().enumerate().filter(|(r, &s)| r / cap != s).count();
    RebalancePlan { centroids, assignment, placement, moved_rows }
}

/// Weighted k-means over the prototype rows: best of a few independent
/// restarts (weighted D² seeding + `iters` weighted Lloyd steps each),
/// scored by weighted distortion. Restarts matter here: Lloyd only finds
/// local optima, and a load-skewed weighting has sharp ones — a single
/// spread-biased seeding can leave the whole hot region to one cell.
fn weighted_kmeans(
    rows: &Codebook,
    k: usize,
    weights: &[f64],
    iters: usize,
    seed: u64,
) -> Codebook {
    // Zero total mass (a never-ingested epoch): fall back to uniform
    // weights — a pure-geometry split, which is also the cold-start
    // router's behaviour.
    let n = rows.kappa();
    let total_mass: f64 = weights.iter().sum();
    let uniform = vec![1.0f64; n];
    let w = if total_mass > 0.0 { weights } else { &uniform[..] };

    const RESTARTS: u64 = 4;
    let mut best: Option<(f64, Codebook)> = None;
    for r in 0..RESTARTS {
        let mut rng = Rng::from_seed_stream(seed, 0x5EBA_1A5C ^ r);
        let candidate = weighted_kmeans_once(rows, k, w, iters, &mut rng);
        let cost: f64 = (0..n)
            .map(|i| {
                let a = vq::nearest(&candidate, rows.row(i));
                vq::row_dist_sq(rows.row(i), candidate.row(a)) as f64 * w[i]
            })
            .sum();
        let better = match &best {
            Some((best_cost, _)) => cost < *best_cost,
            None => true,
        };
        if better {
            best = Some((cost, candidate));
        }
    }
    best.expect("at least one restart").1
}

/// One weighted k-means run: weighted D² seeding plus `iters` weighted
/// Lloyd steps. An empty cell keeps its seed centroid (the
/// capacity-constrained assignment gives it rows regardless).
fn weighted_kmeans_once(
    rows: &Codebook,
    k: usize,
    w: &[f64],
    iters: usize,
    rng: &mut Rng,
) -> Codebook {
    let n = rows.kappa();
    let dim = rows.dim();

    // Weighted k-means++ seeding.
    let mut flat = Vec::with_capacity(k * dim);
    let first = sample_weighted(rng, w);
    flat.extend_from_slice(rows.row(first));
    let mut d2 = vec![f64::INFINITY; n];
    let mut scratch = vec![0.0f64; n];
    for _ in 1..k {
        let last = &flat[flat.len() - dim..];
        for i in 0..n {
            let d = vq::row_dist_sq(rows.row(i), last) as f64;
            if d < d2[i] {
                d2[i] = d;
            }
            scratch[i] = d2[i] * w[i];
        }
        let next = if scratch.iter().sum::<f64>() > 0.0 {
            sample_weighted(rng, &scratch)
        } else {
            // all mass sits on already-chosen rows (duplicate prototypes)
            rng.usize(n)
        };
        flat.extend_from_slice(rows.row(next));
    }
    let mut centroids = Codebook::from_flat(k, dim, flat);

    // Weighted Lloyd.
    let mut sums = vec![0.0f64; k * dim];
    let mut mass = vec![0.0f64; k];
    for _ in 0..iters {
        sums.iter_mut().for_each(|x| *x = 0.0);
        mass.iter_mut().for_each(|x| *x = 0.0);
        for i in 0..n {
            let z = rows.row(i);
            let a = vq::nearest(&centroids, z);
            mass[a] += w[i];
            for j in 0..dim {
                sums[a * dim + j] += z[j] as f64 * w[i];
            }
        }
        for c in 0..k {
            if mass[c] > 0.0 {
                let inv = 1.0 / mass[c];
                let row = centroids.row_mut(c);
                for j in 0..dim {
                    row[j] = (sums[c * dim + j] * inv) as f32;
                }
            }
        }
    }
    centroids
}

/// Sample an index proportionally to `weights` (sum must be positive).
fn sample_weighted(rng: &mut Rng, weights: &[f64]) -> usize {
    let total: f64 = weights.iter().sum();
    let mut target = rng.range_f64(0.0, total);
    for (i, w) in weights.iter().enumerate() {
        target -= w;
        if target <= 0.0 {
            return i;
        }
    }
    weights.len() - 1
}

/// Exact-capacity assignment: every row to a cell, at most `cap` rows per
/// cell, greedily by ascending row-to-centroid distance (deterministic
/// tie-break on row then cell index). Total capacity equals the row
/// count, so every row lands.
fn balanced_assignment(
    rows: &Codebook,
    centroids: &Codebook,
    cap: usize,
) -> Vec<usize> {
    let n = rows.kappa();
    let k = centroids.kappa();
    debug_assert_eq!(n, cap * k);
    let mut pairs: Vec<(f32, usize, usize)> = Vec::with_capacity(n * k);
    for r in 0..n {
        for c in 0..k {
            pairs.push((vq::row_dist_sq(rows.row(r), centroids.row(c)), r, c));
        }
    }
    pairs.sort_unstable_by(|a, b| {
        a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2))
    });
    let mut assignment = vec![usize::MAX; n];
    let mut counts = vec![0usize; k];
    let mut placed = 0usize;
    for (_, r, c) in pairs {
        if assignment[r] == usize::MAX && counts[c] < cap {
            assignment[r] = c;
            counts[c] += 1;
            placed += 1;
            if placed == n {
                break;
            }
        }
    }
    assignment
}

/// Rebalance a quiesced state directory in place: retrain the router from
/// the checkpointed codebooks (rows weighted by each shard's persisted
/// ingest counters), migrate the rows, and rewrite shard files + router +
/// manifest at the bumped partition version. Write order is shards →
/// router → manifest, so an interruption at any point is caught by
/// restore's cross-checks instead of silently serving a torn partition.
pub fn rebalance_state_dir(
    dir: &Path,
    iters: usize,
    seed: u64,
) -> Result<RebalanceReport> {
    let state = load_state(dir)
        .with_context(|| format!("loading state from {}", dir.display()))?
        .ok_or_else(|| {
            anyhow!(
                "{} holds no checkpointed state to rebalance (no manifest)",
                dir.display()
            )
        })?;
    let (report, router, shard_states, manifest) = plan_from_state(&state, iters, seed);
    for st in &shard_states {
        write_atomic(dir, &shard_file(st.shard as usize), &st.encode())?;
    }
    write_atomic(dir, ROUTER_FILE, &router.encode())?;
    manifest.save(dir)?;
    Ok(report)
}

/// The pure core of [`rebalance_state_dir`]: compute the migrated file
/// set from a loaded state. (The live service does NOT call this
/// directly — it deliberately round-trips through
/// [`rebalance_state_dir`] and the warm-restart loader, so what serves
/// after a swap is exactly what a killed-and-restarted process would
/// serve.)
fn plan_from_state(
    state: &RestoredState,
    iters: usize,
    seed: u64,
) -> (RebalanceReport, RouterState, Vec<ShardState>, Manifest) {
    let m = &state.manifest;
    let shards = m.shards;
    let cap = m.kappa / shards;
    let dim = m.dim;

    // Concatenate the shard codebooks into the global row matrix and
    // spread each shard's ingest mass uniformly over its rows (+1
    // smoothing so a zero-traffic shard still anchors its region).
    let mut flat = Vec::with_capacity(m.kappa * dim);
    let mut weights = Vec::with_capacity(m.kappa);
    for st in &state.shards {
        flat.extend_from_slice(st.codebook.flat());
        let per_row = (st.ingested as f64 + 1.0) / cap as f64;
        weights.extend(std::iter::repeat(per_row).take(cap));
    }
    let rows = Codebook::from_flat(m.kappa, dim, flat);

    // Mix the partition version into the seed so successive rebalances
    // explore fresh seedings while each one stays reproducible.
    let router_version = m.router_version + 1;
    let plan = plan_rebalance(&rows, shards, &weights, iters, seed ^ router_version);

    // Every migrated fleet resumes at the max of the old versions: a
    // shard's rows may come from several old shards, and `max` keeps both
    // the per-shard clocks and their service-wide sum monotone.
    let resume_version = state.shards.iter().map(|s| s.version).max().unwrap_or(0);
    let mut remap = vec![0u32; m.kappa];
    let mut shard_states = Vec::with_capacity(shards);
    for (s, rows_here) in plan.placement.iter().enumerate() {
        let mut shard_flat = Vec::with_capacity(cap * dim);
        for (local, &r) in rows_here.iter().enumerate() {
            shard_flat.extend_from_slice(rows.row(r));
            remap[r] = (s * cap + local) as u32;
        }
        shard_states.push(ShardState {
            shard: s as u32,
            version: resume_version,
            merges: resume_version,
            rng_cursor: resume_version * m.points_per_exchange as u64,
            ingested: 0, // load counters are per partition epoch
            shed: 0,
            router_version,
            codebook: Codebook::from_flat(cap, dim, shard_flat),
        });
    }
    let router = RouterState {
        version: router_version,
        centroids: plan.centroids.clone(),
    };
    let manifest = Manifest {
        format: FORMAT,
        shards,
        kappa: m.kappa,
        dim,
        points_per_exchange: m.points_per_exchange,
        router_version,
        // A migration is a directory change like any other: bump the
        // checkpoint generation so replication pollers re-fetch — and
        // adopt the bumped router epoch — on their next pass.
        generation: m.generation + 1,
        shard_versions: vec![resume_version; shards],
    };
    let report = RebalanceReport {
        router_version,
        moved_rows: plan.moved_rows,
        resume_version,
        remap,
    };
    (report, router, shard_states, manifest)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir()
            .join(format!("dalvq-rebalance-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    /// 8 rows in dim 1: 4 hot rows bunched near 0, 4 cold rows spread far.
    fn hot_cold_rows() -> Codebook {
        Codebook::from_flat(
            8,
            1,
            vec![0.0, 1.0, 2.0, 3.0, 100.0, 200.0, 300.0, 400.0],
        )
    }

    #[test]
    fn plan_is_deterministic_and_exactly_balanced() {
        let rows = hot_cold_rows();
        let w = vec![1.0; 8];
        let a = plan_rebalance(&rows, 4, &w, 8, 42);
        let b = plan_rebalance(&rows, 4, &w, 8, 42);
        assert_eq!(a.assignment, b.assignment);
        assert_eq!(a.centroids, b.centroids);
        for cell in &a.placement {
            assert_eq!(cell.len(), 2, "every shard gets exactly kappa/S rows");
        }
        // every row assigned exactly once
        let mut seen = vec![false; 8];
        for cell in &a.placement {
            for &r in cell {
                assert!(!seen[r], "row {r} placed twice");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|s| *s));
    }

    #[test]
    fn heavy_rows_split_the_hot_region() {
        // All ingest mass sits on the 4 bunched rows; the retrained
        // router must spend multiple cells on them instead of leaving the
        // whole hot region to one shard.
        let rows = hot_cold_rows();
        let w = vec![1000.0, 1000.0, 1000.0, 1000.0, 1.0, 1.0, 1.0, 1.0];
        let plan = plan_rebalance(&rows, 4, &w, 16, 7);
        let hot_shards: std::collections::BTreeSet<usize> =
            plan.assignment[..4].iter().copied().collect();
        assert!(
            hot_shards.len() >= 2,
            "hot rows all landed on one shard: {:?}",
            plan.assignment
        );
    }

    #[test]
    fn uniform_weights_split_by_geometry() {
        // Two well-separated row clusters, two shards: the split must be
        // the clusters, whatever the seed.
        let rows = Codebook::from_flat(
            4,
            1,
            vec![0.0, 1.0, 100.0, 101.0],
        );
        for seed in [1u64, 9, 77] {
            let plan = plan_rebalance(&rows, 2, &[1.0; 4], 8, seed);
            assert_eq!(plan.assignment[0], plan.assignment[1]);
            assert_eq!(plan.assignment[2], plan.assignment[3]);
            assert_ne!(plan.assignment[0], plan.assignment[2]);
        }
    }

    #[test]
    fn degenerate_identical_rows_still_balance() {
        // Every prototype identical (a pathologically collapsed fleet):
        // the plan must still hand each shard exactly cap rows.
        let rows = Codebook::from_flat(4, 2, vec![5.0; 8]);
        let plan = plan_rebalance(&rows, 2, &[1.0; 4], 4, 3);
        assert_eq!(plan.placement[0].len(), 2);
        assert_eq!(plan.placement[1].len(), 2);
    }

    #[test]
    fn rebalance_state_dir_bumps_and_migrates() {
        let dir = tmp_dir("dir");
        // Write a 2-shard, kappa=4, dim=1 state: shard 0 holds the hot
        // bunched rows (heavy ingest), shard 1 the far-flung cold rows.
        Manifest {
            format: FORMAT,
            shards: 2,
            kappa: 4,
            dim: 1,
            points_per_exchange: 50,
            router_version: 0,
            generation: 3,
            shard_versions: vec![6, 2],
        }
        .save(&dir)
        .unwrap();
        write_atomic(
            &dir,
            ROUTER_FILE,
            &RouterState {
                version: 0,
                centroids: Codebook::from_flat(2, 1, vec![1.0, 300.0]),
            }
            .encode(),
        )
        .unwrap();
        let shard_rows = [vec![0.0f32, 2.0], vec![200.0f32, 400.0]];
        for (s, rows) in shard_rows.iter().enumerate() {
            let st = ShardState {
                shard: s as u32,
                version: if s == 0 { 6 } else { 2 },
                merges: if s == 0 { 6 } else { 2 },
                rng_cursor: 300,
                ingested: if s == 0 { 10_000 } else { 10 },
                shed: 0,
                router_version: 0,
                codebook: Codebook::from_flat(2, 1, rows.clone()),
            };
            write_atomic(&dir, &shard_file(s), &st.encode()).unwrap();
        }

        let report = rebalance_state_dir(&dir, 8, 99).unwrap();
        assert_eq!(report.router_version, 1);
        assert_eq!(report.resume_version, 6);

        let state = load_state(&dir).unwrap().unwrap();
        assert_eq!(state.manifest.router_version, 1);
        assert_eq!(state.router.version, 1);
        // the migration bumped the checkpoint-generation clock too
        assert_eq!(state.manifest.generation, 4);
        assert_eq!(state.manifest.shard_versions, vec![6, 6]);
        // counters reset for the new partition epoch
        assert!(state.shards.iter().all(|s| s.ingested == 0 && s.shed == 0));
        assert!(state.shards.iter().all(|s| s.version == 6));
        // the migrated global codebook is a permutation of the old rows,
        // and the remap table points each old row at its new position
        let old_rows = [0.0f32, 2.0, 200.0, 400.0];
        let mut new_global = Vec::new();
        for s in &state.shards {
            new_global.extend_from_slice(s.codebook.flat());
        }
        let mut sorted_new = new_global.clone();
        sorted_new.sort_by(f32::total_cmp);
        assert_eq!(sorted_new, old_rows.to_vec());
        for (old_code, &new_code) in report.remap.iter().enumerate() {
            assert_eq!(
                new_global[new_code as usize], old_rows[old_code],
                "remap[{old_code}] = {new_code} points at the wrong row"
            );
        }
        // rebalancing again keeps bumping
        let report2 = rebalance_state_dir(&dir, 8, 99).unwrap();
        assert_eq!(report2.router_version, 2);
        std::fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn rebalancing_an_empty_dir_is_a_clear_error() {
        let dir = tmp_dir("empty");
        let err = format!("{:#}", rebalance_state_dir(&dir, 4, 1).unwrap_err());
        assert!(err.contains("no checkpointed state"), "{err}");
    }
}
