//! The monitor: samples the published shared version on a real wall-clock
//! cadence and records the Figure-4 performance curve.
//!
//! Measurement is out-of-band — the monitor reads the blob with a
//! zero-latency handle and evaluates the criterion natively, consuming no
//! protocol resources (mirrors the paper, whose curves exclude criterion
//! computation).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::metrics::Series;
use crate::vq;

use super::blob::BlobHandle;

/// Monitor parameters.
#[derive(Debug, Clone)]
pub struct MonitorConfig {
    /// Real seconds between samples.
    pub interval: f64,
    /// Held-out evaluation sample (flat) and its dimension.
    pub eval_points: Vec<f32>,
    pub dim: usize,
}

/// Run until `stop` flips to `true`, then take one final sample; returns
/// the recorded curve. Call from a dedicated thread.
pub fn run_monitor(
    cfg: MonitorConfig,
    mut blob: BlobHandle,
    start: Instant,
    stop: Arc<AtomicBool>,
) -> Result<Series> {
    let n = (cfg.eval_points.len() / cfg.dim) as f64;
    let mut series = Series::new("cloud");
    let interval = Duration::from_secs_f64(cfg.interval);
    loop {
        let stopping = stop.load(Ordering::Acquire);
        let (w, v) = blob.get()?;
        let c = vq::distortion_sum(&w, &cfg.eval_points) / n;
        series.push(start.elapsed().as_secs_f64(), c);
        if stopping {
            series.merges = v;
            return Ok(series);
        }
        std::thread::sleep(interval);
    }
}
