//! Client-side latency injection — the “network” between a worker and the
//! storage services.
//!
//! Latency lives in the *handles* (each caller has its own injector and
//! seed), not in the services: concurrent requests must not serialize
//! through a shared sleep, exactly as concurrent Azure calls don't.

use std::time::Duration;

use crate::util::Rng;

/// Per-handle latency/fault model.
#[derive(Debug, Clone)]
pub struct LatencyInjector {
    mean: f64,
    jitter: f64,
    drop_prob: f64,
    rng: Rng,
}

impl LatencyInjector {
    /// `mean` seconds one-way, uniform ±`jitter` fraction, and a
    /// `drop_prob` chance that a fire-and-forget message is lost.
    pub fn new(mean: f64, jitter: f64, drop_prob: f64, seed: u64) -> Self {
        assert!(mean >= 0.0 && (0.0..=1.0).contains(&jitter));
        assert!((0.0..=1.0).contains(&drop_prob));
        Self { mean, jitter, drop_prob, rng: Rng::from_seed(seed) }
    }

    /// Zero-latency, lossless injector (unit tests, monitor, reducer).
    pub fn noop() -> Self {
        Self::new(0.0, 0.0, 0.0, 0)
    }

    /// Sample a one-way delay.
    pub fn sample_delay(&mut self) -> Duration {
        if self.mean <= 0.0 {
            return Duration::ZERO;
        }
        let factor = 1.0 + self.jitter * (self.rng.f64() * 2.0 - 1.0);
        Duration::from_secs_f64((self.mean * factor).max(0.0))
    }

    /// Whether to drop the next fire-and-forget message.
    pub fn should_drop(&mut self) -> bool {
        self.drop_prob > 0.0 && self.rng.bool(self.drop_prob)
    }

    /// Blocking sleep for one sampled delay (callers run on their own
    /// threads — the whole point of the thread-per-worker design).
    pub fn delay(&mut self) {
        let d = self.sample_delay();
        if d > Duration::ZERO {
            std::thread::sleep(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_is_instant_and_lossless() {
        let mut l = LatencyInjector::noop();
        assert_eq!(l.sample_delay(), Duration::ZERO);
        assert!(!l.should_drop());
    }

    #[test]
    fn jitter_stays_in_band() {
        let mut l = LatencyInjector::new(0.010, 0.5, 0.0, 42);
        for _ in 0..1000 {
            let d = l.sample_delay().as_secs_f64();
            assert!((0.005..=0.015).contains(&d), "{d}");
        }
    }

    #[test]
    fn same_seed_gives_identical_delay_and_drop_sequences() {
        // Reproducibility contract: a run's injected "network" is a pure
        // function of its seed, so experiments replay exactly.
        let sample = |seed: u64| -> (Vec<Duration>, Vec<bool>) {
            let mut l = LatencyInjector::new(0.004, 0.6, 0.2, seed);
            (0..500).map(|_| (l.sample_delay(), l.should_drop())).unzip()
        };
        let (d1, k1) = sample(1234);
        let (d2, k2) = sample(1234);
        assert_eq!(d1, d2, "delay sequence must be seed-deterministic");
        assert_eq!(k1, k2, "drop sequence must be seed-deterministic");
        let (d3, k3) = sample(1235);
        assert!(d1 != d3 || k1 != k3, "different seeds must diverge");
    }

    #[test]
    fn cloned_injector_replays_the_original_stream() {
        // Handles re-seed clones explicitly (with_latency); a plain clone
        // must carry the RNG state so both sides replay identically.
        let original = LatencyInjector::new(0.002, 0.3, 0.1, 42);
        let mut a = original.clone();
        let mut b = original;
        for _ in 0..200 {
            assert_eq!(a.sample_delay(), b.sample_delay());
            assert_eq!(a.should_drop(), b.should_drop());
        }
    }

    #[test]
    fn drop_probability_is_respected() {
        let mut l = LatencyInjector::new(0.0, 0.0, 0.3, 7);
        let drops = (0..10_000).filter(|_| l.should_drop()).count();
        let frac = drops as f64 / 10_000.0;
        assert!((frac - 0.3).abs() < 0.03, "{frac}");
    }
}
