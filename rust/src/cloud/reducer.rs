//! The dedicated reducer — “a dedicated unit permanently modifies the
//! shared version with the latest updates received from the other machines
//! without any synchronization barrier” (paper, Section 4).

use std::sync::mpsc;

use anyhow::Result;

use crate::vq::Codebook;

use super::blob::BlobHandle;
use super::queue::DeltaMsg;

/// What the reducer reports when the queue closes.
#[derive(Debug, Clone, PartialEq)]
pub struct ReducerReport {
    /// Deltas folded into the shared version.
    pub merges: u64,
    /// Final shared version.
    pub final_shared: Codebook,
    /// Final published version number.
    pub final_version: u64,
}

/// Run the reducer until every queue sender is gone: pop deltas, fold
/// `w_srd ← w_srd − Δ`, publish to the blob. Folding is barrier-free —
/// whatever arrives next is applied next. Runs on the caller's thread
/// (the runner gives it a dedicated one).
pub fn run_reducer(
    rx: mpsc::Receiver<DeltaMsg>,
    mut blob: BlobHandle,
    w0: Codebook,
) -> Result<ReducerReport> {
    let mut w_srd = w0;
    let mut merges: u64 = 0;
    for msg in rx.iter() {
        w_srd.apply_delta(&msg.delta);
        merges += 1;
        // Publish every fold; a real deployment may batch publishes, which
        // only increases staleness the protocol already tolerates.
        blob.put(w_srd.clone(), merges)?;
    }
    Ok(ReducerReport { merges, final_shared: w_srd, final_version: merges })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cloud::blob::BlobService;
    use crate::cloud::queue::QueueService;
    use crate::vq::Delta;

    #[test]
    fn folds_every_delta_exactly_once() {
        let w0 = Codebook::from_flat(1, 2, vec![10.0, 10.0]);
        let blob = BlobService::spawn(w0.clone());
        let (qh, rx) = QueueService::create(16);
        let blob_r = blob.clone();
        let w0_r = w0.clone();
        let reducer =
            std::thread::spawn(move || run_reducer(rx, blob_r, w0_r));

        let mut q = qh.clone();
        for seq in 0..4u64 {
            q.push(DeltaMsg {
                worker: 0,
                seq,
                delta: Delta::from_flat(1, 2, vec![1.0, 2.0]),
            })
            .unwrap();
        }
        drop(q);
        drop(qh);
        let report = reducer.join().unwrap().unwrap();
        assert_eq!(report.merges, 4);
        // 10 - 4*1 = 6 ; 10 - 4*2 = 2
        assert_eq!(report.final_shared.flat(), &[6.0, 2.0]);
        let (published, v) = blob.clone().get().unwrap();
        assert_eq!(published, report.final_shared);
        assert_eq!(v, 4);
    }
}
