//! A cloud worker: the per-VM loop of CloudDALVQ.
//!
//! Each worker runs on its own OS thread (PJRT clients are
//! thread-confined), computing `τ`-point chunks with its private engine and
//! exchanging displacements through the storage services **without ever
//! blocking on other workers**: uploads/downloads run on a short-lived
//! exchange thread, and the worker folds a completed download in at the
//! next chunk boundary — the paper's “as soon as its previous uploads and
//! downloads are completed”.

use std::sync::{mpsc, Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::data::Shard;
use crate::runtime::EngineSpec;
use crate::vq::{Codebook, Delta, Schedule};

use super::blob::BlobHandle;
use super::queue::{DeltaMsg, QueueHandle};

/// Static parameters of one worker.
pub struct WorkerParams {
    pub worker_id: usize,
    pub shard: Shard,
    pub w0: Codebook,
    pub schedule: Schedule,
    /// Chunk size (the τ of the paper).
    pub tau: usize,
    /// Points between exchange attempts (a multiple of τ).
    pub points_per_exchange: usize,
    /// Total points this worker processes.
    pub points_budget: u64,
    /// Real seconds of compute per point (self-pacing; see
    /// [`crate::config::CloudConfig::point_compute`]).
    pub point_compute: f64,
    pub engine_spec: EngineSpec,
    /// Fleet start barrier: workers build their engines (PJRT compilation
    /// can take seconds), then rendezvous here before the measured run —
    /// the paper's curves measure convergence, not VM boot.
    pub ready: Arc<Barrier>,
}

/// What a worker reports at the end of its run.
#[derive(Debug, Clone)]
pub struct WorkerOutcome {
    pub worker_id: usize,
    pub final_w: Codebook,
    pub points_done: u64,
    pub exchanges_started: u64,
    pub exchanges_completed: u64,
    /// Messages lost to fault injection (at-most-once transport).
    pub pushes_dropped: u64,
}

/// The worker loop. Call from a dedicated thread.
pub fn run_worker(
    params: WorkerParams,
    queue: QueueHandle,
    blob: BlobHandle,
) -> Result<WorkerOutcome> {
    assert!(
        params.points_per_exchange % params.tau == 0,
        "points_per_exchange must be a multiple of tau"
    );
    let mut engine = params.engine_spec.build()?;
    params.ready.wait();
    let dim = params.shard.dim();
    let kappa = params.w0.kappa();
    let mut w = params.w0.clone();
    let mut delta_window = Delta::zeros(kappa, dim);
    let mut chunk_buf = vec![0.0f32; params.tau * dim];
    let mut eps_buf = vec![0.0f32; params.tau];
    let mut t: u64 = 0;
    let mut seq: u64 = 0;
    let mut exchanges_completed = 0u64;
    let mut pushes_dropped = 0u64;
    // In-flight exchange: completion arrives here as (downloaded shared
    // version, whether the upload survived transport).
    let mut in_flight: Option<mpsc::Receiver<(Codebook, bool)>> = None;
    let run_start = Instant::now();

    while t < params.points_budget {
        // Self-pace to the configured per-point compute rate.
        let target = params.point_compute * t as f64;
        let actual = run_start.elapsed().as_secs_f64();
        if target > actual {
            std::thread::sleep(Duration::from_secs_f64(target - actual));
        }
        params.shard.fill_chunk(t, params.tau, &mut chunk_buf);
        params.schedule.fill(t, &mut eps_buf);
        engine.vq_chunk(&mut w, &chunk_buf, &eps_buf, &mut delta_window)?;
        t += params.tau as u64;

        // Fold in a completed exchange, if any (non-blocking).
        if let Some(rx) = &in_flight {
            match rx.try_recv() {
                Ok((w_snap, delivered)) => {
                    // Rebase: shared version minus what we accumulated
                    // while the exchange was in flight (eq. 9).
                    w = w_snap;
                    w.apply_delta(&delta_window);
                    exchanges_completed += 1;
                    if !delivered {
                        pushes_dropped += 1;
                    }
                    in_flight = None;
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    return Err(anyhow!("exchange thread died"));
                }
            }
        }

        // Start a new exchange at window boundaries when the line is free.
        if in_flight.is_none() && t % params.points_per_exchange as u64 == 0 {
            in_flight = Some(start_exchange(
                "dalvq-xchg",
                params.worker_id,
                &mut seq,
                &mut delta_window,
                &queue,
                &blob,
            ));
        }
    }

    // Drain: wait for the in-flight exchange, then flush the tail window.
    if let Some(rx) = in_flight.take() {
        let (w_snap, delivered) =
            rx.recv().map_err(|_| anyhow!("exchange thread died during drain"))?;
        w = w_snap;
        w.apply_delta(&delta_window);
        exchanges_completed += 1;
        if !delivered {
            pushes_dropped += 1;
        }
    }
    if !delta_window.is_zero() {
        let rx = start_exchange(
            "dalvq-xchg",
            params.worker_id,
            &mut seq,
            &mut delta_window,
            &queue,
            &blob,
        );
        let (w_snap, delivered) =
            rx.recv().map_err(|_| anyhow!("flush exchange thread died"))?;
        w = w_snap; // delta_window is empty now; nothing to rebase
        exchanges_completed += 1;
        if !delivered {
            pushes_dropped += 1;
        }
    }

    Ok(WorkerOutcome {
        worker_id: params.worker_id,
        final_w: w,
        points_done: t,
        exchanges_started: seq,
        exchanges_completed,
        pushes_dropped,
    })
}

/// Snapshot the current window displacement and ship it on a short-lived
/// exchange thread; the returned receiver yields the downloaded shared
/// version. At most one exchange thread per worker exists at any time.
/// Shared with the serving fleet (`crate::serve`), which passes its own
/// `thread_prefix`.
pub(crate) fn start_exchange(
    thread_prefix: &str,
    worker_id: usize,
    seq: &mut u64,
    delta_window: &mut Delta,
    queue: &QueueHandle,
    blob: &BlobHandle,
) -> mpsc::Receiver<(Codebook, bool)> {
    let delta_snd = std::mem::replace(
        delta_window,
        Delta::zeros(delta_window.kappa(), delta_window.dim()),
    );
    let msg = DeltaMsg { worker: worker_id, seq: *seq, delta: delta_snd };
    *seq += 1;
    let (tx, rx) = mpsc::channel();
    let mut queue = queue.clone();
    let mut blob = blob.clone();
    std::thread::Builder::new()
        .name(format!("{thread_prefix}-{worker_id}"))
        .spawn(move || {
            let delivered = queue.push(msg).unwrap_or(false);
            if let Ok((w_snap, _version)) = blob.get() {
                let _ = tx.send((w_snap, delivered));
            }
        })
        .expect("spawning exchange thread");
    rx
}
