//! The cloud driver: wires services, reducer, monitor and `M` workers into
//! one run — the programmatic form of `dalvq figures --fig 4`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::config::{CloudConfig, ExperimentConfig};
use crate::metrics::Series;
use crate::vq::{init_codebook, Codebook};

use super::blob::BlobService;
use super::latency::LatencyInjector;
use super::monitor::{run_monitor, MonitorConfig};
use super::queue::QueueService;
use super::reducer::run_reducer;
use super::worker::{run_worker, WorkerOutcome, WorkerParams};

/// Everything a cloud run produces.
pub struct CloudOutcome {
    /// `(real seconds, C)` curve of the published shared version.
    pub series: Series,
    pub final_shared: Codebook,
    /// Deltas folded by the reducer.
    pub merges: u64,
    pub workers: Vec<WorkerOutcome>,
}

/// Run the asynchronous scheme on the real-concurrency cloud runtime:
/// `M` worker threads, a blob service, a queue service, the dedicated
/// reducer and the monitor.
pub fn run_cloud(cfg: &ExperimentConfig, cloud: &CloudConfig) -> Result<CloudOutcome> {
    cfg.validate()?;
    let tau = cfg.scheme.tau();
    if cloud.points_per_exchange % tau != 0 {
        return Err(anyhow!(
            "cloud.points_per_exchange = {} must be a multiple of tau = {tau}",
            cloud.points_per_exchange
        ));
    }
    let dataset = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);
    let shards = dataset.split(cfg.m);
    let w0 = init_codebook(
        cfg.vq.init,
        cfg.vq.kappa,
        cfg.dim(),
        dataset.flat(),
        cfg.seed,
    );
    let eval_points = cfg.data.mixture.eval_sample(cfg.data.eval_points, cfg.seed);

    let blob = BlobService::spawn(w0.clone());
    let (queue, queue_rx) = QueueService::create(1024);
    // Workers + the runner rendezvous once engines are built, so the
    // monitor clock starts at fleet-ready (not at first-VM-boot).
    let ready = Arc::new(Barrier::new(cfg.m + 1));

    // Reducer: dedicated thread, zero-latency blob path (it co-locates
    // with storage in CloudDALVQ; workers see publish latency on reads).
    let reducer = {
        let blob = blob.clone();
        let w0 = w0.clone();
        std::thread::Builder::new()
            .name("dalvq-reducer".into())
            .spawn(move || run_reducer(queue_rx, blob, w0))
            .expect("spawning reducer thread")
    };

    // Workers: one thread each, private engine, private seeded latency
    // injectors (their "network path" to the services).
    let mut joins = Vec::with_capacity(cfg.m);
    for (i, shard) in shards.into_iter().enumerate() {
        let params = WorkerParams {
            worker_id: i,
            shard,
            w0: w0.clone(),
            schedule: cfg.vq.schedule,
            tau,
            points_per_exchange: cloud.points_per_exchange,
            points_budget: cfg.run.points_per_worker,
            point_compute: cloud.point_compute,
            engine_spec: cfg.engine.clone(),
            ready: Arc::clone(&ready),
        };
        let q = queue.clone().with_latency(LatencyInjector::new(
            cloud.service_latency,
            cloud.latency_jitter,
            cloud.drop_prob,
            cfg.seed ^ ((i as u64) << 8),
        ));
        let b = blob.clone().with_latency(LatencyInjector::new(
            cloud.service_latency,
            cloud.latency_jitter,
            0.0, // blob reads are request/response; loss shows as latency
            cfg.seed ^ ((i as u64) << 8) ^ 1,
        ));
        joins.push(
            std::thread::Builder::new()
                .name(format!("dalvq-worker-{i}"))
                .spawn(move || run_worker(params, q, b))
                .expect("spawning worker thread"),
        );
    }

    // Rendezvous: all engines built; start the measured clock + monitor.
    ready.wait();
    let start = Instant::now();
    let stop = Arc::new(AtomicBool::new(false));
    let monitor = {
        let blob = blob.clone();
        let stop = Arc::clone(&stop);
        let mcfg = MonitorConfig {
            interval: cfg.run.eval_interval,
            eval_points,
            dim: cfg.dim(),
        };
        std::thread::Builder::new()
            .name("dalvq-monitor".into())
            .spawn(move || run_monitor(mcfg, blob, start, stop))
            .expect("spawning monitor thread")
    };

    let mut workers: Vec<WorkerOutcome> = Vec::with_capacity(cfg.m);
    for j in joins {
        workers.push(j.join().map_err(|_| anyhow!("worker panicked"))??);
    }
    // All workers done: close the queue so the reducer drains and exits.
    drop(queue);
    let report = reducer.join().map_err(|_| anyhow!("reducer panicked"))??;
    // Let the monitor take its final sample and stop.
    stop.store(true, Ordering::Release);
    let mut series = monitor.join().map_err(|_| anyhow!("monitor panicked"))??;
    series.name = format!("M={}", cfg.m);
    series.points_processed = workers.iter().map(|w| w.points_done).sum();
    series.merges = report.merges;

    Ok(CloudOutcome {
        series,
        final_shared: report.final_shared,
        merges: report.merges,
        workers,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{CloudConfig, SchemeConfig};
    use crate::sim::DelayModel;

    fn tiny_cfg(m: usize) -> ExperimentConfig {
        let mut cfg = ExperimentConfig::default();
        cfg.m = m;
        cfg.data.mixture.components = 4;
        cfg.data.mixture.dim = 2;
        cfg.data.n_total = 2_000;
        cfg.data.eval_points = 256;
        cfg.vq.kappa = 4;
        cfg.run.points_per_worker = 5_000;
        cfg.run.eval_interval = 0.005;
        // stable step envelope for M*window*eps/kappa (see Schedule docs)
        cfg.vq.schedule =
            crate::vq::Schedule::InverseTime { eps0: 0.005, half_life: 5000.0 };
        cfg.scheme = SchemeConfig::AsyncDelta {
            tau: 10,
            up_delay: DelayModel::Instant,
            down_delay: DelayModel::Instant,
        };
        cfg
    }

    #[test]
    fn cloud_run_converges_and_accounts_all_points() {
        let cfg = tiny_cfg(4);
        let cloud = CloudConfig {
            service_latency: 0.0005,
            latency_jitter: 0.5,
            drop_prob: 0.0,
            points_per_exchange: 50,
            point_compute: 1e-5,
        };
        let out = run_cloud(&cfg, &cloud).unwrap();
        assert_eq!(out.series.points_processed, 4 * 5_000);
        assert!(out.merges > 0);
        assert!(out.final_shared.is_finite());
        assert!(
            out.series.last_value() < out.series.first_value(),
            "{} -> {}",
            out.series.first_value(),
            out.series.last_value()
        );
        // no drops configured -> every started exchange delivered
        for w in &out.workers {
            assert_eq!(w.pushes_dropped, 0);
        }
    }

    #[test]
    fn cloud_tolerates_message_drops() {
        let cfg = tiny_cfg(3);
        let cloud = CloudConfig {
            service_latency: 0.0002,
            latency_jitter: 0.2,
            drop_prob: 0.3,
            points_per_exchange: 50,
            point_compute: 1e-5,
        };
        let out = run_cloud(&cfg, &cloud).unwrap();
        let dropped: u64 = out.workers.iter().map(|w| w.pushes_dropped).sum();
        assert!(dropped > 0, "fault injection should have dropped something");
        assert!(out.final_shared.is_finite());
        // the algorithm degrades gracefully: still descending
        assert!(out.series.last_value() < out.series.first_value());
    }
}
