//! The queue service: worker → reducer delta transport
//! (the Azure QueueStorage role in CloudDALVQ).

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::vq::Delta;

use super::LatencyInjector;

/// One displacement message from a worker.
#[derive(Debug, Clone)]
pub struct DeltaMsg {
    pub worker: usize,
    /// The worker's exchange sequence number (for tracing / tests).
    pub seq: u64,
    pub delta: Delta,
}

/// The queue service is a bounded channel; ordering across workers is
/// arrival order (like a real cloud queue, no global ordering guarantee
/// beyond per-sender FIFO).
pub struct QueueService;

impl QueueService {
    /// Create the queue; the receiver side goes to the reducer.
    pub fn create(capacity: usize) -> (QueueHandle, mpsc::Receiver<DeltaMsg>) {
        let (tx, rx) = mpsc::sync_channel(capacity);
        (QueueHandle { tx, latency: LatencyInjector::noop() }, rx)
    }
}

/// A worker-side handle with its own latency/fault injector.
#[derive(Clone)]
pub struct QueueHandle {
    tx: mpsc::SyncSender<DeltaMsg>,
    latency: LatencyInjector,
}

impl QueueHandle {
    pub fn with_latency(mut self, latency: LatencyInjector) -> Self {
        self.latency = latency;
        self
    }

    /// Push a delta. Injects one-way latency; may drop the message
    /// entirely when fault injection is enabled (at-most-once transport —
    /// the stochastic-gradient algorithm tolerates lost updates, which the
    /// robustness tests exercise). Returns whether the message was
    /// delivered.
    pub fn push(&mut self, msg: DeltaMsg) -> Result<bool> {
        if self.latency.should_drop() {
            return Ok(false);
        }
        self.latency.delay();
        self.tx
            .send(msg)
            .map_err(|_| anyhow!("queue service stopped"))?;
        Ok(true)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_pop_preserves_per_sender_fifo() {
        let (h, rx) = QueueService::create(64);
        let mut h1 = h.clone();
        for seq in 0..5u64 {
            h1.push(DeltaMsg { worker: 1, seq, delta: Delta::zeros(1, 1) })
                .unwrap();
        }
        drop(h);
        drop(h1);
        let seqs: Vec<u64> = rx.iter().map(|m| m.seq).collect();
        assert_eq!(seqs, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn fault_injection_is_seed_deterministic() {
        // Same seed => the same subset of pushes is dropped, run after
        // run — the fault-injection experiments replay exactly.
        let run = |seed: u64| -> Vec<bool> {
            let (h, rx) = QueueService::create(256);
            let mut hd =
                h.clone().with_latency(LatencyInjector::new(0.0, 0.0, 0.4, seed));
            let delivered: Vec<bool> = (0..200u64)
                .map(|seq| {
                    hd.push(DeltaMsg { worker: 0, seq, delta: Delta::zeros(1, 1) })
                        .unwrap()
                })
                .collect();
            drop(h);
            drop(hd);
            // what the reducer side sees must match the sender's view
            let received: Vec<u64> = rx.iter().map(|m| m.seq).collect();
            let survivors: Vec<u64> = delivered
                .iter()
                .enumerate()
                .filter(|(_, d)| **d)
                .map(|(i, _)| i as u64)
                .collect();
            assert_eq!(received, survivors);
            delivered
        };
        let a = run(77);
        let b = run(77);
        assert_eq!(a, b, "drop pattern must be identical for the same seed");
        assert!(a.iter().any(|d| !d), "p=0.4 over 200 pushes must drop some");
        assert_ne!(a, run(78), "a different seed must drop differently");
    }

    #[test]
    fn dropping_injector_loses_messages() {
        let (h, rx) = QueueService::create(64);
        let mut hd =
            h.clone().with_latency(LatencyInjector::new(0.0, 0.0, 1.0, 3));
        assert!(!hd
            .push(DeltaMsg { worker: 0, seq: 0, delta: Delta::zeros(1, 1) })
            .unwrap());
        drop(h);
        drop(hd);
        assert!(rx.iter().next().is_none());
    }
}
