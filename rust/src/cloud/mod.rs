//! The cloud runtime — a faithful analogue of the paper's CloudDALVQ
//! implementation on Windows Azure (Figure 4), built on real concurrency.
//!
//! Architecture (mirrors the paper's Section 4 description and the
//! CloudDALVQ codebase it references):
//!
//! * **Workers** (`M` of them — Azure *VMs* there, dedicated OS threads
//!   here, each with its own [`crate::runtime::Engine`]) run the local VQ
//!   walk on their shard and exchange displacements without any barrier.
//! * **Queue service** ([`QueueService`], Azure QueueStorage there) carries
//!   worker deltas to the reducer, with injected transfer latency and
//!   optional message drops (fault injection).
//! * **Reducer** ([`run_reducer`], the paper's “dedicated unit [that]
//!   permanently modifies the shared version with the latest updates …
//!   without any synchronization barrier”) folds deltas as they arrive
//!   and publishes the shared version.
//! * **Blob service** ([`BlobService`], Azure BlobStorage there) stores the
//!   current shared version; workers download it with injected latency.
//! * **Monitor** ([`run_monitor`]) samples the shared version on a real
//!   wall-clock cadence and records the `C_{n,M}` curve — the series
//!   behind Figure 4.
//!
//! Concurrency substrate: plain OS threads and channels (the offline build
//! carries no async runtime). This is, if anything, *closer* to the
//! paper's deployment than green threads would be: every worker is a real
//! preemptively-scheduled execution unit, like a VM, and every service
//! interaction crosses a real thread boundary with injected latency.
//!
//! The substitution argument (DESIGN.md): the paper's claims concern the
//! coordination protocol under slow, unreliable communication. Replacing
//! Azure services with in-process services that inject the same latency
//! distributions preserves every protocol-visible behaviour — staleness,
//! stragglers, barrier-freedom — while making the experiment reproducible
//! on one machine.

mod blob;
mod latency;
mod monitor;
mod queue;
mod reducer;
mod runner;
mod worker;

pub use blob::{BlobHandle, BlobService};
pub use latency::LatencyInjector;
pub use monitor::{run_monitor, MonitorConfig};
pub use queue::{DeltaMsg, QueueHandle, QueueService};
pub use reducer::{run_reducer, ReducerReport};
pub use runner::{run_cloud, CloudOutcome};
pub use worker::{run_worker, WorkerOutcome, WorkerParams};

pub(crate) use worker::start_exchange;
