//! The blob service: versioned storage of the shared prototypes
//! (the Azure BlobStorage role in CloudDALVQ).

use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::vq::Codebook;

use super::LatencyInjector;

enum Cmd {
    /// Replace the stored shared version (reducer only).
    Put { w: Codebook, version: u64 },
    /// Fetch the current shared version and its version number.
    Get { resp: mpsc::Sender<(Codebook, u64)> },
}

/// The service thread: owns the blob state, applies operations instantly
/// (latency is injected caller-side — see [`LatencyInjector`]).
pub struct BlobService;

impl BlobService {
    /// Spawn the service with an initial shared version; returns the
    /// template handle (clone it per client, re-seeding the injector).
    /// The service thread exits when every handle is dropped.
    pub fn spawn(initial: Codebook) -> BlobHandle {
        let (tx, rx) = mpsc::channel::<Cmd>();
        std::thread::Builder::new()
            .name("dalvq-blob".into())
            .spawn(move || {
                let mut state = initial;
                let mut version = 0u64;
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Put { w, version: v } => {
                            state = w;
                            version = v;
                        }
                        Cmd::Get { resp } => {
                            let _ = resp.send((state.clone(), version));
                        }
                    }
                }
            })
            .expect("spawning blob service thread");
        BlobHandle { tx, latency: LatencyInjector::noop() }
    }
}

/// A client handle to the blob service with its own latency injector.
#[derive(Clone)]
pub struct BlobHandle {
    tx: mpsc::Sender<Cmd>,
    latency: LatencyInjector,
}

impl BlobHandle {
    /// Re-seed this handle's latency injector (per-client network path).
    pub fn with_latency(mut self, latency: LatencyInjector) -> Self {
        self.latency = latency;
        self
    }

    /// Download the shared version (one-way latency each direction).
    pub fn get(&mut self) -> Result<(Codebook, u64)> {
        self.latency.delay(); // request travels
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Get { resp: tx })
            .map_err(|_| anyhow!("blob service stopped"))?;
        let out = rx.recv().map_err(|_| anyhow!("blob service dropped reply"))?;
        self.latency.delay(); // response travels
        Ok(out)
    }

    /// Upload a new shared version (reducer's publish path).
    pub fn put(&mut self, w: Codebook, version: u64) -> Result<()> {
        self.latency.delay();
        self.tx
            .send(Cmd::Put { w, version })
            .map_err(|_| anyhow!("blob service stopped"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_then_get_round_trips() {
        let w0 = Codebook::from_flat(1, 2, vec![0.0, 0.0]);
        let mut h = BlobService::spawn(w0.clone());
        let (got, v) = h.get().unwrap();
        assert_eq!(got, w0);
        assert_eq!(v, 0);
        let w1 = Codebook::from_flat(1, 2, vec![1.0, 2.0]);
        h.put(w1.clone(), 7).unwrap();
        let (got, v) = h.get().unwrap();
        assert_eq!(got, w1);
        assert_eq!(v, 7);
    }

    #[test]
    fn concurrent_clients_see_coherent_state() {
        let w0 = Codebook::from_flat(1, 1, vec![0.0]);
        let h = BlobService::spawn(w0);
        let mut joins = Vec::new();
        for i in 0..8u64 {
            let mut hc = h.clone();
            joins.push(std::thread::spawn(move || {
                hc.put(Codebook::from_flat(1, 1, vec![i as f32]), i).unwrap();
                hc.get().unwrap()
            }));
        }
        for j in joins {
            let (w, v) = j.join().unwrap();
            // whatever version we read, state and version must be coherent
            assert_eq!(w.flat()[0] as u64, v);
        }
    }
}
