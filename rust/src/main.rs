//! `dalvq` — the CLI launcher for the parallel-VQ reproduction.
//!
//! ```text
//! dalvq figures --fig all            # regenerate paper Figures 1-4
//! dalvq figures --fig 2 --points 50000 --out-dir results
//! dalvq figures --fig 2 --pjrt-variant k16d16   # hot path on artifacts
//! dalvq ablate --param tau           # §3 merge-frequency ablation
//! dalvq ablate --param delay         # §4 delay-sensitivity ablation
//! dalvq run --preset quickstart      # one experiment (PJRT engine)
//! dalvq run --config my.json         # one experiment from a JSON config
//! dalvq run --preset quickstart --print-config  # dump effective config
//! dalvq baseline --kind batch --m 8  # batch k-means baseline
//! dalvq serve                        # online VQ service (TCP front-end)
//! dalvq loadtest --preset serve      # drive an in-process service
//! dalvq top --addr 127.0.0.1:7171    # live telemetry view of a server
//! dalvq trace --addr 127.0.0.1:7171  # sampled distributed traces
//! dalvq info                         # artifact manifest summary
//! ```
//!
//! (Argument parsing is hand-rolled: the offline build carries no clap.)

use std::path::PathBuf;
use std::process::ExitCode;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use dalvq::baselines;
use dalvq::config::presets::ServePreset;
use dalvq::config::{presets, ExperimentConfig, FigureConfig};
use dalvq::coordinator::Orchestrator;
use dalvq::runtime::{EngineSpec, Manifest};
use dalvq::serve::{LoadSpec, Server, VqService};
use dalvq::sim::Evaluator;
use dalvq::vq::init_codebook;

const USAGE: &str = "\
dalvq — Distributed Asynchronous Learning Vector Quantization
reproduction of Durut, Patra & Rossi (2012)

USAGE:
  dalvq <COMMAND> [OPTIONS]

COMMANDS:
  figures    regenerate paper figures (1-3: simulator, 4: cloud runtime)
  ablate     run the DESIGN.md ablations
  run        run a single experiment from a preset or JSON config
  baseline   run a k-means baseline
  serve      run the online VQ service (ingest + query over TCP)
  loadtest   drive a service with concurrent load; print a latency report
  top        live per-op/per-shard telemetry view of a running server
  trace      fetch and print a server's sampled distributed traces
  state      inspect a --state-dir (manifest, per-shard checkpoints)
  info       print the AOT artifact manifest summary
  help       show this message

OPTIONS (figures):
  --fig <1|2|3|4|all>        which figure [default: all]
  --points <N>               override points per worker
  --pjrt-variant <NAME>      run on the PJRT engine with this variant
  --artifacts-dir <DIR>      artifacts directory [default: artifacts]

OPTIONS (ablate):
  --param <tau|delay>        which ablation family
  --points <N>               override points per worker

OPTIONS (run):
  --preset <quickstart|fig2-single>
  --config <FILE.json>
  --print-config             dump the effective config as JSON and exit

OPTIONS (baseline):
  --kind <batch|minibatch>   [default: batch]
  --m <N>                    virtual workers [default: 8]
  --iters <N>                iterations/steps [default: 50]

OPTIONS (serve):
  --preset <serve>           deployment preset [default: serve]
  --addr <HOST:PORT>         bind address [default: 127.0.0.1:0]
  --duration <SECS>          serve for N seconds then exit [default: forever]
  --shards <S>               codebook shards behind the coarse-quantizer
                             router (kappa must divide by S) [default: 1]
  --probe <N>                shards probed per query point
                             [default: min(2, S)]
  --state-dir <DIR>          durable state: checkpoint shards here and
                             warm-restart from it [default: none]
  --checkpoint-every <N>     folds between automatic shard checkpoints
                             [default: 64]
  --rebalance-skew <R>       auto-rebalance when max/mean per-shard ingest
                             exceeds R (needs --state-dir; 0 = off)
  --rebalance-min-folds <N>  folds that must land in a router epoch before
                             the skew trigger may fire [default: 64]
  --follow <HOST:PORT>       start as a READ-ONLY FOLLOWER of the leader at
                             this address: restore from its shipped
                             checkpoints, keep re-syncing, answer writes
                             with NotLeader. Topology (shards/kappa/dim)
                             is adopted from the leader; --probe applies
                             (clamped to the leader's shard count), and
                             --state-dir mirrors the bundles locally
  --sync-every <MS>          follower sync-poll interval in milliseconds
                             [default: 500]
  --miss-threshold <N>       AUTOMATIC FAILOVER: after N consecutive missed
                             sync polls a mirrored follower promotes itself
                             to leader from its local mirror (needs
                             --follow and --state-dir; 0 = off)
  --metrics-file <FILE>      write periodic telemetry snapshots (counters,
                             gauges, latency digests, recent events) to
                             this file as JSON, plus once at shutdown
  --metrics-every <MS>       milliseconds between snapshots [default: 1000]
  --slow-query-us <N>        journal any request slower than N microseconds
                             with its route/scan stage breakdown (0 = off);
                             with tracing armed, also always keep the
                             slow request's trace
  --trace-sample <N>         distributed tracing: keep the full span tree
                             of one request in N (1 = every request,
                             0 = off). Sampled traces are served by the
                             Trace wire op / `dalvq trace`, carried in
                             --metrics-file snapshots, and joined across
                             processes on the replication path
  --journal-capacity <N>     event-journal ring size, entries retained
                             [default: 256; min 16]
  --batch-window-us <N>      coalesce concurrent read requests for up to N
                             microseconds into one fused multi-probe scan
                             (answers stay bit-identical; 0 = off)
  --batch-max-points <N>     drain a coalesced batch early once it holds
                             this many points [default: 4096]
  --io-workers <N>           request-handler threads behind the event loop
                             [default: 0 = one per available core]
  --max-inflight <N>         per-connection in-flight request quota; excess
                             requests answer Throttled in-band (0 = off)
  --rate-limit <N>           per-connection requests/second token bucket
                             (one-second burst); excess answers Throttled
                             with a retry-after hint (0 = off)
  --brownout-depth <N>       brownout watermark: shed ingest (Throttled)
                             while any shard.<s>.queue_depth gauge is at
                             or above N; reads keep flowing (0 = off)

OPTIONS (top):
  --addr <HOST:PORT>         server to poll (required)
  --interval <MS>            milliseconds between redraws [default: 1000]
  --iterations <N>           screens to draw then exit [default: forever]

OPTIONS (trace):
  --addr <HOST:PORT>         server to poll (required)
  --max <N>                  newest traces to fetch [default: 4]

OPTIONS (state):
  inspect --state-dir <DIR>    print the manifest, router epoch and
                               per-shard checkpoints (incl. ingest load)
  rebalance --state-dir <DIR>  retrain the router from the checkpointed
                               codebooks (ingest-weighted) and migrate
                               prototype rows; bumps the router version.
                               The directory must be quiesced (no live
                               serve process writing it).
    --iters <N>                Lloyd iterations of the retrain [default: 8]
    --seed <N>                 retrain seed [default: 42]

OPTIONS (loadtest):
  --preset <serve>           preset for the in-process service + workload
  --addr <HOST:PORT>         drive an already-running service instead
  --connections <N>          concurrent connections [default: 8]
  --requests <N>             requests per connection [default: 200]
  --batch <N>                points per request [default: 64]
  --pipeline <N>             requests kept in flight per connection before
                             reading replies (1 = classic request/reply)
  --ingest-frac <F>          fraction of ingest requests [default: 0.25]
  --skew <S>                 zipf exponent skewing the workload across
                             mixture components (0 = balanced) — the
                             reproducible hot-shard scenario
  --read-only                issue no ingest at all (reads rotate
                             encode/nearest/distortion) — the workload
                             for read-only followers
  --trace                    stamp a wire trace context on every 16th
                             request; the report prints the slowest traced
                             request's id and server-side span breakdown
  --shards <S>               shard the in-process service [default: 1]
  --probe <N>                shards probed per query [default: min(2, S)]

GLOBAL OPTIONS:
  --out-dir <DIR>            write CSV/JSON reports here
  --quiet                    suppress report tables
";

/// Tiny argument scanner: flags with optional values.
struct Args {
    argv: Vec<String>,
}

impl Args {
    fn take_flag(&mut self, name: &str) -> bool {
        if let Some(i) = self.argv.iter().position(|a| a == name) {
            self.argv.remove(i);
            true
        } else {
            false
        }
    }

    fn take_value(&mut self, name: &str) -> Result<Option<String>> {
        if let Some(i) = self.argv.iter().position(|a| a == name) {
            if i + 1 >= self.argv.len() {
                bail!("{name} requires a value");
            }
            self.argv.remove(i);
            Ok(Some(self.argv.remove(i)))
        } else {
            Ok(None)
        }
    }

    fn finish(self) -> Result<()> {
        if self.argv.is_empty() {
            Ok(())
        } else {
            bail!("unrecognized arguments: {:?}\n\n{USAGE}", self.argv)
        }
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(()) => ExitCode::SUCCESS,
        Err(e) => {
            eprintln!("error: {e:#}");
            ExitCode::FAILURE
        }
    }
}

fn run() -> Result<()> {
    let mut argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() {
        println!("{USAGE}");
        return Ok(());
    }
    let cmd = argv.remove(0);
    let mut args = Args { argv };

    let out_dir = args.take_value("--out-dir")?.map(PathBuf::from);
    let quiet = args.take_flag("--quiet");
    let orch = Orchestrator { out_dir, quiet };

    match cmd.as_str() {
        "figures" => {
            let which = args.take_value("--fig")?.unwrap_or_else(|| "all".into());
            let points = parse_opt_u64(&mut args, "--points")?;
            let pjrt_variant = args.take_value("--pjrt-variant")?;
            let artifacts_dir = PathBuf::from(
                args.take_value("--artifacts-dir")?
                    .unwrap_or_else(|| "artifacts".into()),
            );
            args.finish()?;
            let mut figs: Vec<FigureConfig> = match which.as_str() {
                "1" => vec![presets::fig1()],
                "2" => vec![presets::fig2()],
                "3" => vec![presets::fig3()],
                "4" => vec![presets::fig4()],
                "all" => vec![
                    presets::fig1(),
                    presets::fig2(),
                    presets::fig3(),
                    presets::fig4(),
                ],
                other => bail!("unknown figure {other:?} (want 1|2|3|4|all)"),
            };
            for f in figs.iter_mut() {
                if let Some(p) = points {
                    f.base.run.points_per_worker = p;
                }
                if let Some(v) = &pjrt_variant {
                    f.base.engine = EngineSpec::Pjrt {
                        artifacts_dir: artifacts_dir.clone(),
                        variant: v.clone(),
                    };
                }
            }
            orch.run_figures(&figs)?;
        }
        "ablate" => {
            let param = args
                .take_value("--param")?
                .ok_or_else(|| anyhow!("ablate requires --param tau|delay"))?;
            let points = parse_opt_u64(&mut args, "--points")?;
            args.finish()?;
            let mut figs = match param.as_str() {
                "tau" => presets::ablation_tau(),
                "delay" => presets::ablation_delay(),
                other => bail!("unknown ablation {other:?} (want tau|delay)"),
            };
            for f in figs.iter_mut() {
                if let Some(p) = points {
                    f.base.run.points_per_worker = p;
                }
            }
            orch.run_figures(&figs)?;
        }
        "run" => {
            let preset = args.take_value("--preset")?;
            let config = args.take_value("--config")?;
            let print_config = args.take_flag("--print-config");
            args.finish()?;
            let cfg: ExperimentConfig = match (preset.as_deref(), config) {
                (Some("quickstart"), None) => presets::quickstart(),
                (Some("fig2-single"), None) => {
                    let mut c = presets::fig2().base;
                    c.m = 10;
                    c
                }
                (Some(other), None) => {
                    bail!("unknown preset {other:?} (want quickstart|fig2-single)")
                }
                (None, Some(path)) => {
                    ExperimentConfig::from_file(&PathBuf::from(path))?
                }
                _ => bail!("pass exactly one of --preset / --config"),
            };
            if print_config {
                println!("{}", cfg.to_json_string());
                return Ok(());
            }
            let mut orch = orch;
            orch.quiet = false;
            orch.run_experiment(&cfg)?;
        }
        "baseline" => {
            let kind = args.take_value("--kind")?.unwrap_or_else(|| "batch".into());
            let m = parse_opt_u64(&mut args, "--m")?.unwrap_or(8) as usize;
            let iters = parse_opt_u64(&mut args, "--iters")?.unwrap_or(50);
            args.finish()?;
            let cfg = ExperimentConfig::default();
            let ds = cfg.data.mixture.dataset(cfg.data.n_total, cfg.seed);
            let w0 = init_codebook(
                dalvq::vq::InitMethod::KmeansPlusPlus,
                cfg.vq.kappa,
                cfg.dim(),
                ds.flat(),
                cfg.seed,
            );
            let mut engine = cfg.engine.build()?;
            let mut eval = Evaluator::new(
                cfg.data.mixture.eval_sample(cfg.data.eval_points, cfg.seed),
                cfg.dim(),
                cfg.run.eval_interval,
            );
            let out = match kind.as_str() {
                "batch" => baselines::batch_kmeans(
                    engine.as_mut(), &w0, ds.flat(), m, &cfg.cost, &mut eval,
                    iters, 1e-6,
                )?,
                "minibatch" => baselines::minibatch_kmeans(
                    engine.as_mut(), &w0, ds.flat(), 1024, m, &cfg.cost,
                    &mut eval, iters,
                )?,
                other => bail!("unknown baseline {other:?} (want batch|minibatch)"),
            };
            println!(
                "{}: {} iterations, C {:.6} -> {:.6} in {:.4}s virtual",
                out.series.name,
                out.iterations,
                out.series.first_value(),
                out.series.last_value(),
                out.series.last_wall()
            );
        }
        "serve" => {
            let preset = args.take_value("--preset")?.unwrap_or_else(|| "serve".into());
            let addr = args.take_value("--addr")?;
            let duration = parse_opt_u64(&mut args, "--duration")?;
            let shards = parse_opt_u64(&mut args, "--shards")?;
            let probe = parse_opt_u64(&mut args, "--probe")?;
            let state_dir = args.take_value("--state-dir")?.map(PathBuf::from);
            let checkpoint_every = parse_opt_u64(&mut args, "--checkpoint-every")?;
            let rebalance_skew = parse_opt_f64(&mut args, "--rebalance-skew")?;
            let rebalance_min_folds =
                parse_opt_u64(&mut args, "--rebalance-min-folds")?;
            let follow = args.take_value("--follow")?;
            let sync_every = parse_opt_u64(&mut args, "--sync-every")?;
            let miss_threshold =
                parse_opt_u64(&mut args, "--miss-threshold")?;
            let metrics_file = args.take_value("--metrics-file")?.map(PathBuf::from);
            let metrics_every = parse_opt_u64(&mut args, "--metrics-every")?;
            let slow_query_us = parse_opt_u64(&mut args, "--slow-query-us")?;
            let batch_window_us = parse_opt_u64(&mut args, "--batch-window-us")?;
            let batch_max_points =
                parse_opt_u64(&mut args, "--batch-max-points")?;
            let trace_sample = parse_opt_u64(&mut args, "--trace-sample")?;
            let journal_capacity =
                parse_opt_u64(&mut args, "--journal-capacity")?;
            let io_workers = parse_opt_u64(&mut args, "--io-workers")?;
            let max_inflight = parse_opt_u64(&mut args, "--max-inflight")?;
            let rate_limit = parse_opt_u64(&mut args, "--rate-limit")?;
            let brownout_depth = parse_opt_u64(&mut args, "--brownout-depth")?;
            args.finish()?;
            let mut p = serve_preset(&preset)?;
            apply_sharding(&mut p, shards, probe);
            if let Some(a) = addr {
                p.serve.addr = a;
            }
            if let Some(d) = state_dir {
                p.serve.state_dir = Some(d);
            }
            if let Some(n) = checkpoint_every {
                p.serve.checkpoint_every = n;
            }
            if let Some(r) = rebalance_skew {
                p.serve.rebalance_skew = r;
            }
            if let Some(n) = rebalance_min_folds {
                p.serve.rebalance_min_folds = n;
            }
            if let Some(l) = follow {
                p.serve.follow = Some(l);
            }
            if let Some(ms) = sync_every {
                p.serve.sync_every_ms = ms;
            }
            if let Some(n) = miss_threshold {
                p.serve.miss_threshold = n;
            }
            if let Some(f) = metrics_file {
                p.serve.metrics_file = Some(f);
            }
            if let Some(ms) = metrics_every {
                p.serve.metrics_every_ms = ms;
            }
            if let Some(us) = slow_query_us {
                p.serve.slow_query_us = us;
            }
            if let Some(us) = batch_window_us {
                p.serve.batch_window_us = us;
            }
            if let Some(n) = batch_max_points {
                p.serve.batch_max_points = n as usize;
            }
            if let Some(n) = trace_sample {
                p.serve.trace_sample = n;
            }
            if let Some(n) = journal_capacity {
                p.serve.journal_capacity = n as usize;
            }
            if let Some(n) = io_workers {
                p.serve.io_workers = n as usize;
            }
            if let Some(n) = max_inflight {
                p.serve.max_inflight = n as usize;
            }
            if let Some(n) = rate_limit {
                p.serve.rate_limit = n;
            }
            if let Some(n) = brownout_depth {
                p.serve.brownout_depth = n;
            }
            let service = VqService::start(&p.base, &p.serve)?;
            let server = Server::start(Arc::clone(&service), &p.serve.addr)?;
            match service.follower_of() {
                Some(leader) => println!(
                    "dalvq serve: READ-ONLY FOLLOWER of {leader} on {} \
                     ({} shards, kappa={}, probe={}, sync every {} ms)",
                    server.local_addr(),
                    service.shards(),
                    service.kappa(),
                    service.probe_n(),
                    p.serve.sync_every_ms,
                ),
                None => println!(
                    "dalvq serve: listening on {} (M={}x{} shards, kappa={}, \
                     dim={}, probe={})",
                    server.local_addr(),
                    p.base.m,
                    p.serve.shards,
                    p.base.vq.kappa,
                    p.base.dim(),
                    p.serve.probe_n,
                ),
            }
            if p.serve.miss_threshold > 0 {
                println!(
                    "dalvq serve: automatic failover armed — promote from \
                     the local mirror after {} consecutive missed polls",
                    p.serve.miss_threshold,
                );
            }
            if let Some(dir) = service.state_dir() {
                println!(
                    "dalvq serve: durable state in {} (checkpoint every {} \
                     folds/shard; router epoch {}; resumed at versions {:?})",
                    dir.display(),
                    p.serve.checkpoint_every,
                    service.router_version(),
                    service.shard_versions(),
                );
            }
            if p.serve.rebalance_skew > 0.0 {
                println!(
                    "dalvq serve: auto-rebalance at max/mean ingest skew > \
                     {:.2} (after {} folds/epoch)",
                    p.serve.rebalance_skew, p.serve.rebalance_min_folds,
                );
            }
            if let Some(f) = &p.serve.metrics_file {
                println!(
                    "dalvq serve: telemetry snapshots to {} every {} ms \
                     (`dalvq top --addr {}` for the live view)",
                    f.display(),
                    p.serve.metrics_every_ms,
                    server.local_addr(),
                );
            }
            if p.serve.slow_query_us > 0 {
                println!(
                    "dalvq serve: slow-query log armed at {} us",
                    p.serve.slow_query_us,
                );
            }
            if p.serve.batch_window_us > 0 {
                println!(
                    "dalvq serve: micro-batch coalescing armed ({} us window, \
                     {} point budget)",
                    p.serve.batch_window_us, p.serve.batch_max_points,
                );
            }
            if p.serve.trace_sample > 0 {
                println!(
                    "dalvq serve: distributed tracing armed (1 in {} requests; \
                     `dalvq trace --addr {}` to inspect)",
                    p.serve.trace_sample,
                    server.local_addr(),
                );
            }
            if p.serve.max_inflight > 0
                || p.serve.rate_limit > 0
                || p.serve.brownout_depth > 0
            {
                println!(
                    "dalvq serve: admission control armed (rate {}/s, \
                     in-flight {}, brownout depth {}; 0 = off)",
                    p.serve.rate_limit,
                    p.serve.max_inflight,
                    p.serve.brownout_depth,
                );
            }
            match duration {
                Some(secs) => {
                    std::thread::sleep(std::time::Duration::from_secs(secs))
                }
                None => loop {
                    std::thread::sleep(std::time::Duration::from_secs(60));
                    let s = service.stats();
                    match &s.leader_addr {
                        Some(leader) => println!(
                            "serve[follower of {leader}]: epoch {} version {} \
                             | lag {} folds | last sync {} ms ago | queries {}",
                            s.router_version,
                            s.version,
                            s.sync_lag_folds,
                            s.last_sync_ms,
                            s.queries,
                        ),
                        None => println!(
                            "serve: up {} s | epoch {} version {} | ingested \
                             {} (shed {}) | queries {} (encode {} / nearest \
                             {} / distortion {} / ingest {}) | shard ingest \
                             {:?}",
                            s.uptime_ms / 1000,
                            s.router_version,
                            s.version,
                            s.ingested,
                            s.ingest_shed,
                            s.queries,
                            s.op_encode,
                            s.op_nearest,
                            s.op_distortion,
                            s.op_ingest,
                            s.shard_ingest,
                        ),
                    }
                },
            }
            let s = service.stats();
            println!(
                "serve: stopping at version {} ({} points ingested, {} queries)",
                s.version, s.ingested, s.queries
            );
            server.shutdown()?;
            let out = service.shutdown()?;
            println!("serve: {} folds merged over the run", out.merges);
        }
        "loadtest" => {
            let preset = args.take_value("--preset")?.unwrap_or_else(|| "serve".into());
            let addr = args.take_value("--addr")?;
            let mut spec = LoadSpec::default();
            if let Some(n) = parse_opt_u64(&mut args, "--connections")? {
                spec.connections = n as usize;
            }
            if let Some(n) = parse_opt_u64(&mut args, "--requests")? {
                spec.requests_per_conn = n as usize;
            }
            if let Some(n) = parse_opt_u64(&mut args, "--batch")? {
                spec.batch_points = n as usize;
            }
            if let Some(n) = parse_opt_u64(&mut args, "--pipeline")? {
                spec.pipeline = n as usize;
            }
            if let Some(f) = parse_opt_f64(&mut args, "--ingest-frac")? {
                spec.ingest_frac = f;
            }
            if let Some(s) = parse_opt_f64(&mut args, "--skew")? {
                spec.skew = s;
            }
            spec.read_only = args.take_flag("--read-only");
            spec.trace = args.take_flag("--trace");
            let shards = parse_opt_u64(&mut args, "--shards")?;
            let probe = parse_opt_u64(&mut args, "--probe")?;
            args.finish()?;
            let mut p = serve_preset(&preset)?;
            apply_sharding(&mut p, shards, probe);
            spec.seed = p.base.seed;
            let report = match addr {
                // Drive an externally running service.
                Some(addr) => dalvq::serve::run_load(&addr, &spec, &p.base.data.mixture)?,
                // Stand up an in-process service, drive it, tear it down.
                None => {
                    let service = VqService::start(&p.base, &p.serve)?;
                    let server = Server::start(Arc::clone(&service), &p.serve.addr)?;
                    let addr = server.local_addr().to_string();
                    println!("loadtest: in-process service on {addr}");
                    let report =
                        dalvq::serve::run_load(&addr, &spec, &p.base.data.mixture)?;
                    server.shutdown()?;
                    let out = service.shutdown()?;
                    println!(
                        "loadtest: service folded {} deltas during the run",
                        out.merges
                    );
                    report
                }
            };
            print!("{}", report.format());
            if let Some(dir) = &orch.out_dir {
                std::fs::create_dir_all(dir)?;
                let fig = report.to_figure_report();
                dalvq::metrics::write_json(&fig, &dir.join("loadtest.json"))?;
                dalvq::metrics::write_report_csv(&fig, &dir.join("loadtest.csv"))?;
                println!("wrote {}/loadtest.{{csv,json}}", dir.display());
            }
        }
        "top" => {
            let addr = args
                .take_value("--addr")?
                .ok_or_else(|| anyhow!("top requires --addr HOST:PORT"))?;
            let interval_ms =
                parse_opt_u64(&mut args, "--interval")?.unwrap_or(1_000);
            let iterations =
                parse_opt_u64(&mut args, "--iterations")?.unwrap_or(0);
            args.finish()?;
            dalvq::serve::run_top(&dalvq::serve::TopSpec {
                addr,
                interval_ms,
                iterations,
            })?;
        }
        "trace" => {
            let addr = args
                .take_value("--addr")?
                .ok_or_else(|| anyhow!("trace requires --addr HOST:PORT"))?;
            let max_traces =
                parse_opt_u64(&mut args, "--max")?.unwrap_or(4) as u32;
            args.finish()?;
            dalvq::serve::run_trace(&dalvq::serve::TraceSpec {
                addr,
                max_traces,
            })?;
        }
        "state" => {
            let sub = if args.argv.is_empty() {
                bail!("state requires a subcommand (want: inspect|rebalance)")
            } else {
                args.argv.remove(0)
            };
            match sub.as_str() {
                "inspect" => {
                    let dir = PathBuf::from(args.take_value("--state-dir")?.ok_or_else(
                        || anyhow!("state inspect requires --state-dir"),
                    )?);
                    args.finish()?;
                    let Some(state) = dalvq::persist::load_state(&dir)? else {
                        println!(
                            "{}: no manifest — a `dalvq serve --state-dir` run \
                             has not checkpointed here yet",
                            dir.display()
                        );
                        return Ok(());
                    };
                    let m = &state.manifest;
                    println!(
                        "{}: format {} | {} shard(s), kappa={} dim={} | \
                         points/exchange {} | checkpoint generation {}",
                        dir.display(),
                        m.format,
                        m.shards,
                        m.kappa,
                        m.dim,
                        m.points_per_exchange,
                        m.generation
                    );
                    println!(
                        "router: epoch {} | {} coarse centroids (dim {})",
                        state.router.version,
                        state.router.centroids.kappa(),
                        state.router.centroids.dim()
                    );
                    for s in &state.shards {
                        println!(
                            "  shard {}: version {} | merges {} | rng cursor {} \
                             | ingested {} (shed {}) | {} x {} codebook \
                             (norm^2 {:.4})",
                            s.shard,
                            s.version,
                            s.merges,
                            s.rng_cursor,
                            s.ingested,
                            s.shed,
                            s.codebook.kappa(),
                            s.codebook.dim(),
                            s.codebook.norm_sq(),
                        );
                    }
                }
                "rebalance" => {
                    let dir = PathBuf::from(args.take_value("--state-dir")?.ok_or_else(
                        || anyhow!("state rebalance requires --state-dir"),
                    )?);
                    let iters =
                        parse_opt_u64(&mut args, "--iters")?.unwrap_or(8) as usize;
                    let seed = parse_opt_u64(&mut args, "--seed")?.unwrap_or(42);
                    args.finish()?;
                    let report =
                        dalvq::persist::rebalance_state_dir(&dir, iters, seed)?;
                    println!(
                        "{}: rebalanced to router epoch {} — {} prototype \
                         row(s) migrated; fleets will resume at version {}",
                        dir.display(),
                        report.router_version,
                        report.moved_rows,
                        report.resume_version,
                    );
                    println!(
                        "restart `dalvq serve --state-dir {}` (same shape) to \
                         serve the new partition",
                        dir.display()
                    );
                }
                other => bail!(
                    "unknown state subcommand {other:?} (want: inspect|rebalance)"
                ),
            }
        }
        "info" => {
            let artifacts_dir = PathBuf::from(
                args.take_value("--artifacts-dir")?
                    .unwrap_or_else(|| "artifacts".into()),
            );
            args.finish()?;
            let m = Manifest::load(&artifacts_dir)?;
            println!("artifact format: {}", m.format);
            for (name, v) in &m.variants {
                println!(
                    "  {name}: kappa={} dim={} tau={} eval_batch={} entries={}",
                    v.params.kappa,
                    v.params.dim,
                    v.params.tau,
                    v.params.eval_batch,
                    v.entries.len()
                );
            }
        }
        "help" | "--help" | "-h" => println!("{USAGE}"),
        other => bail!("unknown command {other:?}\n\n{USAGE}"),
    }
    Ok(())
}

fn serve_preset(name: &str) -> Result<ServePreset> {
    match name {
        "serve" => Ok(presets::serve()),
        other => bail!("unknown serve preset {other:?} (want serve)"),
    }
}

/// Apply `--shards` / `--probe` over a serve preset: `--shards` alone
/// defaults the probe width to `min(2, S)`; `--probe` alone adjusts the
/// preset's existing shard count.
fn apply_sharding(p: &mut ServePreset, shards: Option<u64>, probe: Option<u64>) {
    if let Some(s) = shards {
        let s = s as usize;
        p.serve.shards = s;
        p.serve.probe_n = 2.min(s.max(1));
    }
    if let Some(n) = probe {
        p.serve.probe_n = n as usize;
    }
}

fn parse_opt_u64(args: &mut Args, name: &str) -> Result<Option<u64>> {
    args.take_value(name)?
        .map(|v| {
            v.parse::<u64>()
                .map_err(|_| anyhow!("{name} expects an integer, got {v:?}"))
        })
        .transpose()
}

fn parse_opt_f64(args: &mut Args, name: &str) -> Result<Option<f64>> {
    args.take_value(name)?
        .map(|v| {
            v.parse::<f64>()
                .map_err(|_| anyhow!("{name} expects a number, got {v:?}"))
        })
        .transpose()
}
