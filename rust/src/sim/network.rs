//! Network delay models.
//!
//! Section 4 of the paper “improve[s] the model … with random communication
//! costs that follow a geometric distribution”. [`DelayModel::Geometric`]
//! is that model; `Instant` is the Figures-1/2 setting; `Fixed` is useful
//! for ablations and tests.

use crate::util::Rng;

/// One-way message delay distribution.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DelayModel {
    /// Zero-delay (the simulated setting of Figures 1 and 2).
    Instant,
    /// Deterministic delay of `secs` seconds.
    Fixed { secs: f64 },
    /// `unit * G` where `G ~ Geometric(p)` counts trials until first
    /// success (support `1, 2, 3, …`; mean `1/p`). The paper's Section 4
    /// model: mean one-way delay `unit / p`.
    Geometric { p: f64, unit: f64 },
}

impl DelayModel {
    /// Sample a delay (deterministic variants ignore the RNG).
    pub fn sample(&self, rng: &mut Rng) -> f64 {
        match *self {
            DelayModel::Instant => 0.0,
            DelayModel::Fixed { secs } => secs,
            DelayModel::Geometric { p, unit } => {
                // inverse CDF: G = 1 + floor(ln U / ln(1-p))
                let u: f64 = rng.f64().max(f64::EPSILON);
                let g = 1.0 + (u.ln() / (1.0 - p).ln()).floor();
                unit * g.max(1.0)
            }
        }
    }

    /// Expected delay in seconds.
    pub fn mean(&self) -> f64 {
        match *self {
            DelayModel::Instant => 0.0,
            DelayModel::Fixed { secs } => secs,
            DelayModel::Geometric { p, unit } => unit / p,
        }
    }

    pub fn validate(&self) -> Result<(), String> {
        match *self {
            DelayModel::Instant => Ok(()),
            DelayModel::Fixed { secs } => {
                if secs >= 0.0 && secs.is_finite() {
                    Ok(())
                } else {
                    Err("fixed delay must be non-negative".into())
                }
            }
            DelayModel::Geometric { p, unit } => {
                if !(0.0 < p && p < 1.0) {
                    return Err(format!("geometric p must be in (0,1), got {p}"));
                }
                if !(unit > 0.0 && unit.is_finite()) {
                    return Err("geometric unit must be positive".into());
                }
                Ok(())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    #[test]
    fn instant_and_fixed_are_deterministic() {
        let mut rng = Rng::from_seed(0);
        assert_eq!(DelayModel::Instant.sample(&mut rng), 0.0);
        assert_eq!(DelayModel::Fixed { secs: 0.25 }.sample(&mut rng), 0.25);
    }

    #[test]
    fn geometric_support_and_mean() {
        let m = DelayModel::Geometric { p: 0.25, unit: 0.01 };
        let mut rng = Rng::from_seed(42);
        let n = 20_000;
        let mut total = 0.0;
        for _ in 0..n {
            let s = m.sample(&mut rng);
            assert!(s >= 0.01 - 1e-12, "support starts at one unit, got {s}");
            // integer multiples of the unit
            let k = s / 0.01;
            assert!((k - k.round()).abs() < 1e-9);
            total += s;
        }
        let mean = total / n as f64;
        assert!((mean - m.mean()).abs() / m.mean() < 0.05,
            "empirical mean {mean} vs {}", m.mean());
    }

    #[test]
    fn validate_bounds() {
        assert!(DelayModel::Geometric { p: 0.0, unit: 1.0 }.validate().is_err());
        assert!(DelayModel::Geometric { p: 1.0, unit: 1.0 }.validate().is_err());
        assert!(DelayModel::Geometric { p: 0.5, unit: 0.0 }.validate().is_err());
        assert!(DelayModel::Fixed { secs: -1.0 }.validate().is_err());
        assert!(DelayModel::Instant.validate().is_ok());
    }
}
