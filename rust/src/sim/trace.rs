//! Execution traces — the determinism witness.
//!
//! Every scheme run can record its structurally significant events
//! (chunks, merges, exchanges). Two runs with the same config must produce
//! identical traces (DESIGN.md invariant 10); the integration tests assert
//! exactly that, and the traces double as debugging artifacts.


/// One structural event of a simulated run.
#[derive(Debug, Clone, PartialEq)]
pub enum TraceEvent {
    /// Worker finished a chunk of `count` points at local step `t`.
    Chunk { wall: f64, worker: usize, t: u64, count: usize },
    /// A synchronous reduce round completed.
    SyncMerge { wall: f64, round: u64 },
    /// Worker's delta upload arrived at the reducer.
    Upload { wall: f64, worker: usize, delta_norm_sq_bits: u64 },
    /// Worker received and merged the shared version.
    Download { wall: f64, worker: usize },
}

/// Bounded event log (drops silently beyond `cap` to keep memory flat).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    cap: usize,
    dropped: u64,
}

impl Trace {
    /// A trace retaining at most `cap` events.
    pub fn with_capacity(cap: usize) -> Self {
        Self { events: Vec::new(), cap, dropped: 0 }
    }

    /// A disabled trace (records nothing).
    pub fn disabled() -> Self {
        Self::with_capacity(0)
    }

    pub fn record(&mut self, ev: TraceEvent) {
        if self.events.len() < self.cap {
            self.events.push(ev);
        } else {
            self.dropped += 1;
        }
    }

    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Stable fingerprint of the whole trace (for determinism asserts).
    pub fn fingerprint(&self) -> u64 {
        // FNV-1a over a canonical field encoding: stable, dependency-free.
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        let mut eat = |x: u64| {
            for b in x.to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(0x1000_0000_01b3);
            }
        };
        for ev in &self.events {
            match ev {
                TraceEvent::Chunk { wall, worker, t, count } => {
                    eat(1);
                    eat(wall.to_bits());
                    eat(*worker as u64);
                    eat(*t);
                    eat(*count as u64);
                }
                TraceEvent::SyncMerge { wall, round } => {
                    eat(2);
                    eat(wall.to_bits());
                    eat(*round);
                }
                TraceEvent::Upload { wall, worker, delta_norm_sq_bits } => {
                    eat(3);
                    eat(wall.to_bits());
                    eat(*worker as u64);
                    eat(*delta_norm_sq_bits);
                }
                TraceEvent::Download { wall, worker } => {
                    eat(4);
                    eat(wall.to_bits());
                    eat(*worker as u64);
                }
            }
        }
        h
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_bounds_memory() {
        let mut t = Trace::with_capacity(2);
        for i in 0..5 {
            t.record(TraceEvent::SyncMerge { wall: i as f64, round: i });
        }
        assert_eq!(t.len(), 2);
        assert_eq!(t.dropped(), 3);
    }

    #[test]
    fn fingerprint_distinguishes_traces() {
        let mut a = Trace::with_capacity(10);
        let mut b = Trace::with_capacity(10);
        a.record(TraceEvent::SyncMerge { wall: 1.0, round: 1 });
        b.record(TraceEvent::SyncMerge { wall: 2.0, round: 1 });
        assert_ne!(a.fingerprint(), b.fingerprint());
        let mut c = Trace::with_capacity(10);
        c.record(TraceEvent::SyncMerge { wall: 1.0, round: 1 });
        assert_eq!(a.fingerprint(), c.fingerprint());
    }

    #[test]
    fn disabled_records_nothing() {
        let mut t = Trace::disabled();
        t.record(TraceEvent::SyncMerge { wall: 0.0, round: 0 });
        assert!(t.is_empty());
    }
}
