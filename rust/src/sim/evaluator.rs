//! Distortion snapshots on a wall-clock cadence.
//!
//! The figures plot `C_{n,M}(w_srd)` against wall time; [`Evaluator`] is
//! the observer that produces those samples. Evaluation is measurement,
//! not part of the algorithm, so it consumes **no virtual time** (the
//! paper's curves likewise exclude the cost of computing the criterion).

use anyhow::Result;

use crate::metrics::Series;
use crate::runtime::Engine;
use crate::vq::Codebook;

/// Samples the normalized distortion of a codebook every `interval`
/// seconds of (virtual or real) wall time.
pub struct Evaluator {
    eval_points: Vec<f32>,
    dim: usize,
    interval: f64,
    next_due: f64,
}

impl Evaluator {
    /// `eval_points` is a held-out flat sample of the mixture; `interval`
    /// the cadence in seconds.
    pub fn new(eval_points: Vec<f32>, dim: usize, interval: f64) -> Self {
        assert!(interval > 0.0, "eval interval must be positive");
        assert!(!eval_points.is_empty(), "empty evaluation sample");
        assert_eq!(eval_points.len() % dim, 0);
        Self { eval_points, dim, interval, next_due: 0.0 }
    }

    pub fn num_points(&self) -> usize {
        self.eval_points.len() / self.dim
    }

    /// Normalized distortion of `w` on the held-out sample (the paper's
    /// `C_{n,M}` estimator).
    pub fn criterion(&self, engine: &mut dyn Engine, w: &Codebook) -> Result<f64> {
        let sum = engine.distortion_sum(w, &self.eval_points)?;
        Ok(sum / self.num_points() as f64)
    }

    /// Record a sample if `wall` has crossed the next cadence boundary.
    pub fn maybe_record(
        &mut self,
        engine: &mut dyn Engine,
        series: &mut Series,
        wall: f64,
        w: &Codebook,
    ) -> Result<()> {
        if wall >= self.next_due {
            self.force_record(engine, series, wall, w)?;
            // skip ahead past any boundaries the run jumped over
            self.next_due = (wall / self.interval).floor() * self.interval
                + self.interval;
        }
        Ok(())
    }

    /// Record unconditionally (used for the final sample of a run).
    pub fn force_record(
        &mut self,
        engine: &mut dyn Engine,
        series: &mut Series,
        wall: f64,
        w: &Codebook,
    ) -> Result<()> {
        let c = self.criterion(engine, w)?;
        series.push(wall, c);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;

    #[test]
    fn cadence_skips_between_boundaries() {
        let mut ev = Evaluator::new(vec![0.0, 0.0, 1.0, 1.0], 2, 1.0);
        let mut eng = NativeEngine::new();
        let w = Codebook::from_flat(1, 2, vec![0.5, 0.5]);
        let mut s = Series::new("t");
        ev.maybe_record(&mut eng, &mut s, 0.0, &w).unwrap(); // records (t=0)
        ev.maybe_record(&mut eng, &mut s, 0.5, &w).unwrap(); // skipped
        ev.maybe_record(&mut eng, &mut s, 1.2, &w).unwrap(); // records
        ev.maybe_record(&mut eng, &mut s, 1.9, &w).unwrap(); // skipped
        ev.maybe_record(&mut eng, &mut s, 4.0, &w).unwrap(); // records (jumped)
        assert_eq!(s.samples.len(), 3);
        assert!(s.is_time_monotone());
    }

    #[test]
    fn criterion_is_mean_distortion() {
        let ev = Evaluator::new(vec![0.0, 0.0, 2.0, 0.0], 2, 1.0);
        let mut eng = NativeEngine::new();
        let w = Codebook::from_flat(1, 2, vec![0.0, 0.0]);
        // distances: 0 and 4 -> mean 2
        assert_eq!(ev.criterion(&mut eng, &w).unwrap(), 2.0);
    }
}
