//! Compute-cost model for virtual workers.


/// Virtual time costs, in seconds.
///
/// The absolute scale is arbitrary (the paper compares curves, not absolute
/// times); defaults approximate one µs-scale VQ step per point, matching
/// the magnitude the authors report for their .NET implementation.
#[derive(Debug, Clone, PartialEq)]
pub struct CostModel {
    /// Seconds of worker compute per processed data point.
    pub point_compute: f64,
    /// Seconds the reducer spends folding one delta / averaging one
    /// version into the shared version.
    pub merge_cost: f64,
    /// Seconds to broadcast the shared version back to workers in the
    /// synchronous schemes (0 = the paper's “instantaneous communications”
    /// setting for Figures 1 and 2).
    pub broadcast_cost: f64,
    /// Per-worker speed multipliers; worker `i` takes
    /// `point_compute * speed_factor(i)` per point. Workers beyond the
    /// vector's length run at factor 1.0. `> 1` models stragglers.
    pub speed_factors: Vec<f64>,
}

impl Default for CostModel {
    fn default() -> Self {
        Self {
            point_compute: 1e-5,
            merge_cost: 1e-6,
            broadcast_cost: 0.0,
            speed_factors: Vec::new(),
        }
    }
}

impl CostModel {
    pub fn speed_factor(&self, worker: usize) -> f64 {
        self.speed_factors.get(worker).copied().unwrap_or(1.0)
    }

    /// Compute time for `count` points on `worker`.
    pub fn compute_time(&self, worker: usize, count: usize) -> f64 {
        self.point_compute * count as f64 * self.speed_factor(worker)
    }

    pub fn validate(&self) -> Result<(), String> {
        if !(self.point_compute > 0.0 && self.point_compute.is_finite()) {
            return Err("point_compute must be positive".into());
        }
        if self.merge_cost < 0.0 || self.broadcast_cost < 0.0 {
            return Err("costs must be non-negative".into());
        }
        if self.speed_factors.iter().any(|s| !(*s > 0.0 && s.is_finite())) {
            return Err("speed factors must be positive".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_factor_is_one() {
        let c = CostModel::default();
        assert_eq!(c.speed_factor(0), 1.0);
        assert_eq!(c.speed_factor(31), 1.0);
    }

    #[test]
    fn straggler_factor_applies() {
        let c = CostModel { speed_factors: vec![1.0, 3.0], ..Default::default() };
        assert_eq!(c.compute_time(1, 10), 10.0 * 3.0 * c.point_compute);
        assert_eq!(c.compute_time(2, 10), 10.0 * c.point_compute);
    }

    #[test]
    fn validate_rejects_nonpositive() {
        let mut c = CostModel::default();
        c.point_compute = 0.0;
        assert!(c.validate().is_err());
        let mut c = CostModel::default();
        c.speed_factors = vec![-1.0];
        assert!(c.validate().is_err());
        assert!(CostModel::default().validate().is_ok());
    }
}
