//! Deterministic event-driven simulator of a distributed architecture.
//!
//! The paper tests its schemes “using simulated distributed architecture”
//! (Section 1): M virtual workers with a compute-cost model, a network
//! with configurable delay distributions (instantaneous for Figures 1–2,
//! geometric for Figure 3), and a virtual wall clock. Everything is seeded
//! and deterministic — the same config reproduces the same trace bit for
//! bit (DESIGN.md invariant 10), which is what makes the scheme
//! comparisons in the figures meaningful.

mod cost;
mod event;
mod evaluator;
mod network;
mod trace;

pub use cost::CostModel;
pub use event::{EventQueue, ScheduledEvent};
pub use evaluator::Evaluator;
pub use network::DelayModel;
pub use trace::{Trace, TraceEvent};
