//! The discrete-event core: a time-ordered queue with FIFO tie-breaking.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// An event scheduled at virtual `time`, carrying a payload `E`.
#[derive(Debug, Clone)]
pub struct ScheduledEvent<E> {
    pub time: f64,
    /// Monotone sequence number: equal-time events fire in insertion order,
    /// which is what makes the simulator deterministic.
    pub seq: u64,
    pub payload: E,
}

impl<E> PartialEq for ScheduledEvent<E> {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl<E> Eq for ScheduledEvent<E> {}

impl<E> Ord for ScheduledEvent<E> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: invert so the earliest event is on top.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}
impl<E> PartialOrd for ScheduledEvent<E> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Deterministic discrete-event queue.
pub struct EventQueue<E> {
    heap: BinaryHeap<ScheduledEvent<E>>,
    next_seq: u64,
    now: f64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self { heap: BinaryHeap::new(), next_seq: 0, now: 0.0 }
    }

    /// Current virtual time (time of the last popped event).
    pub fn now(&self) -> f64 {
        self.now
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Schedule `payload` at absolute time `at` (clamped to `now`:
    /// the past is not addressable).
    pub fn schedule_at(&mut self, at: f64, payload: E) {
        let time = at.max(self.now);
        let seq = self.next_seq;
        self.next_seq += 1;
        self.heap.push(ScheduledEvent { time, seq, payload });
    }

    /// Schedule `payload` after `delay` seconds of virtual time.
    pub fn schedule_in(&mut self, delay: f64, payload: E) {
        debug_assert!(delay >= 0.0, "negative delay");
        self.schedule_at(self.now + delay, payload);
    }

    /// Pop the earliest event, advancing the clock to it.
    pub fn pop(&mut self) -> Option<ScheduledEvent<E>> {
        let ev = self.heap.pop()?;
        debug_assert!(ev.time >= self.now, "time ran backwards");
        self.now = ev.time;
        Some(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.schedule_at(3.0, "c");
        q.schedule_at(1.0, "a");
        q.schedule_at(2.0, "b");
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec!["a", "b", "c"]);
    }

    #[test]
    fn equal_times_fifo() {
        let mut q = EventQueue::new();
        q.schedule_at(1.0, 1);
        q.schedule_at(1.0, 2);
        q.schedule_at(1.0, 3);
        let order: Vec<_> = std::iter::from_fn(|| q.pop().map(|e| e.payload)).collect();
        assert_eq!(order, vec![1, 2, 3]);
    }

    #[test]
    fn clock_advances_with_pops() {
        let mut q = EventQueue::new();
        q.schedule_in(5.0, ());
        assert_eq!(q.now(), 0.0);
        q.pop().unwrap();
        assert_eq!(q.now(), 5.0);
        q.schedule_in(1.0, ());
        q.pop().unwrap();
        assert_eq!(q.now(), 6.0);
    }

    #[test]
    fn past_scheduling_clamps_to_now() {
        let mut q = EventQueue::new();
        q.schedule_at(10.0, "late");
        q.pop().unwrap();
        q.schedule_at(3.0, "early");
        let e = q.pop().unwrap();
        assert_eq!(e.time, 10.0, "clamped to now");
    }
}
