//! Versioned, atomically published codebook snapshots — the read path's
//! view of the continuously trained shared version.
//!
//! The reducer *publishes* (epoch swap of an `Arc<Snapshot>`); query
//! handlers *load* (clone the `Arc` under a lock held for nanoseconds).
//! Readers therefore never block the reducer on codebook-sized work and
//! never observe a torn codebook: a snapshot is immutable once published,
//! exactly the "shared version usable while it is being updated" property
//! of Patra's companion analysis.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::vq::{self, Codebook};

/// One immutable published state of the service.
#[derive(Debug)]
pub struct Snapshot {
    /// The published codebook, immutable for this snapshot's lifetime.
    pub codebook: Codebook,
    /// Reducer fold count at publication (0 = the initial codebook).
    pub version: u64,
}

impl Snapshot {
    /// Nearest-prototype code per point (the codec's encode).
    pub fn encode(&self, points: &[f32]) -> Vec<u32> {
        vq::assignments(&self.codebook, points)
            .into_iter()
            .map(|i| i as u32)
            .collect()
    }

    /// `(index, squared distance)` of the nearest centroid to one point
    /// (`z.len() == dim`). The router's multi-probe scan calls this per
    /// probed shard; one scan computes both (no winner rescan).
    pub fn nearest_one(&self, z: &[f32]) -> (u32, f32) {
        let (i, d) = vq::nearest_with_dist(&self.codebook, z);
        (i as u32, d)
    }

    /// `(index, squared distance)` of the nearest centroid per point, via
    /// the fused batch kernel ([`vq::nearest_batch`]) — bit-identical per
    /// point to [`Snapshot::nearest_one`] (the test below pins it).
    /// An empty slice yields empty vectors.
    pub fn nearest(&self, points: &[f32]) -> (Vec<u32>, Vec<f32>) {
        vq::nearest_batch(&self.codebook, points)
    }

    /// Normalized empirical distortion of `points` (paper eq. 2).
    /// An empty slice is a defined 0.0, not a 0/0 fold artifact.
    pub fn distortion(&self, points: &[f32]) -> f64 {
        if points.is_empty() {
            return 0.0;
        }
        vq::distortion_mean(&self.codebook, points)
    }
}

/// The epoch-swapped publication cell.
///
/// `publish` replaces the current `Arc<Snapshot>`; `load` hands out a
/// reference to whichever epoch is current. Old epochs die when their last
/// in-flight query drops them.
#[derive(Debug)]
pub struct SnapshotStore {
    cell: Mutex<Arc<Snapshot>>,
    /// Version mirror for lock-free freshness polling.
    version: AtomicU64,
}

impl SnapshotStore {
    /// A store whose initial epoch is `w0` at version 0 (a cold start).
    pub fn new(w0: Codebook) -> Arc<Self> {
        Self::with_version(w0, 0)
    }

    /// A store whose initial epoch is already at `version` — the warm
    /// restart path: a restored shard resumes publishing *from* its
    /// checkpointed version, keeping the freshness clock monotone across
    /// restarts.
    pub fn with_version(w0: Codebook, version: u64) -> Arc<Self> {
        Arc::new(Self {
            cell: Mutex::new(Arc::new(Snapshot { codebook: w0, version })),
            version: AtomicU64::new(version),
        })
    }

    /// Swap in a new epoch. Called by the reducer only.
    pub fn publish(&self, codebook: Codebook, version: u64) {
        let next = Arc::new(Snapshot { codebook, version });
        *self.cell.lock().unwrap_or_else(|e| e.into_inner()) = next;
        self.version.store(version, Ordering::Release);
    }

    /// Current epoch (an `Arc` clone — O(1), never copies the codebook).
    pub fn load(&self) -> Arc<Snapshot> {
        Arc::clone(&self.cell.lock().unwrap_or_else(|e| e.into_inner()))
    }

    /// Version of the current epoch without taking the lock.
    pub fn version(&self) -> u64 {
        self.version.load(Ordering::Acquire)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn publish_swaps_epochs_and_old_readers_keep_theirs() {
        let store = SnapshotStore::new(Codebook::from_flat(1, 2, vec![0.0, 0.0]));
        let old = store.load();
        assert_eq!(old.version, 0);
        store.publish(Codebook::from_flat(1, 2, vec![1.0, 2.0]), 7);
        assert_eq!(store.version(), 7);
        // the pre-publish reader still sees its epoch untouched
        assert_eq!(old.codebook.flat(), &[0.0, 0.0]);
        let new = store.load();
        assert_eq!(new.version, 7);
        assert_eq!(new.codebook.flat(), &[1.0, 2.0]);
    }

    #[test]
    fn with_version_seeds_the_freshness_clock() {
        let store =
            SnapshotStore::with_version(Codebook::from_flat(1, 1, vec![3.0]), 42);
        assert_eq!(store.version(), 42);
        assert_eq!(store.load().version, 42);
        assert_eq!(store.load().codebook.flat(), &[3.0]);
    }

    #[test]
    fn snapshot_queries_agree_with_vq_math() {
        let w = Codebook::from_flat(2, 1, vec![0.0, 10.0]);
        let snap = Snapshot { codebook: w.clone(), version: 1 };
        let pts = [1.0f32, 9.0];
        assert_eq!(snap.encode(&pts), vec![0, 1]);
        let (idx, dist) = snap.nearest(&pts);
        assert_eq!(idx, vec![0, 1]);
        assert_eq!(dist, vec![1.0, 1.0]);
        assert_eq!(snap.distortion(&pts), vq::distortion_mean(&w, &pts));
    }

    #[test]
    fn empty_point_slice_yields_defined_values() {
        // Regression: every query op on zero points must return a defined
        // value (no codes / 0.0), never NaN from an empty fold or a
        // division by zero.
        let snap = Snapshot {
            codebook: Codebook::from_flat(2, 3, vec![0.5; 6]),
            version: 3,
        };
        assert_eq!(snap.encode(&[]), Vec::<u32>::new());
        let (idx, dist) = snap.nearest(&[]);
        assert!(idx.is_empty() && dist.is_empty());
        let c = snap.distortion(&[]);
        assert_eq!(c, 0.0);
        assert!(!c.is_nan());
    }

    #[test]
    fn nearest_one_matches_batch_nearest() {
        let snap = Snapshot {
            codebook: Codebook::from_flat(3, 2, vec![0.0, 0.0, 5.0, 5.0, -3.0, 4.0]),
            version: 1,
        };
        let pts = [4.9f32, 5.2, -2.0, 3.0, 0.1, -0.1];
        let (idx, dist) = snap.nearest(&pts);
        for (j, z) in pts.chunks_exact(2).enumerate() {
            let (i1, d1) = snap.nearest_one(z);
            assert_eq!(i1, idx[j]);
            assert_eq!(d1, dist[j]);
        }
    }

    #[test]
    fn concurrent_loads_see_coherent_versions() {
        let store = SnapshotStore::new(Codebook::zeros(1, 1));
        let mut joins = Vec::new();
        for i in 1..=8u64 {
            let store = Arc::clone(&store);
            joins.push(std::thread::spawn(move || {
                store.publish(Codebook::from_flat(1, 1, vec![i as f32]), i);
                let snap = store.load();
                // state and version always pair up, whatever epoch we read
                assert_eq!(snap.codebook.flat()[0] as u64, snap.version);
            }));
        }
        for j in joins {
            j.join().unwrap();
        }
    }
}
