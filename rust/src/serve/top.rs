//! `dalvq top`: a live terminal view of a running server's telemetry.
//!
//! Polls `Stats` + `Metrics` over the wire protocol on a fixed cadence
//! and redraws one screenful per poll: a header (role, uptime, codebook
//! and router versions), a per-op table joining the `op.<name>.requests`
//! counters with the `op.<name>.total_us` latency digests, a per-shard
//! table joining `StatsReply`'s shard vectors with the live
//! `shard.<s>.queue_depth` gauges, and the newest journal events. The
//! rendering is a pure function of the two replies ([`render`]), so the
//! unit tests exercise it on synthetic payloads without a server.

use std::io::Write as _;
use std::time::Duration;

use anyhow::Result;

use crate::obs::Level;

use super::client::Client;
use super::protocol::{MetricsReply, StatsReply};

/// Journal events requested (and shown) per poll.
const TOP_EVENTS: u32 = 8;

/// One `dalvq top` invocation.
#[derive(Debug, Clone)]
pub struct TopSpec {
    /// Server address (`host:port`).
    pub addr: String,
    /// Milliseconds between polls.
    pub interval_ms: u64,
    /// Screens to draw before exiting (0 = until interrupted).
    pub iterations: u64,
}

impl Default for TopSpec {
    fn default() -> Self {
        Self { addr: "127.0.0.1:7171".into(), interval_ms: 1_000, iterations: 0 }
    }
}

/// Poll `spec.addr` and redraw the telemetry screen every
/// `spec.interval_ms` until `spec.iterations` screens have been drawn
/// (forever when 0). One connection for the whole run; a dropped server
/// surfaces as the poll error it is.
pub fn run_top(spec: &TopSpec) -> Result<()> {
    let mut client = Client::connect(spec.addr.as_str())?;
    let mut drawn: u64 = 0;
    loop {
        let stats = client.stats()?;
        let metrics = client.metrics(TOP_EVENTS)?;
        let screen = render(&spec.addr, &stats, &metrics);
        let mut out = std::io::stdout().lock();
        // Clear + home, then the fresh screen — the classic top redraw.
        write!(out, "\x1b[2J\x1b[H{screen}")?;
        out.flush()?;
        drawn += 1;
        if spec.iterations > 0 && drawn >= spec.iterations {
            return Ok(());
        }
        std::thread::sleep(Duration::from_millis(spec.interval_ms.max(1)));
    }
}

/// Render one screenful from a `Stats` + `Metrics` reply pair. Pure:
/// everything shown is a function of the arguments.
pub fn render(addr: &str, stats: &StatsReply, metrics: &MetricsReply) -> String {
    let mut s = String::new();
    let up = metrics.uptime_ms as f64 / 1000.0;
    let role =
        if stats.role.is_empty() { "leader" } else { stats.role.as_str() };
    s.push_str(&format!("dalvq top — {addr} ({role})  up {up:.1} s\n"));
    s.push_str(&format!(
        "codebook v{}  router v{}  kappa {}  dim {}  shards {}  probe {}  \
         workers {}\n",
        stats.version,
        stats.router_version,
        stats.kappa,
        stats.dim,
        stats.shards,
        stats.probe_n,
        stats.workers,
    ));
    if stats.role == "follower" {
        s.push_str(&format!(
            "following {}  lag {} folds  last sync {} ms ago\n",
            stats.leader_addr, stats.sync_lag_folds, stats.last_sync,
        ));
    }
    // Present only on tracing-armed servers (or after a slow-query keep).
    if let Some((_, n)) =
        metrics.counters.iter().find(|(n, _)| n == "trace.sampled")
    {
        s.push_str(&format!(
            "traces sampled: {n}  (inspect with `dalvq trace --addr {addr}`)\n",
        ));
    }
    s.push('\n');

    // ------------------------------------------------------ per-op table
    s.push_str(&format!(
        "{:<12} {:>10} {:>9} {:>8} {:>8} {:>8} {:>9}\n",
        "op", "requests", "mean_us", "p50_us", "p95_us", "p99_us", "max_us",
    ));
    for op in ["encode", "nearest", "distortion", "ingest", "other"] {
        let requests = counter(metrics, &format!("op.{op}.requests"));
        let hist = metrics
            .hists
            .iter()
            .find(|h| h.name == format!("op.{op}.total_us"));
        match hist {
            Some(h) if h.count > 0 => s.push_str(&format!(
                "{op:<12} {requests:>10} {:>9.0} {:>8.0} {:>8.0} {:>8.0} \
                 {:>9.0}\n",
                h.mean_us, h.p50_us, h.p95_us, h.p99_us, h.max_us,
            )),
            _ => s.push_str(&format!(
                "{op:<12} {requests:>10} {:>9} {:>8} {:>8} {:>8} {:>9}\n",
                "-", "-", "-", "-", "-",
            )),
        }
    }
    s.push('\n');

    // --------------------------------------------------- per-shard table
    s.push_str(&format!(
        "{:<6} {:>10} {:>10} {:>12} {:>10} {:>7}\n",
        "shard", "version", "merges", "ingest", "shed", "queue",
    ));
    for sh in 0..stats.shard_versions.len() {
        let at = |v: &[u64]| v.get(sh).copied().unwrap_or(0);
        s.push_str(&format!(
            "{sh:<6} {:>10} {:>10} {:>12} {:>10} {:>7}\n",
            at(&stats.shard_versions),
            at(&stats.shard_merges),
            at(&stats.shard_ingest),
            at(&stats.shard_shed),
            gauge(metrics, &format!("shard.{sh}.queue_depth")),
        ));
    }
    s.push('\n');

    // ------------------------------------------------------- events tail
    s.push_str("recent events (oldest first):\n");
    if metrics.events.is_empty() {
        s.push_str("  (none)\n");
    }
    for e in &metrics.events {
        let level = Level::from_u8(e.level).map_or("?????", Level::label);
        s.push_str(&format!(
            "  [{level:<5}] #{:<4} +{:>8} ms  {:<18} {}\n",
            e.seq, e.ts_ms, e.kind, e.message,
        ));
    }
    s
}

fn counter(metrics: &MetricsReply, name: &str) -> u64 {
    metrics
        .counters
        .iter()
        .find(|(n, _)| n == name)
        .map_or(0, |(_, v)| *v)
}

fn gauge(metrics: &MetricsReply, name: &str) -> u64 {
    metrics
        .gauges
        .iter()
        .find(|(n, _)| n == name)
        .map_or(0, |(_, v)| *v)
}

#[cfg(test)]
mod tests {
    use super::super::protocol::{MetricEvent, MetricHist};
    use super::*;

    fn sample_stats() -> StatsReply {
        StatsReply {
            version: 42,
            kappa: 16,
            dim: 2,
            workers: 8,
            shards: 2,
            probe_n: 1,
            router_version: 3,
            shard_versions: vec![40, 2],
            shard_merges: vec![40, 2],
            shard_ingest: vec![900, 100],
            shard_shed: vec![7, 0],
            role: "leader".into(),
            uptime_ms: 12_345,
            op_encode: 5,
            op_nearest: 11,
            ..StatsReply::default()
        }
    }

    fn sample_metrics() -> MetricsReply {
        MetricsReply {
            uptime_ms: 12_345,
            counters: vec![
                ("op.encode.requests".into(), 5),
                ("op.nearest.requests".into(), 11),
            ],
            gauges: vec![
                ("shard.0.queue_depth".into(), 3),
                ("shard.1.queue_depth".into(), 0),
            ],
            hists: vec![MetricHist {
                name: "op.nearest.total_us".into(),
                count: 11,
                mean_us: 120.0,
                p50_us: 100.0,
                p95_us: 300.0,
                p99_us: 400.0,
                max_us: 512.0,
            }],
            events: vec![MetricEvent {
                seq: 1,
                ts_ms: 99,
                level: 1,
                kind: "slow_query".into(),
                message: "nearest took 9000 us".into(),
            }],
        }
    }

    #[test]
    fn render_shows_header_ops_shards_and_events() {
        let screen = render("127.0.0.1:7171", &sample_stats(), &sample_metrics());
        // header
        assert!(screen.contains("127.0.0.1:7171 (leader)"), "{screen}");
        assert!(screen.contains("up 12.3 s"), "{screen}");
        assert!(screen.contains("codebook v42"), "{screen}");
        assert!(screen.contains("router v3"), "{screen}");
        // per-op rows: counters joined with the latency digest
        let nearest = screen
            .lines()
            .find(|l| l.starts_with("nearest"))
            .expect("nearest row");
        assert!(nearest.contains("11"), "{nearest}");
        assert!(nearest.contains("400"), "{nearest}"); // p99
        // an op with no samples renders dashes, not zeros
        let ingest = screen
            .lines()
            .find(|l| l.starts_with("ingest"))
            .expect("ingest row");
        assert!(ingest.contains('-'), "{ingest}");
        // per-shard rows join stats vectors with queue-depth gauges
        let shard0 = screen
            .lines()
            .find(|l| l.starts_with("0 "))
            .expect("shard 0 row");
        assert!(shard0.contains("900"), "{shard0}");
        assert!(shard0.ends_with('3'), "{shard0}"); // queue depth
        // events tail with decoded level
        assert!(screen.contains("[warn ]"), "{screen}");
        assert!(screen.contains("slow_query"), "{screen}");
        // no trace.sampled counter -> no tracing line
        assert!(!screen.contains("traces sampled"), "{screen}");
    }

    #[test]
    fn render_surfaces_the_trace_counter_when_tracing_is_armed() {
        let mut metrics = sample_metrics();
        metrics.counters.push(("trace.sampled".into(), 17));
        let screen = render("127.0.0.1:7171", &sample_stats(), &metrics);
        assert!(screen.contains("traces sampled: 17"), "{screen}");
        assert!(screen.contains("dalvq trace --addr"), "{screen}");
    }

    #[test]
    fn render_follower_header_names_the_leader() {
        let mut stats = sample_stats();
        stats.role = "follower".into();
        stats.leader_addr = "127.0.0.1:7000".into();
        stats.sync_lag_folds = 12;
        let screen = render("127.0.0.1:7171", &stats, &sample_metrics());
        assert!(screen.contains("(follower)"), "{screen}");
        assert!(
            screen.contains("following 127.0.0.1:7000  lag 12 folds"),
            "{screen}"
        );
    }

    #[test]
    fn render_tolerates_missing_metrics() {
        // A server that answered Stats but reported an empty telemetry
        // plane still renders every section.
        let screen =
            render("x:1", &sample_stats(), &MetricsReply::default());
        assert!(screen.contains("(none)"), "{screen}");
        let encode = screen
            .lines()
            .find(|l| l.starts_with("encode"))
            .expect("encode row");
        assert!(encode.contains('0'), "{encode}"); // zero requests
    }
}
