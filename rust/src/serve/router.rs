//! The coarse quantizer that partitions the prototype space across shards.
//!
//! Patra's convergence analysis of distributed asynchronous LVQ justifies
//! running each shard's fleet without cross-shard synchronization; the
//! router is the only piece that ever sees all shards at once. It is a
//! tiny codebook of `S` coarse centroids, trained once at service start by
//! a short k-means pass over a bootstrap sample, and then frozen:
//!
//! * **ingest** routes every point to the shard owning its coarse cell, so
//!   each fleet trains `kappa/S` prototypes on its own region of the input
//!   space and per-query distance work drops from `kappa*dim` to
//!   `probe_n * kappa/S * dim`;
//! * **queries** multi-probe the `probe_n` nearest coarse cells (SOM-style
//!   coarse-to-fine search), which recovers nearest/distortion correctness
//!   for points near shard boundaries without scanning every shard.
//!
//! The router is deterministic in the seed — two services built from the
//! same config partition identically, which the determinism suite pins.
//!
//! "Frozen" is per **router epoch**, not per process: a rebalance
//! retrains a fresh coarse quantizer offline from the checkpointed shard
//! codebooks ([`crate::persist::rebalance`]) and the service swaps the
//! whole epoch — router plus fleets — atomically
//! ([`super::VqService::rebalance`]). Within an epoch nothing here ever
//! mutates.

use crate::vq::{self, Codebook, InitMethod};

/// A frozen coarse quantizer over `S` shards.
#[derive(Debug, Clone, PartialEq)]
pub struct Router {
    coarse: Codebook,
}

impl Router {
    /// Train a coarse quantizer: k-means++ seeding plus a short Lloyd
    /// pass (`iters` full-batch steps) over `sample` (flat row-major).
    /// Deterministic in `seed`. With `shards == 1` the single centroid is
    /// the sample mean and every point routes to shard 0.
    pub fn train(
        sample: &[f32],
        dim: usize,
        shards: usize,
        iters: usize,
        seed: u64,
    ) -> Router {
        assert!(shards >= 1, "router needs at least one shard");
        assert!(
            sample.len() / dim >= shards,
            "bootstrap sample smaller than shard count"
        );
        let mut coarse =
            vq::init_codebook(InitMethod::KmeansPlusPlus, shards, dim, sample, seed);
        // Short Lloyd pass, same math as the batch baseline's kmeans_step;
        // an empty cell keeps its seeding centroid (k-means++ makes that
        // rare, and a frozen slightly-off centroid only costs probe work).
        let mut sums = vec![0.0f64; shards * dim];
        let mut counts = vec![0u64; shards];
        for _ in 0..iters {
            sums.iter_mut().for_each(|s| *s = 0.0);
            counts.iter_mut().for_each(|c| *c = 0);
            for z in sample.chunks_exact(dim) {
                let a = vq::nearest(&coarse, z);
                counts[a] += 1;
                for k in 0..dim {
                    sums[a * dim + k] += z[k] as f64;
                }
            }
            for i in 0..shards {
                if counts[i] > 0 {
                    let inv = 1.0 / counts[i] as f64;
                    let row = coarse.row_mut(i);
                    for k in 0..dim {
                        row[k] = (sums[i * dim + k] * inv) as f32;
                    }
                }
            }
        }
        Router { coarse }
    }

    /// Rebuild a router from persisted centroids (the warm-restart path:
    /// retraining from a fresh bootstrap sample would repartition the
    /// space and orphan every saved shard codebook).
    pub fn from_centroids(coarse: Codebook) -> Router {
        assert!(coarse.kappa() >= 1, "router needs at least one shard");
        Router { coarse }
    }

    /// Number of coarse cells (= shard count `S`).
    pub fn shards(&self) -> usize {
        self.coarse.kappa()
    }

    /// Dimension of the space the router partitions.
    pub fn dim(&self) -> usize {
        self.coarse.dim()
    }

    /// The coarse centroids (diagnostics / docs diagrams).
    pub fn centroids(&self) -> &Codebook {
        &self.coarse
    }

    /// The shard owning `point` (nearest coarse centroid, first-minimum
    /// tie break — identical to the fine quantizer's).
    pub fn route(&self, point: &[f32]) -> usize {
        vq::nearest(&self.coarse, point)
    }

    /// The `probe_n` shards nearest to `point`, nearest first, written
    /// into `out` (cleared). `probe_n` is clamped to the shard count.
    pub fn probe_into(&self, point: &[f32], probe_n: usize, out: &mut Vec<usize>) {
        let s = self.shards();
        let n = probe_n.clamp(1, s);
        out.clear();
        if s == 1 {
            out.push(0);
            return;
        }
        let mut dists: Vec<(f32, usize)> = (0..s)
            .map(|i| (vq::row_dist_sq(self.coarse.row(i), point), i))
            .collect();
        // Selection of the n smallest — S is small (single digits), so a
        // partial selection sort beats anything fancier.
        for j in 0..n {
            let mut best = j;
            for k in (j + 1)..s {
                if dists[k].0 < dists[best].0
                    || (dists[k].0 == dists[best].0 && dists[k].1 < dists[best].1)
                {
                    best = k;
                }
            }
            dists.swap(j, best);
            out.push(dists[j].1);
        }
    }

    /// Partition flat row-major `points` into one flat buffer per shard,
    /// preserving input order within each shard (stable — determinism of
    /// downstream worker sharding depends on it).
    pub fn partition(&self, points: &[f32]) -> Vec<Vec<f32>> {
        let dim = self.dim();
        let mut parts: Vec<Vec<f32>> = vec![Vec::new(); self.shards()];
        for z in points.chunks_exact(dim) {
            parts[self.route(z)].extend_from_slice(z);
        }
        parts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Two tight clusters far apart, dim 1.
    fn two_clusters() -> Vec<f32> {
        let mut pts = Vec::new();
        for i in 0..64 {
            pts.push((i % 8) as f32 * 0.01);
            pts.push(100.0 + (i % 8) as f32 * 0.01);
        }
        pts
    }

    #[test]
    fn train_is_seed_deterministic() {
        let pts = two_clusters();
        let a = Router::train(&pts, 1, 2, 8, 42);
        let b = Router::train(&pts, 1, 2, 8, 42);
        assert_eq!(a, b);
    }

    #[test]
    fn routes_separate_clusters_to_separate_shards() {
        let pts = two_clusters();
        let r = Router::train(&pts, 1, 2, 8, 7);
        assert_ne!(r.route(&[0.0]), r.route(&[100.0]));
        let parts = r.partition(&pts);
        assert_eq!(parts.len(), 2);
        // every point lands in exactly one shard
        assert_eq!(parts[0].len() + parts[1].len(), pts.len());
        // and each shard's buffer is pure (one cluster only)
        for part in &parts {
            let near_zero = part.iter().filter(|x| **x < 50.0).count();
            assert!(near_zero == 0 || near_zero == part.len());
        }
    }

    #[test]
    fn single_shard_router_is_trivial() {
        let pts = two_clusters();
        let r = Router::train(&pts, 1, 1, 4, 3);
        assert_eq!(r.shards(), 1);
        assert_eq!(r.route(&[-1e6]), 0);
        let mut probes = Vec::new();
        r.probe_into(&[55.0], 4, &mut probes);
        assert_eq!(probes, vec![0]);
    }

    #[test]
    fn probe_orders_shards_by_distance_and_clamps() {
        let pts = two_clusters();
        let r = Router::train(&pts, 1, 2, 8, 9);
        let near0 = r.route(&[0.0]);
        let near100 = r.route(&[100.0]);
        let mut probes = Vec::new();
        r.probe_into(&[1.0], 2, &mut probes);
        assert_eq!(probes, vec![near0, near100]);
        r.probe_into(&[99.0], 1, &mut probes);
        assert_eq!(probes, vec![near100]);
        // probe_n past the shard count clamps to a full scan
        r.probe_into(&[1.0], 100, &mut probes);
        assert_eq!(probes.len(), 2);
        // probe_n == 0 clamps up to 1
        r.probe_into(&[1.0], 0, &mut probes);
        assert_eq!(probes, vec![near0]);
    }

    #[test]
    fn probe_wider_than_the_shard_count_is_a_full_scan() {
        // probe_n > S must clamp to S and enumerate every shard exactly
        // once, nearest first — the oracle mode the e2e suites rely on.
        let pts = two_clusters();
        let r = Router::train(&pts, 1, 2, 8, 5);
        let mut probes = Vec::new();
        r.probe_into(&[0.5], usize::MAX, &mut probes);
        assert_eq!(probes.len(), 2);
        let mut sorted = probes.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1], "every shard probed exactly once");
        assert_eq!(probes[0], r.route(&[0.5]), "nearest shard probed first");
    }

    #[test]
    fn duplicate_bootstrap_samples_still_train_a_usable_router() {
        // A degenerate bootstrap: every sample identical. k-means++ falls
        // back to uniform picks, Lloyd leaves centroids coincident —
        // routing must stay total (first-minimum tie break), probing must
        // still enumerate distinct shards, and partition must keep every
        // point.
        let pts = vec![3.0f32; 64]; // 32 identical points, dim 2
        let r = Router::train(&pts, 2, 4, 8, 13);
        assert_eq!(r.shards(), 4);
        let z = [3.0f32, 3.0];
        assert_eq!(r.route(&z), 0, "ties break to the first shard");
        let mut probes = Vec::new();
        r.probe_into(&z, 4, &mut probes);
        let mut sorted = probes.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        let parts = r.partition(&pts);
        assert_eq!(parts.iter().map(Vec::len).sum::<usize>(), pts.len());
        // far-away queries still route somewhere valid
        assert!(r.route(&[1e6, -1e6]) < 4);
    }

    #[test]
    fn partition_with_empty_cells_keeps_every_point() {
        // Train on two clusters, then partition a buffer drawn entirely
        // from one of them: the other shard's cell must come back empty
        // (not padded, not crashed) and the hot cell must hold everything
        // in input order.
        let r = Router::train(&two_clusters(), 1, 2, 8, 11);
        let hot = [100.0f32, 101.0, 102.5];
        let parts = r.partition(&hot);
        assert_eq!(parts.len(), 2);
        let hot_shard = r.route(&[100.0]);
        assert_eq!(parts[hot_shard][..], [100.0, 101.0, 102.5]);
        assert!(parts[1 - hot_shard].is_empty());
        // and an empty input yields S empty cells
        let parts = r.partition(&[]);
        assert!(parts.iter().all(Vec::is_empty));
        assert_eq!(parts.len(), 2);
    }

    #[test]
    fn partition_is_stable_within_a_shard() {
        // dim 1, interleaved clusters; within-shard order must follow
        // input order
        let pts = [0.0f32, 100.0, 1.0, 101.0, 2.0, 102.0];
        let r = Router::train(&two_clusters(), 1, 2, 8, 11);
        let parts = r.partition(&pts);
        let lo = &parts[r.route(&[0.0])];
        let hi = &parts[r.route(&[100.0])];
        assert_eq!(lo[..], [0.0, 1.0, 2.0]);
        assert_eq!(hi[..], [100.0, 101.0, 102.0]);
    }
}
