//! The load generator: N concurrent connections driving a mixed
//! encode / nearest / distortion / ingest workload, with latency
//! percentiles and a throughput curve recorded into the crate's standard
//! metrics types ([`Series`] / [`FigureReport`]).

use std::collections::VecDeque;
use std::sync::{Arc, Barrier};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::data::MixtureSpec;
use crate::metrics::{FigureReport, Series};
use crate::util::Rng;

use super::client::Client;
use super::protocol::{Request, Response, WireSpan};
use super::traceview;

/// Workload shape.
#[derive(Debug, Clone, PartialEq)]
pub struct LoadSpec {
    /// Concurrent connections (one OS thread each).
    pub connections: usize,
    /// Requests each connection issues.
    pub requests_per_conn: usize,
    /// Points per request batch.
    pub batch_points: usize,
    /// Requests each connection keeps in flight before reading replies
    /// (`dalvq loadtest --pipeline`): 1 is the classic blocking
    /// request/reply loop; N > 1 queues up to N requests on the wire and
    /// drains replies in order, exercising the server's pipelined read
    /// path. Latencies then measure send-to-reply including queueing.
    pub pipeline: usize,
    /// Fraction of requests that are ingest (writes); the rest rotate
    /// through encode / nearest / distortion evenly.
    pub ingest_frac: f64,
    /// Zipf exponent skewing the generated stream across the mixture's
    /// components: component `k` is drawn with weight `1/(k+1)^skew`
    /// (0 = the mixture's own balance). This is the reproducible
    /// skewed-ingest scenario the rebalance subsystem exists for —
    /// `dalvq loadtest --skew 2` concentrates most of the stream on one
    /// region of the input space.
    pub skew: f64,
    /// Issue no ingest at all, whatever `ingest_frac` says: every
    /// request rotates through encode / nearest / distortion. This is
    /// the workload for read-only followers (`dalvq loadtest --read-only
    /// --addr <follower>`), where an ingest would only collect
    /// `NotLeader` errors.
    pub read_only: bool,
    /// Stamp a wire trace context on every [`TRACE_EVERY`]-th request of
    /// each connection (`dalvq loadtest --trace`): the server joins the
    /// trace and ships its span breakdown back in the response envelope,
    /// and the report keeps the slowest traced request as
    /// [`LoadReport::trace_sample`]. Needs a tracing-aware server; off by
    /// default (zero wire overhead).
    pub trace: bool,
    /// Seed of the deterministic per-connection point/op streams.
    pub seed: u64,
}

/// How often a tracing load connection stamps a wire trace context
/// (every Nth request): frequent enough that the slow tail is sampled,
/// rare enough that the envelope overhead never dominates the workload.
pub const TRACE_EVERY: usize = 16;

impl Default for LoadSpec {
    fn default() -> Self {
        Self {
            connections: 8,
            requests_per_conn: 200,
            batch_points: 64,
            pipeline: 1,
            ingest_frac: 0.25,
            skew: 0.0,
            read_only: false,
            trace: false,
            seed: 1,
        }
    }
}

impl LoadSpec {
    /// Reject shapes that cannot run (zero counts, out-of-range
    /// fractions, non-finite skew).
    pub fn validate(&self) -> Result<()> {
        if self.connections == 0
            || self.requests_per_conn == 0
            || self.batch_points == 0
        {
            return Err(anyhow!(
                "loadtest needs connections, requests and batch_points >= 1"
            ));
        }
        if self.pipeline == 0 {
            return Err(anyhow!("pipeline must be >= 1 (1 = no pipelining)"));
        }
        if self.trace && self.pipeline > 1 {
            return Err(anyhow!(
                "trace sampling needs pipeline = 1: a pipelined reply \
                 stream cannot attribute server spans to the request \
                 that minted the trace id"
            ));
        }
        if !(0.0..=1.0).contains(&self.ingest_frac) {
            return Err(anyhow!("ingest_frac must be in [0, 1]"));
        }
        if !self.skew.is_finite() || self.skew < 0.0 {
            return Err(anyhow!("skew must be finite and >= 0"));
        }
        Ok(())
    }

    /// The mixture this spec actually draws from: `skew > 0` overrides
    /// the base mixture's component imbalance with the zipf exponent
    /// (centers, spread and noise stay the base's — the skewed stream
    /// hits the *same* regions, just unevenly).
    fn skewed_mixture(&self, base: &MixtureSpec) -> MixtureSpec {
        let mut m = base.clone();
        if self.skew > 0.0 {
            m.imbalance = self.skew as f32;
        }
        m
    }
}

/// Max-over-mean imbalance of per-shard counters: 1.0 = perfectly even,
/// `S` = everything on one shard. An all-zero (or empty) vector reads as
/// balanced. This is THE skew metric of the rebalance subsystem — the
/// service's auto-trigger, the bench sweep and the e2e acceptance all
/// judge the same formula.
pub fn max_over_mean(xs: &[u64]) -> f64 {
    let total: u64 = xs.iter().sum();
    if total == 0 {
        return 1.0;
    }
    *xs.iter().max().expect("nonzero total implies nonempty") as f64
        / (total as f64 / xs.len() as f64)
}

/// Empirical share of `points` owned by each mixture component (nearest
/// center), component order. The skewed generator is validated through
/// this: a zipf-`s` stream's top component must carry ~its zipf weight.
pub fn component_shares(points: &[f32], centers: &[f32], dim: usize) -> Vec<f64> {
    let k = centers.len() / dim;
    let n = (points.len() / dim).max(1);
    // One Codebook wrap so attribution rides the crate's single
    // nearest-centroid scan instead of reimplementing it.
    let book = crate::vq::Codebook::from_flat(k, dim, centers.to_vec());
    let mut counts = vec![0u64; k];
    for z in points.chunks_exact(dim) {
        counts[crate::vq::nearest(&book, z)] += 1;
    }
    counts.into_iter().map(|c| c as f64 / n as f64).collect()
}

/// Per-operation request counts.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct OpCounts {
    /// `Encode` requests issued.
    pub encode: u64,
    /// `Nearest` requests issued.
    pub nearest: u64,
    /// `Distortion` requests issued.
    pub distortion: u64,
    /// `Ingest` requests issued.
    pub ingest: u64,
}

/// One generated request kind.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Encode,
    Nearest,
    Distortion,
    Ingest,
}

/// The workload mix math, in one testable place: a request is an ingest
/// with probability `ingest_frac` — unless the spec is `read_only`,
/// which suppresses ingest entirely — and reads rotate deterministically
/// encode → nearest → distortion on the connection's `read_rotor` (each
/// connection starts its rotor at its id, staggering read kinds across
/// the fan-out).
fn choose_op(spec: &LoadSpec, rng: &mut Rng, read_rotor: &mut usize) -> Op {
    if !spec.read_only && rng.bool(spec.ingest_frac) {
        return Op::Ingest;
    }
    let op = match *read_rotor % 3 {
        0 => Op::Encode,
        1 => Op::Nearest,
        _ => Op::Distortion,
    };
    *read_rotor += 1;
    op
}

/// What a load run measured.
#[derive(Debug, Clone)]
pub struct LoadReport {
    /// The workload that was driven.
    pub spec: LoadSpec,
    /// Requests completed across all connections.
    pub requests: u64,
    /// Per-operation request counts.
    pub ops: OpCounts,
    /// Ingested points the server shed (admission control).
    pub points_shed: u64,
    /// Requests the server answered `Throttled` (admission control:
    /// quota or brownout refusals). Counted toward `requests` and the
    /// latency percentiles — a refusal is a completed round trip — but
    /// not toward the per-op counts, since no work ran.
    pub throttled: u64,
    /// Wall-clock seconds from the start gate to the last join.
    pub wall_secs: f64,
    /// Completed requests per second over the whole run.
    pub throughput_rps: f64,
    /// Points pushed through queries+ingest per second.
    pub points_per_sec: f64,
    /// Median request latency, microseconds.
    pub p50_us: f64,
    /// 95th-percentile request latency, microseconds.
    pub p95_us: f64,
    /// 99th-percentile request latency, microseconds.
    pub p99_us: f64,
    /// Worst observed request latency, microseconds.
    pub max_us: f64,
    /// Requests-per-second curve over the run (100 ms buckets).
    pub series: Series,
    /// The slowest traced request of the run (`--trace` only): its trace
    /// id and the server-side span breakdown, rendered next to the
    /// client-side percentiles so "where did my p99 go" is answered by
    /// the same report that measured it.
    pub trace_sample: Option<TraceSample>,
}

/// One traced request a load connection kept: the trace id it stamped,
/// the client-observed latency, and the span tree the server shipped
/// back in the response envelope.
#[derive(Debug, Clone)]
pub struct TraceSample {
    /// Trace id, high half.
    pub hi: u64,
    /// Trace id, low half.
    pub lo: u64,
    /// Client-observed request latency, microseconds.
    pub client_us: f64,
    /// The server's spans for this request (offsets relative to the
    /// server's frame arrival).
    pub spans: Vec<WireSpan>,
}

impl TraceSample {
    /// The 32-hex-digit trace id, as `dalvq trace` prints it.
    pub fn id_hex(&self) -> String {
        format!("{:016x}{:016x}", self.hi, self.lo)
    }
}

/// Drive `spec` against a server at `addr`, generating query/ingest points
/// from `mixture` (each connection uses its own deterministic stream).
pub fn run_load(addr: &str, spec: &LoadSpec, mixture: &MixtureSpec) -> Result<LoadReport> {
    spec.validate()?;
    let mixture = &spec.skewed_mixture(mixture);
    mixture.validate().map_err(|e| anyhow!("mixture: {e}"))?;
    let start_gate = Arc::new(Barrier::new(spec.connections + 1));
    let mut joins = Vec::with_capacity(spec.connections);
    for c in 0..spec.connections {
        let addr = addr.to_string();
        let spec_c = spec.clone();
        let mixture = mixture.clone();
        let gate = Arc::clone(&start_gate);
        joins.push(
            std::thread::Builder::new()
                .name(format!("dalvq-load-{c}"))
                .spawn(move || drive_connection(&addr, &spec_c, &mixture, c, gate))
                .expect("spawning load connection thread"),
        );
    }
    start_gate.wait();
    let run_start = Instant::now();
    let mut latencies_ns: Vec<u64> = Vec::new();
    let mut stamps: Vec<f64> = Vec::new();
    let mut ops = OpCounts::default();
    let mut points_shed = 0u64;
    let mut throttled = 0u64;
    let mut trace_sample: Option<TraceSample> = None;
    for j in joins {
        let conn = j.join().map_err(|_| anyhow!("load connection panicked"))??;
        latencies_ns.extend(conn.latencies_ns);
        stamps.extend(conn.stamps);
        ops.encode += conn.ops.encode;
        ops.nearest += conn.ops.nearest;
        ops.distortion += conn.ops.distortion;
        ops.ingest += conn.ops.ingest;
        points_shed += conn.points_shed;
        throttled += conn.throttled;
        if let Some(s) = conn.trace_sample {
            let slower = trace_sample
                .as_ref()
                .map_or(true, |best| s.client_us > best.client_us);
            if slower {
                trace_sample = Some(s);
            }
        }
    }
    let wall_secs = run_start.elapsed().as_secs_f64().max(1e-9);
    let requests = latencies_ns.len() as u64;
    latencies_ns.sort_unstable();

    let mut series = throughput_series(
        &mut stamps,
        0.1, // completions per 100 ms bucket
        format!("rps (conns={})", spec.connections),
    );
    series.points_processed = requests * spec.batch_points as u64;

    Ok(LoadReport {
        spec: spec.clone(),
        requests,
        ops,
        points_shed,
        throttled,
        wall_secs,
        throughput_rps: requests as f64 / wall_secs,
        points_per_sec: (requests * spec.batch_points as u64) as f64 / wall_secs,
        p50_us: percentile_us(&latencies_ns, 0.50),
        p95_us: percentile_us(&latencies_ns, 0.95),
        p99_us: percentile_us(&latencies_ns, 0.99),
        max_us: percentile_us(&latencies_ns, 1.0),
        series,
        trace_sample,
    })
}

/// Latency percentile, microseconds, by nearest-rank on a **sorted**
/// nanosecond series — the rank comes from the shared
/// [`crate::obs::nearest_rank_index`], the same math the server-side
/// latency histograms use, so loadgen-side and server-side percentiles
/// are directly comparable. An empty window is a defined `NaN` (there is
/// no latency to report), a single sample answers every quantile.
fn percentile_us(sorted_ns: &[u64], q: f64) -> f64 {
    match crate::obs::nearest_rank_index(sorted_ns.len(), q) {
        Some(idx) => sorted_ns[idx] as f64 / 1e3,
        None => f64::NAN,
    }
}

/// Throughput curve: completion stamps (seconds) bucketed at `bucket`
/// seconds, each sample converted to a rate. Sorts `stamps` in place; an
/// empty window yields an empty series.
fn throughput_series(stamps: &mut [f64], bucket: f64, name: String) -> Series {
    stamps.sort_unstable_by(f64::total_cmp);
    let mut series = Series::new(name);
    if let Some(&last) = stamps.last() {
        let buckets = (last / bucket).floor() as usize + 1;
        let mut counts = vec![0u64; buckets];
        for &s in stamps.iter() {
            counts[(s / bucket).floor() as usize] += 1;
        }
        for (i, n) in counts.iter().enumerate() {
            series.push((i as f64 + 1.0) * bucket, *n as f64 / bucket);
        }
    }
    series
}

struct ConnOutcome {
    latencies_ns: Vec<u64>,
    /// Completion times, seconds since the start gate.
    stamps: Vec<f64>,
    ops: OpCounts,
    points_shed: u64,
    /// Requests answered `Throttled` by admission control.
    throttled: u64,
    /// This connection's slowest traced request (`spec.trace` only).
    trace_sample: Option<TraceSample>,
}

fn drive_connection(
    addr: &str,
    spec: &LoadSpec,
    mixture: &MixtureSpec,
    conn_id: usize,
    gate: Arc<Barrier>,
) -> Result<ConnOutcome> {
    // Connect before the gate, but defer the error past it — a failed
    // connection must not leave run_load stuck on the start barrier.
    let client = Client::connect(addr);
    // A private point pool: enough to slice fresh batches from, cheap to
    // generate, deterministic per connection.
    let pool_points = (spec.batch_points * 64).max(1024);
    let pool = mixture.generate(pool_points, spec.seed, 0x10AD + conn_id as u64);
    let dim = mixture.dim;
    let mut rng = Rng::from_seed_stream(spec.seed, 0x10AD_0000 + conn_id as u64);
    let mut out = ConnOutcome {
        latencies_ns: Vec::with_capacity(spec.requests_per_conn),
        stamps: Vec::with_capacity(spec.requests_per_conn),
        ops: OpCounts::default(),
        points_shed: 0,
        throttled: 0,
        trace_sample: None,
    };
    gate.wait();
    let mut client = client?;
    if spec.pipeline > 1 {
        drive_pipelined(client, spec, &pool, dim, &mut rng, conn_id, &mut out)?;
        return Ok(out);
    }
    let t0 = Instant::now();
    let mut read_rotor = conn_id; // stagger read ops across connections
    for i in 0..spec.requests_per_conn {
        let start = rng.usize(pool_points - spec.batch_points + 1);
        let batch = &pool[start * dim..(start + spec.batch_points) * dim];
        // Every TRACE_EVERY-th request carries a wire trace context (a
        // fresh client-minted id; the server forcibly samples it and
        // ships its spans back). `traced` remembers the id so the
        // response's spans can be attributed after the latency stamp.
        let mut traced: Option<(u64, u64)> = None;
        if spec.trace && i % TRACE_EVERY == 0 {
            let (hi, lo) = (rng.next_u64() | 1, rng.next_u64() | 1);
            client.trace_next(hi, lo, 0);
            traced = Some((hi, lo));
        }
        let req_start = Instant::now();
        match choose_op(spec, &mut rng, &mut read_rotor) {
            Op::Ingest => {
                let (_, shed) = client.ingest(batch)?;
                out.points_shed += shed;
                out.ops.ingest += 1;
            }
            Op::Encode => {
                client.encode(batch)?;
                out.ops.encode += 1;
            }
            Op::Nearest => {
                client.nearest(batch)?;
                out.ops.nearest += 1;
            }
            Op::Distortion => {
                client.distortion(batch)?;
                out.ops.distortion += 1;
            }
        }
        let lat_ns = req_start.elapsed().as_nanos() as u64;
        out.latencies_ns.push(lat_ns);
        out.stamps.push(t0.elapsed().as_secs_f64());
        if let Some((hi, lo)) = traced {
            let client_us = lat_ns as f64 / 1e3;
            let slower = out
                .trace_sample
                .as_ref()
                .map_or(true, |best| client_us > best.client_us);
            if slower {
                out.trace_sample = Some(TraceSample {
                    hi,
                    lo,
                    client_us,
                    spans: client.take_server_spans(),
                });
            }
        }
    }
    Ok(out)
}

/// The windowed pipelining driver (`spec.pipeline > 1`): keep up to
/// `pipeline` requests queued on the connection, then drain replies in
/// order — the server answers pipelined frames strictly in request
/// order, so reply K always belongs to the K-th send. Latencies measure
/// send-to-reply and so include the queueing a deep window creates;
/// `Throttled` refusals are counted, not failed, since admission
/// control answering in-band is exactly what a pipelined burst probes.
fn drive_pipelined(
    mut client: Client,
    spec: &LoadSpec,
    pool: &[f32],
    dim: usize,
    rng: &mut Rng,
    conn_id: usize,
    out: &mut ConnOutcome,
) -> Result<()> {
    let pool_points = pool.len() / dim;
    let t0 = Instant::now();
    let mut read_rotor = conn_id;
    let mut inflight: VecDeque<Instant> = VecDeque::new();
    let mut issued = 0usize;
    let n = spec.requests_per_conn;
    while issued < n || !inflight.is_empty() {
        while issued < n && inflight.len() < spec.pipeline {
            let start = rng.usize(pool_points - spec.batch_points + 1);
            let batch =
                &pool[start * dim..(start + spec.batch_points) * dim];
            let req = match choose_op(spec, rng, &mut read_rotor) {
                Op::Ingest => Request::Ingest { points: batch.to_vec() },
                Op::Encode => Request::Encode { points: batch.to_vec() },
                Op::Nearest => Request::Nearest { points: batch.to_vec() },
                Op::Distortion => {
                    Request::Distortion { points: batch.to_vec() }
                }
            };
            client.send(&req)?;
            inflight.push_back(Instant::now());
            issued += 1;
        }
        client.flush()?;
        let started = inflight.pop_front().expect("window nonempty");
        match client.recv()? {
            Response::Codes { .. } => out.ops.encode += 1,
            Response::Neighbors { .. } => out.ops.nearest += 1,
            Response::Distortion { .. } => out.ops.distortion += 1,
            Response::IngestAck { shed, .. } => {
                out.points_shed += shed;
                out.ops.ingest += 1;
            }
            Response::Throttled { .. } => out.throttled += 1,
            Response::Error { message } => bail!("server error: {message}"),
            Response::NotLeader { leader } => bail!(
                "server is a read-only follower; send writes (and state \
                 fetches) to its leader at {leader}"
            ),
            other => bail!("unexpected response {other:?}"),
        }
        out.latencies_ns.push(started.elapsed().as_nanos() as u64);
        out.stamps.push(t0.elapsed().as_secs_f64());
    }
    Ok(())
}

impl LoadReport {
    /// Human-readable table (what `dalvq loadtest` prints).
    pub fn format(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "loadtest: {} connections x {} requests, {} pts/batch, \
             ingest frac {:.0}%{}{}\n",
            self.spec.connections,
            self.spec.requests_per_conn,
            self.spec.batch_points,
            self.spec.ingest_frac * 100.0,
            if self.spec.pipeline > 1 {
                format!(", pipeline {}", self.spec.pipeline)
            } else {
                String::new()
            },
            if self.spec.read_only { " (read-only)" } else { "" },
        ));
        s.push_str(&format!(
            "  ops: encode {} | nearest {} | distortion {} | ingest {} \
             (shed {} pts)\n",
            self.ops.encode,
            self.ops.nearest,
            self.ops.distortion,
            self.ops.ingest,
            self.points_shed,
        ));
        if self.throttled > 0 {
            s.push_str(&format!(
                "  throttled: {} requests answered Throttled \
                 (admission control)\n",
                self.throttled,
            ));
        }
        s.push_str(&format!(
            "  throughput: {:.0} req/s ({:.0} pts/s) over {:.2}s\n",
            self.throughput_rps, self.points_per_sec, self.wall_secs,
        ));
        s.push_str(&format!(
            "  latency: p50 {:.0} us | p95 {:.0} us | p99 {:.0} us | \
             max {:.0} us\n",
            self.p50_us, self.p95_us, self.p99_us, self.max_us,
        ));
        if let Some(t) = &self.trace_sample {
            s.push_str(&format!(
                "  slowest traced request: {} ({:.0} us client-side)\n",
                t.id_hex(),
                t.client_us,
            ));
            if t.spans.is_empty() {
                s.push_str(
                    "    (server shipped no spans — is it tracing-aware?)\n",
                );
            } else {
                for line in traceview::render_tree(&t.spans).lines() {
                    s.push_str(&format!("    {line}\n"));
                }
            }
        }
        s
    }

    /// Persistable form: the throughput curve plus the headline numbers as
    /// report params (feeds the standard CSV/JSON/SVG writers).
    pub fn to_figure_report(&self) -> FigureReport {
        let mut report = FigureReport::new(
            "loadtest",
            "dalvq serve throughput/latency under concurrent load",
        );
        report.param("connections", self.spec.connections);
        report.param("requests", self.requests);
        report.param("batch_points", self.spec.batch_points);
        report.param("throughput_rps", format!("{:.1}", self.throughput_rps));
        report.param("p50_us", format!("{:.1}", self.p50_us));
        report.param("p95_us", format!("{:.1}", self.p95_us));
        report.param("p99_us", format!("{:.1}", self.p99_us));
        report.series.push(self.series.clone());
        report
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentiles_are_exact_on_known_series() {
        // 1..=100 us in nanoseconds: nearest-rank lands exactly.
        let series: Vec<u64> = (1..=100).map(|i| i * 1_000).collect();
        assert_eq!(percentile_us(&series, 0.0), 1.0); // min
        // idx = round(99 * 0.5) = 50 -> 51st sample
        assert_eq!(percentile_us(&series, 0.50), 51.0);
        // idx = round(99 * 0.99) = 98 -> 99th sample
        assert_eq!(percentile_us(&series, 0.99), 99.0);
        assert_eq!(percentile_us(&series, 1.0), 100.0); // max
    }

    #[test]
    fn percentiles_on_a_two_point_distribution() {
        // 90 fast requests at 100 us, 10 slow at 10_000 us: p50 must sit
        // in the fast mode, p99 in the slow tail.
        let mut series: Vec<u64> = std::iter::repeat(100_000)
            .take(90)
            .chain(std::iter::repeat(10_000_000).take(10))
            .collect();
        series.sort_unstable();
        assert_eq!(percentile_us(&series, 0.50), 100.0);
        assert_eq!(percentile_us(&series, 0.99), 10_000.0);
        // monotone in q
        let qs = [0.0, 0.25, 0.5, 0.9, 0.95, 0.99, 1.0];
        for w in qs.windows(2) {
            assert!(percentile_us(&series, w[0]) <= percentile_us(&series, w[1]));
        }
    }

    #[test]
    fn percentile_single_sample_answers_every_quantile() {
        let series = [42_000u64];
        for q in [0.0, 0.5, 0.99, 1.0] {
            assert_eq!(percentile_us(&series, q), 42.0);
        }
    }

    #[test]
    fn percentile_empty_window_is_nan_not_panic() {
        for q in [0.0, 0.5, 1.0] {
            assert!(percentile_us(&[], q).is_nan());
        }
    }

    #[test]
    fn percentiles_agree_with_the_server_side_histogram() {
        // Feed identical samples to the loadgen percentile and a server-
        // side obs histogram: within the histogram's exact range (values
        // below its linear cutoff) the two report the *same* number at
        // every quantile, because both sides share
        // `obs::nearest_rank_index`.
        let h = crate::obs::Histogram::new();
        let mut sorted_ns: Vec<u64> = Vec::new();
        for us in [0u64, 1, 1, 2, 3, 5, 8, 13, 13, 15] {
            h.record(us);
            sorted_ns.push(us * 1_000);
        }
        sorted_ns.sort_unstable();
        let s = h.summary();
        assert_eq!(percentile_us(&sorted_ns, 0.50), s.p50_us);
        assert_eq!(percentile_us(&sorted_ns, 0.95), s.p95_us);
        assert_eq!(percentile_us(&sorted_ns, 0.99), s.p99_us);
        assert_eq!(percentile_us(&sorted_ns, 1.0), s.max_us);
    }

    #[test]
    fn throughput_series_buckets_completions() {
        // 3 completions in [0, 0.1), 1 in [0.2, 0.3) — out of order on
        // purpose (the helper sorts).
        let mut stamps = vec![0.25, 0.01, 0.05, 0.09];
        let s = throughput_series(&mut stamps, 0.1, "rps".into());
        let ys: Vec<f64> = s.samples.iter().map(|p| p.value).collect();
        assert_eq!(ys, vec![30.0, 0.0, 10.0]);
    }

    #[test]
    fn throughput_series_empty_window_is_empty() {
        let s = throughput_series(&mut [], 0.1, "rps".into());
        assert!(s.samples.is_empty());
    }

    #[test]
    fn spec_validation() {
        assert!(LoadSpec::default().validate().is_ok());
        let mut s = LoadSpec::default();
        s.connections = 0;
        assert!(s.validate().is_err());
        let mut s = LoadSpec::default();
        s.ingest_frac = 1.5;
        assert!(s.validate().is_err());
        let mut s = LoadSpec::default();
        s.skew = -1.0;
        assert!(s.validate().is_err());
        s.skew = f64::INFINITY;
        assert!(s.validate().is_err());
        s.skew = 2.0;
        assert!(s.validate().is_ok());
        let mut s = LoadSpec::default();
        s.pipeline = 0;
        assert!(s.validate().is_err());
        s.pipeline = 32;
        assert!(s.validate().is_ok());
        // trace attribution needs the classic one-at-a-time loop
        s.trace = true;
        assert!(s.validate().is_err());
        s.pipeline = 1;
        assert!(s.validate().is_ok());
    }

    /// Replay `n` draws of the op chooser and tally them.
    fn tally_ops(spec: &LoadSpec, conn_id: usize, n: usize) -> OpCounts {
        let mut rng = Rng::from_seed_stream(spec.seed, 0x10AD_0000 + conn_id as u64);
        let mut rotor = conn_id;
        let mut counts = OpCounts::default();
        for _ in 0..n {
            match choose_op(spec, &mut rng, &mut rotor) {
                Op::Encode => counts.encode += 1,
                Op::Nearest => counts.nearest += 1,
                Op::Distortion => counts.distortion += 1,
                Op::Ingest => counts.ingest += 1,
            }
        }
        counts
    }

    #[test]
    fn read_only_suppresses_ingest_and_splits_reads_exactly() {
        // read_only overrides any ingest_frac — even a pure-write spec
        // issues zero ingest — and the rotor splits 300 reads exactly
        // 100/100/100 whatever the connection id offset.
        for conn_id in 0..4 {
            let mut spec = LoadSpec::default();
            spec.ingest_frac = 1.0;
            spec.read_only = true;
            let counts = tally_ops(&spec, conn_id, 300);
            assert_eq!(counts.ingest, 0, "conn {conn_id}");
            assert_eq!(counts.encode, 100, "conn {conn_id}");
            assert_eq!(counts.nearest, 100, "conn {conn_id}");
            assert_eq!(counts.distortion, 100, "conn {conn_id}");
        }
    }

    #[test]
    fn ingest_frac_mix_matches_its_probability() {
        // Without read_only, ingest_frac = 1.0 is all writes…
        let mut spec = LoadSpec::default();
        spec.ingest_frac = 1.0;
        let counts = tally_ops(&spec, 0, 200);
        assert_eq!(counts.ingest, 200);
        assert_eq!(counts.encode + counts.nearest + counts.distortion, 0);

        // …0.0 is all reads…
        spec.ingest_frac = 0.0;
        let counts = tally_ops(&spec, 0, 300);
        assert_eq!(counts.ingest, 0);
        assert_eq!(counts.encode + counts.nearest + counts.distortion, 300);

        // …and 0.25 lands near a quarter (deterministic seed, loose
        // binomial bound), with the remainder split ~evenly across the
        // three read kinds.
        spec.ingest_frac = 0.25;
        let n = 4_000u64;
        let counts = tally_ops(&spec, 0, n as usize);
        let ingest_share = counts.ingest as f64 / n as f64;
        assert!(
            (ingest_share - 0.25).abs() < 0.05,
            "ingest share {ingest_share}"
        );
        let reads = [counts.encode, counts.nearest, counts.distortion];
        let total_reads: u64 = reads.iter().sum();
        assert_eq!(total_reads, n - counts.ingest);
        for r in reads {
            // the rotor is exact: read kinds differ by at most one
            assert!(
                (r as i64 - (total_reads / 3) as i64).abs() <= 1,
                "reads {reads:?}"
            );
        }
    }

    #[test]
    fn skewed_generator_concentrates_mass_like_its_zipf_weights() {
        // The percentile check for the skew knob: empirical component
        // shares of a skewed stream must match the zipf weights the spec
        // promises (the service-side rebalance trigger is calibrated
        // against exactly these ratios).
        // dim 4 keeps the random centers far apart relative to the
        // cluster spread, so nearest-center attribution is unambiguous.
        let mut base = crate::data::MixtureSpec::default();
        base.components = 8;
        base.dim = 4;
        base.noise_frac = 0.0;
        let mut spec = LoadSpec::default();
        spec.skew = 2.0;
        let skewed = spec.skewed_mixture(&base);
        assert_eq!(skewed.imbalance, 2.0);

        let seed = 11u64;
        let pts = skewed.generate(20_000, seed, 77);
        let shares = component_shares(&pts, &skewed.centers(seed), 4);
        assert_eq!(shares.len(), 8);
        let expected = skewed.weights();
        // top component carries its zipf share (~0.65 at s = 2, n = 8)
        assert!(
            (shares[0] - expected[0]).abs() < 0.05,
            "top share {} vs zipf {}",
            shares[0],
            expected[0]
        );
        // total variation from the zipf law stays small
        let tv: f64 = shares
            .iter()
            .zip(&expected)
            .map(|(s, e)| (s - e).abs())
            .sum::<f64>()
            / 2.0;
        assert!(tv < 0.05, "total variation {tv}: {shares:?} vs {expected:?}");

        // skew = 0 leaves the base mixture untouched: near-uniform shares
        let mut flat_spec = LoadSpec::default();
        flat_spec.skew = 0.0;
        let flat = flat_spec.skewed_mixture(&base);
        assert_eq!(flat.imbalance, base.imbalance);
        let pts = flat.generate(20_000, seed, 78);
        let shares = component_shares(&pts, &flat.centers(seed), 4);
        for s in &shares {
            assert!((s - 0.125).abs() < 0.05, "uniform share {s}");
        }
    }

    #[test]
    fn report_formats_without_panicking() {
        let report = LoadReport {
            spec: LoadSpec::default(),
            requests: 10,
            ops: OpCounts { encode: 4, nearest: 3, distortion: 2, ingest: 1 },
            points_shed: 0,
            throttled: 0,
            wall_secs: 0.5,
            throughput_rps: 20.0,
            points_per_sec: 1280.0,
            p50_us: 100.0,
            p95_us: 200.0,
            p99_us: 300.0,
            max_us: 400.0,
            series: Series::new("rps"),
            trace_sample: None,
        };
        let text = report.format();
        assert!(text.contains("p99"));
        assert!(!text.contains("slowest traced"));
        let fig = report.to_figure_report();
        assert_eq!(fig.id, "loadtest");
        assert_eq!(fig.series.len(), 1);
    }

    #[test]
    fn report_renders_the_trace_sample_as_a_span_tree() {
        let mut report = LoadReport {
            spec: LoadSpec::default(),
            requests: 1,
            ops: OpCounts::default(),
            points_shed: 0,
            throttled: 0,
            wall_secs: 0.1,
            throughput_rps: 10.0,
            points_per_sec: 640.0,
            p50_us: 100.0,
            p95_us: 200.0,
            p99_us: 300.0,
            max_us: 400.0,
            series: Series::new("rps"),
            trace_sample: Some(TraceSample {
                hi: 0xABCD,
                lo: 0x1234,
                client_us: 412.0,
                spans: vec![
                    WireSpan {
                        id: 1,
                        parent: 0,
                        start_us: 0,
                        dur_us: 400,
                        name: "req.nearest".into(),
                    },
                    WireSpan {
                        id: 2,
                        parent: 1,
                        start_us: 10,
                        dur_us: 350,
                        name: "scan".into(),
                    },
                ],
            }),
        };
        let text = report.format();
        assert!(text.contains("slowest traced request"));
        assert!(text.contains(&format!("{:016x}{:016x}", 0xABCD, 0x1234)));
        assert!(text.contains("req.nearest"));
        assert!(text.contains("scan"));

        // A pre-tracing server ships no spans; the report says so
        // instead of printing an empty tree.
        report.trace_sample.as_mut().unwrap().spans.clear();
        assert!(report.format().contains("no spans"));
    }
}
