//! The non-blocking connection engine behind [`super::Server`].
//!
//! One reactor thread owns every connection: it polls for readiness
//! (`poll(2)` through a minimal FFI shim — std-only, no mio), reads
//! into a growable per-connection [`Decoder`] buffer, parses as many
//! complete frames as arrived (request pipelining), and hands each
//! frame to a fixed worker pool sized to cores. Workers run the
//! protocol handler and hand back a complete reply frame; the reactor
//! emits replies in request order through per-connection reply slots
//! and drains them with vectored writes. A socketpair wake token
//! retires the old "throwaway connection" shutdown hack:
//! `Server::shutdown` just sets the stop flag and wakes the loop.
//!
//! Admission control happens at parse time, before any worker is
//! involved: a per-connection in-flight quota (`serve.max_inflight`
//! parsed but unanswered frames), a brownout watermark
//! (`serve.brownout_depth`) that sheds ingest frames — reads are never
//! shed — while any `shard.<s>.queue_depth` gauge sits at or above the
//! watermark, and a per-connection token bucket (`serve.rate_limit`
//! req/s), checked last so a request refused by a non-consuming gate
//! never burns a rate token. Refusals answer in-band with a
//! `Throttled` frame carrying a retry-after hint; the connection
//! survives. With every quota off, backpressure is still bounded: a
//! connection more than [`PARSE_AHEAD`] frames ahead of its replies
//! (or holding more than [`WQ_HIGH`] queued reply bytes) simply stops
//! being read until the backlog drains, which surfaces to the client
//! as ordinary TCP flow control. That pause is level-triggered, not
//! edge-triggered: frames already sitting whole in the decoder when
//! parsing stops at a watermark are revisited as completions and
//! flushes drain the backlog ([`Reactor::resume_parse`]) — the socket
//! may be empty by then, so `POLLIN` alone would never fire again.

use std::collections::VecDeque;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use super::protocol::{begin_frame, end_frame, is_ingest_frame, Decoder, Response};
use super::service::VqService;

#[cfg(unix)]
use std::os::unix::io::AsRawFd;
#[cfg(unix)]
use std::os::unix::net::UnixStream;

/// A frame handler: decodes `payload` (arrived at `Instant`), appends
/// exactly one complete reply frame — length prefix included — to
/// `out`, and returns `true`. Returning `false` means no frame could
/// be produced (reply over the frame cap, or the handler panicked);
/// the reactor then drops the connection after flushing already-queued
/// replies, which is the same fate the blocking server handed such
/// connections.
pub(crate) type Handler = Arc<dyn Fn(&[u8], Instant, &mut Vec<u8>) -> bool + Send + Sync>;

/// Frames parsed but not yet answered per connection before the
/// reactor stops reading from it. Quotas, when armed, throttle in-band
/// well before this.
const PARSE_AHEAD: usize = 64;
/// Queued reply bytes per connection before reads pause.
const WQ_HIGH: usize = 4 << 20;
/// Queued reply frames covered by one vectored write.
const WRITE_BATCH: usize = 8;
/// How long shutdown waits for in-flight work to finish and flush.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);
/// Minimum spare capacity asked of the decoder per read.
const READ_CHUNK: usize = 16 << 10;
/// Recycled-buffer pool bounds: entries kept, and the per-buffer
/// capacity above which a buffer is dropped instead of pooled.
const POOL_MAX: usize = 1024;
const POOL_BUF_CAP: usize = 1 << 20;

/// Minimal readiness shim. On unix this is `poll(2)` through a
/// hand-rolled FFI declaration (std exposes no readiness API); on
/// other hosts it degrades to "everything you asked about is ready"
/// after a ~1ms tick, which keeps the engine correct — nonblocking
/// reads and writes just return `WouldBlock` — at the cost of an idle
/// spin.
mod sys {
    #[cfg(not(unix))]
    pub use fallback_impl::*;
    #[cfg(unix)]
    pub use unix_impl::*;

    #[cfg(unix)]
    mod unix_impl {
        pub use std::os::unix::io::RawFd;

        pub const POLLIN: i16 = 0x001;
        pub const POLLOUT: i16 = 0x004;
        pub const POLLERR: i16 = 0x008;
        pub const POLLHUP: i16 = 0x010;
        pub const POLLNVAL: i16 = 0x020;

        #[repr(C)]
        pub struct PollFd {
            pub fd: RawFd,
            pub events: i16,
            pub revents: i16,
        }

        #[cfg(target_os = "linux")]
        type Nfds = std::ffi::c_ulong;
        #[cfg(not(target_os = "linux"))]
        type Nfds = std::ffi::c_uint;

        extern "C" {
            fn poll(
                fds: *mut PollFd,
                nfds: Nfds,
                timeout: std::ffi::c_int,
            ) -> std::ffi::c_int;
        }

        /// `poll(2)` with EINTR retried; `timeout_ms < 0` blocks until
        /// something is ready.
        pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
            loop {
                let rc =
                    unsafe { poll(fds.as_mut_ptr(), fds.len() as Nfds, timeout_ms) };
                if rc >= 0 {
                    return Ok(rc as usize);
                }
                let err = std::io::Error::last_os_error();
                if err.kind() != std::io::ErrorKind::Interrupted {
                    return Err(err);
                }
            }
        }
    }

    #[cfg(not(unix))]
    mod fallback_impl {
        pub type RawFd = i32;

        pub const POLLIN: i16 = 0x001;
        pub const POLLOUT: i16 = 0x004;
        pub const POLLERR: i16 = 0x008;
        pub const POLLHUP: i16 = 0x010;
        pub const POLLNVAL: i16 = 0x020;

        pub struct PollFd {
            pub fd: RawFd,
            pub events: i16,
            pub revents: i16,
        }

        pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> std::io::Result<usize> {
            let tick = if timeout_ms < 0 { 1 } else { i64::from(timeout_ms).min(1) };
            if tick > 0 {
                std::thread::sleep(std::time::Duration::from_millis(tick as u64));
            }
            let mut ready = 0;
            for f in fds.iter_mut() {
                f.revents = f.events;
                if f.revents != 0 {
                    ready += 1;
                }
            }
            Ok(ready)
        }
    }
}

#[cfg(not(unix))]
trait AsRawFd {
    fn as_raw_fd(&self) -> sys::RawFd;
}
#[cfg(not(unix))]
impl AsRawFd for TcpListener {
    fn as_raw_fd(&self) -> sys::RawFd {
        -1
    }
}
#[cfg(not(unix))]
impl AsRawFd for TcpStream {
    fn as_raw_fd(&self) -> sys::RawFd {
        -1
    }
}

/// Wakes the reactor from another thread: worker completions and
/// `Server::shutdown` both go through this instead of the old
/// throwaway `TcpStream::connect` hack.
pub(crate) struct Waker {
    /// Write end of the wake socketpair; the reactor polls the read
    /// end. On non-unix hosts the fallback loop self-ticks, so there
    /// is nothing to signal.
    #[cfg(unix)]
    tx: UnixStream,
}

impl Waker {
    pub(crate) fn wake(&self) {
        // A full pipe means a wake is already pending — both are fine.
        #[cfg(unix)]
        {
            let _ = (&self.tx).write(&[1u8]);
        }
    }
}

/// The reactor-side read end of the wake channel.
pub(crate) struct WakeRx {
    #[cfg(unix)]
    rx: UnixStream,
}

pub(crate) fn wake_pair() -> Result<(Arc<Waker>, WakeRx)> {
    #[cfg(unix)]
    {
        let (rx, tx) =
            UnixStream::pair().context("creating the reactor wake socketpair")?;
        rx.set_nonblocking(true)
            .context("making the wake read end nonblocking")?;
        tx.set_nonblocking(true)
            .context("making the wake write end nonblocking")?;
        Ok((Arc::new(Waker { tx }), WakeRx { rx }))
    }
    #[cfg(not(unix))]
    {
        Ok((Arc::new(Waker {}), WakeRx {}))
    }
}

/// A parsed request on its way to the worker pool.
struct Job {
    conn: usize,
    gen: u64,
    seq: u64,
    arrived: Instant,
    payload: Vec<u8>,
    out: Vec<u8>,
}

/// A finished job on its way back to the reactor.
struct Done {
    conn: usize,
    gen: u64,
    seq: u64,
    payload: Vec<u8>,
    out: Vec<u8>,
    ok: bool,
}

/// Recycles payload and reply buffers so the steady-state wire path
/// allocates nothing per frame.
struct Pool(Vec<Vec<u8>>);

impl Pool {
    fn get(&mut self) -> Vec<u8> {
        let mut buf = self.0.pop().unwrap_or_default();
        buf.clear();
        buf
    }

    fn put(&mut self, buf: Vec<u8>) {
        if self.0.len() < POOL_MAX && buf.capacity() <= POOL_BUF_CAP {
            self.0.push(buf);
        }
    }
}

/// Refill-and-take on a per-connection token bucket whose capacity is
/// one second's worth of `rate`. `None` admits the request; `Some`
/// carries the milliseconds until a token will exist.
fn take_token(tokens: &mut f64, refilled: &mut Instant, rate: u64) -> Option<u64> {
    if rate == 0 {
        return None;
    }
    let now = Instant::now();
    let dt = now.duration_since(*refilled).as_secs_f64();
    *tokens = (*tokens + dt * rate as f64).min(rate as f64);
    *refilled = now;
    if *tokens >= 1.0 {
        *tokens -= 1.0;
        None
    } else {
        let wait_ms = (1.0 - *tokens) / rate as f64 * 1000.0;
        Some(wait_ms.ceil().max(1.0) as u64)
    }
}

/// Per-connection state machine.
struct Conn {
    stream: TcpStream,
    /// Guards against completions for a previous occupant of this
    /// slab slot.
    gen: u64,
    dec: Decoder,
    /// Complete reply frames awaiting the socket, oldest first.
    wq: VecDeque<Vec<u8>>,
    /// Bytes of `wq[0]` already written.
    wq_off: usize,
    wq_bytes: usize,
    /// Sequence number the next parsed frame gets.
    seq_next: u64,
    /// Sequence number the next emitted reply must carry.
    emit_next: u64,
    /// Reply frames indexed by `seq - emit_next`; `None` is a hole
    /// whose answer is still being computed.
    slots: VecDeque<Option<Vec<u8>>>,
    /// Parsed request payloads awaiting their turn on the worker pool.
    /// Dispatch is strictly serial per connection — like the blocking
    /// server, pipelined requests never reorder service side effects.
    pending: VecDeque<(u64, Instant, Vec<u8>)>,
    dispatched: bool,
    /// No more reads: peer EOF, a framing error, or a fatal reply
    /// failure. The connection closes once outstanding work flushes.
    closing: bool,
    /// The decoder's buffered bytes are garbage (framing error) or the
    /// connection is past saving (handler failure): never parse them.
    /// Implies `closing`. A plain EOF leaves this unset so frames the
    /// peer pipelined before half-closing are still parsed and
    /// answered, as the blocking server did.
    poisoned: bool,
    /// Token bucket for `rate_limit`.
    tokens: f64,
    refilled: Instant,
}

impl Conn {
    fn new(stream: TcpStream, gen: u64, rate: u64) -> Self {
        Conn {
            stream,
            gen,
            dec: Decoder::new(),
            wq: VecDeque::new(),
            wq_off: 0,
            wq_bytes: 0,
            seq_next: 0,
            emit_next: 0,
            slots: VecDeque::new(),
            pending: VecDeque::new(),
            dispatched: false,
            closing: false,
            poisoned: false,
            tokens: rate as f64,
            refilled: Instant::now(),
        }
    }

    /// Parsed-but-unanswered frames.
    fn backlog(&self) -> usize {
        (self.seq_next - self.emit_next) as usize
    }

    fn wants_read(&self, stopping: bool) -> bool {
        !stopping
            && !self.closing
            && self.backlog() < PARSE_AHEAD
            && self.wq_bytes < WQ_HIGH
    }

    /// Nothing queued, in flight, or waiting to flush.
    fn idle(&self) -> bool {
        self.pending.is_empty() && !self.dispatched && self.wq.is_empty()
    }

    /// Record `frame` as the reply for `seq`, then emit every reply
    /// that is now unblocked, in request order.
    fn slot(&mut self, seq: u64, frame: Vec<u8>) {
        let idx = (seq - self.emit_next) as usize;
        while self.slots.len() <= idx {
            self.slots.push_back(None);
        }
        self.slots[idx] = Some(frame);
        while matches!(self.slots.front(), Some(Some(_))) {
            let ready = self.slots.pop_front().unwrap().unwrap();
            self.emit_next += 1;
            self.wq_bytes += ready.len();
            self.wq.push_back(ready);
        }
    }
}

fn conn_closable(conn: &Conn) -> bool {
    // A half-closed peer may still be owed answers for frames that sat
    // whole in the decoder when parsing paused at a watermark; only a
    // poisoned connection abandons buffered frames.
    conn.closing && conn.idle() && (conn.poisoned || !conn.dec.has_frame())
}

/// Admission verdict for one parsed frame.
enum Admit {
    /// Buffer holds a copy of the frame payload, ready to dispatch.
    Run(Vec<u8>),
    Throttle { retry_ms: u64, message: String },
    /// Framing error — drop the connection without a reply.
    Bad,
    /// No complete frame buffered.
    Empty,
}

enum Tok {
    Listener,
    #[cfg(unix)]
    Waker,
    Conn(usize),
}

/// Run the event loop until `stop` is observed; drains in-flight work
/// (bounded by [`DRAIN_DEADLINE`]), closes every connection, and joins
/// the worker pool before returning. Fatal reactor errors land in the
/// journal — the serving process keeps running so an operator can
/// still reach `Metrics` over a fresh bind.
pub(crate) fn run(
    listener: TcpListener,
    service: Arc<VqService>,
    handler: Handler,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    wake_rx: WakeRx,
) {
    if let Err(e) = run_inner(listener, &service, handler, &stop, &waker, wake_rx) {
        service
            .telemetry()
            .journal()
            .error("serve.reactor", format!("event loop failed: {e:#}"));
    }
}

fn run_inner(
    listener: TcpListener,
    service: &Arc<VqService>,
    handler: Handler,
    stop: &AtomicBool,
    waker: &Arc<Waker>,
    wake_rx: WakeRx,
) -> Result<()> {
    listener
        .set_nonblocking(true)
        .context("making the serve listener nonblocking")?;

    let worker_n = match service.io_workers() {
        0 => thread::available_parallelism().map(|n| n.get()).unwrap_or(4),
        n => n,
    };
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let (done_tx, done_rx) = mpsc::channel::<Done>();
    let mut workers = Vec::with_capacity(worker_n);
    for w in 0..worker_n {
        let rx = Arc::clone(&job_rx);
        let tx = done_tx.clone();
        let handler = Arc::clone(&handler);
        let waker = Arc::clone(waker);
        let t = thread::Builder::new()
            .name(format!("dalvq-io-{w}"))
            .spawn(move || worker_loop(&rx, &tx, &handler, &waker))
            .context("spawning an io worker")?;
        workers.push(t);
    }
    drop(done_tx);

    let mut reactor = Reactor {
        listener,
        service: Arc::clone(service),
        wake_rx,
        job_tx: Some(job_tx),
        done_rx,
        conns: Vec::new(),
        free: Vec::new(),
        next_gen: 0,
        pool: Pool(Vec::new()),
        in_brownout: false,
        rate_limit: service.rate_limit(),
        max_inflight: service.max_inflight(),
        brownout_depth: service.brownout_depth(),
    };
    let outcome = reactor.run(stop);
    reactor.teardown();
    for t in workers {
        let _ = t.join();
    }
    outcome
}

fn worker_loop(
    rx: &Mutex<Receiver<Job>>,
    tx: &Sender<Done>,
    handler: &Handler,
    waker: &Waker,
) {
    loop {
        let job = {
            let rx = rx.lock().unwrap_or_else(|e| e.into_inner());
            rx.recv()
        };
        let Ok(job) = job else { return };
        let Job { conn, gen, seq, arrived, payload, mut out } = job;
        let ok = catch_unwind(AssertUnwindSafe(|| handler(&payload, arrived, &mut out)))
            .unwrap_or(false);
        let done = Done { conn, gen, seq, payload, out, ok };
        if tx.send(done).is_err() {
            return;
        }
        waker.wake();
    }
}

struct Reactor {
    listener: TcpListener,
    service: Arc<VqService>,
    wake_rx: WakeRx,
    /// `Some` while accepting work; dropped at teardown so idle
    /// workers see a closed queue and exit.
    job_tx: Option<Sender<Job>>,
    done_rx: Receiver<Done>,
    /// Connection slab: `free` lists vacant indices for reuse, `gen`
    /// inside each [`Conn`] disambiguates successive occupants.
    conns: Vec<Option<Conn>>,
    free: Vec<usize>,
    next_gen: u64,
    pool: Pool,
    in_brownout: bool,
    rate_limit: u64,
    max_inflight: usize,
    brownout_depth: u64,
}

impl Reactor {
    fn run(&mut self, stop: &AtomicBool) -> Result<()> {
        let mut fds: Vec<sys::PollFd> = Vec::new();
        let mut toks: Vec<Tok> = Vec::new();
        let mut drain_deadline: Option<Instant> = None;
        loop {
            let stopping = stop.load(Ordering::Acquire);
            if stopping {
                let deadline = *drain_deadline
                    .get_or_insert_with(|| Instant::now() + DRAIN_DEADLINE);
                self.drain_done(true);
                let busy = self.conns.iter().flatten().any(|c| !c.idle());
                if !busy || Instant::now() >= deadline {
                    return Ok(());
                }
            }

            fds.clear();
            toks.clear();
            for (i, slot) in self.conns.iter().enumerate() {
                let Some(conn) = slot else { continue };
                let mut events = 0i16;
                if conn.wants_read(stopping) {
                    events |= sys::POLLIN;
                }
                if !conn.wq.is_empty() {
                    events |= sys::POLLOUT;
                }
                if events != 0 {
                    fds.push(sys::PollFd {
                        fd: conn.stream.as_raw_fd(),
                        events,
                        revents: 0,
                    });
                    toks.push(Tok::Conn(i));
                }
            }
            #[cfg(unix)]
            {
                fds.push(sys::PollFd {
                    fd: self.wake_rx.rx.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
                toks.push(Tok::Waker);
            }
            // The listener comes last so connection events in this
            // batch are handled before a freed slab slot can be
            // reoccupied by a fresh accept.
            if !stopping {
                fds.push(sys::PollFd {
                    fd: self.listener.as_raw_fd(),
                    events: sys::POLLIN,
                    revents: 0,
                });
                toks.push(Tok::Listener);
            }

            let timeout_ms = if stopping { 50 } else { -1 };
            sys::poll_fds(&mut fds, timeout_ms)
                .context("polling for socket readiness")?;

            let cycle_start = Instant::now();
            self.drain_wakes();
            self.drain_done(stopping);
            for (k, tok) in toks.iter().enumerate() {
                let revents = fds[k].revents;
                if revents == 0 {
                    continue;
                }
                match *tok {
                    #[cfg(unix)]
                    Tok::Waker => {}
                    Tok::Listener => self.accept_ready(),
                    Tok::Conn(i) => self.conn_ready(i, revents, stopping),
                }
            }
            self.service
                .tel()
                .readiness_us
                .record(cycle_start.elapsed().as_micros() as u64);
        }
    }

    fn drain_wakes(&mut self) {
        #[cfg(unix)]
        {
            let mut sink = [0u8; 64];
            while matches!((&self.wake_rx.rx).read(&mut sink), Ok(n) if n > 0) {}
        }
    }

    fn accept_ready(&mut self) {
        loop {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let _ = stream.set_nodelay(true);
                    if stream.set_nonblocking(true).is_err() {
                        continue;
                    }
                    self.service.tel().conn_accepted.inc();
                    self.service.tel().conn_active.add(1);
                    self.next_gen += 1;
                    let conn = Conn::new(stream, self.next_gen, self.rate_limit);
                    match self.free.pop() {
                        Some(i) => self.conns[i] = Some(conn),
                        None => self.conns.push(Some(conn)),
                    }
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
                // Transient accept failures (fd exhaustion, an aborted
                // handshake): retry on the next readiness cycle
                // instead of spinning here.
                Err(_) => break,
            }
        }
    }

    fn conn_ready(&mut self, i: usize, revents: i16, stopping: bool) {
        let Some(mut conn) = self.conns[i].take() else { return };
        if revents & (sys::POLLERR | sys::POLLNVAL) != 0 {
            self.close(conn);
            self.free.push(i);
            return;
        }
        let mut dead = false;
        if revents & (sys::POLLIN | sys::POLLHUP) != 0 && conn.wants_read(stopping) {
            dead = !self.read_and_parse(i, &mut conn);
        }
        if !dead && !conn.wq.is_empty() {
            dead = self.flush(&mut conn).is_err();
        }
        if !dead && !stopping {
            // The flush may have dropped wq_bytes below WQ_HIGH: frames
            // already buffered in the decoder can proceed now even if
            // the socket itself stays silent.
            self.resume_parse(i, &mut conn);
            if !conn.wq.is_empty() {
                dead = self.flush(&mut conn).is_err();
            }
        }
        if dead || conn_closable(&conn) {
            self.close(conn);
            self.free.push(i);
        } else {
            self.conns[i] = Some(conn);
        }
    }

    /// Read until `WouldBlock`, parsing and admitting every complete
    /// frame along the way. Returns `false` on a socket error that
    /// warrants dropping the connection immediately.
    fn read_and_parse(&mut self, i: usize, conn: &mut Conn) -> bool {
        loop {
            let spare = conn.dec.spare(READ_CHUNK);
            match conn.stream.read(spare) {
                Ok(0) => {
                    conn.closing = true;
                    return true;
                }
                Ok(n) => conn.dec.advance(n),
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return true,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(_) => return false,
            }
            if !self.parse_frames(i, conn) {
                return true; // framing error: closing is set, stop reading
            }
            if !conn.wants_read(false) {
                return true; // backlog or write-queue watermark reached
            }
        }
    }

    /// Parse every complete frame currently buffered, routing each
    /// through admission. Returns `false` when the stream is
    /// undecodable and the connection should stop reading.
    fn parse_frames(&mut self, i: usize, conn: &mut Conn) -> bool {
        loop {
            if conn.backlog() >= PARSE_AHEAD || conn.wq_bytes >= WQ_HIGH {
                return true;
            }
            match self.admit(conn) {
                Admit::Empty => return true,
                Admit::Bad => {
                    // The blocking server dropped the connection on a
                    // framing error without a reply; same here, after
                    // queued replies flush.
                    conn.closing = true;
                    conn.poisoned = true;
                    return false;
                }
                Admit::Throttle { retry_ms, message } => {
                    let seq = conn.seq_next;
                    conn.seq_next += 1;
                    let frame = self.throttled_frame(retry_ms, message);
                    conn.slot(seq, frame);
                    self.service.tel().conn_rejected.inc();
                }
                Admit::Run(payload) => {
                    let seq = conn.seq_next;
                    conn.seq_next += 1;
                    conn.pending.push_back((seq, Instant::now(), payload));
                    self.try_dispatch(i, conn);
                }
            }
        }
    }

    /// Pull the next frame out of the connection's decoder and decide
    /// its fate. The non-consuming gates run first — the in-flight cap,
    /// then the brownout watermark (ingest frames only — reads are
    /// never shed) — and the rate-limit token bucket last, so a request
    /// another gate refuses never burns a rate token and the retry
    /// hints a throttled burst sees stay honest.
    fn admit(&mut self, conn: &mut Conn) -> Admit {
        let payload = match conn.dec.next_frame() {
            Ok(Some(payload)) => payload,
            Ok(None) => return Admit::Empty,
            Err(_) => return Admit::Bad,
        };
        // The backlog (spelled out field-wise — `payload` still borrows
        // the decoder) is exactly the documented quota: frames parsed
        // but not yet answered — queued, executing, or finished but
        // still held in a reorder slot behind an earlier reply.
        let backlog = (conn.seq_next - conn.emit_next) as usize;
        if self.max_inflight > 0 && backlog >= self.max_inflight {
            return Admit::Throttle {
                retry_ms: 1,
                message: format!(
                    "in-flight quota exceeded: {} requests per connection",
                    self.max_inflight
                ),
            };
        }
        if self.brownout_depth > 0 && is_ingest_frame(payload) {
            let depth = self.service.max_queue_depth();
            let shedding = depth >= self.brownout_depth;
            if shedding != self.in_brownout {
                self.in_brownout = shedding;
                let journal = self.service.telemetry().journal();
                if shedding {
                    journal.warn(
                        "brownout.enter",
                        format!(
                            "shedding ingest: shard queue depth {depth} at watermark {}",
                            self.brownout_depth
                        ),
                    );
                } else {
                    journal.info(
                        "brownout.exit",
                        format!(
                            "ingest restored: shard queue depth {depth} below watermark {}",
                            self.brownout_depth
                        ),
                    );
                }
            }
            if shedding {
                return Admit::Throttle {
                    retry_ms: 100,
                    message: format!(
                        "brownout: ingest shed at shard queue depth {depth} (watermark {})",
                        self.brownout_depth
                    ),
                };
            }
        }
        if let Some(retry_ms) =
            take_token(&mut conn.tokens, &mut conn.refilled, self.rate_limit)
        {
            return Admit::Throttle {
                retry_ms,
                message: format!(
                    "rate quota exceeded: {} requests/s per connection",
                    self.rate_limit
                ),
            };
        }
        let mut buf = self.pool.get();
        buf.extend_from_slice(payload);
        Admit::Run(buf)
    }

    fn throttled_frame(&mut self, retry_after_ms: u64, message: String) -> Vec<u8> {
        let mut out = self.pool.get();
        let at = begin_frame(&mut out);
        Response::Throttled { retry_after_ms, message }.encode_into(&mut out);
        end_frame(&mut out, at).expect("throttled reply fits the frame cap");
        out
    }

    /// Hand the connection's next pending frame to the worker pool, if
    /// none of its frames is already there.
    fn try_dispatch(&mut self, i: usize, conn: &mut Conn) {
        if conn.dispatched {
            return;
        }
        let Some((seq, arrived, payload)) = conn.pending.pop_front() else {
            return;
        };
        let Some(job_tx) = &self.job_tx else { return };
        let job = Job {
            conn: i,
            gen: conn.gen,
            seq,
            arrived,
            payload,
            out: self.pool.get(),
        };
        if job_tx.send(job).is_ok() {
            conn.dispatched = true;
        }
    }

    /// Re-run the frame parser over bytes already buffered in the
    /// connection's decoder. Watermark pauses are level-triggered: a
    /// burst of pipelined frames can be consumed off the socket in one
    /// read but parsed only up to [`PARSE_AHEAD`]/[`WQ_HIGH`] — after
    /// that the socket may never signal `POLLIN` again, so every place
    /// that drains the backlog (worker completions, write flushes) must
    /// revisit the leftovers or the connection deadlocks on its own
    /// buffer. `parse_frames` re-checks the watermarks itself, so this
    /// only has to ask whether a whole frame is waiting.
    fn resume_parse(&mut self, i: usize, conn: &mut Conn) {
        if !conn.poisoned && conn.dec.has_frame() {
            // A framing error here sets `closing`/`poisoned`, which the
            // caller's closable check picks up after the next flush.
            let _ = self.parse_frames(i, conn);
        }
    }

    fn drain_done(&mut self, stopping: bool) {
        while let Ok(done) = self.done_rx.try_recv() {
            self.pool.put(done.payload);
            let live = self
                .conns
                .get(done.conn)
                .and_then(|slot| slot.as_ref())
                .is_some_and(|c| c.gen == done.gen);
            if !live {
                // The connection closed (or its slot was reused) while
                // this job was in flight; just recycle the buffers.
                self.pool.put(done.out);
                continue;
            }
            let mut conn = self.conns[done.conn].take().unwrap();
            conn.dispatched = false;
            if done.ok {
                conn.slot(done.seq, done.out);
                self.try_dispatch(done.conn, &mut conn);
            } else {
                // The handler could not produce a frame (reply over
                // the cap, or a panic): drop the connection once its
                // queued replies flush, discarding unanswered pipeline
                // work — the blocking server died at the same point.
                self.pool.put(done.out);
                conn.closing = true;
                conn.poisoned = true;
                for (_, _, buf) in conn.pending.drain(..) {
                    self.pool.put(buf);
                }
                for slot in conn.slots.drain(..) {
                    if let Some(buf) = slot {
                        self.pool.put(buf);
                    }
                }
            }
            let mut dead = !conn.wq.is_empty() && self.flush(&mut conn).is_err();
            if !dead && !stopping {
                // This completion lowered the backlog below PARSE_AHEAD
                // (and the flush may have drained wq_bytes): frames
                // still buffered in the decoder are parsable again.
                self.resume_parse(done.conn, &mut conn);
                if !conn.wq.is_empty() {
                    dead = self.flush(&mut conn).is_err();
                }
            }
            if dead || conn_closable(&conn) {
                self.close(conn);
                self.free.push(done.conn);
            } else {
                self.conns[done.conn] = Some(conn);
            }
        }
    }

    /// Vectored-write the reply queue until it empties or the socket
    /// would block.
    fn flush(&mut self, conn: &mut Conn) -> std::io::Result<()> {
        use std::io::IoSlice;
        while !conn.wq.is_empty() {
            let mut iov: [IoSlice; WRITE_BATCH] =
                std::array::from_fn(|_| IoSlice::new(&[]));
            let mut cnt = 0;
            for (k, frame) in conn.wq.iter().take(WRITE_BATCH).enumerate() {
                iov[k] = IoSlice::new(if k == 0 { &frame[conn.wq_off..] } else { frame });
                cnt += 1;
            }
            let wrote = match conn.stream.write_vectored(&iov[..cnt]) {
                Ok(0) => return Err(std::io::ErrorKind::WriteZero.into()),
                Ok(n) => n,
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => return Ok(()),
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
                Err(e) => return Err(e),
            };
            conn.wq_bytes -= wrote;
            let mut left = wrote;
            while left > 0 {
                let front_rem = conn.wq[0].len() - conn.wq_off;
                if left >= front_rem {
                    left -= front_rem;
                    conn.wq_off = 0;
                    let done = conn.wq.pop_front().unwrap();
                    self.pool.put(done);
                } else {
                    conn.wq_off += left;
                    left = 0;
                }
            }
        }
        Ok(())
    }

    fn close(&mut self, mut conn: Conn) {
        self.service.tel().conn_active.sub(1);
        for (_, _, buf) in conn.pending.drain(..) {
            self.pool.put(buf);
        }
        for slot in conn.slots.drain(..) {
            if let Some(buf) = slot {
                self.pool.put(buf);
            }
        }
        for buf in conn.wq.drain(..) {
            self.pool.put(buf);
        }
        // `conn.stream` drops here, closing the socket.
    }

    /// Last act after the loop exits: flush whatever the drain phase
    /// queued, close everything, and retire the job queue so workers
    /// exit. Late completions die with the channel.
    fn teardown(&mut self) {
        let conns: Vec<Conn> = self.conns.iter_mut().filter_map(Option::take).collect();
        for mut conn in conns {
            let _ = self.flush(&mut conn);
            self.close(conn);
        }
        self.job_tx = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn token_bucket_admits_rate_then_throttles() {
        let mut tokens = 2.0;
        let mut refilled = Instant::now();
        assert!(take_token(&mut tokens, &mut refilled, 2).is_none());
        assert!(take_token(&mut tokens, &mut refilled, 2).is_none());
        let retry = take_token(&mut tokens, &mut refilled, 2)
            .expect("third back-to-back request exceeds a 2/s bucket");
        assert!((1..=501).contains(&retry), "retry hint {retry} ms out of range");
        // A disabled limiter admits everything without touching state.
        let mut tokens = 0.0;
        assert!(take_token(&mut tokens, &mut refilled, 0).is_none());
    }

    #[test]
    fn pool_recycles_cleared_buffers_and_caps_growth() {
        let mut pool = Pool(Vec::new());
        let mut buf = pool.get();
        buf.extend_from_slice(b"payload");
        let cap = buf.capacity();
        pool.put(buf);
        let again = pool.get();
        assert!(again.is_empty());
        assert_eq!(again.capacity(), cap, "recycled buffer keeps its allocation");
        // Oversized buffers are dropped rather than hoarded.
        pool.put(vec![0u8; POOL_BUF_CAP + 1]);
        assert!(pool.0.is_empty());
    }

    #[test]
    fn reply_slots_emit_in_request_order() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let stream = TcpStream::connect(listener.local_addr().unwrap()).unwrap();
        let mut conn = Conn::new(stream, 1, 0);
        conn.seq_next = 3;
        conn.slot(1, vec![1]);
        conn.slot(2, vec![2]);
        assert!(conn.wq.is_empty(), "seq 0 is still a hole");
        conn.slot(0, vec![0]);
        let order: Vec<u8> = conn.wq.iter().map(|f| f[0]).collect();
        assert_eq!(order, vec![0, 1, 2]);
        assert_eq!(conn.emit_next, 3);
        assert_eq!(conn.wq_bytes, 3);
    }

    #[cfg(unix)]
    #[test]
    fn poll_shim_reports_readiness_and_the_waker_unblocks_it() {
        use std::os::unix::io::AsRawFd;
        let (waker, wake_rx) = wake_pair().unwrap();
        let mut fds = [sys::PollFd {
            fd: wake_rx.rx.as_raw_fd(),
            events: sys::POLLIN,
            revents: 0,
        }];
        assert_eq!(sys::poll_fds(&mut fds, 0).unwrap(), 0, "nothing pending yet");
        waker.wake();
        assert_eq!(sys::poll_fds(&mut fds, 1000).unwrap(), 1);
        assert_ne!(fds[0].revents & sys::POLLIN, 0);
    }
}
