//! The wire protocol: length-prefixed binary frames over any byte stream.
//!
//! Frame = `u32` little-endian payload length, then the payload; payload =
//! 1-byte opcode + fixed-width little-endian fields + flat `f32` tails.
//! Hand-rolled (the offline build carries no serde) and symmetric: the
//! in-crate [`super::Client`] and the server share these encoders, and the
//! unit tests round-trip every variant.
//!
//! Points always travel as flat row-major `f32` — the same layout the
//! engines and kernels use, so a server handler can pass a request body to
//! the VQ math without reshaping.
//!
//! The byte-level layout of every frame — opcodes, field order, framing
//! rules, and version/compatibility notes — is documented in
//! `docs/PROTOCOL.md`; keep the two in lockstep.

use std::io::{Read, Write};

use anyhow::{anyhow, bail, Result};

/// Hard cap on frame payloads (64 MiB) — a garbage length prefix must not
/// become an allocation.
pub const MAX_FRAME: u32 = 64 << 20;

/// What a client asks the service.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Quantize: nearest-prototype code per point.
    Encode { points: Vec<f32> },
    /// Nearest centroid per point, with squared distances.
    Nearest { points: Vec<f32> },
    /// Normalized empirical distortion of the batch.
    Distortion { points: Vec<f32> },
    /// Feed points into the online training stream.
    Ingest { points: Vec<f32> },
    /// Service counters and shape.
    Stats,
    /// Force a durable checkpoint of every shard that advanced since its
    /// last one (errors when the service runs without a state dir).
    Checkpoint,
    /// Re-partition the service online: retrain the coarse router from
    /// the checkpointed shard codebooks and migrate prototype rows across
    /// the fleets at a bumped router version. Queries keep answering from
    /// the old epoch until the new one publishes. Errors when the service
    /// runs without a state dir (the checkpointed files are the migration
    /// source). With `want_remap`, the ack carries the old→new global-code
    /// remap so clients holding cached codes can translate them.
    Rebalance {
        /// Ask for the old→new code remap in the ack (it is `kappa`
        /// `u32`s — cheap, but only useful to clients that cache codes).
        want_remap: bool,
    },
    /// Fetch the server's durable state as a consistent bundle of raw
    /// checkpoint files, cut at a checkpoint generation. Pass the
    /// generation already adopted to make the poll cheap: a server whose
    /// current generation equals it answers with an empty file list, and
    /// one that remembers the shard versions of `have_generation` ships
    /// a *delta* — manifest plus only the shard files whose version
    /// advanced (`StateShipment::delta`). Bootstrap with
    /// [`FETCH_ANY_GENERATION`]. Answered by the leader and by any
    /// follower serving from a mirror directory (that is the fan-out
    /// tree); a mirror-less follower answers [`Response::NotLeader`].
    /// Errors without a state dir. When the cut outgrows one frame the
    /// reply is chunk 1 of `chunks` — fetch the rest with
    /// [`Request::FetchChunk`].
    FetchState {
        /// Generation the requester already holds; a server that cannot
        /// relate its cut to it ships the full bundle.
        have_generation: u64,
    },
    /// Fetch one chunk of a multi-chunk state cut, by the generation the
    /// [`Request::FetchState`] reply announced. Chunking is
    /// deterministic per generation, so chunks can be fetched in any
    /// order over any connection; a server whose generation moved on
    /// answers with an error (re-start from `FetchState`).
    FetchChunk {
        /// Generation of the cut being assembled.
        generation: u64,
        /// 1-based chunk index in `1..=chunks`.
        chunk: u32,
    },
    /// Failover: tell a (possibly returning) leader that a follower
    /// promoted at a higher checkpoint generation. The receiver demotes
    /// into a follower of `leader` iff `generation` is strictly above
    /// its own; otherwise it answers [`Response::Error`] and keeps its
    /// role (a stale promoter must not depose a live leader).
    Demote {
        /// The promoted leader's checkpoint generation.
        generation: u64,
        /// Address the demoted server should re-point to (`host:port`).
        leader: String,
    },
    /// Fetch the server's telemetry plane: every counter, gauge and
    /// latency-histogram digest plus the newest journal events. Read-only
    /// — answered by leaders **and** followers (watching a follower's
    /// sync lag is half the point).
    Metrics {
        /// Cap on journal events in the reply (0 = metrics only).
        max_events: u32,
    },
    /// Fetch the newest sampled traces from the server's completed-trace
    /// ring (span trees with microsecond offsets). Read-only — answered
    /// by leaders **and** followers, like `Metrics`.
    Trace {
        /// Cap on traces in the reply.
        max_traces: u32,
    },
    /// The trace-context envelope: any *other* request wrapped together
    /// with the caller's 128-bit trace id and parent span id. A server
    /// handles the inner request exactly as if it arrived bare, but
    /// records its spans under the caller's trace and ships them back in
    /// a [`Response::Traced`] envelope. Clients that never trace emit
    /// byte-identical frames to the pre-tracing protocol — the envelope
    /// only exists on the wire when a trace is in flight. Nesting an
    /// envelope inside an envelope is a decode error.
    Traced {
        /// High 64 bits of the caller's trace id.
        hi: u64,
        /// Low 64 bits of the caller's trace id.
        lo: u64,
        /// The caller-side span the server's root span hangs under.
        parent: u64,
        /// The wrapped request (never itself `Traced`).
        inner: Box<Request>,
    },
}

/// `have_generation` sentinel that never matches a real checkpoint
/// generation, so a bootstrap `FetchState` always ships the full bundle.
/// (Real generations are manifest-write counters; reaching `u64::MAX`
/// would take longer than the hardware exists.)
pub const FETCH_ANY_GENERATION: u64 = u64::MAX;

/// What the service answers.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// `Encode` reply: nearest-prototype global code per point, plus the
    /// snapshot version that answered.
    Codes {
        /// Aggregate snapshot version of the answering epoch.
        version: u64,
        /// One global prototype code per query point.
        codes: Vec<u32>,
    },
    /// `Nearest` reply: nearest-centroid index and squared distance per
    /// point.
    Neighbors {
        /// Aggregate snapshot version of the answering epoch.
        version: u64,
        /// Nearest global prototype index per point.
        indices: Vec<u32>,
        /// Squared distance to that prototype per point.
        dists: Vec<f32>,
    },
    /// `Distortion` reply: normalized empirical distortion of the batch.
    Distortion {
        /// Aggregate snapshot version of the answering epoch.
        version: u64,
        /// Mean squared quantization error of the batch (paper eq. 2).
        value: f64,
    },
    /// `Ingest` reply: how many points entered worker queues vs were shed.
    IngestAck {
        /// Points accepted into worker queues.
        accepted: u64,
        /// Points shed (full queues, or a draining epoch).
        shed: u64,
    },
    /// `Stats` reply: service shape + live counters.
    Stats(StatsReply),
    /// Per-shard last-checkpointed versions after a forced flush.
    CheckpointAck {
        /// Last durable version per shard, shard order.
        versions: Vec<u64>,
    },
    /// A completed rebalance: the bumped router version, how many
    /// prototype rows changed shard, and the per-shard versions the
    /// migrated fleets resumed at.
    RebalanceAck {
        /// The bumped partition version now serving.
        router_version: u64,
        /// Prototype rows that changed shard.
        moved_rows: u64,
        /// Per-shard versions the migrated fleets resumed at.
        shard_versions: Vec<u64>,
        /// Old→new global-code remap (`remap[old] = new`); empty unless
        /// the request set `want_remap`.
        remap: Vec<u32>,
    },
    /// `FetchState` / `FetchChunk` reply: a consistent bundle (or one
    /// chunk, or the delta) of checkpoint files.
    State(StateShipment),
    /// `Demote` reply: the receiver accepted the higher generation and
    /// is now a follower of the requested leader.
    DemoteAck,
    /// `Metrics` reply: the telemetry digest.
    Metrics(MetricsReply),
    /// `Trace` reply: the newest sampled traces, newest first.
    Traces(Vec<WireTrace>),
    /// The reply-side trace envelope: the server's recorded spans for
    /// this request, wrapped around the ordinary reply. Only ever sent
    /// in answer to a [`Request::Traced`] envelope; nesting is a decode
    /// error.
    Traced {
        /// High 64 bits of the trace id (echoed from the request).
        hi: u64,
        /// Low 64 bits of the trace id (echoed from the request).
        lo: u64,
        /// The server-side spans, offsets relative to the server's
        /// request arrival (the caller re-anchors them — see
        /// `TraceBuilder::graft`).
        spans: Vec<WireSpan>,
        /// The wrapped reply (never itself `Traced`).
        inner: Box<Response>,
    },
    /// The server refused to *start* this request: an admission-control
    /// quota (per-connection rate or in-flight cap) or an overload
    /// brownout turned it away before any work ran. In-band and
    /// connection-preserving — the stream stays in sync and the client
    /// may retry after the hint. Distinct from `Error` so clients can
    /// back off instead of treating load shedding as a failure.
    Throttled {
        /// Suggested wait before retrying, milliseconds (0 = retry at
        /// will — e.g. an in-flight cap that frees up as replies drain).
        retry_after_ms: u64,
        /// Human-readable reason (which quota tripped, or the brownout).
        message: String,
    },
    /// The addressed server is a read-only follower: ingest, checkpoint,
    /// rebalance and state-fetch belong on its leader. Distinct from
    /// `Error` so clients can redirect instead of just failing.
    NotLeader {
        /// Address of the leader this follower replicates
        /// (`host:port`, as configured by `--follow`).
        leader: String,
    },
    /// Request-level failure; the connection stays usable.
    Error {
        /// Human-readable failure description.
        message: String,
    },
}

/// The `FetchState` / `FetchChunk` payload: checkpoint files cut
/// consistently at one checkpoint generation (see
/// [`crate::persist::ship`]), possibly one chunk of a larger cut,
/// possibly a delta against the requester's held cut.
#[derive(Debug, Clone, PartialEq)]
pub struct StateShipment {
    /// Checkpoint generation the bundle was cut at. Equal to the
    /// request's `have_generation` when nothing changed (then `files` is
    /// empty).
    pub generation: u64,
    /// The shipper's *live* summed snapshot version at answer time — what
    /// a follower measures its `sync_lag_folds` against (the bundle
    /// itself only carries the last-checkpointed versions).
    pub leader_version: u64,
    /// 1-based index of this chunk within the cut.
    pub chunk: u32,
    /// Total chunks in the cut (≥ 1; 1 = the whole cut fit one frame).
    /// Chunking is deterministic per generation, so `FetchChunk` can
    /// collect the rest in any order.
    pub chunks: u32,
    /// Whether `files` is a *delta* against the cut the requester said
    /// it holds (merge with [`crate::persist::ship::apply_delta`])
    /// rather than a complete bundle (adopt wholesale).
    pub delta: bool,
    /// Raw checkpoint file pieces (`manifest.json`, `router.bin`,
    /// `shard-<s>.state`), byte-identical to the shipper's directory.
    /// Empty when the requester's generation is already current.
    pub files: Vec<StateFile>,
}

impl Default for StateShipment {
    fn default() -> Self {
        Self {
            generation: 0,
            leader_version: 0,
            chunk: 1,
            chunks: 1,
            delta: false,
            files: Vec::new(),
        }
    }
}

/// One shipped checkpoint file piece.
#[derive(Debug, Clone, PartialEq)]
pub struct StateFile {
    /// File name inside the state directory (no path separators).
    pub name: String,
    /// Byte offset of this piece within the whole file (0 when the file
    /// travels whole).
    pub offset: u64,
    /// Complete length of the file this piece belongs to.
    pub file_len: u64,
    /// The piece's raw bytes.
    pub bytes: Vec<u8>,
}

/// The `Stats` payload: shape + live counters of the service, including
/// the sharded-routing topology (`shards`, `probe_n`) and per-shard
/// version/fold vectors. Requests are unchanged — an old client's `Stats`
/// request still decodes; only this reply grew fields.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct StatsReply {
    /// Sum of per-shard snapshot versions.
    pub version: u64,
    /// Total prototypes across shards.
    pub kappa: u64,
    /// Prototype dimension.
    pub dim: u64,
    /// Total workers across shards (0 on a follower).
    pub workers: u64,
    /// Shard count of the serving epoch.
    pub shards: u64,
    /// Shards probed per query point.
    pub probe_n: u64,
    /// Partition version of the serving router epoch (0 = bootstrap,
    /// bumped by every rebalance).
    pub router_version: u64,
    /// Completed rebalances this process lifetime.
    pub rebalances: u64,
    /// Fold clock across every shard's reducer.
    pub merges: u64,
    /// Points accepted into worker queues, service lifetime.
    pub ingested: u64,
    /// Points shed, service lifetime.
    pub ingest_shed: u64,
    /// Read requests answered, service lifetime.
    pub queries: u64,
    /// Published snapshot version per shard, shard order.
    pub shard_versions: Vec<u64>,
    /// Reducer fold count per shard, shard order.
    pub shard_merges: Vec<u64>,
    /// Points accepted per shard during the current router epoch (what
    /// the rebalance skew trigger reads), shard order.
    pub shard_ingest: Vec<u64>,
    /// Points shed per shard during the current router epoch, shard order.
    pub shard_shed: Vec<u64>,
    /// Last checkpointed version per shard (empty without persistence).
    pub last_checkpoint: Vec<u64>,
    /// Durable state directory (empty string = no persistence).
    pub state_dir: String,
    /// Replication role: `"leader"` (default — also what every
    /// pre-replication deployment is) or `"follower"`.
    pub role: String,
    /// Leader address this server replicates (empty on a leader).
    pub leader_addr: String,
    /// Follower freshness: the leader's live summed version at the last
    /// sync poll minus the summed version served here. 0 on a leader.
    pub sync_lag_folds: u64,
    /// Milliseconds since the last successful sync poll of the leader
    /// (0 on a leader).
    pub last_sync: u64,
    /// Milliseconds since the service came up.
    pub uptime_ms: u64,
    /// `Encode` requests answered, service lifetime.
    pub op_encode: u64,
    /// `Nearest` requests answered, service lifetime.
    pub op_nearest: u64,
    /// `Distortion` requests answered, service lifetime.
    pub op_distortion: u64,
    /// `Ingest` requests answered (requests, not points), service
    /// lifetime.
    pub op_ingest: u64,
    /// How the last sync adoption arrived: `"delta"` or `"full"` on a
    /// follower that has adopted at least once, `""` otherwise (leaders
    /// included).
    pub sync_source: String,
}

/// One span inside a [`WireTrace`] or a [`Response::Traced`] envelope.
/// Offsets are microseconds relative to the owning trace's origin (for
/// envelope spans: the server's request arrival).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WireSpan {
    /// Span id, unique within its trace (never 0).
    pub id: u64,
    /// Parent span id; 0 marks the root (or, in an envelope, a span of
    /// the *caller's*, so the receiver re-parents it).
    pub parent: u64,
    /// Start offset from the trace origin, µs.
    pub start_us: u64,
    /// Duration, µs.
    pub dur_us: u64,
    /// Catalog name (`req.nearest`, `scan`, `state.ship`, …; see
    /// `docs/OBSERVABILITY.md`).
    pub name: String,
}

/// One completed trace inside a [`Response::Traces`] reply.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct WireTrace {
    /// High 64 bits of the 128-bit trace id.
    pub hi: u64,
    /// Low 64 bits of the 128-bit trace id.
    pub lo: u64,
    /// Unix-epoch milliseconds when the trace committed.
    pub ts_ms: u64,
    /// The span tree in recording order (the root first).
    pub spans: Vec<WireSpan>,
}

/// The `Metrics` payload: a point-in-time digest of the server's
/// telemetry plane — name-sorted counters, gauges and histogram digests
/// plus the newest journal events. The metric *names* are the catalog in
/// `docs/OBSERVABILITY.md`; the wire layer treats them as opaque strings
/// so the catalog can grow without a protocol bump.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricsReply {
    /// Milliseconds since the service came up.
    pub uptime_ms: u64,
    /// Monotone counters, name-sorted.
    pub counters: Vec<(String, u64)>,
    /// Instantaneous gauges, name-sorted.
    pub gauges: Vec<(String, u64)>,
    /// Latency-histogram digests, name-sorted.
    pub hists: Vec<MetricHist>,
    /// Newest journal events, oldest first.
    pub events: Vec<MetricEvent>,
}

/// One latency-histogram digest inside a [`MetricsReply`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricHist {
    pub name: String,
    /// Samples recorded.
    pub count: u64,
    /// Exact mean (microseconds).
    pub mean_us: f64,
    /// Nearest-rank percentiles (microseconds, ≤ 6.25% quantization).
    pub p50_us: f64,
    pub p95_us: f64,
    pub p99_us: f64,
    /// Exact maximum (microseconds).
    pub max_us: f64,
}

/// One journal event inside a [`MetricsReply`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct MetricEvent {
    /// Monotone per-journal sequence number.
    pub seq: u64,
    /// Wall-clock milliseconds since the Unix epoch.
    pub ts_ms: u64,
    /// Severity: 0 = info, 1 = warn, 2 = error (other values reserved;
    /// carried verbatim so old clients survive new levels).
    pub level: u8,
    /// Dot-separated event family, e.g. `checkpoint.flush`.
    pub kind: String,
    /// Human-readable detail line.
    pub message: String,
}

// ------------------------------------------------------------ frame I/O

/// Write one length-prefixed frame.
pub fn write_frame(w: &mut impl Write, payload: &[u8]) -> Result<()> {
    let len = u32::try_from(payload.len())
        .map_err(|_| anyhow!("frame too large: {} bytes", payload.len()))?;
    if len > MAX_FRAME {
        bail!("frame too large: {len} bytes (max {MAX_FRAME})");
    }
    w.write_all(&len.to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one length-prefixed frame. `Ok(None)` on clean EOF **at a frame
/// boundary** (the peer hung up between requests); EOF anywhere inside a
/// frame — mid-header or mid-payload — is an error, so a dying peer is
/// never mistaken for a clean hang-up.
pub fn read_frame(r: &mut impl Read) -> Result<Option<Vec<u8>>> {
    let mut buf = Vec::new();
    Ok(if read_frame_into(r, &mut buf)? { Some(buf) } else { None })
}

/// [`read_frame`] into a caller-owned buffer: `buf` is cleared and
/// resized to the payload, so its *capacity* is what carries over — a
/// client reading replies through one scratch buffer allocates only when
/// a reply outgrows every earlier one. `Ok(false)` on clean EOF at a
/// frame boundary; the mid-frame EOF and oversized-prefix errors are
/// exactly [`read_frame`]'s.
pub fn read_frame_into(r: &mut impl Read, buf: &mut Vec<u8>) -> Result<bool> {
    let mut len_buf = [0u8; 4];
    let mut filled = 0usize;
    while filled < 4 {
        match r.read(&mut len_buf[filled..]) {
            Ok(0) if filled == 0 => return Ok(false),
            Ok(0) => bail!("EOF after {filled} bytes of a 4-byte frame header"),
            Ok(n) => filled += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    let len = u32::from_le_bytes(len_buf);
    if len > MAX_FRAME {
        bail!("incoming frame of {len} bytes exceeds cap {MAX_FRAME}");
    }
    buf.clear();
    buf.resize(len as usize, 0);
    r.read_exact(buf)?;
    Ok(true)
}

/// Begin a length-prefixed frame in `out`: append a 4-byte placeholder
/// and return its offset for [`end_frame`]. Together they let a writer
/// encode a payload straight into its write buffer — no staging `Vec`,
/// no copy — and patch the length afterwards.
pub fn begin_frame(out: &mut Vec<u8>) -> usize {
    let at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    at
}

/// Finish a frame begun at `at`: patch the length prefix to cover the
/// bytes appended since. When the payload outgrew [`MAX_FRAME`] the
/// frame is rolled back (`out` truncates to `at`) and this errors — the
/// peer never sees a half-frame, mirroring [`write_frame`]'s refusal.
pub fn end_frame(out: &mut Vec<u8>, at: usize) -> Result<()> {
    let len = out.len() - at - 4;
    if len > MAX_FRAME as usize {
        out.truncate(at);
        bail!("frame too large: {len} bytes (max {MAX_FRAME})");
    }
    let prefix = (len as u32).to_le_bytes();
    out[at..at + 4].copy_from_slice(&prefix);
    Ok(())
}

/// Does this frame payload carry an `Ingest` — bare, or wrapped in a
/// trace envelope? A constant-time peek (the opcode byte, or the inner
/// opcode behind the envelope's 29-byte prefix) so brownout shedding can
/// classify a frame without decoding it.
pub fn is_ingest_frame(payload: &[u8]) -> bool {
    match payload.first() {
        Some(&OP_INGEST) => true,
        Some(&OP_TRACED_REQ) => payload.get(29) == Some(&OP_INGEST),
        _ => false,
    }
}

/// An incremental frame decoder over one growable buffer: feed raw bytes
/// in however the transport chunks them, take complete frame payloads
/// out as **borrowed slices** — the zero-copy counterpart of
/// [`read_frame`] for nonblocking transports. The event-loop server owns
/// one per connection; a frame split at any byte boundary across reads
/// yields exactly the bytes a whole-frame read would have.
pub struct Decoder {
    buf: Vec<u8>,
    start: usize,
    end: usize,
}

impl Decoder {
    pub fn new() -> Self {
        Self::with_capacity(4 << 10)
    }

    pub fn with_capacity(cap: usize) -> Self {
        Self { buf: vec![0; cap.max(8)], start: 0, end: 0 }
    }

    /// Writable spare room of at least `min` bytes, compacting consumed
    /// frames out of the way and growing the buffer only when compaction
    /// is not enough. Fill some prefix of it from the transport, then
    /// report how much arrived via [`Decoder::advance`].
    pub fn spare(&mut self, min: usize) -> &mut [u8] {
        if self.start == self.end {
            // Empty: restart at the front so steady-state traffic never
            // compacts at all.
            self.start = 0;
            self.end = 0;
        }
        if self.buf.len() - self.end < min {
            if self.start > 0 {
                self.buf.copy_within(self.start..self.end, 0);
                self.end -= self.start;
                self.start = 0;
            }
            if self.buf.len() - self.end < min {
                let want = (self.end + min).next_power_of_two();
                self.buf.resize(want, 0);
            }
        }
        &mut self.buf[self.end..]
    }

    /// Mark `n` bytes of the spare region as filled.
    pub fn advance(&mut self, n: usize) {
        debug_assert!(self.end + n <= self.buf.len());
        self.end += n;
    }

    /// Bytes buffered but not yet yielded as frames.
    pub fn pending(&self) -> usize {
        self.end - self.start
    }

    /// Whether [`Decoder::next_frame`] would make progress without more
    /// bytes: a complete frame is buffered, or the buffered length
    /// prefix is over the cap and the next call will report the error.
    /// Callers that pause mid-buffer (watermarks) poll this to know the
    /// leftovers still need a visit.
    pub fn has_frame(&self) -> bool {
        let have = self.end - self.start;
        if have < 4 {
            return false;
        }
        let len_buf: [u8; 4] =
            self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = u32::from_le_bytes(len_buf);
        len > MAX_FRAME || have >= 4 + len as usize
    }

    /// The next complete frame payload, borrowed from the buffer (valid
    /// until the next `spare`/`next_frame` call). `Ok(None)` when the
    /// buffered bytes end mid-header or mid-payload — read more and ask
    /// again. An oversized length prefix errors exactly like
    /// [`read_frame`], before any allocation sized by it.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>> {
        let have = self.end - self.start;
        if have < 4 {
            return Ok(None);
        }
        let len_buf: [u8; 4] =
            self.buf[self.start..self.start + 4].try_into().unwrap();
        let len = u32::from_le_bytes(len_buf);
        if len > MAX_FRAME {
            bail!("incoming frame of {len} bytes exceeds cap {MAX_FRAME}");
        }
        let total = 4 + len as usize;
        if have < total {
            return Ok(None);
        }
        let at = self.start + 4;
        self.start += total;
        Ok(Some(&self.buf[at..at + len as usize]))
    }
}

// ------------------------------------------------------------ encoders

const OP_ENCODE: u8 = 0x01;
const OP_NEAREST: u8 = 0x02;
const OP_DISTORTION: u8 = 0x03;
const OP_INGEST: u8 = 0x04;
const OP_STATS: u8 = 0x05;
const OP_CHECKPOINT: u8 = 0x06;
const OP_REBALANCE: u8 = 0x07;
const OP_FETCH_STATE: u8 = 0x08;
const OP_METRICS: u8 = 0x09;
const OP_TRACE: u8 = 0x0A;
const OP_TRACED_REQ: u8 = 0x0B;
const OP_FETCH_CHUNK: u8 = 0x0C;
const OP_DEMOTE: u8 = 0x0D;

const OP_CODES: u8 = 0x81;
const OP_NEIGHBORS: u8 = 0x82;
const OP_DISTORTION_R: u8 = 0x83;
const OP_INGEST_ACK: u8 = 0x84;
const OP_STATS_R: u8 = 0x85;
const OP_CHECKPOINT_ACK: u8 = 0x86;
const OP_REBALANCE_ACK: u8 = 0x87;
const OP_STATE: u8 = 0x88;
const OP_METRICS_R: u8 = 0x89;
const OP_TRACE_R: u8 = 0x8A;
const OP_TRACED_RESP: u8 = 0x8B;
const OP_DEMOTE_ACK: u8 = 0x8C;
const OP_THROTTLED: u8 = 0xFD;
const OP_NOT_LEADER: u8 = 0xFE;
const OP_ERROR: u8 = 0xFF;

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_u64s(out: &mut Vec<u8>, xs: &[u64]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    let bytes = s.as_bytes();
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn put_bytes(out: &mut Vec<u8>, bytes: &[u8]) {
    out.extend_from_slice(&(bytes.len() as u32).to_le_bytes());
    out.extend_from_slice(bytes);
}

fn put_spans(out: &mut Vec<u8>, spans: &[WireSpan]) {
    out.extend_from_slice(&(spans.len() as u32).to_le_bytes());
    for s in spans {
        out.extend_from_slice(&s.id.to_le_bytes());
        out.extend_from_slice(&s.parent.to_le_bytes());
        out.extend_from_slice(&s.start_us.to_le_bytes());
        out.extend_from_slice(&s.dur_us.to_le_bytes());
        put_str(out, &s.name);
    }
}

/// Assemble a [`Response::Traced`] envelope around an already-encoded
/// inner reply. The server uses this so the inner encode can be timed
/// (and recorded as the `encode` span) *before* the envelope — whose
/// span list must already be final — is written.
pub fn encode_traced_response(
    hi: u64,
    lo: u64,
    spans: &[WireSpan],
    inner: &[u8],
) -> Vec<u8> {
    let mut out = Vec::with_capacity(inner.len() + 64);
    encode_traced_response_into(&mut out, hi, lo, spans, inner);
    out
}

/// [`encode_traced_response`] appending to a caller-owned buffer — the
/// event-loop server assembles the envelope directly in a connection's
/// reply frame instead of allocating an intermediate `Vec`.
pub fn encode_traced_response_into(
    out: &mut Vec<u8>,
    hi: u64,
    lo: u64,
    spans: &[WireSpan],
    inner: &[u8],
) {
    out.push(OP_TRACED_RESP);
    out.extend_from_slice(&hi.to_le_bytes());
    out.extend_from_slice(&lo.to_le_bytes());
    put_spans(out, spans);
    put_bytes(out, inner);
}

/// Append a [`Request::Traced`] envelope around `inner` to `out`,
/// encoding the inner request in place behind a patched length field —
/// byte-identical to `Request::Traced { .. }.encode_into(..)` without
/// boxing a clone of the inner request. The client's trace stamping
/// rides this so its per-connection scratch buffer stays the only
/// allocation on the send path.
pub fn encode_traced_request_into(
    out: &mut Vec<u8>,
    hi: u64,
    lo: u64,
    parent: u64,
    inner: &Request,
) {
    debug_assert!(
        !matches!(inner, Request::Traced { .. }),
        "trace envelopes do not nest"
    );
    out.push(OP_TRACED_REQ);
    out.extend_from_slice(&hi.to_le_bytes());
    out.extend_from_slice(&lo.to_le_bytes());
    out.extend_from_slice(&parent.to_le_bytes());
    let len_at = out.len();
    out.extend_from_slice(&[0u8; 4]);
    inner.encode_into(out);
    let inner_len = (out.len() - len_at - 4) as u32;
    out[len_at..len_at + 4].copy_from_slice(&inner_len.to_le_bytes());
}

/// A bounds-checked little-endian reader over a payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| anyhow!("truncated frame at byte {}", self.pos))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    /// A count-prefixed point vector as a borrowed, finite-validated
    /// [`PointsRef`] — the zero-copy twin of `f32s` + finiteness. Same
    /// bounds discipline (the `bytes` check fires before anything sized
    /// by the count) and same error text, but no allocation either way.
    fn points_ref(&mut self) -> Result<PointsRef<'a>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n * 4)?;
        for (i, b) in raw.chunks_exact(4).enumerate() {
            let x = f32::from_le_bytes(b.try_into().unwrap());
            if !x.is_finite() {
                bail!("non-finite point coordinate {x} at index {i}");
            }
        }
        Ok(PointsRef { raw })
    }

    fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n * 4)?;
        Ok(raw
            .chunks_exact(4)
            .map(|b| u32::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.u32()? as usize;
        // Bounds-check before allocating: a lying element count must not
        // become a huge Vec (same discipline as f32s/u32s — `bytes` fails
        // first, so allocation is proportional to real payload only).
        let raw = self.bytes(n * 8)?;
        Ok(raw
            .chunks_exact(8)
            .map(|b| u64::from_le_bytes(b.try_into().unwrap()))
            .collect())
    }

    fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let raw = self.bytes(n)?;
        Ok(String::from_utf8_lossy(raw).into_owned())
    }

    fn blob(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.bytes(n)?.to_vec())
    }

    fn spans(&mut self) -> Result<Vec<WireSpan>> {
        let n = self.u32()? as usize;
        // Each span consumes at least 36 bytes of payload, so a lying
        // count fails in `bytes` before any oversized allocation.
        let mut spans = Vec::new();
        for _ in 0..n {
            spans.push(WireSpan {
                id: self.u64()?,
                parent: self.u64()?,
                start_us: self.u64()?,
                dur_us: self.u64()?,
                name: self.str()?,
            });
        }
        Ok(spans)
    }

    fn finish(self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            bail!("{} trailing bytes in frame", self.buf.len() - self.pos)
        }
    }
}

/// A borrowed view of a point payload: the raw little-endian `f32` bytes
/// straight out of a frame buffer, already validated finite at decode
/// (a NaN that reached the distance kernels would fail every `<` and
/// silently answer code 0; one that reached `Ingest` would poison a
/// codebook row for every later query) but not yet copied anywhere.
/// `copy_into` a reusable scratch buffer to hand the flat row-major
/// floats to the VQ math — that copy is the *only* one a zero-copy
/// request pays between socket and kernel.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PointsRef<'a> {
    raw: &'a [u8],
}

impl<'a> PointsRef<'a> {
    /// Number of `f32` coordinates.
    pub fn len(&self) -> usize {
        self.raw.len() / 4
    }

    pub fn is_empty(&self) -> bool {
        self.raw.is_empty()
    }

    /// Iterate the coordinates without allocating.
    pub fn iter(&self) -> impl Iterator<Item = f32> + 'a {
        self.raw
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
    }

    /// Replace `out`'s contents with the decoded coordinates, reusing
    /// its capacity.
    pub fn copy_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.len());
        out.extend(self.iter());
    }

    pub fn to_vec(&self) -> Vec<f32> {
        self.iter().collect()
    }
}

/// A request decoded *in place*: point payloads stay as borrowed
/// [`PointsRef`] slices of the frame buffer instead of fresh
/// `Vec<f32>`s. This is the server's hot-path view — [`Request::decode`]
/// delegates here and copies out, so the two decoders can never drift.
/// Acceptance set and error text are byte-for-byte the owned decoder's:
/// bounds, finiteness, trailing bytes and envelope nesting all check
/// identically.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestRef<'a> {
    Encode { points: PointsRef<'a> },
    Nearest { points: PointsRef<'a> },
    Distortion { points: PointsRef<'a> },
    Ingest { points: PointsRef<'a> },
    Stats,
    Checkpoint,
    Rebalance { want_remap: bool },
    FetchState { have_generation: u64 },
    FetchChunk { generation: u64, chunk: u32 },
    Demote { generation: u64, leader: String },
    Metrics { max_events: u32 },
    Trace { max_traces: u32 },
    Traced { hi: u64, lo: u64, parent: u64, inner: Box<RequestRef<'a>> },
}

impl<'a> RequestRef<'a> {
    /// Decode one request payload without copying point data. Total,
    /// like [`Request::decode`].
    pub fn decode(payload: &'a [u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let req = match c.u8()? {
            OP_ENCODE => RequestRef::Encode { points: c.points_ref()? },
            OP_NEAREST => RequestRef::Nearest { points: c.points_ref()? },
            OP_DISTORTION => {
                RequestRef::Distortion { points: c.points_ref()? }
            }
            OP_INGEST => RequestRef::Ingest { points: c.points_ref()? },
            OP_STATS => RequestRef::Stats,
            OP_CHECKPOINT => RequestRef::Checkpoint,
            OP_REBALANCE => {
                RequestRef::Rebalance { want_remap: c.u8()? != 0 }
            }
            OP_FETCH_STATE => {
                RequestRef::FetchState { have_generation: c.u64()? }
            }
            OP_FETCH_CHUNK => RequestRef::FetchChunk {
                generation: c.u64()?,
                chunk: c.u32()?,
            },
            OP_DEMOTE => RequestRef::Demote {
                generation: c.u64()?,
                leader: c.str()?,
            },
            OP_METRICS => RequestRef::Metrics { max_events: c.u32()? },
            OP_TRACE => RequestRef::Trace { max_traces: c.u32()? },
            OP_TRACED_REQ => {
                let hi = c.u64()?;
                let lo = c.u64()?;
                let parent = c.u64()?;
                let n = c.u32()? as usize;
                let inner_bytes = c.bytes(n)?;
                let inner = RequestRef::decode(inner_bytes)
                    .map_err(|e| anyhow!("inside a trace envelope: {e}"))?;
                if matches!(inner, RequestRef::Traced { .. }) {
                    bail!("nested trace envelopes are not allowed");
                }
                RequestRef::Traced { hi, lo, parent, inner: Box::new(inner) }
            }
            op => bail!("unknown request opcode 0x{op:02x}"),
        };
        c.finish()?;
        Ok(req)
    }

    /// Copy out into an owned [`Request`].
    pub fn to_owned(&self) -> Request {
        match self {
            RequestRef::Encode { points } => {
                Request::Encode { points: points.to_vec() }
            }
            RequestRef::Nearest { points } => {
                Request::Nearest { points: points.to_vec() }
            }
            RequestRef::Distortion { points } => {
                Request::Distortion { points: points.to_vec() }
            }
            RequestRef::Ingest { points } => {
                Request::Ingest { points: points.to_vec() }
            }
            RequestRef::Stats => Request::Stats,
            RequestRef::Checkpoint => Request::Checkpoint,
            RequestRef::Rebalance { want_remap } => {
                Request::Rebalance { want_remap: *want_remap }
            }
            RequestRef::FetchState { have_generation } => {
                Request::FetchState { have_generation: *have_generation }
            }
            RequestRef::FetchChunk { generation, chunk } => {
                Request::FetchChunk {
                    generation: *generation,
                    chunk: *chunk,
                }
            }
            RequestRef::Demote { generation, leader } => Request::Demote {
                generation: *generation,
                leader: leader.clone(),
            },
            RequestRef::Metrics { max_events } => {
                Request::Metrics { max_events: *max_events }
            }
            RequestRef::Trace { max_traces } => {
                Request::Trace { max_traces: *max_traces }
            }
            RequestRef::Traced { hi, lo, parent, inner } => Request::Traced {
                hi: *hi,
                lo: *lo,
                parent: *parent,
                inner: Box::new(inner.to_owned()),
            },
        }
    }
}

impl Request {
    /// Encode this request as one frame payload (opcode + fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Append this request's frame payload to `out` — which is *not*
    /// cleared, so a caller can reuse one scratch buffer across frames
    /// (or build a frame in place behind a [`begin_frame`] prefix). The
    /// trace envelope encodes its inner request directly into `out`
    /// through a patched length field, so even enveloped encoding
    /// allocates nothing beyond `out` itself.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Request::Encode { points } => {
                out.push(OP_ENCODE);
                put_f32s(out, points);
            }
            Request::Nearest { points } => {
                out.push(OP_NEAREST);
                put_f32s(out, points);
            }
            Request::Distortion { points } => {
                out.push(OP_DISTORTION);
                put_f32s(out, points);
            }
            Request::Ingest { points } => {
                out.push(OP_INGEST);
                put_f32s(out, points);
            }
            Request::Stats => out.push(OP_STATS),
            Request::Checkpoint => out.push(OP_CHECKPOINT),
            Request::Rebalance { want_remap } => {
                out.push(OP_REBALANCE);
                out.push(*want_remap as u8);
            }
            Request::FetchState { have_generation } => {
                out.push(OP_FETCH_STATE);
                out.extend_from_slice(&have_generation.to_le_bytes());
            }
            Request::FetchChunk { generation, chunk } => {
                out.push(OP_FETCH_CHUNK);
                out.extend_from_slice(&generation.to_le_bytes());
                out.extend_from_slice(&chunk.to_le_bytes());
            }
            Request::Demote { generation, leader } => {
                out.push(OP_DEMOTE);
                out.extend_from_slice(&generation.to_le_bytes());
                put_str(out, leader);
            }
            Request::Metrics { max_events } => {
                out.push(OP_METRICS);
                out.extend_from_slice(&max_events.to_le_bytes());
            }
            Request::Trace { max_traces } => {
                out.push(OP_TRACE);
                out.extend_from_slice(&max_traces.to_le_bytes());
            }
            Request::Traced { hi, lo, parent, inner } => {
                debug_assert!(
                    !matches!(**inner, Request::Traced { .. }),
                    "trace envelopes do not nest"
                );
                out.push(OP_TRACED_REQ);
                out.extend_from_slice(&hi.to_le_bytes());
                out.extend_from_slice(&lo.to_le_bytes());
                out.extend_from_slice(&parent.to_le_bytes());
                let len_at = out.len();
                out.extend_from_slice(&[0u8; 4]);
                inner.encode_into(out);
                let inner_len = (out.len() - len_at - 4) as u32;
                out[len_at..len_at + 4]
                    .copy_from_slice(&inner_len.to_le_bytes());
            }
        }
    }

    /// Decode one request payload. Total: any byte string either decodes
    /// to exactly the request that produced it or errors.
    ///
    /// Point-carrying ops additionally reject non-finite coordinates at
    /// the wire boundary — see [`PointsRef`]. Delegates to
    /// [`RequestRef::decode`] (the borrowing decoder) and copies out.
    pub fn decode(payload: &[u8]) -> Result<Self> {
        Ok(RequestRef::decode(payload)?.to_owned())
    }
}

impl Response {
    /// Encode this response as one frame payload (opcode + fields).
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        self.encode_into(&mut out);
        out
    }

    /// Append this response's frame payload to `out` (not cleared) —
    /// see [`Request::encode_into`] for the reuse contract.
    pub fn encode_into(&self, out: &mut Vec<u8>) {
        match self {
            Response::Codes { version, codes } => {
                out.push(OP_CODES);
                out.extend_from_slice(&version.to_le_bytes());
                put_u32s(out, codes);
            }
            Response::Neighbors { version, indices, dists } => {
                out.push(OP_NEIGHBORS);
                out.extend_from_slice(&version.to_le_bytes());
                put_u32s(out, indices);
                put_f32s(out, dists);
            }
            Response::Distortion { version, value } => {
                out.push(OP_DISTORTION_R);
                out.extend_from_slice(&version.to_le_bytes());
                out.extend_from_slice(&value.to_le_bytes());
            }
            Response::IngestAck { accepted, shed } => {
                out.push(OP_INGEST_ACK);
                out.extend_from_slice(&accepted.to_le_bytes());
                out.extend_from_slice(&shed.to_le_bytes());
            }
            Response::Stats(s) => {
                out.push(OP_STATS_R);
                for field in [
                    s.version, s.kappa, s.dim, s.workers, s.shards, s.probe_n,
                    s.router_version, s.rebalances, s.merges, s.ingested,
                    s.ingest_shed, s.queries,
                ] {
                    out.extend_from_slice(&field.to_le_bytes());
                }
                put_u64s(out, &s.shard_versions);
                put_u64s(out, &s.shard_merges);
                put_u64s(out, &s.shard_ingest);
                put_u64s(out, &s.shard_shed);
                put_u64s(out, &s.last_checkpoint);
                put_str(out, &s.state_dir);
                put_str(out, &s.role);
                put_str(out, &s.leader_addr);
                out.extend_from_slice(&s.sync_lag_folds.to_le_bytes());
                out.extend_from_slice(&s.last_sync.to_le_bytes());
                for field in [
                    s.uptime_ms, s.op_encode, s.op_nearest, s.op_distortion,
                    s.op_ingest,
                ] {
                    out.extend_from_slice(&field.to_le_bytes());
                }
                put_str(out, &s.sync_source);
            }
            Response::CheckpointAck { versions } => {
                out.push(OP_CHECKPOINT_ACK);
                put_u64s(out, versions);
            }
            Response::RebalanceAck {
                router_version,
                moved_rows,
                shard_versions,
                remap,
            } => {
                out.push(OP_REBALANCE_ACK);
                out.extend_from_slice(&router_version.to_le_bytes());
                out.extend_from_slice(&moved_rows.to_le_bytes());
                put_u64s(out, shard_versions);
                put_u32s(out, remap);
            }
            Response::State(s) => {
                out.push(OP_STATE);
                out.extend_from_slice(&s.generation.to_le_bytes());
                out.extend_from_slice(&s.leader_version.to_le_bytes());
                out.extend_from_slice(&s.chunk.to_le_bytes());
                out.extend_from_slice(&s.chunks.to_le_bytes());
                out.push(s.delta as u8);
                out.extend_from_slice(&(s.files.len() as u32).to_le_bytes());
                for f in &s.files {
                    put_str(out, &f.name);
                    out.extend_from_slice(&f.offset.to_le_bytes());
                    out.extend_from_slice(&f.file_len.to_le_bytes());
                    put_bytes(out, &f.bytes);
                }
            }
            Response::DemoteAck => out.push(OP_DEMOTE_ACK),
            Response::Metrics(m) => {
                out.push(OP_METRICS_R);
                out.extend_from_slice(&m.uptime_ms.to_le_bytes());
                out.extend_from_slice(&(m.counters.len() as u32).to_le_bytes());
                for (name, v) in &m.counters {
                    put_str(out, name);
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.extend_from_slice(&(m.gauges.len() as u32).to_le_bytes());
                for (name, v) in &m.gauges {
                    put_str(out, name);
                    out.extend_from_slice(&v.to_le_bytes());
                }
                out.extend_from_slice(&(m.hists.len() as u32).to_le_bytes());
                for h in &m.hists {
                    put_str(out, &h.name);
                    out.extend_from_slice(&h.count.to_le_bytes());
                    for field in
                        [h.mean_us, h.p50_us, h.p95_us, h.p99_us, h.max_us]
                    {
                        out.extend_from_slice(&field.to_le_bytes());
                    }
                }
                out.extend_from_slice(&(m.events.len() as u32).to_le_bytes());
                for e in &m.events {
                    out.extend_from_slice(&e.seq.to_le_bytes());
                    out.extend_from_slice(&e.ts_ms.to_le_bytes());
                    out.push(e.level);
                    put_str(out, &e.kind);
                    put_str(out, &e.message);
                }
            }
            Response::Traces(traces) => {
                out.push(OP_TRACE_R);
                out.extend_from_slice(&(traces.len() as u32).to_le_bytes());
                for t in traces {
                    out.extend_from_slice(&t.hi.to_le_bytes());
                    out.extend_from_slice(&t.lo.to_le_bytes());
                    out.extend_from_slice(&t.ts_ms.to_le_bytes());
                    put_spans(out, &t.spans);
                }
            }
            Response::Traced { hi, lo, spans, inner } => {
                debug_assert!(
                    !matches!(**inner, Response::Traced { .. }),
                    "trace envelopes do not nest"
                );
                out.push(OP_TRACED_RESP);
                out.extend_from_slice(&hi.to_le_bytes());
                out.extend_from_slice(&lo.to_le_bytes());
                put_spans(out, spans);
                let len_at = out.len();
                out.extend_from_slice(&[0u8; 4]);
                inner.encode_into(out);
                let inner_len = (out.len() - len_at - 4) as u32;
                out[len_at..len_at + 4]
                    .copy_from_slice(&inner_len.to_le_bytes());
            }
            Response::Throttled { retry_after_ms, message } => {
                out.push(OP_THROTTLED);
                out.extend_from_slice(&retry_after_ms.to_le_bytes());
                put_str(out, message);
            }
            Response::NotLeader { leader } => {
                out.push(OP_NOT_LEADER);
                put_str(out, leader);
            }
            Response::Error { message } => {
                out.push(OP_ERROR);
                put_str(out, message);
            }
        }
    }

    /// Decode one response payload. Total, like [`Request::decode`].
    pub fn decode(payload: &[u8]) -> Result<Self> {
        let mut c = Cursor::new(payload);
        let resp = match c.u8()? {
            OP_CODES => Response::Codes { version: c.u64()?, codes: c.u32s()? },
            OP_NEIGHBORS => Response::Neighbors {
                version: c.u64()?,
                indices: c.u32s()?,
                dists: c.f32s()?,
            },
            OP_DISTORTION_R => {
                Response::Distortion { version: c.u64()?, value: c.f64()? }
            }
            OP_INGEST_ACK => {
                Response::IngestAck { accepted: c.u64()?, shed: c.u64()? }
            }
            OP_STATS_R => Response::Stats(StatsReply {
                version: c.u64()?,
                kappa: c.u64()?,
                dim: c.u64()?,
                workers: c.u64()?,
                shards: c.u64()?,
                probe_n: c.u64()?,
                router_version: c.u64()?,
                rebalances: c.u64()?,
                merges: c.u64()?,
                ingested: c.u64()?,
                ingest_shed: c.u64()?,
                queries: c.u64()?,
                shard_versions: c.u64s()?,
                shard_merges: c.u64s()?,
                shard_ingest: c.u64s()?,
                shard_shed: c.u64s()?,
                last_checkpoint: c.u64s()?,
                state_dir: c.str()?,
                role: c.str()?,
                leader_addr: c.str()?,
                sync_lag_folds: c.u64()?,
                last_sync: c.u64()?,
                uptime_ms: c.u64()?,
                op_encode: c.u64()?,
                op_nearest: c.u64()?,
                op_distortion: c.u64()?,
                op_ingest: c.u64()?,
                sync_source: c.str()?,
            }),
            OP_CHECKPOINT_ACK => {
                Response::CheckpointAck { versions: c.u64s()? }
            }
            OP_REBALANCE_ACK => Response::RebalanceAck {
                router_version: c.u64()?,
                moved_rows: c.u64()?,
                shard_versions: c.u64s()?,
                remap: c.u32s()?,
            },
            OP_STATE => {
                let generation = c.u64()?;
                let leader_version = c.u64()?;
                let chunk = c.u32()?;
                let chunks = c.u32()?;
                let delta = c.u8()? != 0;
                let n = c.u32()? as usize;
                // Bounded by the frame cap: each entry consumes at least
                // 24 bytes of payload, so a lying count fails in `bytes`
                // before any oversized allocation.
                let mut files = Vec::new();
                for _ in 0..n {
                    files.push(StateFile {
                        name: c.str()?,
                        offset: c.u64()?,
                        file_len: c.u64()?,
                        bytes: c.blob()?,
                    });
                }
                Response::State(StateShipment {
                    generation,
                    leader_version,
                    chunk,
                    chunks,
                    delta,
                    files,
                })
            }
            OP_DEMOTE_ACK => Response::DemoteAck,
            OP_METRICS_R => {
                let uptime_ms = c.u64()?;
                // Every count-prefixed loop below is bounded by the frame
                // cap: each entry consumes at least 8 bytes of payload, so
                // a lying count fails in `bytes` before any oversized
                // allocation.
                let n = c.u32()? as usize;
                let mut counters = Vec::new();
                for _ in 0..n {
                    counters.push((c.str()?, c.u64()?));
                }
                let n = c.u32()? as usize;
                let mut gauges = Vec::new();
                for _ in 0..n {
                    gauges.push((c.str()?, c.u64()?));
                }
                let n = c.u32()? as usize;
                let mut hists = Vec::new();
                for _ in 0..n {
                    hists.push(MetricHist {
                        name: c.str()?,
                        count: c.u64()?,
                        mean_us: c.f64()?,
                        p50_us: c.f64()?,
                        p95_us: c.f64()?,
                        p99_us: c.f64()?,
                        max_us: c.f64()?,
                    });
                }
                let n = c.u32()? as usize;
                let mut events = Vec::new();
                for _ in 0..n {
                    events.push(MetricEvent {
                        seq: c.u64()?,
                        ts_ms: c.u64()?,
                        level: c.u8()?,
                        kind: c.str()?,
                        message: c.str()?,
                    });
                }
                Response::Metrics(MetricsReply {
                    uptime_ms,
                    counters,
                    gauges,
                    hists,
                    events,
                })
            }
            OP_TRACE_R => {
                let n = c.u32()? as usize;
                // Each trace consumes at least 28 bytes, so a lying count
                // fails in `bytes` before any oversized allocation.
                let mut traces = Vec::new();
                for _ in 0..n {
                    traces.push(WireTrace {
                        hi: c.u64()?,
                        lo: c.u64()?,
                        ts_ms: c.u64()?,
                        spans: c.spans()?,
                    });
                }
                Response::Traces(traces)
            }
            OP_TRACED_RESP => {
                let hi = c.u64()?;
                let lo = c.u64()?;
                let spans = c.spans()?;
                let inner_bytes = c.blob()?;
                let inner = Response::decode(&inner_bytes)
                    .map_err(|e| anyhow!("inside a trace envelope: {e}"))?;
                if matches!(inner, Response::Traced { .. }) {
                    bail!("nested trace envelopes are not allowed");
                }
                Response::Traced { hi, lo, spans, inner: Box::new(inner) }
            }
            OP_THROTTLED => Response::Throttled {
                retry_after_ms: c.u64()?,
                message: c.str()?,
            },
            OP_NOT_LEADER => Response::NotLeader { leader: c.str()? },
            OP_ERROR => Response::Error { message: c.str()? },
            op => bail!("unknown response opcode 0x{op:02x}"),
        };
        c.finish()?;
        Ok(resp)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn round_trip_req(r: Request) {
        assert_eq!(Request::decode(&r.encode()).unwrap(), r);
    }

    fn round_trip_resp(r: Response) {
        assert_eq!(Response::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn requests_round_trip() {
        round_trip_req(Request::Encode { points: vec![1.0, -2.5, 3.25] });
        round_trip_req(Request::Nearest { points: vec![] });
        round_trip_req(Request::Distortion { points: vec![0.5; 7] });
        round_trip_req(Request::Ingest { points: vec![f32::MIN, f32::MAX] });
        round_trip_req(Request::Stats);
        round_trip_req(Request::Checkpoint);
        round_trip_req(Request::Rebalance { want_remap: false });
        round_trip_req(Request::Rebalance { want_remap: true });
        round_trip_req(Request::FetchState { have_generation: 0 });
        round_trip_req(Request::FetchState {
            have_generation: FETCH_ANY_GENERATION,
        });
        round_trip_req(Request::FetchChunk { generation: 0, chunk: 1 });
        round_trip_req(Request::FetchChunk {
            generation: u64::MAX,
            chunk: u32::MAX,
        });
        round_trip_req(Request::Demote {
            generation: 12,
            leader: "10.0.0.9:7171".into(),
        });
        round_trip_req(Request::Demote {
            generation: 0,
            leader: String::new(),
        });
        round_trip_req(Request::Metrics { max_events: 0 });
        round_trip_req(Request::Metrics { max_events: u32::MAX });
    }

    #[test]
    fn non_finite_points_are_rejected_at_decode() {
        // Every point-carrying op refuses NaN and ±Inf at the wire
        // boundary, naming the offending index; finite extremes pass.
        let makes: [fn(Vec<f32>) -> Request; 4] = [
            |p| Request::Encode { points: p },
            |p| Request::Nearest { points: p },
            |p| Request::Distortion { points: p },
            |p| Request::Ingest { points: p },
        ];
        for make in makes {
            for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
                let frame = make(vec![1.0, bad, 3.0]).encode();
                let err = Request::decode(&frame).unwrap_err().to_string();
                assert!(
                    err.contains("non-finite") && err.contains("index 1"),
                    "unexpected error: {err}"
                );
            }
            round_trip_req(make(vec![f32::MIN, 0.0, f32::MAX]));
        }
    }

    #[test]
    fn responses_round_trip() {
        round_trip_resp(Response::Codes { version: 9, codes: vec![0, 7, 3] });
        round_trip_resp(Response::Neighbors {
            version: 1,
            indices: vec![2, 2],
            dists: vec![0.25, 4.0],
        });
        round_trip_resp(Response::Distortion { version: 3, value: 1.5e-3 });
        round_trip_resp(Response::IngestAck { accepted: 64, shed: 2 });
        round_trip_resp(Response::Stats(StatsReply {
            version: 5,
            kappa: 16,
            dim: 4,
            workers: 8,
            shards: 4,
            probe_n: 2,
            router_version: 3,
            rebalances: 3,
            merges: 5,
            ingested: 1024,
            ingest_shed: 0,
            queries: 33,
            shard_versions: vec![1, 2, 1, 1],
            shard_merges: vec![2, 2, 1, 1],
            shard_ingest: vec![512, 256, 128, 128],
            shard_shed: vec![0, 0, 7, 0],
            last_checkpoint: vec![1, 2, 0, 1],
            state_dir: "/var/lib/dalvq/state".into(),
            role: "follower".into(),
            leader_addr: "10.0.0.7:7171".into(),
            sync_lag_folds: 12,
            last_sync: 480,
            uptime_ms: 61_000,
            op_encode: 10,
            op_nearest: 11,
            op_distortion: 12,
            op_ingest: 13,
            sync_source: "delta".into(),
        }));
        round_trip_resp(Response::Stats(StatsReply::default()));
        round_trip_resp(Response::CheckpointAck { versions: vec![9, 8, 7] });
        round_trip_resp(Response::CheckpointAck { versions: vec![] });
        round_trip_resp(Response::RebalanceAck {
            router_version: 2,
            moved_rows: 5,
            shard_versions: vec![7, 7, 7, 7],
            remap: vec![3, 2, 1, 0],
        });
        round_trip_resp(Response::RebalanceAck {
            router_version: 1,
            moved_rows: 0,
            shard_versions: vec![],
            remap: vec![],
        });
        round_trip_resp(Response::State(StateShipment {
            generation: 4,
            leader_version: 97,
            chunk: 1,
            chunks: 1,
            delta: false,
            files: vec![
                StateFile {
                    name: "manifest.json".into(),
                    offset: 0,
                    file_len: 2,
                    bytes: b"{}".to_vec(),
                },
                StateFile {
                    name: "router.bin".into(),
                    offset: 0,
                    file_len: 3,
                    bytes: vec![0, 1, 255],
                },
                StateFile {
                    name: "shard-0.state".into(),
                    offset: 0,
                    file_len: 0,
                    bytes: vec![],
                },
            ],
        }));
        round_trip_resp(Response::State(StateShipment {
            generation: 9,
            leader_version: 40,
            chunk: 2,
            chunks: 3,
            delta: true,
            files: vec![StateFile {
                name: "shard-1.state".into(),
                offset: 4096,
                file_len: 1 << 20,
                bytes: vec![7; 16],
            }],
        }));
        round_trip_resp(Response::State(StateShipment::default()));
        round_trip_resp(Response::DemoteAck);
        round_trip_resp(Response::Metrics(MetricsReply {
            uptime_ms: 12_345,
            counters: vec![
                ("op.encode.requests".into(), 42),
                ("slow_queries".into(), 1),
            ],
            gauges: vec![("shard.0.queue_depth".into(), 3)],
            hists: vec![MetricHist {
                name: "op.encode.total_us".into(),
                count: 42,
                mean_us: 85.5,
                p50_us: 80.0,
                p95_us: 120.0,
                p99_us: 130.0,
                max_us: 131.0,
            }],
            events: vec![MetricEvent {
                seq: 7,
                ts_ms: 1_700_000_000_123,
                level: 1,
                kind: "slow_query".into(),
                message: "nearest took 9ms".into(),
            }],
        }));
        round_trip_resp(Response::Metrics(MetricsReply::default()));
        round_trip_resp(Response::NotLeader {
            leader: "127.0.0.1:7171".into(),
        });
        round_trip_resp(Response::Error { message: "bad dim".into() });
    }

    #[test]
    fn trace_op_and_envelopes_round_trip() {
        round_trip_req(Request::Trace { max_traces: 0 });
        round_trip_req(Request::Trace { max_traces: u32::MAX });
        round_trip_req(Request::Traced {
            hi: 0xDEAD_BEEF,
            lo: 7,
            parent: 3,
            inner: Box::new(Request::Nearest { points: vec![1.0, -2.0] }),
        });
        round_trip_req(Request::Traced {
            hi: 0,
            lo: 0,
            parent: 0,
            inner: Box::new(Request::FetchState { have_generation: 9 }),
        });
        round_trip_resp(Response::Traces(vec![]));
        round_trip_resp(Response::Traces(vec![
            WireTrace {
                hi: 1,
                lo: 2,
                ts_ms: 1_700_000_000_000,
                spans: vec![
                    WireSpan {
                        id: 1,
                        parent: 0,
                        start_us: 0,
                        dur_us: 120,
                        name: "req.nearest".into(),
                    },
                    WireSpan {
                        id: 2,
                        parent: 1,
                        start_us: 10,
                        dur_us: 80,
                        name: "scan".into(),
                    },
                ],
            },
            WireTrace::default(),
        ]));
        round_trip_resp(Response::Traced {
            hi: 5,
            lo: 6,
            spans: vec![WireSpan {
                id: 1,
                parent: 0,
                start_us: 0,
                dur_us: 44,
                name: "req.fetch_state".into(),
            }],
            inner: Box::new(Response::State(StateShipment::default())),
        });
    }

    #[test]
    fn nested_trace_envelopes_are_rejected_at_decode() {
        // Hand-assemble a Traced wrapping a Traced (encode() would
        // debug_assert, so build the bytes directly).
        let inner = Request::Traced {
            hi: 1,
            lo: 2,
            parent: 0,
            inner: Box::new(Request::Stats),
        }
        .encode();
        let mut wire = vec![0x0Bu8];
        wire.extend_from_slice(&9u64.to_le_bytes());
        wire.extend_from_slice(&9u64.to_le_bytes());
        wire.extend_from_slice(&0u64.to_le_bytes());
        put_bytes(&mut wire, &inner);
        let err = Request::decode(&wire).unwrap_err().to_string();
        assert!(err.contains("nested"), "{err}");

        let inner = Response::Traced {
            hi: 1,
            lo: 2,
            spans: vec![],
            inner: Box::new(Response::Error { message: "x".into() }),
        }
        .encode();
        let mut wire = vec![0x8Bu8];
        wire.extend_from_slice(&9u64.to_le_bytes());
        wire.extend_from_slice(&9u64.to_le_bytes());
        wire.extend_from_slice(&0u32.to_le_bytes()); // no spans
        put_bytes(&mut wire, &inner);
        let err = Response::decode(&wire).unwrap_err().to_string();
        assert!(err.contains("nested"), "{err}");
    }

    #[test]
    fn traced_envelope_wraps_the_exact_bare_encoding() {
        // The envelope carries the *unchanged* inner frame: stripping the
        // context (hi, lo, parent, length prefix) yields bytes an old
        // server would decode identically — the compat story in one
        // assertion.
        let bare = Request::Nearest { points: vec![3.0, 4.0] };
        let enveloped = Request::Traced {
            hi: 11,
            lo: 22,
            parent: 1,
            inner: Box::new(bare.clone()),
        }
        .encode();
        // opcode(1) + hi(8) + lo(8) + parent(8) + len(4) = 29-byte prefix
        assert_eq!(&enveloped[29..], &bare.encode()[..]);
        // and the server-side assembly helper agrees with the enum encoder
        let reply = Response::Codes { version: 1, codes: vec![7] };
        let via_enum = Response::Traced {
            hi: 11,
            lo: 22,
            spans: vec![],
            inner: Box::new(reply.clone()),
        }
        .encode();
        let via_helper =
            encode_traced_response(11, 22, &[], &reply.encode());
        assert_eq!(via_enum, via_helper);
    }

    #[test]
    fn frames_round_trip_over_a_buffer() {
        let mut wire = Vec::new();
        let a = Request::Encode { points: vec![1.0, 2.0] }.encode();
        let b = Request::Stats.encode();
        write_frame(&mut wire, &a).unwrap();
        write_frame(&mut wire, &b).unwrap();
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), a);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b);
        assert_eq!(read_frame(&mut r).unwrap(), None); // clean EOF
    }

    #[test]
    fn truncated_and_trailing_frames_are_rejected() {
        let good = Request::Encode { points: vec![1.0] }.encode();
        assert!(Request::decode(&good[..good.len() - 1]).is_err());
        let mut trailing = good.clone();
        trailing.push(0);
        assert!(Request::decode(&trailing).is_err());
        assert!(Request::decode(&[0x7Fu8]).is_err()); // unknown opcode
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME + 1).to_le_bytes());
        let mut r = &wire[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn throttled_round_trips_and_truncates_like_any_variant() {
        round_trip_resp(Response::Throttled {
            retry_after_ms: 0,
            message: String::new(),
        });
        round_trip_resp(Response::Throttled {
            retry_after_ms: 1_500,
            message: "rate quota: 100 req/s".into(),
        });
        let wire = Response::Throttled {
            retry_after_ms: 250,
            message: "brownout".into(),
        }
        .encode();
        assert_eq!(wire[0], 0xFD);
        for cut in 0..wire.len() {
            assert!(Response::decode(&wire[..cut]).is_err(), "cut {cut}");
        }
        let mut trailing = wire.clone();
        trailing.push(0);
        assert!(Response::decode(&trailing).is_err());
        // and it rides a trace envelope like any other reply
        round_trip_resp(Response::Traced {
            hi: 1,
            lo: 2,
            spans: vec![],
            inner: Box::new(Response::Throttled {
                retry_after_ms: 9,
                message: "in-flight quota: 4".into(),
            }),
        });
    }

    #[test]
    fn encode_into_appends_without_clearing() {
        let mut out = vec![0xAAu8, 0xBB];
        Request::Stats.encode_into(&mut out);
        assert_eq!(out[..2], [0xAA, 0xBB]);
        assert_eq!(&out[2..], &Request::Stats.encode()[..]);
        // the enveloped encoders patch their length prefix in place and
        // still match the allocating encoder byte for byte
        let req = Request::Traced {
            hi: 7,
            lo: 8,
            parent: 9,
            inner: Box::new(Request::Encode { points: vec![1.0, 2.0] }),
        };
        let mut appended = vec![0x55u8];
        req.encode_into(&mut appended);
        assert_eq!(&appended[1..], &req.encode()[..]);
        let resp = Response::Traced {
            hi: 7,
            lo: 8,
            spans: vec![WireSpan {
                id: 1,
                parent: 0,
                start_us: 0,
                dur_us: 3,
                name: "req.encode".into(),
            }],
            inner: Box::new(Response::Codes { version: 1, codes: vec![4] }),
        };
        let mut appended = Vec::new();
        resp.encode_into(&mut appended);
        assert_eq!(appended, resp.encode());
    }

    #[test]
    fn traced_request_helper_matches_the_boxed_encoder() {
        // The client's clone-free envelope writer is byte-identical to
        // encoding a boxed Request::Traced.
        let inner = Request::Nearest { points: vec![0.25, -1.5, 3.0] };
        let boxed = Request::Traced {
            hi: 11,
            lo: 22,
            parent: 33,
            inner: Box::new(inner.clone()),
        };
        let mut streamed = vec![0xEEu8]; // append semantics too
        encode_traced_request_into(&mut streamed, 11, 22, 33, &inner);
        assert_eq!(streamed[0], 0xEE);
        assert_eq!(&streamed[1..], &boxed.encode()[..]);
    }

    #[test]
    fn request_ref_matches_the_owned_decoder() {
        // Same acceptance set, same values, same error text — on every
        // variant, the non-finite rejections, and the envelope errors.
        let reqs = [
            Request::Encode { points: vec![1.0, -2.5] },
            Request::Nearest { points: vec![] },
            Request::Distortion { points: vec![0.5; 5] },
            Request::Ingest { points: vec![f32::MIN, f32::MAX] },
            Request::Stats,
            Request::Checkpoint,
            Request::Rebalance { want_remap: true },
            Request::FetchState { have_generation: 3 },
            Request::FetchChunk { generation: 3, chunk: 2 },
            Request::Demote { generation: 5, leader: "h:1".into() },
            Request::Metrics { max_events: 7 },
            Request::Trace { max_traces: 2 },
            Request::Traced {
                hi: 1,
                lo: 2,
                parent: 3,
                inner: Box::new(Request::Nearest { points: vec![4.0] }),
            },
        ];
        for req in &reqs {
            let wire = req.encode();
            let by_ref = RequestRef::decode(&wire).unwrap();
            assert_eq!(by_ref.to_owned(), *req);
            for cut in 0..wire.len() {
                let a = RequestRef::decode(&wire[..cut])
                    .err()
                    .map(|e| e.to_string());
                let b = Request::decode(&wire[..cut])
                    .err()
                    .map(|e| e.to_string());
                assert_eq!(a, b, "{req:?} cut {cut}");
                assert!(a.is_some(), "{req:?} cut {cut} decoded");
            }
        }
        let mut bad = vec![0x01u8];
        bad.extend_from_slice(&1u32.to_le_bytes());
        bad.extend_from_slice(&f32::NAN.to_le_bytes());
        let a = RequestRef::decode(&bad).unwrap_err().to_string();
        let b = Request::decode(&bad).unwrap_err().to_string();
        assert_eq!(a, b);
        assert!(a.contains("non-finite") && a.contains("index 0"), "{a}");
    }

    #[test]
    fn points_ref_views_without_copying() {
        let wire = Request::Nearest { points: vec![1.5, -2.0, 0.25] }.encode();
        match RequestRef::decode(&wire).unwrap() {
            RequestRef::Nearest { points } => {
                assert_eq!(points.len(), 3);
                assert!(!points.is_empty());
                assert_eq!(points.to_vec(), vec![1.5, -2.0, 0.25]);
                let mut scratch = vec![9.0f32; 17];
                points.copy_into(&mut scratch);
                assert_eq!(scratch, vec![1.5, -2.0, 0.25]);
                assert_eq!(points.iter().count(), 3);
            }
            other => panic!("expected Nearest, got {other:?}"),
        }
    }

    #[test]
    fn decoder_yields_whole_frames_from_any_chunking() {
        let frames: Vec<Vec<u8>> = vec![
            Request::Stats.encode(),
            Request::Encode { points: vec![1.0, 2.0, 3.0] }.encode(),
            Request::Ingest { points: vec![-4.5] }.encode(),
        ];
        let mut wire = Vec::new();
        for f in &frames {
            write_frame(&mut wire, f).unwrap();
        }
        // feed the byte stream in chunks of every size from 1 up
        for chunk in 1..=wire.len() {
            let mut dec = Decoder::with_capacity(8);
            let mut got = Vec::new();
            for piece in wire.chunks(chunk) {
                let spare = dec.spare(piece.len());
                spare[..piece.len()].copy_from_slice(piece);
                dec.advance(piece.len());
                while let Some(frame) = dec.next_frame().unwrap() {
                    got.push(frame.to_vec());
                }
            }
            assert_eq!(got, frames, "chunk size {chunk}");
            assert_eq!(dec.pending(), 0);
        }
    }

    #[test]
    fn decoder_rejects_oversized_prefixes_like_read_frame() {
        let mut dec = Decoder::new();
        let bad = (MAX_FRAME + 1).to_le_bytes();
        dec.spare(4)[..4].copy_from_slice(&bad);
        dec.advance(4);
        assert!(dec.has_frame(), "an oversized prefix is reportable progress");
        let err = dec.next_frame().unwrap_err().to_string();
        assert!(err.contains("exceeds cap"), "{err}");
    }

    #[test]
    fn decoder_has_frame_tracks_complete_frames_without_consuming() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Request::Stats.encode()).unwrap();
        let cut = wire.len(); // first frame ends here
        write_frame(&mut wire, &Request::Encode { points: vec![1.0] }.encode())
            .unwrap();

        let mut dec = Decoder::new();
        assert!(!dec.has_frame(), "empty buffer");
        // Everything up to one byte short of the first frame: no frame yet.
        dec.spare(cut - 1)[..cut - 1].copy_from_slice(&wire[..cut - 1]);
        dec.advance(cut - 1);
        assert!(!dec.has_frame(), "mid-frame bytes are not a frame");
        // The rest of the stream: both frames whole, peeking consumes nothing.
        let rest = wire.len() - (cut - 1);
        dec.spare(rest)[..rest].copy_from_slice(&wire[cut - 1..]);
        dec.advance(rest);
        assert!(dec.has_frame());
        assert!(dec.has_frame(), "peeking is idempotent");
        assert!(dec.next_frame().unwrap().is_some());
        assert!(dec.has_frame(), "second frame still whole after the first pops");
        assert!(dec.next_frame().unwrap().is_some());
        assert!(!dec.has_frame(), "drained");
    }

    #[test]
    fn frame_builders_match_write_frame() {
        let payload = Request::Encode { points: vec![7.0] }.encode();
        let mut via_write = Vec::new();
        write_frame(&mut via_write, &payload).unwrap();
        let mut via_builder = Vec::new();
        let at = begin_frame(&mut via_builder);
        via_builder.extend_from_slice(&payload);
        end_frame(&mut via_builder, at).unwrap();
        assert_eq!(via_builder, via_write);
        // an over-cap frame rolls back to the begin mark
        let mut out = vec![1u8, 2, 3];
        let at = begin_frame(&mut out);
        out.resize(at + 4 + MAX_FRAME as usize + 1, 0);
        assert!(end_frame(&mut out, at).is_err());
        assert_eq!(out, vec![1, 2, 3]);
    }

    #[test]
    fn ingest_frames_are_classified_without_decoding() {
        let ingest = Request::Ingest { points: vec![1.0] }.encode();
        assert!(is_ingest_frame(&ingest));
        let traced_ingest = Request::Traced {
            hi: 1,
            lo: 2,
            parent: 3,
            inner: Box::new(Request::Ingest { points: vec![1.0] }),
        }
        .encode();
        assert!(is_ingest_frame(&traced_ingest));
        assert!(!is_ingest_frame(&Request::Stats.encode()));
        assert!(!is_ingest_frame(
            &Request::Nearest { points: vec![1.0] }.encode()
        ));
        let traced_read = Request::Traced {
            hi: 1,
            lo: 2,
            parent: 3,
            inner: Box::new(Request::Nearest { points: vec![1.0] }),
        }
        .encode();
        assert!(!is_ingest_frame(&traced_read));
        assert!(!is_ingest_frame(&[]));
    }
}
