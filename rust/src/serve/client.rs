//! The in-crate client: a blocking, connection-per-`Client` counterpart of
//! the server, used by the CLI, the load generator and the e2e tests.

use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{read_frame, write_frame, Request, Response, StatsReply};

/// One connection to a `dalvq serve` instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Client> {
        let stream = TcpStream::connect(&addr)
            .with_context(|| format!("connecting to dalvq serve at {addr:?}"))?;
        stream.set_nodelay(true).ok();
        Ok(Client {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
        })
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.writer, &req.encode())?;
        let payload = read_frame(&mut self.reader)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        let resp = Response::decode(&payload)?;
        if let Response::Error { message } = &resp {
            bail!("server error: {message}");
        }
        Ok(resp)
    }

    /// Quantize a batch: nearest-prototype code per point, plus the
    /// snapshot version that answered.
    pub fn encode(&mut self, points: &[f32]) -> Result<(Vec<u32>, u64)> {
        match self.call(&Request::Encode { points: points.to_vec() })? {
            Response::Codes { version, codes } => Ok((codes, version)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Nearest centroid per point: `(indices, squared distances, version)`.
    pub fn nearest(&mut self, points: &[f32]) -> Result<(Vec<u32>, Vec<f32>, u64)> {
        match self.call(&Request::Nearest { points: points.to_vec() })? {
            Response::Neighbors { version, indices, dists } => {
                Ok((indices, dists, version))
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Normalized distortion of a batch under the served codebook.
    pub fn distortion(&mut self, points: &[f32]) -> Result<(f64, u64)> {
        match self.call(&Request::Distortion { points: points.to_vec() })? {
            Response::Distortion { version, value } => Ok((value, version)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Stream points into the training fleet; `(accepted, shed)` counts.
    pub fn ingest(&mut self, points: &[f32]) -> Result<(u64, u64)> {
        match self.call(&Request::Ingest { points: points.to_vec() })? {
            Response::IngestAck { accepted, shed } => Ok((accepted, shed)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Service shape + counters.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
