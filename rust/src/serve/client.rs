//! The in-crate client: a blocking, connection-per-`Client` counterpart of
//! the server, used by the CLI, the load generator and the e2e tests.

use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::protocol::{
    read_frame, write_frame, MetricsReply, Request, Response, StateShipment,
    StatsReply,
};

/// Default per-attempt connect timeout.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Default extra attempts after the first (3 attempts total).
const CONNECT_RETRIES: usize = 2;

/// One connection to a `dalvq serve` instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
}

impl Client {
    /// Connect with the default timeout and retry budget: each attempt is
    /// bounded (a black-holed address cannot hang the caller the way a
    /// plain `TcpStream::connect` can), and a server that is briefly not
    /// up yet gets two more chances before the caller sees a clear
    /// error. `dalvq loadtest --addr` fails fast through
    /// this instead of stalling its whole connection fan-out.
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Client> {
        Self::connect_with(addr, CONNECT_TIMEOUT, CONNECT_RETRIES)
    }

    /// Connect with an explicit per-attempt `timeout` and `retries`
    /// additional attempts (0 = exactly one try). Each attempt tries
    /// every resolved address once under its own `timeout`; retries back
    /// off linearly (100 ms, 200 ms, …), so the total budget is bounded
    /// by `(retries + 1) * addrs * timeout` plus the backoffs — a few
    /// seconds, never the minutes an OS-default connect can hang.
    pub fn connect_with(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        timeout: Duration,
        retries: usize,
    ) -> Result<Client> {
        let addrs: Vec<std::net::SocketAddr> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving dalvq serve address {addr:?}"))?
            .collect();
        if addrs.is_empty() {
            bail!("dalvq serve address {addr:?} resolved to nothing");
        }
        let mut last_err = None;
        for attempt in 0..=retries {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(100 * attempt as u64));
            }
            for sa in &addrs {
                match TcpStream::connect_timeout(sa, timeout) {
                    Ok(stream) => {
                        stream.set_nodelay(true).ok();
                        return Ok(Client {
                            reader: BufReader::new(stream.try_clone()?),
                            writer: BufWriter::new(stream),
                        });
                    }
                    Err(e) => last_err = Some(e),
                }
            }
        }
        Err(anyhow!(last_err.unwrap())).with_context(|| {
            format!(
                "connecting to dalvq serve at {addr:?} failed after {} \
                 attempt(s) of {timeout:?} each — is the server up?",
                retries + 1
            )
        })
    }

    fn call(&mut self, req: &Request) -> Result<Response> {
        write_frame(&mut self.writer, &req.encode())?;
        let payload = read_frame(&mut self.reader)?
            .ok_or_else(|| anyhow!("server closed the connection"))?;
        let resp = Response::decode(&payload)?;
        if let Response::Error { message } = &resp {
            bail!("server error: {message}");
        }
        if let Response::NotLeader { leader } = &resp {
            bail!(
                "server is a read-only follower; send writes (and state \
                 fetches) to its leader at {leader}"
            );
        }
        Ok(resp)
    }

    /// Quantize a batch: nearest-prototype code per point, plus the
    /// snapshot version that answered.
    pub fn encode(&mut self, points: &[f32]) -> Result<(Vec<u32>, u64)> {
        match self.call(&Request::Encode { points: points.to_vec() })? {
            Response::Codes { version, codes } => Ok((codes, version)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Nearest centroid per point: `(indices, squared distances, version)`.
    pub fn nearest(&mut self, points: &[f32]) -> Result<(Vec<u32>, Vec<f32>, u64)> {
        match self.call(&Request::Nearest { points: points.to_vec() })? {
            Response::Neighbors { version, indices, dists } => {
                Ok((indices, dists, version))
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Normalized distortion of a batch under the served codebook.
    pub fn distortion(&mut self, points: &[f32]) -> Result<(f64, u64)> {
        match self.call(&Request::Distortion { points: points.to_vec() })? {
            Response::Distortion { version, value } => Ok((value, version)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Stream points into the training fleet; `(accepted, shed)` counts.
    pub fn ingest(&mut self, points: &[f32]) -> Result<(u64, u64)> {
        match self.call(&Request::Ingest { points: points.to_vec() })? {
            Response::IngestAck { accepted, shed } => Ok((accepted, shed)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Service shape + counters.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// The server's telemetry digest: counters, gauges, latency
    /// histograms, and the newest `max_events` journal entries (oldest
    /// first). Works on leaders and followers alike — a follower reports
    /// its own plane, not the leader's.
    pub fn metrics(&mut self, max_events: u32) -> Result<MetricsReply> {
        match self.call(&Request::Metrics { max_events })? {
            Response::Metrics(m) => Ok(m),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Force a durable checkpoint; returns the per-shard checkpointed
    /// versions. Errors when the service has no `--state-dir`.
    pub fn checkpoint(&mut self) -> Result<Vec<u64>> {
        match self.call(&Request::Checkpoint)? {
            Response::CheckpointAck { versions } => Ok(versions),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Trigger an online rebalance (router retrain + shard migration at a
    /// bumped partition version); returns `(router_version, moved_rows,
    /// per-shard resume versions)`. Blocks until the new epoch serves —
    /// reads issued on other connections keep answering throughout.
    /// Errors when the service has no `--state-dir`.
    pub fn rebalance(&mut self) -> Result<(u64, u64, Vec<u64>)> {
        let (router_version, moved_rows, shard_versions, _remap) =
            self.rebalance_full(false)?;
        Ok((router_version, moved_rows, shard_versions))
    }

    /// [`Client::rebalance`] with control over the remap: when
    /// `want_remap` is set, the fourth element is the old→new
    /// global-code table (`remap[old] = new`) — a client holding cached
    /// codes from the previous epoch translates them through it instead
    /// of re-encoding. Empty when `want_remap` is false.
    pub fn rebalance_full(
        &mut self,
        want_remap: bool,
    ) -> Result<(u64, u64, Vec<u64>, Vec<u32>)> {
        match self.call(&Request::Rebalance { want_remap })? {
            Response::RebalanceAck {
                router_version,
                moved_rows,
                shard_versions,
                remap,
            } => Ok((router_version, moved_rows, shard_versions, remap)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the server's durable state as one consistent checkpoint
    /// bundle (replication's sync primitive). Pass the generation
    /// already held — an unchanged leader answers with an empty file
    /// list — or [`super::protocol::FETCH_ANY_GENERATION`] to force the
    /// full bundle. Errors on a follower (`NotLeader`) and on a leader
    /// without `--state-dir`.
    pub fn fetch_state(
        &mut self,
        have_generation: u64,
    ) -> Result<StateShipment> {
        match self.call(&Request::FetchState { have_generation })? {
            Response::State(shipment) => Ok(shipment),
            other => bail!("unexpected response {other:?}"),
        }
    }
}
