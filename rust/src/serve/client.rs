//! The in-crate client: a blocking, connection-per-`Client` counterpart of
//! the server, used by the CLI, the load generator and the e2e tests.

use std::io::{BufReader, BufWriter, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::faults;
use super::protocol::{
    begin_frame, encode_traced_request_into, end_frame, read_frame_into,
    MetricsReply, Request, Response, StateFile, StateShipment, StatsReply,
    WireSpan, WireTrace,
};
use crate::persist;

/// Default per-attempt connect timeout.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(2);
/// Default extra attempts after the first (3 attempts total).
const CONNECT_RETRIES: usize = 2;
/// Maximum `NotLeader` redirects one call follows before giving up.
/// Bounds the pathological case of two nodes each claiming the other
/// leads (a failover in flight): the client backs off between hops and
/// errors out after this many instead of ping-ponging forever.
const MAX_REDIRECTS: usize = 4;

/// One connection to a `dalvq serve` instance.
pub struct Client {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    /// Armed by [`Client::trace_next`]: `(trace_id_hi, trace_id_lo,
    /// parent_span_id)` to stamp on the next request as a wire trace
    /// context. One-shot — consumed by the next `call`.
    trace_next: Option<(u64, u64, u64)>,
    /// Server-side spans returned by the last traced call, kept until
    /// [`Client::take_server_spans`] collects them.
    server_spans: Vec<WireSpan>,
    /// Request-encode scratch: each [`Client::send`] builds its wire
    /// frame (length prefix + payload) here, so only a request that
    /// outgrows every earlier one allocates.
    enc_buf: Vec<u8>,
    /// Reply-payload scratch for [`super::protocol::read_frame_into`] —
    /// the read-side counterpart of `enc_buf`.
    frame_buf: Vec<u8>,
    /// Where the last `NotLeader` redirect landed this connection (the
    /// address now on the other end), if any call ever redirected. Sync
    /// code reads it through [`Client::redirected_to`] to re-point its
    /// poll target after a failover.
    redirected: Option<String>,
}

impl Client {
    /// Connect with the default timeout and retry budget: each attempt is
    /// bounded (a black-holed address cannot hang the caller the way a
    /// plain `TcpStream::connect` can), and a server that is briefly not
    /// up yet gets two more chances before the caller sees a clear
    /// error. `dalvq loadtest --addr` fails fast through
    /// this instead of stalling its whole connection fan-out.
    pub fn connect(addr: impl ToSocketAddrs + std::fmt::Debug) -> Result<Client> {
        Self::connect_with(addr, CONNECT_TIMEOUT, CONNECT_RETRIES)
    }

    /// Connect with an explicit per-attempt `timeout` and `retries`
    /// additional attempts (0 = exactly one try). Each attempt tries
    /// every resolved address once under its own `timeout`; retries back
    /// off linearly (100 ms, 200 ms, …), so the total budget is bounded
    /// by `(retries + 1) * addrs * timeout` plus the backoffs — a few
    /// seconds, never the minutes an OS-default connect can hang.
    pub fn connect_with(
        addr: impl ToSocketAddrs + std::fmt::Debug,
        timeout: Duration,
        retries: usize,
    ) -> Result<Client> {
        let addrs: Vec<std::net::SocketAddr> = addr
            .to_socket_addrs()
            .with_context(|| format!("resolving dalvq serve address {addr:?}"))?
            .collect();
        if addrs.is_empty() {
            bail!("dalvq serve address {addr:?} resolved to nothing");
        }
        let mut last_err = None;
        for attempt in 0..=retries {
            if attempt > 0 {
                std::thread::sleep(Duration::from_millis(100 * attempt as u64));
            }
            for sa in &addrs {
                match TcpStream::connect_timeout(sa, timeout) {
                    Ok(stream) => {
                        stream.set_nodelay(true).ok();
                        return Ok(Client {
                            reader: BufReader::new(stream.try_clone()?),
                            writer: BufWriter::new(stream),
                            trace_next: None,
                            server_spans: Vec::new(),
                            enc_buf: Vec::new(),
                            frame_buf: Vec::new(),
                            redirected: None,
                        });
                    }
                    Err(e) => last_err = Some(e),
                }
            }
        }
        Err(anyhow!(last_err.unwrap())).with_context(|| {
            format!(
                "connecting to dalvq serve at {addr:?} failed after {} \
                 attempt(s) of {timeout:?} each — is the server up?",
                retries + 1
            )
        })
    }

    /// Stamp the next request with a wire trace context: the server
    /// joins trace `(hi, lo)`, parents its handler span under
    /// `parent_span`, and returns its span tree alongside the reply
    /// (collect it with [`Client::take_server_spans`]). One-shot; the
    /// call after the next one goes out bare again. A pre-tracing server
    /// that answers the envelope with `Error` fails that call cleanly.
    pub fn trace_next(&mut self, hi: u64, lo: u64, parent_span: u64) {
        self.trace_next = Some((hi, lo, parent_span));
    }

    /// The server-side spans of the last traced call (empty when the
    /// last call was untraced). Draining — a second take returns empty.
    pub fn take_server_spans(&mut self) -> Vec<WireSpan> {
        std::mem::take(&mut self.server_spans)
    }

    /// Encode `req` — wrapped in a trace envelope when
    /// [`Client::trace_next`] armed one — and queue it on the
    /// connection's buffered writer *without* reading the reply: the
    /// send half of a pipelined exchange. Pair with [`Client::flush`]
    /// and [`Client::recv`]; every queued request is answered in order.
    /// The frame is built in the connection's reused scratch buffer, so
    /// a steady request stream allocates nothing per frame.
    pub fn send(&mut self, req: &Request) -> Result<()> {
        self.enc_buf.clear();
        let at = begin_frame(&mut self.enc_buf);
        match self.trace_next.take() {
            Some((hi, lo, parent)) => {
                self.server_spans.clear();
                encode_traced_request_into(
                    &mut self.enc_buf,
                    hi,
                    lo,
                    parent,
                    req,
                );
            }
            None => req.encode_into(&mut self.enc_buf),
        }
        end_frame(&mut self.enc_buf, at)?;
        self.writer.write_all(&self.enc_buf)?;
        Ok(())
    }

    /// Push every queued [`Client::send`] frame onto the wire.
    pub fn flush(&mut self) -> Result<()> {
        self.writer.flush()?;
        Ok(())
    }

    /// Read the next reply frame (the receive half of a pipelined
    /// exchange). A trace envelope is unwrapped — its spans land in
    /// [`Client::take_server_spans`] — but protocol-level refusals
    /// (`Throttled`, `NotLeader`, `Error`) are returned as values, not
    /// errors, so a pipelined caller can count or redirect them without
    /// losing its place in the reply stream.
    pub fn recv(&mut self) -> Result<Response> {
        if !read_frame_into(&mut self.reader, &mut self.frame_buf)? {
            bail!("server closed the connection");
        }
        let mut resp = Response::decode(&self.frame_buf)?;
        if let Response::Traced { spans, inner, .. } = resp {
            self.server_spans = spans;
            resp = *inner;
        }
        Ok(resp)
    }

    /// The address the connection last redirected to via `NotLeader`
    /// (and is now speaking to), or `None` when no call ever
    /// redirected. A follower's sync loop reads this after a fetch to
    /// re-point its poll target at whoever actually leads.
    pub fn redirected_to(&self) -> Option<String> {
        self.redirected.clone()
    }

    /// Send `req` and read its reply, following `NotLeader` redirects:
    /// the client reconnects to the advertised leader (with a short
    /// growing backoff) and resends, up to [`MAX_REDIRECTS`] hops — a
    /// failover in flight can leave two nodes briefly pointing at each
    /// other, and the bound turns that ping-pong into a clean error
    /// instead of an infinite loop. `Error` and `Throttled` refusals
    /// surface as errors.
    fn call(&mut self, req: &Request) -> Result<Response> {
        let trace = self.trace_next.take();
        for hop in 0..=MAX_REDIRECTS {
            self.trace_next = trace;
            self.send(req)?;
            self.flush()?;
            let resp = self.recv()?;
            match resp {
                Response::Error { message } => {
                    bail!("server error: {message}")
                }
                Response::NotLeader { leader } => {
                    if leader.is_empty() {
                        bail!(
                            "server is a read-only follower that has not \
                             named a leader yet; retry shortly"
                        );
                    }
                    if hop == MAX_REDIRECTS {
                        bail!(
                            "gave up after {MAX_REDIRECTS} NotLeader \
                             redirects (last one pointed at {leader}) — \
                             the replica set may be mid-failover, retry \
                             shortly"
                        );
                    }
                    std::thread::sleep(Duration::from_millis(
                        50 * (hop as u64 + 1),
                    ));
                    let next = Client::connect_with(
                        leader.as_str(),
                        CONNECT_TIMEOUT,
                        CONNECT_RETRIES,
                    )
                    .with_context(|| {
                        format!(
                            "following a NotLeader redirect to {leader}"
                        )
                    })?;
                    self.reader = next.reader;
                    self.writer = next.writer;
                    self.redirected = Some(leader);
                }
                Response::Throttled { retry_after_ms, message } => {
                    bail!(
                        "server throttled the request: {message} (retry \
                         in {retry_after_ms} ms)"
                    );
                }
                other => return Ok(other),
            }
        }
        unreachable!("redirect loop exits via return or bail");
    }

    /// Quantize a batch: nearest-prototype code per point, plus the
    /// snapshot version that answered.
    pub fn encode(&mut self, points: &[f32]) -> Result<(Vec<u32>, u64)> {
        match self.call(&Request::Encode { points: points.to_vec() })? {
            Response::Codes { version, codes } => Ok((codes, version)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Nearest centroid per point: `(indices, squared distances, version)`.
    pub fn nearest(&mut self, points: &[f32]) -> Result<(Vec<u32>, Vec<f32>, u64)> {
        match self.call(&Request::Nearest { points: points.to_vec() })? {
            Response::Neighbors { version, indices, dists } => {
                Ok((indices, dists, version))
            }
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Normalized distortion of a batch under the served codebook.
    pub fn distortion(&mut self, points: &[f32]) -> Result<(f64, u64)> {
        match self.call(&Request::Distortion { points: points.to_vec() })? {
            Response::Distortion { version, value } => Ok((value, version)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Stream points into the training fleet; `(accepted, shed)` counts.
    pub fn ingest(&mut self, points: &[f32]) -> Result<(u64, u64)> {
        match self.call(&Request::Ingest { points: points.to_vec() })? {
            Response::IngestAck { accepted, shed } => Ok((accepted, shed)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Service shape + counters.
    pub fn stats(&mut self) -> Result<StatsReply> {
        match self.call(&Request::Stats)? {
            Response::Stats(s) => Ok(s),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// The server's telemetry digest: counters, gauges, latency
    /// histograms, and the newest `max_events` journal entries (oldest
    /// first). Works on leaders and followers alike — a follower reports
    /// its own plane, not the leader's.
    pub fn metrics(&mut self, max_events: u32) -> Result<MetricsReply> {
        match self.call(&Request::Metrics { max_events })? {
            Response::Metrics(m) => Ok(m),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Force a durable checkpoint; returns the per-shard checkpointed
    /// versions. Errors when the service has no `--state-dir`.
    pub fn checkpoint(&mut self) -> Result<Vec<u64>> {
        match self.call(&Request::Checkpoint)? {
            Response::CheckpointAck { versions } => Ok(versions),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Trigger an online rebalance (router retrain + shard migration at a
    /// bumped partition version); returns `(router_version, moved_rows,
    /// per-shard resume versions)`. Blocks until the new epoch serves —
    /// reads issued on other connections keep answering throughout.
    /// Errors when the service has no `--state-dir`.
    pub fn rebalance(&mut self) -> Result<(u64, u64, Vec<u64>)> {
        let (router_version, moved_rows, shard_versions, _remap) =
            self.rebalance_full(false)?;
        Ok((router_version, moved_rows, shard_versions))
    }

    /// [`Client::rebalance`] with control over the remap: when
    /// `want_remap` is set, the fourth element is the old→new
    /// global-code table (`remap[old] = new`) — a client holding cached
    /// codes from the previous epoch translates them through it instead
    /// of re-encoding. Empty when `want_remap` is false.
    pub fn rebalance_full(
        &mut self,
        want_remap: bool,
    ) -> Result<(u64, u64, Vec<u64>, Vec<u32>)> {
        match self.call(&Request::Rebalance { want_remap })? {
            Response::RebalanceAck {
                router_version,
                moved_rows,
                shard_versions,
                remap,
            } => Ok((router_version, moved_rows, shard_versions, remap)),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Fetch the server's durable state as one consistent checkpoint
    /// shipment (replication's sync primitive). Pass the generation
    /// already held — a shipper that indexed it answers with a *delta*
    /// (`delta: true`, only the advanced files), an unchanged one with
    /// an empty file list — or
    /// [`super::protocol::FETCH_ANY_GENERATION`] to force the full
    /// bundle. A cut too big for one frame arrives chunked; this method
    /// collects every chunk and returns the reassembled whole-file
    /// shipment (`chunks == 1`, every file at offset 0), so callers
    /// never see a partial file. Errors on a mirror-less follower
    /// (`NotLeader`, auto-redirected) and on a leader without
    /// `--state-dir`.
    pub fn fetch_state(
        &mut self,
        have_generation: u64,
    ) -> Result<StateShipment> {
        let first = match self.call(&Request::FetchState { have_generation })?
        {
            Response::State(shipment) => shipment,
            other => bail!("unexpected response {other:?}"),
        };
        if first.chunks <= 1 {
            return Ok(first);
        }
        let (generation, leader_version, chunks, delta) =
            (first.generation, first.leader_version, first.chunks, first.delta);
        let mut parts = file_parts(first.files);
        for chunk in 2..=chunks {
            faults::hit("sync.chunk")?;
            let piece = self.fetch_chunk(generation, chunk)?;
            if piece.generation != generation || piece.chunks != chunks {
                bail!(
                    "chunked fetch raced a new checkpoint: started on \
                     generation {generation} ({chunks} chunks), chunk \
                     {chunk} answered from generation {} ({} chunks); \
                     restart the fetch",
                    piece.generation,
                    piece.chunks
                );
            }
            parts.extend(file_parts(piece.files));
        }
        let files = persist::reassemble_chunks(parts).with_context(|| {
            format!(
                "reassembling {chunks} shipped chunks of generation \
                 {generation}"
            )
        })?;
        Ok(StateShipment {
            generation,
            leader_version,
            chunk: 1,
            chunks: 1,
            delta,
            files: files
                .into_iter()
                .map(|(name, bytes)| StateFile {
                    name,
                    offset: 0,
                    file_len: bytes.len() as u64,
                    bytes,
                })
                .collect(),
        })
    }

    /// Fetch one chunk of a multi-chunk cut by `(generation, chunk)`
    /// (1-based; the chunk count came back on the first
    /// [`Client::fetch_state`] frame). Chunking is deterministic per
    /// generation, so chunks can be collected in any order — but the
    /// shipper errors if its state dir has moved past `generation`.
    pub fn fetch_chunk(
        &mut self,
        generation: u64,
        chunk: u32,
    ) -> Result<StateShipment> {
        match self.call(&Request::FetchChunk { generation, chunk })? {
            Response::State(shipment) => Ok(shipment),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// Tell a stale leader (or a rival promotee) that `leader` now
    /// serves `generation`, which must be strictly above the
    /// receiver's own: the receiver steps down and redirects its
    /// writers there. Sent by a promoted follower's demote patrol when
    /// the old leader comes back; never redirected by the receiver.
    pub fn demote(&mut self, generation: u64, leader: &str) -> Result<()> {
        match self.call(&Request::Demote {
            generation,
            leader: leader.to_string(),
        })? {
            Response::DemoteAck => Ok(()),
            other => bail!("unexpected response {other:?}"),
        }
    }

    /// The newest completed traces from the server's sampled-trace ring
    /// (newest first), each a span tree with microsecond offsets.
    /// Answered by leaders and followers alike; empty when tracing was
    /// never armed (`--trace-sample 0` and no slow-query keeps).
    pub fn trace(&mut self, max_traces: u32) -> Result<Vec<WireTrace>> {
        match self.call(&Request::Trace { max_traces })? {
            Response::Traces(traces) => Ok(traces),
            other => bail!("unexpected response {other:?}"),
        }
    }
}

/// Wire [`StateFile`] pieces → [`persist::FilePart`]s for
/// [`persist::reassemble_chunks`].
fn file_parts(files: Vec<StateFile>) -> Vec<persist::FilePart> {
    files
        .into_iter()
        .map(|f| persist::FilePart {
            name: f.name,
            offset: f.offset,
            file_len: f.file_len,
            bytes: f.bytes,
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    /// A fake server that answers every request on every connection
    /// with `NotLeader { leader }` — half of a redirect ping-pong.
    fn not_leader_server(listener: TcpListener, leader: String) {
        std::thread::spawn(move || {
            for stream in listener.incoming() {
                let Ok(stream) = stream else { break };
                let leader = leader.clone();
                std::thread::spawn(move || {
                    let mut reader = BufReader::new(match stream.try_clone()
                    {
                        Ok(s) => s,
                        Err(_) => return,
                    });
                    let mut writer = BufWriter::new(stream);
                    let mut payload = Vec::new();
                    while let Ok(true) =
                        read_frame_into(&mut reader, &mut payload)
                    {
                        let mut out = Vec::new();
                        let at = begin_frame(&mut out);
                        Response::NotLeader { leader: leader.clone() }
                            .encode_into(&mut out);
                        end_frame(&mut out, at).unwrap();
                        if writer.write_all(&out).is_err()
                            || writer.flush().is_err()
                        {
                            return;
                        }
                    }
                });
            }
        });
    }

    #[test]
    fn redirect_ping_pong_is_bounded() {
        // Two nodes each claim the other leads — the degenerate
        // mid-failover topology. The client must follow a few hops,
        // then give up with an error naming the bound, not spin.
        let la = TcpListener::bind("127.0.0.1:0").unwrap();
        let lb = TcpListener::bind("127.0.0.1:0").unwrap();
        let aa = la.local_addr().unwrap().to_string();
        let ab = lb.local_addr().unwrap().to_string();
        not_leader_server(la, ab.clone());
        not_leader_server(lb, aa.clone());

        let mut client = Client::connect(aa.as_str()).unwrap();
        let err = client.stats().expect_err("ping-pong must not succeed");
        let msg = format!("{err:#}");
        assert!(
            msg.contains(&format!("{MAX_REDIRECTS} NotLeader redirects")),
            "error should name the redirect bound, got: {msg}"
        );
        // The client still knows where it last got pointed.
        let to = client.redirected_to().expect("redirects were followed");
        assert!(to == aa || to == ab, "redirected inside the pair: {to}");
    }
}
