//! The serving fleet's worker: the cloud worker's eq.-9 loop, made
//! open-ended and fed by ingestion.
//!
//! Differences from [`crate::cloud::run_worker`], which this mirrors:
//!
//! * **Open-ended by default** — the loop runs until the service's stop
//!   flag flips, because a serving codebook is maintained, not
//!   converged-and-done (`max_points` bounds it when a run's endpoint
//!   must be part of the config, e.g. the determinism suite).
//! * **The local corpus is a sliding window** — seeded from the worker's
//!   shard and progressively overwritten by ingested points (oldest first),
//!   so a drifting input distribution eventually owns the whole window and
//!   the codebook tracks it. Bounded memory, no allocation in the loop.
//! * Exchange is byte-identical to the cloud protocol: barrier-free delta
//!   upload through the queue, shared-version download from the blob, with
//!   the eq.-9 rebase `w ← w_srd − Δ_window` at completion.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Barrier};
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::cloud::{start_exchange, BlobHandle, DeltaMsg, QueueHandle};
use crate::data::Shard;
use crate::obs::{Gauge, Telemetry, TraceBuilder, NO_PARENT};
use crate::runtime::EngineSpec;
use crate::vq::{Codebook, Delta, Schedule};

/// Static parameters of one serving worker.
pub struct ServeWorkerParams {
    /// Fleet-global worker id (shard * M + local index).
    pub worker_id: usize,
    /// Seed corpus; becomes the sliding window.
    pub shard: Shard,
    /// Initial codebook the worker trains from.
    pub w0: Codebook,
    /// Learning-rate schedule.
    pub schedule: Schedule,
    /// Points per VQ step batch (the scheme's tau).
    pub tau: usize,
    /// Points between exchange attempts (a multiple of tau).
    pub points_per_exchange: usize,
    /// Real seconds of compute per point; 0 = free-running.
    pub point_compute: f64,
    /// Max ingested points absorbed into the window per chunk boundary
    /// (keeps training and absorption interleaved under ingest bursts).
    pub absorb_per_chunk: usize,
    /// Engine the worker builds for its VQ math.
    pub engine_spec: EngineSpec,
    /// Start barrier every worker passes once its engine is built.
    pub ready: Arc<Barrier>,
    /// The owning epoch's stop flag.
    pub stop: Arc<AtomicBool>,
    /// Training gate: the worker idles (absorbing nothing, training
    /// nothing) until this flips. Lets the service preload ingest queues
    /// before the first chunk — the determinism suite's anchor.
    pub go: Arc<AtomicBool>,
    /// Block on each exchange until the reducer has folded this worker's
    /// delta (deterministic with one worker per shard).
    pub sync_exchange: bool,
    /// Stop after training this many points (0 = open-ended).
    pub max_points: u64,
    /// Initial training-step cursor (a multiple of
    /// `points_per_exchange`). 0 on a cold start; a warm restart seeds it
    /// from the checkpoint's RNG cursor so a decaying schedule resumes
    /// its position instead of restarting hot. `max_points` counts from
    /// here (points trained *this run*).
    pub t0: u64,
    /// The shard reducer's fold count at startup (restored merges on a
    /// warm start). Sync exchanges wait for `fold_base + delivered` folds
    /// — without the base, a resumed blob version would satisfy the wait
    /// before the delta actually folded.
    pub fold_base: u64,
    /// The shard's unabsorbed-ingest gauge (`shard.<s>.queue_depth`):
    /// the service increments it per batch accepted into `ingest_rx`;
    /// this worker decrements it once per batch taken off the channel.
    pub queue_depth: Arc<Gauge>,
    /// The service's telemetry plane; its tracer samples exchange
    /// intervals into `train.cycle` traces.
    pub telemetry: Arc<Telemetry>,
}

/// What a serving worker reports at shutdown.
#[derive(Debug, Clone)]
pub struct ServeWorkerOutcome {
    /// Fleet-global worker id.
    pub worker_id: usize,
    /// Points this worker trained on.
    pub points_trained: u64,
    /// Ingested points absorbed into the sliding window.
    pub points_absorbed: u64,
    /// Delta uploads attempted.
    pub exchanges_started: u64,
    /// Delta uploads acknowledged by the reducer path.
    pub exchanges_completed: u64,
    /// Delta uploads lost to injected faults.
    pub pushes_dropped: u64,
}

/// The serving loop. Call from a dedicated thread; runs until
/// `params.stop` flips, then drains its in-flight exchange and flushes the
/// tail displacement so nothing the worker learned is lost.
pub fn run_serve_worker(
    params: ServeWorkerParams,
    ingest_rx: mpsc::Receiver<Vec<f32>>,
    queue: QueueHandle,
    blob: BlobHandle,
) -> Result<ServeWorkerOutcome> {
    assert!(
        params.points_per_exchange % params.tau == 0,
        "points_per_exchange must be a multiple of tau"
    );
    // Hit the barrier even if the engine fails to build — otherwise the
    // service's start() would block forever on the fleet rendezvous; the
    // error surfaces at shutdown via the join.
    let engine = params.engine_spec.build();
    params.ready.wait();
    let mut engine = engine?;
    // Paused start: idle until released (or told to stop outright).
    while !params.go.load(Ordering::Acquire) && !params.stop.load(Ordering::Acquire) {
        std::thread::sleep(Duration::from_micros(200));
    }

    let dim = params.shard.dim();
    let kappa = params.w0.kappa();
    // The sliding window: starts as the shard, refreshed by ingestion.
    let mut window: Vec<f32> = params.shard.flat().to_vec();
    let window_points = window.len() / dim;
    let mut write_pos: usize = 0; // next window slot to overwrite (points)

    let mut w = params.w0.clone();
    let mut delta_window = Delta::zeros(kappa, dim);
    let mut chunk_buf = vec![0.0f32; params.tau * dim];
    let mut eps_buf = vec![0.0f32; params.tau];
    assert!(
        params.t0 % params.points_per_exchange as u64 == 0,
        "t0 must sit on an exchange boundary"
    );
    let mut queue = queue;
    let mut blob = blob;
    let mut t: u64 = params.t0;
    let mut seq: u64 = 0;
    let mut absorbed: u64 = 0;
    let mut exchanges_completed = 0u64;
    let mut pushes_dropped = 0u64;
    // Deltas that reached the reducer (sync mode waits on this many folds;
    // only meaningful for single-worker shards, where the shard's fold
    // count is exactly this worker's delivered count).
    let mut delivered_folds: u64 = 0;
    let mut in_flight: Option<mpsc::Receiver<(Codebook, bool)>> = None;
    // A batch absorbed only partway when the per-chunk budget ran out;
    // `usize` is the resume offset in points.
    let mut carry: Option<(Vec<f32>, usize)> = None;
    let run_start = Instant::now();

    // Tracing: one trace candidate per exchange interval. `train.fold`
    // aggregates the interval's vq_chunk compute; `train.exchange_wait`
    // covers the boundary's exchange (the blocking fold wait in sync
    // mode, just the upload handoff in async mode — the compute-vs-
    // synchronization split the paper's schemes differ on).
    let tracer = params.telemetry.tracer();
    let begin_cycle = |tr: &crate::obs::Tracer| -> Option<(TraceBuilder, u64)> {
        tr.begin().map(|mut tb| {
            let root = tb.begin("train.cycle", NO_PARENT);
            (tb, root)
        })
    };
    let mut cycle = begin_cycle(tracer);
    let mut fold_us_acc: u64 = 0;

    while !params.stop.load(Ordering::Acquire)
        && (params.max_points == 0 || t - params.t0 < params.max_points)
    {
        if params.point_compute > 0.0 {
            let target = params.point_compute * (t - params.t0) as f64;
            let actual = run_start.elapsed().as_secs_f64();
            if target > actual {
                std::thread::sleep(Duration::from_secs_f64(target - actual));
            }
        }

        // Absorb ingested points into the window, oldest-slot-first, at
        // most absorb_per_chunk points per chunk boundary — a huge batch
        // must not stall training (the rest carries over to later chunks).
        let mut budget = params.absorb_per_chunk;
        loop {
            let (batch, offset) = match carry.take() {
                Some(pending) => pending,
                None => match ingest_rx.try_recv() {
                    Ok(batch) => {
                        params.queue_depth.sub(1);
                        (batch, 0)
                    }
                    Err(mpsc::TryRecvError::Empty) => break,
                    // Service gone: finish the loop on the stop flag.
                    Err(mpsc::TryRecvError::Disconnected) => break,
                },
            };
            let total = batch.len() / dim;
            let take = (total - offset).min(budget);
            for p in offset..offset + take {
                window[write_pos * dim..(write_pos + 1) * dim]
                    .copy_from_slice(&batch[p * dim..(p + 1) * dim]);
                write_pos = (write_pos + 1) % window_points;
            }
            absorbed += take as u64;
            budget -= take;
            if offset + take < total {
                carry = Some((batch, offset + take));
            }
            if budget == 0 {
                break;
            }
        }

        // One tau-point walk over the window (cyclic, like a shard).
        fill_cyclic(&window, dim, t, &mut chunk_buf);
        params.schedule.fill(t, &mut eps_buf);
        let t_chunk = cycle.as_ref().map(|_| Instant::now());
        engine.vq_chunk(&mut w, &chunk_buf, &eps_buf, &mut delta_window)?;
        if let Some(tc) = t_chunk {
            fold_us_acc += tc.elapsed().as_micros() as u64;
        }
        t += params.tau as u64;

        // Fold in a completed exchange, if any (non-blocking).
        if let Some(rx) = &in_flight {
            match rx.try_recv() {
                Ok((w_snap, delivered)) => {
                    // eq. 9 rebase: shared version minus our open window.
                    w = w_snap;
                    w.apply_delta(&delta_window);
                    exchanges_completed += 1;
                    if !delivered {
                        pushes_dropped += 1;
                    }
                    in_flight = None;
                }
                Err(mpsc::TryRecvError::Empty) => {}
                Err(mpsc::TryRecvError::Disconnected) => {
                    return Err(anyhow!("exchange thread died"));
                }
            }
        }

        if t % params.points_per_exchange as u64 == 0 {
            let wait_start = cycle.as_mut().map(|(tb, _)| tb.now_us());
            if params.sync_exchange {
                // Synchronous exchange: ship the window, then block until
                // the reducer has folded every delta we delivered. With a
                // single worker per shard the shard's fold count equals
                // our delivered count, so the downloaded version is
                // exactly "shared including our last delta" — the
                // deterministic timeline the reproducibility suite pins.
                let delta_snd =
                    std::mem::replace(&mut delta_window, Delta::zeros(kappa, dim));
                let msg = DeltaMsg { worker: params.worker_id, seq, delta: delta_snd };
                seq += 1;
                if queue.push(msg)? {
                    delivered_folds += 1;
                } else {
                    pushes_dropped += 1;
                }
                // Escape hatch: a dead reducer can never fold our delta;
                // once the stop flag is up, give it a short grace window
                // and then fail the worker instead of hanging shutdown.
                let mut stop_seen: Option<Instant> = None;
                loop {
                    let (w_snap, version) = blob.get()?;
                    if version >= params.fold_base + delivered_folds {
                        // delta_window is empty: nothing to rebase.
                        w = w_snap;
                        break;
                    }
                    if params.stop.load(Ordering::Acquire) {
                        let since = *stop_seen.get_or_insert_with(Instant::now);
                        if since.elapsed() > Duration::from_secs(5) {
                            return Err(anyhow!(
                                "sync exchange never folded (fold {} of {}); \
                                 reducer gone?",
                                version,
                                params.fold_base + delivered_folds
                            ));
                        }
                    }
                    std::thread::sleep(Duration::from_micros(200));
                }
                exchanges_completed += 1;
            } else if in_flight.is_none() {
                in_flight = Some(start_exchange(
                    "dalvq-serve-xchg",
                    params.worker_id,
                    &mut seq,
                    &mut delta_window,
                    &queue,
                    &blob,
                ));
            }
            // The interval ends here: close its trace (fold is the
            // interval's aggregate compute, anchored at the trace start)
            // and open the next candidate.
            if let Some((mut tb, root)) = cycle.take() {
                let ws = wait_start.unwrap_or(0);
                tb.add("train.fold", root, 0, fold_us_acc);
                tb.add(
                    "train.exchange_wait",
                    root,
                    ws,
                    tb.now_us().saturating_sub(ws),
                );
                tb.end(root);
                tracer.commit(tb);
            }
            fold_us_acc = 0;
            cycle = begin_cycle(tracer);
        }
    }

    // Drain: complete the in-flight exchange, then flush the tail window.
    if let Some(rx) = in_flight.take() {
        let (w_snap, delivered) =
            rx.recv().map_err(|_| anyhow!("exchange thread died during drain"))?;
        w = w_snap;
        w.apply_delta(&delta_window);
        exchanges_completed += 1;
        if !delivered {
            pushes_dropped += 1;
        }
    }
    if !delta_window.is_zero() {
        let rx = start_exchange(
            "dalvq-serve-xchg",
            params.worker_id,
            &mut seq,
            &mut delta_window,
            &queue,
            &blob,
        );
        let (_w_snap, delivered) =
            rx.recv().map_err(|_| anyhow!("flush exchange thread died"))?;
        exchanges_completed += 1;
        if !delivered {
            pushes_dropped += 1;
        }
    }

    Ok(ServeWorkerOutcome {
        worker_id: params.worker_id,
        points_trained: t - params.t0,
        points_absorbed: absorbed,
        exchanges_started: seq,
        exchanges_completed,
        pushes_dropped,
    })
}

/// Copy `count = out.len()/dim` consecutive points starting at step `t0`
/// (cyclically) out of the flat window.
fn fill_cyclic(window: &[f32], dim: usize, t0: u64, out: &mut [f32]) {
    let n = (window.len() / dim) as u64;
    let count = out.len() / dim;
    for j in 0..count {
        let i = ((t0 + j as u64) % n) as usize;
        out[j * dim..(j + 1) * dim]
            .copy_from_slice(&window[i * dim..(i + 1) * dim]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fill_cyclic_wraps_like_a_shard() {
        let window = [0.0f32, 1.0, 2.0]; // 3 points, dim 1
        let mut out = [0.0f32; 5];
        fill_cyclic(&window, 1, 1, &mut out);
        assert_eq!(out, [1.0, 2.0, 0.0, 1.0, 2.0]);
    }
}
