//! The TCP front-end: thread-per-connection over the length-prefixed
//! protocol, answering every query from the current snapshot epoch.
//!
//! std-only by design (the offline build carries no async runtime), and
//! consistent with the crate's substrate: a connection is a real
//! preemptively-scheduled execution unit, like a worker. Queries touch the
//! service only through [`VqService::snapshot`]/[`VqService::ingest`], so
//! a slow client can never hold a lock the reducer or another reader
//! needs.

use std::io::{BufReader, BufWriter};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::protocol::{read_frame, write_frame, Request, Response, StatsReply};
use super::service::VqService;

/// A running TCP front-end over a [`VqService`].
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    service: Arc<VqService>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `service`.
    pub fn start(service: Arc<VqService>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serve front-end to {addr}"))?;
        let local = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let accept = {
            let stop = Arc::clone(&stop);
            let service = Arc::clone(&service);
            std::thread::Builder::new()
                .name("dalvq-serve-accept".into())
                .spawn(move || accept_loop(listener, service, stop))
                .expect("spawning accept thread")
        };
        Ok(Server { addr: local, stop, accept: Some(accept), service })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind this front-end.
    pub fn service(&self) -> &Arc<VqService> {
        &self.service
    }

    /// Stop accepting. Existing connections finish on their own threads
    /// and exit at client hang-up.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Release);
        // Unblock the accept() call with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(j) = self.accept.take() {
            j.join().map_err(|_| anyhow::anyhow!("accept thread panicked"))?;
        }
        Ok(())
    }
}

fn accept_loop(listener: TcpListener, service: Arc<VqService>, stop: Arc<AtomicBool>) {
    for conn in listener.incoming() {
        if stop.load(Ordering::Acquire) {
            return;
        }
        let Ok(stream) = conn else { continue };
        let service = Arc::clone(&service);
        let _ = std::thread::Builder::new()
            .name("dalvq-serve-conn".into())
            .spawn(move || {
                let _ = serve_connection(stream, &service);
            });
    }
}

/// One connection: frames in, frames out, until the peer hangs up.
fn serve_connection(stream: TcpStream, service: &VqService) -> Result<()> {
    stream.set_nodelay(true).ok(); // request/response pattern
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    while let Some(payload) = read_frame(&mut reader)? {
        let resp = match Request::decode(&payload) {
            Ok(req) => handle(service, req),
            Err(e) => Response::Error { message: format!("{e:#}") },
        };
        write_frame(&mut writer, &resp.encode())?;
    }
    Ok(())
}

/// Dispatch one request through the service's routed query/ingest surface
/// (multi-probe over the shard fleets happens inside [`VqService`]).
///
/// On a follower, every leader-only op — writes (`Ingest`,
/// `Checkpoint`, `Rebalance`) and state shipping (`FetchState`) —
/// answers `NotLeader` with the leader's address, so a client can
/// redirect instead of parsing an error string. The read surface is
/// identical on both roles.
fn handle(service: &VqService, req: Request) -> Response {
    if matches!(
        req,
        Request::Ingest { .. }
            | Request::Checkpoint
            | Request::Rebalance { .. }
            | Request::FetchState { .. }
    ) {
        if let Some(leader) = service.follower_of() {
            return Response::NotLeader { leader };
        }
    }
    let dim = service.dim();
    let check = |points: &[f32]| -> Option<Response> {
        if points.is_empty() || points.len() % dim != 0 {
            Some(Response::Error {
                message: format!(
                    "batch of {} floats is not a positive multiple of dim {dim}",
                    points.len()
                ),
            })
        } else {
            None
        }
    };
    let count_query = || {
        service
            .counters()
            .queries
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    };
    match req {
        Request::Encode { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            count_query();
            let (version, codes) = service.query_encode(&points);
            Response::Codes { version, codes }
        }
        Request::Nearest { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            count_query();
            let (version, indices, dists) = service.query_nearest(&points);
            Response::Neighbors { version, indices, dists }
        }
        Request::Distortion { points } => {
            if let Some(err) = check(&points) {
                return err;
            }
            count_query();
            let (version, value) = service.query_distortion(&points);
            Response::Distortion { version, value }
        }
        Request::Ingest { points } => match service.ingest(&points) {
            Ok((accepted, shed)) => Response::IngestAck { accepted, shed },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        Request::Stats => {
            let s = service.stats();
            Response::Stats(StatsReply {
                version: s.version,
                kappa: s.kappa as u64,
                dim: s.dim as u64,
                workers: s.workers as u64,
                shards: s.shards as u64,
                probe_n: s.probe_n as u64,
                router_version: s.router_version,
                rebalances: s.rebalances,
                merges: s.merges,
                ingested: s.ingested,
                ingest_shed: s.ingest_shed,
                queries: s.queries,
                shard_versions: s.shard_versions,
                shard_merges: s.shard_merges,
                shard_ingest: s.shard_ingest,
                shard_shed: s.shard_shed,
                last_checkpoint: s.last_checkpoint,
                state_dir: s.state_dir.unwrap_or_default(),
                role: s.role,
                leader_addr: s.leader_addr.unwrap_or_default(),
                sync_lag_folds: s.sync_lag_folds,
                last_sync: s.last_sync_ms,
            })
        }
        Request::Checkpoint => match service.checkpoint_now() {
            Ok(versions) => Response::CheckpointAck { versions },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // The epoch swap happens entirely inside the service; this
        // connection blocks until the new partition serves, while reads
        // on other connections keep answering from the old epoch.
        Request::Rebalance { want_remap } => match service.rebalance() {
            Ok(out) => Response::RebalanceAck {
                router_version: out.router_version,
                moved_rows: out.moved_rows,
                shard_versions: out.shard_versions,
                remap: if want_remap { out.remap } else { Vec::new() },
            },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // Replication: ship the durable state as one consistent bundle.
        Request::FetchState { have_generation } => {
            match service.fetch_state(have_generation) {
                Ok(shipment) => Response::State(shipment),
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }
    }
}
