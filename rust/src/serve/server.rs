//! The TCP front-end: a non-blocking event loop over the
//! length-prefixed protocol, answering every query from the current
//! snapshot epoch.
//!
//! std-only by design (the offline build carries no async runtime). One
//! reactor thread ([`super::eventloop`]) owns every socket: it polls
//! for readiness, parses as many complete frames as each read delivers
//! (request pipelining), runs admission control, and hands admitted
//! frames to a fixed worker pool sized to cores. The per-frame work —
//! zero-copy decode via [`RequestRef`], dispatch, and encoding the
//! reply straight into a recycled frame buffer — happens here, on a
//! worker thread, through [`process_frame`]. Queries touch the service
//! only through [`VqService::snapshot`]/[`VqService::ingest`], so a
//! slow client can never hold a lock the reducer or another reader
//! needs; replies for one connection always leave in request order.

use std::cell::RefCell;
use std::net::{SocketAddr, TcpListener};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::obs::{
    FinishedTrace, SpanRec, TelemetrySnapshot, TraceBuilder, NO_PARENT,
};

use super::batch::Batcher;
use super::eventloop::{self, Handler, Waker};
use super::protocol::{
    begin_frame, encode_traced_response_into, end_frame, MetricEvent,
    MetricHist, MetricsReply, RequestRef, Response, StatsReply, WireSpan,
    WireTrace, MAX_FRAME,
};
use super::service::{TimedQuery, VqService};

thread_local! {
    /// Worker-local landing pad for request point batches: the wire
    /// payload stays borrowed end to end, the floats are copied out
    /// exactly once per request into this buffer, and the allocation is
    /// reused for the life of the worker thread.
    static POINTS: RefCell<Vec<f32>> = const { RefCell::new(Vec::new()) };
    /// Worker-local scratch for the inner reply of a traced response:
    /// the envelope's span list precedes the inner bytes on the wire,
    /// but must be encoded after them (the `encode` span has to be
    /// final), so traced replies stage the inner encode here.
    static TRACE_INNER: RefCell<Vec<u8>> = const { RefCell::new(Vec::new()) };
}

/// A running TCP front-end over a [`VqService`].
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    waker: Arc<Waker>,
    reactor: Option<JoinHandle<()>>,
    service: Arc<VqService>,
    /// The cross-request coalescer — `Some` only when the serve config
    /// arms `batch_window_us` (default off = the direct scan path).
    batcher: Option<Arc<Batcher>>,
}

impl Server {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and start
    /// accepting connections against `service`.
    pub fn start(service: Arc<VqService>, addr: &str) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding serve front-end to {addr}"))?;
        let local = listener.local_addr()?;
        // Where a promotion's demote patrol advertises this service.
        service.set_advertise_addr(local.to_string());
        let stop = Arc::new(AtomicBool::new(false));
        let batcher = if service.batch_window_us() > 0 {
            Some(Batcher::start(Arc::clone(&service)))
        } else {
            None
        };
        let (waker, wake_rx) = eventloop::wake_pair()?;
        let handler: Handler = {
            let service = Arc::clone(&service);
            let batcher = batcher.clone();
            Arc::new(move |payload: &[u8], arrived: Instant, out: &mut Vec<u8>| {
                process_frame(&service, batcher.as_deref(), payload, arrived, out)
            })
        };
        let reactor = {
            let service = Arc::clone(&service);
            let stop = Arc::clone(&stop);
            let waker = Arc::clone(&waker);
            std::thread::Builder::new()
                .name("dalvq-serve-reactor".into())
                .spawn(move || {
                    eventloop::run(listener, service, handler, stop, waker, wake_rx)
                })
                .expect("spawning reactor thread")
        };
        Ok(Server {
            addr: local,
            stop,
            waker,
            reactor: Some(reactor),
            service,
            batcher,
        })
    }

    /// The bound address (resolves `:0` to the actual port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// The service behind this front-end.
    pub fn service(&self) -> &Arc<VqService> {
        &self.service
    }

    /// Deterministic shutdown through the reactor's wake token (the old
    /// throwaway self-connection is gone): set the stop flag, wake the
    /// loop, and join it. The reactor stops accepting and reading,
    /// finishes every request already parsed or handed to a worker,
    /// flushes the replies (bounded drain), closes every connection,
    /// and joins its worker pool before its thread exits.
    pub fn shutdown(mut self) -> Result<()> {
        self.stop.store(true, Ordering::Release);
        self.waker.wake();
        if let Some(j) = self.reactor.take() {
            j.join().map_err(|_| anyhow::anyhow!("reactor thread panicked"))?;
        }
        // Stop the coalescer after the front door: queued requests are
        // still answered, and stragglers fall back to the direct scan
        // path.
        if let Some(b) = &self.batcher {
            b.shutdown();
        }
        Ok(())
    }
}

/// One frame: decode (borrowing the payload — no per-frame copy),
/// dispatch, and encode the reply as a complete wire frame appended to
/// `out`. Runs on an event-loop worker thread. Returns `false` when no
/// frame could be produced (the reply overflows [`MAX_FRAME`]); the
/// reactor then drops the connection, as the blocking server did when
/// `write_frame` refused the same reply.
///
/// Tracing wraps the whole per-frame lifetime: the trace origin is the
/// instant the frame was parsed off the socket (queue time ahead of the
/// worker is inside the trace, deliberately — it is latency the client
/// saw), the `decode` span is replayed from the stage timer, the
/// handler records its own children, and the `encode` span is measured
/// on the inner reply *before* the optional [`Response::Traced`]
/// envelope — whose span list must already be final — goes out.
fn process_frame(
    service: &VqService,
    batcher: Option<&Batcher>,
    payload: &[u8],
    arrived: Instant,
    out: &mut Vec<u8>,
) -> bool {
    let at = begin_frame(out);
    let t_start = arrived;
    let t_decode = Instant::now();
    let decoded = RequestRef::decode(payload);
    let decode_us = t_decode.elapsed().as_micros() as u64;
    service.tel().decode_us.record(decode_us);
    // Unwrap the optional trace-context envelope; the inner request is
    // handled exactly as if it had arrived bare.
    let (decoded, wire_ctx) = match decoded {
        Ok(RequestRef::Traced { hi, lo, parent, inner }) => {
            (Ok(*inner), Some((hi, lo, parent)))
        }
        other => (other, None),
    };
    let tracer = service.telemetry().tracer();
    let mut tb = match wire_ctx {
        // A remote caller already committed to this trace: join it even
        // when local sampling is off.
        Some((hi, lo, _)) => Some(tracer.begin_forced_at(hi, lo, t_start)),
        None => tracer.begin_at(t_start),
    };
    let wire_parent = wire_ctx.map_or(NO_PARENT, |(_, _, parent)| parent);
    let (resp, root) = match decoded {
        Ok(req) => handle(service, batcher, req, decode_us, wire_parent, &mut tb),
        Err(e) => (Response::Error { message: format!("{e:#}") }, NO_PARENT),
    };
    let t_encode = Instant::now();
    match tb.take() {
        None => {
            resp.encode_into(out);
            let encode_us = t_encode.elapsed().as_micros() as u64;
            service.tel().encode_us.record(encode_us);
        }
        Some(mut tb) => {
            let finish = |tb: &mut TraceBuilder, encode_us: u64| {
                if root != NO_PARENT {
                    let enc_start =
                        t_encode.duration_since(t_start).as_micros() as u64;
                    tb.add("encode", root, enc_start, encode_us);
                    tb.end(root);
                }
            };
            match wire_ctx {
                None => {
                    resp.encode_into(out);
                    let encode_us = t_encode.elapsed().as_micros() as u64;
                    service.tel().encode_us.record(encode_us);
                    finish(&mut tb, encode_us);
                }
                Some((hi, lo, _)) => TRACE_INNER.with(|cell| {
                    let inner = &mut *cell.borrow_mut();
                    inner.clear();
                    resp.encode_into(inner);
                    let encode_us = t_encode.elapsed().as_micros() as u64;
                    service.tel().encode_us.record(encode_us);
                    finish(&mut tb, encode_us);
                    // Ship the root span detached (parent 0). Its true
                    // parent is a span id in the *caller's* ring; span
                    // ids are sequential in both processes, so shipping
                    // the raw foreign id could collide with one of our
                    // own ids and mis-nest the tree when the caller
                    // grafts.
                    let mut spans = wire_spans(tb.spans());
                    if let Some(r) = spans.iter_mut().find(|s| s.id == root) {
                        r.parent = NO_PARENT;
                    }
                    encode_traced_response_into(out, hi, lo, &spans, inner);
                }),
            }
            tracer.commit(tb);
        }
    }
    end_frame(out, at).is_ok()
}

/// [`SpanRec`]s in wire shape.
fn wire_spans(spans: &[SpanRec]) -> Vec<WireSpan> {
    spans
        .iter()
        .map(|s| WireSpan {
            id: s.id,
            parent: s.parent,
            start_us: s.start_us,
            dur_us: s.dur_us,
            name: s.name.clone(),
        })
        .collect()
}

/// A [`FinishedTrace`] in wire shape (for the `Trace` op's reply).
fn wire_trace(t: FinishedTrace) -> WireTrace {
    WireTrace { hi: t.hi, lo: t.lo, ts_ms: t.ts_ms, spans: wire_spans(&t.spans) }
}

/// Dispatch one request with per-op accounting wrapped around
/// [`dispatch`]: count the request into its op family, time the whole
/// handler into the op's latency histogram, and — when the slow-query
/// log is armed — journal any request over the threshold with whatever
/// stage breakdown the dispatch recorded.
///
/// When a trace is live, opens the root `req.<op>` span (under the wire
/// context's parent, if any), replays the already-measured `decode`
/// stage as its first child, and returns the root's id so the caller
/// can hang the `encode` span off it and close it after framing.
fn handle(
    service: &VqService,
    batcher: Option<&Batcher>,
    req: RequestRef<'_>,
    decode_us: u64,
    wire_parent: u64,
    tb: &mut Option<TraceBuilder>,
) -> (Response, u64) {
    let tel = service.tel();
    let (op_name, op) = match &req {
        RequestRef::Encode { .. } => ("encode", &tel.op_encode),
        RequestRef::Nearest { .. } => ("nearest", &tel.op_nearest),
        RequestRef::Distortion { .. } => ("distortion", &tel.op_distortion),
        RequestRef::Ingest { .. } => ("ingest", &tel.op_ingest),
        RequestRef::Stats => ("stats", &tel.op_other),
        RequestRef::Checkpoint => ("checkpoint", &tel.op_other),
        RequestRef::Rebalance { .. } => ("rebalance", &tel.op_other),
        RequestRef::FetchState { .. } => ("fetch_state", &tel.op_other),
        RequestRef::FetchChunk { .. } => ("fetch_chunk", &tel.op_other),
        RequestRef::Demote { .. } => ("demote", &tel.op_other),
        RequestRef::Metrics { .. } => ("metrics", &tel.op_other),
        RequestRef::Trace { .. } => ("trace", &tel.op_other),
        RequestRef::Traced { .. } => ("traced", &tel.op_other),
    };
    op.requests.inc();
    let mut root = NO_PARENT;
    if let Some(tb) = tb.as_mut() {
        root = tb.begin(&format!("req.{op_name}"), wire_parent);
        tb.add("decode", root, 0, decode_us);
    }
    let t0 = Instant::now();
    let mut stages: Option<(u64, u64)> = None;
    let resp = dispatch(service, batcher, req, &mut stages, root, tb);
    let total_us = t0.elapsed().as_micros() as u64;
    op.total_us.record(total_us);
    let threshold = service.slow_query_us();
    if threshold > 0 && total_us > threshold {
        tel.slow_queries.inc();
        let breakdown = match stages {
            Some((route_us, scan_us)) => {
                format!(", route {route_us} us + scan {scan_us} us")
            }
            None => String::new(),
        };
        service.telemetry().journal().warn(
            "slow_query",
            format!(
                "{op_name} took {total_us} us (threshold {threshold} us, \
                 {} shards{breakdown})",
                service.shards()
            ),
        );
    }
    (resp, root)
}

/// Dispatch one request through the service's routed query/ingest surface
/// (multi-probe over the shard fleets happens inside [`VqService`]).
/// Read queries run the timed path and report their (route, scan) µs
/// through `stages` for the slow-query log. Point batches arrive as
/// borrowed [`super::protocol::PointsRef`] views and are copied exactly
/// once into the worker's thread-local buffer.
///
/// On a follower, writes (`Ingest`, `Checkpoint`, `Rebalance`) answer
/// `NotLeader` with the leader's address, so a client can redirect
/// instead of parsing an error string. State shipping (`FetchState` /
/// `FetchChunk`) redirects only when the follower keeps no mirror
/// `--state-dir` — a mirror-keeping follower serves the sync path
/// itself, which is what lets replication form a fan-out tree instead
/// of a star on the leader. `Demote` is never redirected: it is
/// addressed to *this* node's role, and bouncing it would ping-pong a
/// failover. The read surface — `Metrics` included (a follower's
/// telemetry is its own, not the leader's) — is identical on both
/// roles.
fn dispatch(
    service: &VqService,
    batcher: Option<&Batcher>,
    req: RequestRef<'_>,
    stages: &mut Option<(u64, u64)>,
    root: u64,
    tb: &mut Option<TraceBuilder>,
) -> Response {
    let leader_only = matches!(
        req,
        RequestRef::Ingest { .. }
            | RequestRef::Checkpoint
            | RequestRef::Rebalance { .. }
    );
    let ship_op = matches!(
        req,
        RequestRef::FetchState { .. } | RequestRef::FetchChunk { .. }
    );
    if leader_only || (ship_op && !service.can_ship_state()) {
        if let Some(leader) = service.follower_of() {
            return Response::NotLeader { leader };
        }
    }
    let dim = service.dim();
    let check = |points: &[f32]| -> Option<Response> {
        if points.is_empty() || points.len() % dim != 0 {
            Some(Response::Error {
                message: format!(
                    "batch of {} floats is not a positive multiple of dim {dim}",
                    points.len()
                ),
            })
        } else {
            None
        }
    };
    // Admission: a request small enough to *arrive* can still demand a
    // reply too large to *frame* (at dim 1 a Nearest request of n points
    // is 5 + 4n bytes but its reply is 17 + 8n — past the cap for the
    // top half of the admissible range). Reject those here, before any
    // routing or scan work is spent on an unanswerable query.
    let reply_cap = |op: &str, fixed: usize, per_point: usize, n: usize| {
        let bytes = fixed + per_point * n;
        if bytes > MAX_FRAME as usize {
            Some(Response::Error {
                message: format!(
                    "{op} reply for {n} points would be {bytes} bytes, over \
                     the {MAX_FRAME}-byte frame cap; split the batch",
                ),
            })
        } else {
            None
        }
    };
    let count_query = || {
        service
            .counters()
            .queries
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    };
    match req {
        RequestRef::Encode { points } => POINTS.with(|cell| {
            let points_buf = &mut *cell.borrow_mut();
            points.copy_into(points_buf);
            if let Some(err) = check(points_buf) {
                return err;
            }
            // Codes reply: op + version + len prefix + 4 bytes/code.
            if let Some(err) = reply_cap("encode", 13, 4, points_buf.len() / dim)
            {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, points_buf, root, tb);
            *stages = Some((q.route_us, q.scan_us));
            Response::Codes { version: q.version, codes: q.codes }
        }),
        RequestRef::Nearest { points } => POINTS.with(|cell| {
            let points_buf = &mut *cell.borrow_mut();
            points.copy_into(points_buf);
            if let Some(err) = check(points_buf) {
                return err;
            }
            // Neighbors reply: op + version + two prefixed f32/u32 runs.
            if let Some(err) = reply_cap("nearest", 17, 8, points_buf.len() / dim)
            {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, points_buf, root, tb);
            *stages = Some((q.route_us, q.scan_us));
            Response::Neighbors {
                version: q.version,
                indices: q.codes,
                dists: q.dists,
            }
        }),
        RequestRef::Distortion { points } => POINTS.with(|cell| {
            let points_buf = &mut *cell.borrow_mut();
            points.copy_into(points_buf);
            if let Some(err) = check(points_buf) {
                return err;
            }
            count_query();
            let q = run_query(service, batcher, points_buf, root, tb);
            *stages = Some((q.route_us, q.scan_us));
            // check() rejected empty batches, so dists is never empty.
            let sum: f64 = q.dists.iter().map(|d| *d as f64).sum();
            Response::Distortion {
                version: q.version,
                value: sum / q.dists.len() as f64,
            }
        }),
        RequestRef::Ingest { points } => POINTS.with(|cell| {
            let points_buf = &mut *cell.borrow_mut();
            points.copy_into(points_buf);
            match service.ingest(points_buf) {
                Ok((accepted, shed)) => Response::IngestAck { accepted, shed },
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }),
        RequestRef::Stats => {
            let s = service.stats();
            Response::Stats(StatsReply {
                version: s.version,
                kappa: s.kappa as u64,
                dim: s.dim as u64,
                workers: s.workers as u64,
                shards: s.shards as u64,
                probe_n: s.probe_n as u64,
                router_version: s.router_version,
                rebalances: s.rebalances,
                merges: s.merges,
                ingested: s.ingested,
                ingest_shed: s.ingest_shed,
                queries: s.queries,
                shard_versions: s.shard_versions,
                shard_merges: s.shard_merges,
                shard_ingest: s.shard_ingest,
                shard_shed: s.shard_shed,
                last_checkpoint: s.last_checkpoint,
                state_dir: s.state_dir.unwrap_or_default(),
                role: s.role,
                leader_addr: s.leader_addr.unwrap_or_default(),
                sync_lag_folds: s.sync_lag_folds,
                last_sync: s.last_sync_ms,
                sync_source: s.sync_source,
                uptime_ms: s.uptime_ms,
                op_encode: s.op_encode,
                op_nearest: s.op_nearest,
                op_distortion: s.op_distortion,
                op_ingest: s.op_ingest,
            })
        }
        RequestRef::Metrics { max_events } => Response::Metrics(metrics_reply(
            service.metrics_snapshot(max_events as usize),
        )),
        RequestRef::Checkpoint => match service.checkpoint_now() {
            Ok(versions) => Response::CheckpointAck { versions },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // The epoch swap happens entirely inside the service; this
        // request blocks its worker until the new partition serves,
        // while reads keep answering from the old epoch.
        RequestRef::Rebalance { want_remap } => match service.rebalance() {
            Ok(out) => Response::RebalanceAck {
                router_version: out.router_version,
                moved_rows: out.moved_rows,
                shard_versions: out.shard_versions,
                remap: if want_remap { out.remap } else { Vec::new() },
            },
            Err(e) => Response::Error { message: format!("{e:#}") },
        },
        // Replication: ship the durable state as one consistent bundle.
        // The service records `state.cut` / `state.ship` children when a
        // trace is live (a follower's wire context joins them into its
        // own sync-cycle trace).
        RequestRef::FetchState { have_generation } => {
            match service.fetch_state(have_generation, tb.as_mut(), root) {
                Ok(shipment) => Response::State(shipment),
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }
        // Chunk 2..=N of a shipment that outgrew one frame. Same cut
        // discipline as FetchState; a generation that moved mid-fetch
        // answers an error and the client restarts the collection.
        RequestRef::FetchChunk { generation, chunk } => {
            match service.fetch_chunk(generation, chunk, tb.as_mut(), root) {
                Ok(shipment) => Response::State(shipment),
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }
        // Failover fencing: a promoted peer presenting a strictly higher
        // generation turns this node into a redirect to it.
        RequestRef::Demote { generation, leader } => {
            match service.demote(generation, &leader) {
                Ok(()) => Response::DemoteAck,
                Err(e) => Response::Error { message: format!("{e:#}") },
            }
        }
        RequestRef::Trace { max_traces } => Response::Traces(
            service
                .telemetry()
                .tracer()
                .recent(max_traces as usize)
                .into_iter()
                .map(wire_trace)
                .collect(),
        ),
        // The frame processor unwraps the envelope before dispatch, and
        // the decoder rejects nesting — this arm is unreachable short of
        // a bug, and answers cleanly rather than panicking.
        RequestRef::Traced { .. } => Response::Error {
            message: "nested trace envelopes are not allowed".into(),
        },
    }
}

/// One read batch through the query plane: the coalescer when armed
/// (falling back to the direct path if it is already shut down), else an
/// immediate fused scan on this worker thread. Either route answers
/// bit-identically; only latency and staleness differ.
///
/// A live trace gets the stage breakdown as child spans of `root`:
/// `route` + `scan` on both paths (the measurements come from the fused
/// scan either way), plus `batch.wait` / `batch.scatter` around them
/// when the coalescer carried the request — the queueing delay and the
/// fan-back are exactly the latency the batching trade-off adds.
fn run_query(
    service: &VqService,
    batcher: Option<&Batcher>,
    points: &[f32],
    root: u64,
    tb: &mut Option<TraceBuilder>,
) -> TimedQuery {
    let s0 = tb.as_ref().map_or(0, |t| t.now_us());
    if let Some(b) = batcher {
        if let Some(a) = b.submit(points.to_vec()) {
            if let Some(tb) = tb.as_mut() {
                tb.add("batch.wait", root, s0, a.wait_us);
                let r0 = s0 + a.wait_us;
                tb.add("route", root, r0, a.route_us);
                tb.add("scan", root, r0 + a.route_us, a.scan_us);
                tb.add(
                    "batch.scatter",
                    root,
                    r0 + a.route_us + a.scan_us,
                    a.scatter_us,
                );
            }
            return TimedQuery {
                version: a.version,
                codes: a.codes,
                dists: a.dists,
                route_us: a.route_us,
                scan_us: a.scan_us,
            };
        }
    }
    let q = service.query_nearest_timed(points, service.probe_n());
    if let Some(tb) = tb.as_mut() {
        tb.add("route", root, s0, q.route_us);
        tb.add("scan", root, s0 + q.route_us, q.scan_us);
    }
    q
}

/// A telemetry snapshot in wire shape. By value: the snapshot is already
/// this handler's own copy, so the strings and vectors move instead of
/// cloning.
fn metrics_reply(snap: TelemetrySnapshot) -> MetricsReply {
    MetricsReply {
        uptime_ms: snap.uptime_ms,
        counters: snap.counters,
        gauges: snap.gauges,
        hists: snap
            .hists
            .into_iter()
            .map(|(name, s)| MetricHist {
                name,
                count: s.count,
                mean_us: s.mean_us,
                p50_us: s.p50_us,
                p95_us: s.p95_us,
                p99_us: s.p99_us,
                max_us: s.max_us,
            })
            .collect(),
        events: snap
            .events
            .into_iter()
            .map(|e| MetricEvent {
                seq: e.seq,
                ts_ms: e.ts_ms,
                level: e.level.as_u8(),
                kind: e.kind,
                message: e.message,
            })
            .collect(),
    }
}
